"""Distributed-runtime integration tests (subprocess: the 8-host-device XLA
flag must be set before jax initializes, so these run isolated).

The full 9-architecture sweep lives in ``repro.launch.dist_selftest`` (run
directly for the complete matrix); here a representative subset keeps CI
time bounded while covering every mechanism: pipeline+TP+DP (dense), MoE
expert-parallel all_to_all, TP serve decode, and the seq-sharded
long-context decode path.
"""

import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dist_selftest", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT)
    assert proc.returncode == 0, f"\nstdout:{proc.stdout}\nstderr:{proc.stderr[-2000:]}"
    assert "ALL OK" in proc.stdout
    return proc.stdout


@pytest.mark.slow
def test_train_parity_dense_and_moe():
    out = _run(["phi3-mini-3.8b", "phi3.5-moe-42b-a6.6b"])
    assert out.count("OK") >= 2


@pytest.mark.slow
def test_train_parity_hybrid():
    _run(["jamba-1.5-large-398b"])


@pytest.mark.slow
def test_train_planned_lowering():
    """Algorithm 2 plan -> core.lowering -> runtime: parity + 1F1B step,
    including a heterogeneous (3 periods | 1 period) stage split."""
    _run(["--plan", "phi3-mini-3.8b"])


@pytest.mark.slow
def test_train_hetero_allocation():
    """Heterogeneous intra-stage allocation (Algorithm 1) executed by the
    runtime: y=(3,1) on the 2-wide data axis (padded to B_max with validity
    masks) — loss parity vs the single-device reference and gradient parity
    vs the uniform-allocation baseline on the same global batch."""
    _run(["--hetero", "phi3-mini-3.8b"])


@pytest.mark.slow
def test_train_async_equivalence():
    """Async 1F1B runtime: staleness 0 + double-buffered sends is
    gradient-bit-identical to the synchronous runtime on the same batch,
    a staleness-1 run applies exactly as many optimizer updates as sync
    (the first round computes gradients only), and converges to within
    tolerance of the sync run on the same batch stream (DESIGN.md §8)."""
    out = _run(["--async", "phi3-mini-3.8b"])
    assert "grad-bit-identical=True" in out


@pytest.mark.slow
def test_train_compressed_transfers():
    """Compressed boundary transfers + bucketed gradient AllReduce
    (DESIGN.md §10): bucketed-uncompressed gradients match the legacy path
    to float reassociation, int8-compressed gradients land within the
    pinned tolerance of the uncompressed run on the same params/batch,
    error feedback beats the no-feedback quantizer in mean-gradient bias,
    and a compressed optimizer step reduces the loss."""
    _run(["--compress", "phi3-mini-3.8b"])


@pytest.mark.slow
def test_replay_session():
    """Live pipeline replay (runtime.session): kill a rank mid-training,
    recover through lightweight replay + param migration, keep training —
    untouched periods bit-identical, boundary bytes reconcile with the
    analytical RecoveryReport, re-lowered step == fresh lowering."""
    _run(["--replay", "phi3-mini-3.8b"])


@pytest.mark.slow
def test_serve_parity():
    _run(["--serve", "phi3-mini-3.8b", "gemma-2b"])


@pytest.mark.slow
def test_serve_seq_sharded_long_context():
    _run(["--serve", "--seq-shard", "gemma2-2b"])


@pytest.mark.slow
def test_serve_hetero_slot_split():
    """Heterogeneous decode slot split (build_slot_serve_step): an
    unbalanced shard_alloc=(3, 1) with per-row positions and staggered
    slot admission reproduces the uniform lockstep single-device decode
    logits row-for-row (one attention + one recurrent arch — the reset
    mask must wipe RWKV state on admission), and padded slot rows return
    exactly-zero logits."""
    _run(["--serve-hetero", "gemma-2b", "rwkv6-7b"])


@pytest.mark.slow
def test_serve_hetero_pipelined():
    """Same parity through the stage=2 pipelined slot path (per-row
    positions sliced per decode group)."""
    _run(["--serve-hetero", "--stage2", "gemma-2b"])
