"""Regression tests for prediction-gap edge cases (DESIGN.md §12).

Three failure modes the closed-loop portfolio machinery must degrade
through gracefully, pinned so they stay behaviors and not crashes:

* a stale/incompatible measured-profile artifact resolves to the analytic
  fallback with a warning (``profiler.resolve_profile``), never an
  exception — a stale measurement is an expected state, not a bug;
* an all-zero measured sweep row (a silently failed measurement) is
  rejected by ``Profile.measured`` with an error naming the device and
  batch, instead of producing a profile that prices that device as free
  and magnetizes every planner toward it;
* the gap of a plan against the profile it was just repriced on is
  *exactly* zero (``gap_ratio == 1.0`` bit-for-bit) — repricing is
  idempotent, so the drift watchdog's baseline can't self-drift.
"""

import numpy as np
import pytest

from repro.core.hardware import JETSON_NANO, JETSON_NX, Cluster
from repro.core.planner import plan_hpp
from repro.core.profiler import (LayerCost, LayerTable, MeasuredProfile,
                                 Profile, ProfileError, config_fingerprint,
                                 device_fingerprint, resolve_profile)
from repro.core.simulator import observed_gap, prediction_gap, reprice_plan
from repro.models import AttentionConfig, LayerSpec, ModelConfig

TINY = ModelConfig(name="tiny", n_layers=2, d_model=32, vocab_size=64,
                   d_ff=64,
                   attn=AttentionConfig(n_heads=2, n_kv_heads=2, head_dim=16),
                   pattern=(LayerSpec(),))


def _table(L=3):
    return LayerTable("m", tuple(
        LayerCost(f"l{i}", 1e6 * (i + 1), 1e4, 1e3) for i in range(L)))


def _mp(D=2, batches=(1, 2, 4), L=3, seed=0, **kw):
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-4, 1e-3, size=(D, 1, L))
    tf = base * np.asarray(batches, float)[None, :, None]
    defaults = dict(
        arch="m", seq_len=16, batch_sizes=tuple(batches),
        layer_names=tuple(f"l{i}" for i in range(L)), tf=tf, tb=2.0 * tf,
        device_names=tuple(f"cpu:{d}" for d in range(D)),
        config_hash="cfg0", device_hash="dev0",
        mem_bytes=(8e9,) * D, est_flops=(1e9,) * D)
    defaults.update(kw)
    return MeasuredProfile(**defaults)


# ---------------------------------------------------------------------------
# stale artifacts fall back analytic with a warning, never a crash
# ---------------------------------------------------------------------------


def test_resolve_profile_stale_fingerprint_warns_not_crashes():
    mp = _mp()                                 # config_hash "cfg0" != TINY's
    table = _table()
    with pytest.warns(UserWarning, match="stale or incompatible"):
        prof = resolve_profile(mp, TINY, 16, table, max_batch=4)
    assert prof is None                        # caller falls back analytic
    # the caller's label and note make it into the warning text
    with pytest.warns(UserWarning, match=r"profile p\.json.*\(env B\)"):
        resolve_profile(mp, TINY, 16, table, max_batch=4,
                        label="profile p.json", fallback_note=" (env B)")


def test_resolve_profile_densify_error_also_falls_back():
    # fingerprints match, but the layer table does not — to_profile's
    # ProfileError must degrade to the same warning path
    mp = _mp(config_hash=config_fingerprint(TINY, 16),
             device_hash=device_fingerprint())
    wrong = LayerTable("other", tuple(
        LayerCost(f"x{i}", 1e6, 1e4, 1e3) for i in range(3)))
    with pytest.warns(UserWarning, match="stale or incompatible"):
        assert resolve_profile(mp, TINY, 16, wrong, max_batch=4) is None


def test_resolve_profile_passthrough():
    # a compatible artifact resolves (no warning), and None stays None
    mp = _mp(config_hash=config_fingerprint(TINY, 16),
             device_hash=device_fingerprint())
    prof = resolve_profile(mp, TINY, 16, _table(), max_batch=4)
    assert isinstance(prof, Profile) and prof.source == "measured"
    assert resolve_profile(None, TINY, 16, _table(), max_batch=4) is None


# ---------------------------------------------------------------------------
# zero measured-time rows are rejected, not planned around
# ---------------------------------------------------------------------------


def test_measured_rejects_all_zero_sweep_row():
    table = _table(L=2)
    cluster = Cluster((JETSON_NANO, JETSON_NX))
    ok = np.full((2, 5, 2), 1e-3)
    Profile.measured(table, cluster, 4, ok, ok)        # sanity: accepted
    bad = ok.copy()
    bad[1, 2, :] = 0.0                                 # device 1, batch 2
    with pytest.raises(ProfileError, match="zero measured-time row"):
        Profile.measured(table, cluster, 4, bad, ok)
    with pytest.raises(ProfileError, match="device 1 at batch 2"):
        Profile.measured(table, cluster, 4, ok, bad)


def test_measured_allows_zero_batch_zero_row():
    # the batch-0 row means "zero samples" and is zero by construction —
    # only batches >= 1 are checked
    table = _table(L=2)
    s = np.full((1, 5, 2), 1e-3)
    s[0, 0, :] = 0.0
    prof = Profile.measured(table, Cluster((JETSON_NANO,)), 4, s, s)
    assert prof.t_fwd(0, 0, 0, table.L) == 0.0


# ---------------------------------------------------------------------------
# gap of a repriced plan against its own reference is exactly zero
# ---------------------------------------------------------------------------


def test_gap_zero_after_repricing_and_reprice_idempotent():
    table = _table(L=4)
    analytic = Profile.analytic(table, Cluster((JETSON_NANO, JETSON_NX)),
                                max_batch=8)
    s = np.asarray([[b * 1e-3 * (l + 1) for l in range(4)]
                    for b in range(9)])
    measured = Profile.measured(table, Cluster((JETSON_NANO, JETSON_NX)), 8,
                                np.stack([s, 0.7 * s]),
                                np.stack([2.0 * s, 1.5 * s]))
    plan = plan_hpp(analytic, 8, 2, arch="m")

    once = reprice_plan(plan, measured)
    twice = reprice_plan(once, measured)
    assert twice.latency == once.latency               # exactly, not approx
    gap = prediction_gap(once, measured)
    assert gap["gap_ratio"] == 1.0                     # bit-exact
    assert gap["predicted_s"] == gap["reference_s"]
    # the analytically-priced plan genuinely mispredicts on this reference
    # (so the == 1.0 above is not vacuous)
    assert prediction_gap(plan, measured)["gap_ratio"] != 1.0

    # the watchdog's quantity: observing exactly the repriced latency is
    # exactly ratio 1
    obs = observed_gap(plan, measured, once.latency)
    assert obs["predicted_s"] == once.latency
    assert obs["gap_ratio"] == 1.0
