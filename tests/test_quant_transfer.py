"""Quantized-transfer wire format: tile-exact kernel-vs-reference parity,
round-trip error bounds, error-feedback telescoping.  No hypothesis
dependency — these must run on the bare container (tier-1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quant_transfer import (QDIV, QUANT_FORMATS, dequantize_op,
                                          dequantize_tiles, pack_tiles,
                                          quant_dtype, quantize_op,
                                          quantize_tiles, roundtrip,
                                          roundtrip_ef, unpack_tiles,
                                          wire_bits)
from repro.kernels.ref import (naive_dequantize_tiles, naive_quantize_tiles,
                               quant_scale)

# single-shot relative round-trip error on N(0, 3) data; int8 rounds to
# ~1/128 of the tile amax, fp8 e4m3 carries 3 mantissa bits (~2^-4 rel).
ROUNDTRIP_TOL = {"int8": 0.02, "fp8": 0.06}


def rand(key, shape, scale=3.0):
    return jax.random.normal(key, shape) * scale


# ---------------------------------------------------------------------------
# kernel vs reference: bitwise
# ---------------------------------------------------------------------------

PARITY_CASES = [
    # (R, tile, block_rows)
    (16, 64, 8),
    (19, 64, 8),    # R not divisible by block_rows
    (8, 256, 8),
    (3, 32, 8),     # fewer rows than block
]


@pytest.mark.parametrize("fmt", QUANT_FORMATS)
@pytest.mark.parametrize("case", PARITY_CASES)
def test_quantize_kernel_bitwise_parity(fmt, case):
    R, T, br = case
    x = rand(jax.random.PRNGKey(R * T), (R, T))
    qk, sk = quantize_tiles(x, fmt=fmt, block_rows=br, interpret=True)
    qr, sr = naive_quantize_tiles(x, fmt=fmt)
    assert qk.dtype == quant_dtype(fmt) == qr.dtype
    # int8 compares exactly; fp8 compared via f32 view (same bit pattern)
    assert np.array_equal(np.asarray(qk, np.float32),
                          np.asarray(qr, np.float32))
    assert np.array_equal(np.asarray(sk), np.asarray(sr))
    dk = dequantize_tiles(qk, sk, block_rows=br, interpret=True)
    dr = naive_dequantize_tiles(qr, sr)
    assert np.array_equal(np.asarray(dk), np.asarray(dr))


def test_scale_is_power_of_two_division():
    """The scale divisor must be a power of two so eager/jit/kernel agree
    bitwise (XLA rewrites constant divisions into reciprocal multiplies)."""
    for fmt, div in QDIV.items():
        assert div == 2.0 ** round(np.log2(div)), (fmt, div)
        amax = jnp.asarray([[3.7], [0.0]], jnp.float32)
        s = quant_scale(amax, fmt)
        # division by 2^k is exact: result is the f32 amax scaled in exponent
        assert float(s[0, 0]) == float(np.float32(3.7)) / div
        assert float(s[1, 0]) == 1.0  # zero tile -> neutral scale


# ---------------------------------------------------------------------------
# pack / unpack and the high-level ops
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5, 7, 33), (256,), (4, 64), (1, 1)])
def test_pack_unpack_roundtrip_exact(shape):
    x = rand(jax.random.PRNGKey(1), shape).astype(jnp.float32)
    x2d = pack_tiles(x, 64)
    assert x2d.shape[1] == 64 and x2d.shape[0] * 64 >= x.size
    back = unpack_tiles(x2d, x.shape, x.dtype)
    assert np.array_equal(np.asarray(back), np.asarray(x))


@pytest.mark.parametrize("fmt", QUANT_FORMATS)
def test_quantize_op_roundtrip_error_bound(fmt):
    x = rand(jax.random.PRNGKey(2), (5, 7, 33))
    packed = quantize_op(x, fmt=fmt, tile=64)
    assert packed["q"].dtype == quant_dtype(fmt)
    assert packed["scale"].dtype == jnp.float32
    xh = dequantize_op(packed, x.shape, x.dtype, tile=64)
    rel = float(jnp.linalg.norm(xh - x) / jnp.linalg.norm(x))
    assert rel < ROUNDTRIP_TOL[fmt], (fmt, rel)


def test_quantize_op_zero_input_safe():
    z = jnp.zeros((3, 5), jnp.float32)
    for fmt in QUANT_FORMATS:
        zh = roundtrip(z, fmt=fmt, tile=16)
        assert np.array_equal(np.asarray(zh), np.zeros((3, 5), np.float32))


def test_wire_bits_ratio():
    # int8 + one f32 scale per 256-tile: (8 + 32/256) / 32 of fp32 bytes
    assert wire_bits("int8", 256) == pytest.approx(8.125)
    assert wire_bits("int8", 256) / 32.0 < 0.26


def test_unknown_format_raises():
    with pytest.raises(ValueError):
        quant_dtype("int4")
    with pytest.raises(ValueError):
        naive_quantize_tiles(jnp.ones((2, 4)), fmt="int4")


# ---------------------------------------------------------------------------
# error feedback
# ---------------------------------------------------------------------------

def test_error_feedback_telescopes():
    """Mean of transmitted gradients converges to the true gradient: the
    running bias after T steps is one residual / T."""
    g = rand(jax.random.PRNGKey(3), (257,), scale=1.0)
    err = jnp.zeros_like(g)
    tot = jnp.zeros_like(g)
    T = 8
    for _ in range(T):
        gh, err = roundtrip_ef(g, err, fmt="int8", tile=64)
        tot = tot + gh
    bias = float(jnp.linalg.norm(tot / T - g) / jnp.linalg.norm(g))
    one_shot = float(jnp.linalg.norm(roundtrip(g, fmt="int8", tile=64) - g)
                     / jnp.linalg.norm(g))
    # telescoping: bias = |e_T| / T <= one_shot / T (up to residual growth)
    assert bias < one_shot / 4, (bias, one_shot)


def test_error_feedback_exact_sum_identity():
    """sum_t x_hat_t + e_T == sum_t x_t + e_0 holds to fp accuracy."""
    x = rand(jax.random.PRNGKey(4), (100,), scale=1.0)
    err = jnp.zeros_like(x)
    tot = jnp.zeros_like(x)
    T = 5
    for _ in range(T):
        xh, err = roundtrip_ef(x, err, fmt="int8", tile=32)
        tot = tot + xh
    np.testing.assert_allclose(np.asarray(tot + err), np.asarray(x * T),
                               atol=1e-4, rtol=1e-5)
