"""Measured-profiling subsystem: artifact round-trip (bit-exact),
``Profile.measured`` validation, sweep densification, staleness
fingerprints, cross-profile repricing, and planning on measured tables end
to end (including the live replay session reusing the loaded profile)."""

import dataclasses

import numpy as np
import pytest

from repro.core.hardware import JETSON_NANO, JETSON_NX, Cluster
from repro.core.planner import plan_hpp
from repro.core.profiler import (LayerCost, LayerTable, MeasuredProfile,
                                 Profile, ProfileError, config_fingerprint,
                                 device_fingerprint, load_profile,
                                 save_profile)
from repro.core.simulator import prediction_gap, reprice_plan, simulate
from repro.models import AttentionConfig, LayerSpec, ModelConfig

TINY = ModelConfig(name="tiny", n_layers=2, d_model=32, vocab_size=64,
                   d_ff=64,
                   attn=AttentionConfig(n_heads=2, n_kv_heads=2, head_dim=16),
                   pattern=(LayerSpec(),))


def _table(L=3):
    return LayerTable("m", tuple(
        LayerCost(f"l{i}", 1e6 * (i + 1), 1e4, 1e3) for i in range(L)))


def _mp(D=2, batches=(1, 2, 4), L=3, seed=0, **kw):
    rng = np.random.default_rng(seed)
    base = rng.uniform(1e-4, 1e-3, size=(D, 1, L))
    tf = base * np.asarray(batches, float)[None, :, None]
    defaults = dict(
        arch="m", seq_len=16, batch_sizes=tuple(batches),
        layer_names=tuple(f"l{i}" for i in range(L)), tf=tf, tb=2.0 * tf,
        device_names=tuple(f"cpu:{d}" for d in range(D)),
        config_hash="cfg0", device_hash="dev0",
        mem_bytes=(8e9,) * D, est_flops=(1e9,) * D)
    defaults.update(kw)
    return MeasuredProfile(**defaults)


# ---------------------------------------------------------------------------
# Serialization
# ---------------------------------------------------------------------------


def test_save_load_round_trip_bit_exact(tmp_path):
    mp = _mp(meta={"jax": "x", "note": "n"})
    path = str(tmp_path / "prof.json")
    save_profile(path, mp)
    back = load_profile(path)
    # float arrays survive JSON bit-for-bit (repr round-trip of doubles)
    assert back.tf.dtype == np.float64
    assert np.array_equal(back.tf, mp.tf) and np.array_equal(back.tb, mp.tb)
    assert (back.tf.view(np.uint64) == mp.tf.view(np.uint64)).all()
    for f in dataclasses.fields(MeasuredProfile):
        if f.name in ("tf", "tb"):
            continue
        assert getattr(back, f.name) == getattr(mp, f.name), f.name
    # ... and the planner tables built from both are identical
    t = _table()
    p1 = mp.to_profile(t, max_batch=6)
    p2 = back.to_profile(t, max_batch=6)
    assert np.array_equal(p1.tf_prefix, p2.tf_prefix)
    assert np.array_equal(p1.tb_prefix, p2.tb_prefix)


def test_load_rejects_malformed(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text("{\"schema\": \"something-else\"}")
    with pytest.raises(ProfileError, match="schema"):
        load_profile(str(path))
    path.write_text("not json")
    with pytest.raises(ProfileError, match="JSON"):
        load_profile(str(path))
    path.write_text("{\"schema\": \"asteroid-profile\", \"version\": 1}")
    with pytest.raises(ProfileError, match="missing keys"):
        load_profile(str(path))


# ---------------------------------------------------------------------------
# Profile.measured validation (max_batch coverage per device)
# ---------------------------------------------------------------------------


def test_measured_rejects_uncovered_max_batch():
    table = _table(L=2)
    cluster = Cluster((JETSON_NANO,))
    ok = np.full((1, 5, 2), 1e-3)
    Profile.measured(table, cluster, 4, ok, ok)          # covers 0..4
    short = np.full((1, 3, 2), 1e-3)                     # covers only 0..2
    with pytest.raises(ProfileError, match="cover"):
        Profile.measured(table, cluster, 4, short, ok)
    with pytest.raises(ProfileError, match="cover"):
        Profile.measured(table, cluster, 4, ok, short)


def test_measured_rejects_device_and_layer_mismatch():
    table = _table(L=2)
    two_dev = Cluster((JETSON_NANO, JETSON_NX))
    one_row = np.full((1, 5, 2), 1e-3)
    with pytest.raises(ProfileError, match="devices=2"):
        Profile.measured(table, two_dev, 4, one_row, one_row)
    wrong_L = np.full((1, 5, 3), 1e-3)
    with pytest.raises(ProfileError, match="layers=2"):
        Profile.measured(table, Cluster((JETSON_NANO,)), 4, wrong_L, wrong_L)
    neg = np.full((1, 5, 2), -1e-3)
    with pytest.raises(ProfileError, match="negative"):
        Profile.measured(table, Cluster((JETSON_NANO,)), 4, neg, neg)


def test_measured_profile_source_tag():
    table = _table(L=2)
    s = np.full((1, 5, 2), 1e-3)
    assert Profile.measured(table, Cluster((JETSON_NANO,)), 4, s, s).source \
        == "measured"
    assert Profile.analytic(table, Cluster((JETSON_NANO,)), 4).source \
        == "analytic"


# ---------------------------------------------------------------------------
# Densification
# ---------------------------------------------------------------------------


def test_densify_interpolates_and_extrapolates():
    L = 1
    tf = np.array([[[1.0], [4.0]]])                      # batches 1 and 4
    mp = _mp(D=1, batches=(1, 4), L=L, tf=tf, tb=2 * tf,
             device_names=("cpu:0",), mem_bytes=(8e9,), est_flops=(1e9,),
             layer_names=("l0",))
    tf_s, _ = mp.densify(max_batch=6)
    assert tf_s.shape == (1, 7, 1)
    assert tf_s[0, 0, 0] == 0.0                          # batch-0 row zero
    assert tf_s[0, 1, 0] == pytest.approx(1.0)
    assert tf_s[0, 2, 0] == pytest.approx(2.0)           # linear interior
    assert tf_s[0, 3, 0] == pytest.approx(3.0)
    assert tf_s[0, 4, 0] == pytest.approx(4.0)
    assert tf_s[0, 6, 0] == pytest.approx(6.0)           # last-segment slope
    # noisy non-monotone sweeps are clamped monotone (Fig. 6 shape)
    tf2 = np.array([[[2.0], [1.0]]])
    mp2 = dataclasses.replace(mp, tf=tf2, tb=tf2)
    tf2_s, _ = mp2.densify(4)
    assert (np.diff(tf2_s[0, 1:, 0]) >= 0).all()
    with pytest.raises(ProfileError, match="max_batch"):
        mp.densify(0)


def test_to_profile_prefix_matches_samples():
    mp = _mp()
    prof = mp.to_profile(_table(), max_batch=4, sort_by_memory=False)
    # range query at a measured batch returns the raw layer-sum
    assert prof.t_fwd(0, 2, 0, 3) == pytest.approx(mp.tf[0, 1].sum(), rel=1e-12)
    assert prof.t_bwd(1, 4, 0, 3) == pytest.approx(mp.tb[1, 2].sum(), rel=1e-12)
    with pytest.raises(ProfileError, match="match the measured layers"):
        mp.to_profile(_table(L=4), max_batch=4)


def test_to_profile_sorts_rows_with_devices():
    mp = _mp(D=2, mem_bytes=(4e9, 16e9), est_flops=(1e9, 4e9))
    prof = mp.to_profile(_table(), max_batch=4)
    # big-memory device must now be rank 0, carrying its own measured row
    assert prof.cluster.devices[0].mem_bytes == 16e9
    assert prof.t_fwd(0, 1, 0, 3) == pytest.approx(mp.tf[1, 0].sum(), rel=1e-12)
    assert prof.t_fwd(1, 1, 0, 3) == pytest.approx(mp.tf[0, 0].sum(), rel=1e-12)


# ---------------------------------------------------------------------------
# Staleness / compatibility
# ---------------------------------------------------------------------------


def test_compatibility_issues():
    good_hash = config_fingerprint(TINY, 16)
    mp = _mp(config_hash=good_hash, device_hash=device_fingerprint())
    assert mp.compatibility_issues(TINY, 16) == []
    assert mp.compatibility_issues(TINY, 32)            # seq changed
    assert mp.compatibility_issues(TINY.replace(d_model=64), 16)
    stale = dataclasses.replace(mp, device_hash="feedbeef00000000")
    issues = stale.compatibility_issues(TINY, 16)
    assert issues and "device fingerprint" in issues[0]
    assert stale.compatibility_issues(TINY, 16, check_device=False) == []
    future = dataclasses.replace(mp, version=99)
    assert any("version" in i for i in future.compatibility_issues(TINY, 16))


def test_config_fingerprint_sensitivity():
    h = config_fingerprint(TINY, 16)
    assert h == config_fingerprint(TINY, 16)
    assert h != config_fingerprint(TINY, 17)
    assert h != config_fingerprint(TINY.replace(n_layers=4), 16)


# ---------------------------------------------------------------------------
# Cross-profile repricing
# ---------------------------------------------------------------------------


def _hetero_profile():
    table = _table(L=4)
    cluster = Cluster((JETSON_NX, JETSON_NANO, JETSON_NANO)).sorted_by_memory()
    return Profile.analytic(table, cluster, max_batch=8)


def test_reprice_plan_identity():
    prof = _hetero_profile()
    plan = plan_hpp(prof, 16, 4, arch="t")
    again = reprice_plan(plan, prof)
    assert again.latency == pytest.approx(plan.latency, rel=1e-9)
    for a, b in zip(plan.steps, again.steps):
        assert a.kind == b.kind
        assert a.ef == pytest.approx(b.ef, rel=1e-9)
        assert a.eb == pytest.approx(b.eb, rel=1e-9)
    gap = prediction_gap(plan, prof)
    assert gap["gap_ratio"] == pytest.approx(1.0, rel=1e-9)
    assert gap["reference_sim_s"] >= 0


def test_prediction_gap_detects_misprediction():
    prof = _hetero_profile()
    plan = plan_hpp(prof, 16, 4, arch="t")
    # a reference twice as slow must show up as gap ~2x on exec-dominated
    slow = Profile(prof.table, prof.cluster, prof.max_batch,
                   2.0 * prof.tf_prefix, 2.0 * prof.tb_prefix, "measured")
    gap = prediction_gap(plan, slow)
    assert gap["gap_ratio"] > 1.2
    assert gap["reference_source"] == "measured"
    sim = simulate(reprice_plan(plan, slow), slow)
    assert sim.makespan == pytest.approx(gap["reference_sim_s"])


# ---------------------------------------------------------------------------
# End to end: measure -> artifact -> plan (and the replay session)
# ---------------------------------------------------------------------------


def test_measure_model_to_plan(tmp_path):
    from repro.launch.profile import measure_model

    mp = measure_model(TINY, seq_len=8, batch_sizes=(1, 2), repeats=1,
                       replicate=3)
    assert mp.D == 3 and mp.L == TINY.n_layers + 2
    assert (mp.tf > 0).all() and (mp.tb > 0).all()
    path = str(tmp_path / "prof.json")
    save_profile(path, mp)
    back = load_profile(path)
    assert back.compatibility_issues(TINY, 8) == []
    table = LayerTable.from_model_config(TINY, 8)
    prof = back.to_profile(table, max_batch=4)
    assert prof.source == "measured"
    plan = plan_hpp(prof, 4, 2, arch=TINY.name)
    assert plan.latency > 0 and len(plan.stages) >= 1
    assert prediction_gap(plan, prof)["gap_ratio"] == pytest.approx(1.0)


def test_session_replay_reuses_measured_profile():
    import jax
    from jax.sharding import Mesh

    from repro.data import SyntheticLM
    from repro.launch.profile import measure_model
    from repro.runtime.session import PipelineSession

    mp = measure_model(TINY, seq_len=8, batch_sizes=(1, 2), repeats=1,
                       replicate=4)
    table = LayerTable.from_model_config(TINY, 8)
    prof = mp.to_profile(table, max_batch=8)
    plan = plan_hpp(prof, 8, 2, arch=TINY.name, allowed_stages={1})
    devs = jax.devices()
    mesh = Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))
    session = PipelineSession(TINY, mesh, plan, prof, backup_every=2)
    session.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(TINY.vocab_size, 8)
    session.step(ds.batch(0, 8))
    session.fail(plan.stages[0].group[-1])
    session.step(ds.batch(1, 8))
    assert len(session.recoveries) == 1
    # the replan ran on the SAME measured profile object the session loaded
    assert session.profile is prof and session.profile.source == "measured"
    assert session.recoveries[0].report.new_plan.latency > 0


@pytest.mark.slow
def test_two_process_gather_selftest():
    """The multi-process gather path (``process_allgather`` with the CPU
    KV-store fallback) produces a 2-row artifact a planner can consume —
    run in subprocesses so the distributed runtime does not leak into this
    process (ROADMAP: multi-process gather CI coverage)."""
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(root, "src")}
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.profile_selftest"],
        capture_output=True, text=True, timeout=540, env=env, cwd=root)
    assert proc.returncode == 0, \
        f"\nstdout:{proc.stdout}\nstderr:{proc.stderr[-2000:]}"
    assert "2-process gather OK" in proc.stdout
