"""Property tests: the closed-loop plan portfolio (DESIGN.md §12).

Four invariants the tentpole promises, each pinned at the level where it
lives:

* **winner optimality** (pure + session): ``pick_winner`` returns the
  earliest measured argmin, so the installed plan's measured latency is
  never above any probed finalist's;
* **tie stability** (pure + session): measurements equal to predictions
  keep the analytically-best finalist, and a repeat auction under the
  same measurements never churns the installed plan;
* **probation bit-identity** (session): a full K-plan probation sweep —
  adopt, migrate, probe, swap back — leaves params and Adam moments
  bit-identical to a never-probed twin trained on the same batches;
* **reprice stability** (pure): ``simulator.reprice_plan`` is idempotent
  and ``portfolio.plan_key`` is invariant under repricing on any
  profile, so the structural dedupe can never split one candidate into
  two.

Uses hypothesis when installed, seeded ``random`` otherwise — same test
bodies either way (the ``test_membership_props`` pattern).
"""

import random

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.hardware import A100, JETSON_NX, JETSON_TX2, Cluster
from repro.core.portfolio import (PlanPortfolio, pick_winner, plan_key,
                                  robust_latency)
from repro.core.profiler import LayerTable, Profile
from repro.core.simulator import reprice_plan

try:
    from hypothesis import given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:          # container without hypothesis: seeded fallback
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# pure level: pick_winner / robust_latency / reprice stability
# ---------------------------------------------------------------------------


def _check_pick_winner(measured) -> None:
    best = pick_winner(measured)
    lo = min(measured)
    assert measured[best] == lo                      # measured argmin...
    assert all(m > lo for m in measured[:best])      # ...at its earliest index


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(measured=hst.lists(hst.floats(1e-3, 10.0), min_size=1,
                              max_size=8))
    def test_pick_winner_is_earliest_measured_argmin(measured):
        _check_pick_winner(measured)
else:
    @pytest.mark.parametrize("seed", range(16))
    def test_pick_winner_is_earliest_measured_argmin(seed):
        rng = random.Random(seed)
        measured = [rng.uniform(1e-3, 10.0)
                    for _ in range(rng.randint(1, 8))]
        if seed % 3 == 0 and len(measured) > 1:      # force ties sometimes
            measured[-1] = measured[0]
        _check_pick_winner(measured)


def test_pick_winner_tie_and_hysteresis():
    # exact tie: the earlier (analytically better) finalist keeps the slot
    assert pick_winner([1.0, 1.0, 1.0]) == 0
    # a 5% faster challenger loses under a 10% hysteresis margin...
    assert pick_winner([1.0, 0.95], hysteresis=0.10) == 0
    # ...and wins once it clears it
    assert pick_winner([1.0, 0.85], hysteresis=0.10) == 1


def test_robust_latency_trims_warmup():
    # the jit-compile spike in round 0 must not leak into the estimate
    assert robust_latency([50.0, 1.0, 1.2, 1.1]) == pytest.approx(1.1)
    # degenerate windows fall back to the full median rather than dying
    assert robust_latency([2.0]) == 2.0
    with pytest.raises(ValueError):
        robust_latency([])


_S = 32
_DEVICE_POOL = (JETSON_NX, JETSON_TX2, A100)


@pytest.fixture(scope="module")
def smoke_table():
    cfg = get_smoke_config("phi3-mini-3.8b")
    cfg = cfg.replace(n_layers=2 * len(cfg.pattern))
    return cfg, LayerTable.from_model_config(cfg, _S)


def _random_profile(smoke_table, rng):
    cfg, table = smoke_table
    devs = tuple(rng.choice(_DEVICE_POOL)
                 for _ in range(rng.randint(2, 4)))
    bw = rng.uniform(1e7, 1e9)
    return Profile.analytic(table, Cluster(devs, bw), max_batch=8)


def _check_reprice_stability(smoke_table, rng) -> None:
    cfg, _ = smoke_table
    prof_a = _random_profile(smoke_table, rng)
    prof_b = _random_profile(smoke_table, rng)
    pf = PlanPortfolio.enumerate(prof_a, 8, 2, arch=cfg.name)
    assert pf.candidates, "portfolio enumerated nothing"
    for c in pf.candidates:
        if c.plan is None:
            continue
        once = reprice_plan(c.plan, prof_b)
        twice = reprice_plan(once, prof_b)
        # idempotent: pricing a repriced plan changes nothing
        assert twice.latency == once.latency
        assert [(s.ef, s.eb, s.ta) for s in twice.steps] == \
               [(s.ef, s.eb, s.ta) for s in once.steps]
        # the dedupe key never moves under repricing, on either profile
        assert plan_key(once) == plan_key(c.plan)
        assert plan_key(reprice_plan(c.plan, prof_a)) == plan_key(c.plan)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(seed=hst.integers(0, 2**31 - 1))
    def test_reprice_idempotent_and_key_stable(smoke_table, seed):
        _check_reprice_stability(smoke_table, random.Random(seed))
else:
    @pytest.mark.parametrize("seed", range(6))
    def test_reprice_idempotent_and_key_stable(smoke_table, seed):
        _check_reprice_stability(smoke_table, random.Random(seed))


# ---------------------------------------------------------------------------
# session level: live auctions on a 1-host-device smoke session
# ---------------------------------------------------------------------------

_B = 8
_STEPS_BEFORE = 2


def _make_session():
    from jax.sharding import Mesh

    from repro.core.planner import plan_hpp
    from repro.runtime.session import PipelineSession

    cfg = get_smoke_config("phi3-mini-3.8b")
    cfg = cfg.replace(n_layers=2 * len(cfg.pattern))
    table = LayerTable.from_model_config(cfg, _S)
    prof = Profile.analytic(table, Cluster((JETSON_NX,) * 3, 1e9 / 8),
                            max_batch=_B)
    plan = plan_hpp(prof, _B, micro_batch=4, arch=cfg.name,
                    allowed_stages={1})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    session = PipelineSession(cfg, mesh, plan, prof, backup_every=1)
    session.init(jax.random.PRNGKey(0))
    return cfg, session


def _canon_leaves(session):
    return [np.asarray(x) for x in jax.tree.leaves(session.canonical_leaves())]


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_winner_measured_never_above_any_finalist(seed):
    """Synthetic measurements (full adopt/migrate cycle, injected clock):
    the installed winner's measured latency is the finalists' minimum, and
    re-auctioning under the same measurements never churns it."""
    rng = random.Random(seed)
    _, session = _make_session()
    report = session.probe_portfolio(
        k=3, measure=lambda c: rng.uniform(0.01, 1.0))
    assert report.winner.installed
    assert all(report.winner.measured_s <= r.measured_s
               for r in report.results)
    assert report.to_record()["measured_winner_gain"] >= 1.0

    # same measurements again: the winner is already installed -> no churn
    fixed = {r.family: r.measured_s for r in report.results}
    again = session.probe_portfolio(
        k=3, measure=lambda c: fixed.get(c.family, 2.0))
    assert again.winner.family == report.winner.family
    assert not again.churned


def test_ties_keep_analytic_first_choice():
    """Measurements that exactly match the predictions must keep the
    analytically-best finalist: the cost model is only ever *overruled by
    evidence*, never by noise-free agreement."""
    _, session = _make_session()
    report = session.probe_portfolio(k=3, measure=lambda c: c.predicted_s)
    assert report.winner_index == 0
    assert report.winner.family == report.first_choice.family
    # the analytic best is now installed; a repeat tie auction cannot churn
    again = session.probe_portfolio(k=3, measure=lambda c: c.predicted_s)
    assert again.winner_index == 0
    assert not again.churned
    # and literal ties across all finalists also resolve to index 0
    flat = session.probe_portfolio(k=3, measure=lambda c: 1.0)
    assert flat.winner_index == 0


@pytest.fixture(scope="module")
def never_probed_twin():
    """Reference state: same init, same batches, zero auctions."""
    from repro.data import SyntheticLM

    cfg, session = _make_session()
    ds = SyntheticLM(cfg.vocab_size, _S)
    for s in range(_STEPS_BEFORE):
        session.step(ds.batch(s, _B))
    return _canon_leaves(session)


def test_probation_sweep_is_bit_identical(never_probed_twin):
    """A full live K-plan probation (real probe rounds, k=2, 1-round
    window) between training steps leaves params + Adam moments
    bit-identical to the never-probed twin, and the session still trains
    on the installed winner."""
    from repro.data import SyntheticLM

    cfg, session = _make_session()
    ds = SyntheticLM(cfg.vocab_size, _S)
    for s in range(_STEPS_BEFORE):
        session.step(ds.batch(s, _B))

    report = session.probe_portfolio(ds.batch(_STEPS_BEFORE, _B),
                                     k=2, window=1)
    assert report.winner.installed
    assert len(report.results) >= 1
    assert all(len(r.rounds) == 2 for r in report.results)

    ours = _canon_leaves(session)
    assert len(ours) == len(never_probed_twin)
    for a, b in zip(ours, never_probed_twin):
        assert np.array_equal(a, b)

    loss, _ = session.step(ds.batch(_STEPS_BEFORE, _B))
    assert np.isfinite(loss)
