"""Property tests for Algorithm 1 (``core.allocation``) and its lowering
to data-shard coordinates (``core.lowering.lower_micro_alloc``).

Pinned invariants, fuzzed over random heterogeneous clusters / layer ranges
/ micro-batch sizes:

1. allocations always sum to the micro-batch,
2. no device ever exceeds its Eq. (3) memory cap,
3. Phase 2 (StragglerWorkloadOffloading) never increases the straggler
   latency over Phase 1 (MemoryAwareBalancing) alone,
4. the lowered per-shard allocation partitions the micro-batch for any
   data-axis width.
"""

import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the 'test' extra")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.allocation import AllocationError, allocate_microbatch
from repro.core.costmodel import kp_policy, stage_memory
from repro.core.hardware import Cluster, DeviceProfile
from repro.core.lowering import lower_micro_alloc
from repro.core.profiler import LayerTable, Profile
from repro.models import AttentionConfig, LayerSpec, ModelConfig
from test_lowering import _lp_alloc

pytestmark = pytest.mark.slow


def _table(L=8):
    cfg = ModelConfig(name="prop", n_layers=L, d_model=128, vocab_size=4000,
                      d_ff=512,
                      attn=AttentionConfig(n_heads=4, n_kv_heads=4,
                                           head_dim=32),
                      pattern=(LayerSpec(),))
    return LayerTable.from_model_config(cfg, seq_len=64)


TABLE = _table()

devices = st.lists(
    st.tuples(st.floats(0.5, 64.0),        # memory scale (GB)
              st.floats(0.05, 4.0),        # TFLOP/s
              st.floats(1.0, 32.0)),       # half-saturation batch
    min_size=2, max_size=5)


@st.composite
def alloc_cases(draw):
    devs = draw(devices)
    cluster = Cluster(tuple(
        DeviceProfile(f"d{i}", mem_bytes=m * 1e9, flops=f * 1e12,
                      sat_batch=k)
        for i, (m, f, k) in enumerate(devs)))
    micro_batch = draw(st.integers(1, 32))
    L = TABLE.L
    i = draw(st.integers(0, L - 1))
    j = draw(st.integers(i + 1, L))
    P = draw(st.integers(1, 4))
    k_p = kp_policy(P, draw(st.integers(0, P - 1)))
    block = draw(st.integers(1, 4))
    prof = Profile.analytic(TABLE, cluster, max_batch=micro_batch)
    return prof, tuple(range(len(devs))), micro_batch, i, j, k_p, block


@settings(max_examples=60, deadline=None)
@given(alloc_cases())
def test_allocation_invariants(case):
    prof, group, micro_batch, i, j, k_p, block = case
    try:
        full = allocate_microbatch(prof, group, micro_batch, i, j, k_p,
                                   block=block, offload=True)
        phase1 = allocate_microbatch(prof, group, micro_batch, i, j, k_p,
                                     block=block, offload=False)
    except AllocationError:
        return                           # memory-infeasible case: fine

    for alloc in (full, phase1):
        # 1. conservation
        assert sum(alloc.y) == micro_batch
        assert all(y >= 0 for y in alloc.y)
        # 2. per-device Eq. (3) memory caps
        for d, y in zip(group, alloc.y):
            mem = stage_memory(prof.table, i, j, y, k_p)
            assert mem <= prof.cluster.devices[d].mem_bytes
        # Eq. (8): the reported stage times are the group maxima
        assert alloc.ef == pytest.approx(
            max(prof.t_fwd(d, y, i, j) for d, y in zip(group, alloc.y)))
        assert alloc.eb == pytest.approx(
            max(prof.t_bwd(d, y, i, j) for d, y in zip(group, alloc.y)))

    # 3. offloading never increases the straggler latency
    def straggler(y):
        return max(prof.t_both(d, yy, i, j) for d, yy in zip(group, y))

    assert straggler(full.y) <= straggler(phase1.y) + 1e-12


@settings(max_examples=60, deadline=None)
@given(st.lists(st.lists(st.integers(0, 16), min_size=1, max_size=6),
                min_size=1, max_size=4),
       st.integers(1, 8))
def test_lowered_shard_alloc_partitions_micro_batch(allocs, dp):
    """lower_micro_alloc partitions the micro-batch over any dp width, for
    any combination of per-stage group sizes and allocations."""
    mb = sum(allocs[0])
    if mb == 0:
        return
    allocs = [tuple(a) for a in allocs]
    # per-stage allocations must each sum to the micro-batch: rescale the
    # drawn lists by largest remainder
    norm = []
    for a in allocs:
        s = sum(a)
        if s == 0:
            a = tuple([mb] + [0] * (len(a) - 1))
            s = mb
        scaled = [y * mb / s for y in a]
        base = [int(x) for x in scaled]
        rem = mb - sum(base)
        order = sorted(range(len(a)), key=lambda d: (base[d] - scaled[d], d))
        for d in order[:rem]:
            base[d] += 1
        norm.append(tuple(base))
    out = lower_micro_alloc(_lp_alloc(norm, mb), dp)
    assert len(out) == dp
    assert sum(out) == mb
    assert min(out) >= 0
    # stages that agree after projection lower exactly
    if len(set(norm)) == 1 and len(norm[0]) == dp:
        assert out == norm[0]
