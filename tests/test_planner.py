"""Planner unit + property tests: Algorithm 1/2, cost model, schedules."""

import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the 'test' extra")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.allocation import AllocationError, allocate_microbatch
from repro.core.costmodel import (Step, dominant_index, hdp_volume, hpp_volume,
                                  kp_policy, round_latency, stage_memory)
from repro.core.hardware import (JETSON_NANO, JETSON_NX, JETSON_TX2, Cluster,
                                 env_b, env_c, env_d)
from repro.core.planner import (auto_microbatch, plan_dp, plan_gpipe,
                                plan_hpp, plan_homogeneous_hpp)
from repro.core.profiler import LayerCost, LayerTable, Profile
from repro.core.schedule import (max_inflight, schedule_orders,
                                 stage_order_1f1b, stage_order_gpipe)
from repro.core.simulator import simulate
from repro.models import AttentionConfig, LayerSpec, ModelConfig


def toy_table(L=12, d=512, seq=128, vocab=32000):
    cfg = ModelConfig(name=f"toy-{L}L", n_layers=L, d_model=d, vocab_size=vocab,
                      d_ff=4 * d,
                      attn=AttentionConfig(n_heads=8, n_kv_heads=8, head_dim=d // 8),
                      pattern=(LayerSpec(),))
    return LayerTable.from_model_config(cfg, seq_len=seq)


@pytest.fixture(scope="module")
def profile():
    return Profile.analytic(toy_table(), env_c().sorted_by_memory(), max_batch=64)


# ---------------------------------------------------------------------------
# Algorithm 1
# ---------------------------------------------------------------------------


def test_allocation_conserves_and_respects_memory(profile):
    group = tuple(range(len(profile.cluster.devices)))
    alloc = allocate_microbatch(profile, group, 32, 0, profile.table.L, k_p=1)
    assert sum(alloc.y) == 32
    for d, y in zip(group, alloc.y):
        mem = stage_memory(profile.table, 0, profile.table.L, y, 1)
        assert mem <= profile.cluster.devices[d].mem_bytes


def test_allocation_prefers_fast_devices(profile):
    # rank 0 is the NX (fastest, most memory after sorting) — it should get
    # at least as many samples as the weakest nano
    group = tuple(range(len(profile.cluster.devices)))
    alloc = allocate_microbatch(profile, group, 24, 0, profile.table.L, k_p=1)
    assert alloc.y[0] >= alloc.y[-1]


def test_allocation_memory_infeasible_raises():
    tiny = Cluster((JETSON_NANO._replace_mem(1e4) if hasattr(JETSON_NANO, "_replace_mem")
                    else JETSON_NANO.__class__(**{**JETSON_NANO.__dict__, "mem_bytes": 1e4}),))
    prof = Profile.analytic(toy_table(), tiny, max_batch=8)
    with pytest.raises(AllocationError):
        allocate_microbatch(prof, (0,), 8, 0, prof.table.L, k_p=1)


@given(mb=st.integers(2, 48))
@settings(max_examples=10, deadline=None)
def test_allocation_total_property(mb):
    prof = Profile.analytic(toy_table(), env_d().sorted_by_memory(), max_batch=64)
    group = tuple(range(len(prof.cluster.devices)))
    alloc = allocate_microbatch(prof, group, mb, 0, prof.table.L, k_p=1)
    assert sum(alloc.y) == mb
    assert all(y >= 0 for y in alloc.y)
    # Eq. 8: reported times are the max over the group
    ef = max(prof.t_fwd(d, y, 0, prof.table.L) for d, y in zip(group, alloc.y))
    assert abs(ef - alloc.ef) < 1e-12


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def test_kp_policy_values():
    assert [kp_policy(3, p) for p in range(3)] == [5, 3, 1]
    assert [kp_policy(3, p, "a") for p in range(3)] == [6, 4, 2]
    assert [kp_policy(3, p, "b") for p in range(3)] == [3, 2, 1]
    assert [kp_policy(3, p, "c") for p in range(3)] == [7, 5, 3]


def test_hdp_vs_hpp_volume_shape():
    """HDP must exceed HPP when parameters dominate activations (Table 2)."""
    P_bytes = 100e6
    groups = [{"batch": 16, "act_bytes": [1e6] * 2} for _ in range(2)]
    v_hdp = hdp_volume(P_bytes, groups)
    v_hpp = hpp_volume([P_bytes * 0.6, P_bytes * 0.4], [2, 3], [1e6], 32)
    assert v_hdp > v_hpp


def test_round_latency_single_stage_matches_direct():
    steps = (Step("exec", ef=1.0, eb=2.0, ta=0.5),)
    # single stage: M*(ef+eb) + ta
    assert round_latency(steps, 4) == pytest.approx(4 * 3.0 + 0.5)


def test_dominant_index_prefers_heavy_step():
    steps = (Step("exec", 1.0, 1.0), Step("comm", 0.1, 0.1), Step("exec", 2.0, 2.0))
    assert dominant_index(steps, 8) == 2


# ---------------------------------------------------------------------------
# Schedules
# ---------------------------------------------------------------------------


def test_1f1b_order_valid():
    order = stage_order_1f1b(8, 3)
    # every micro-batch appears exactly once as F and once as B, B after F
    fs = [op.micro for op in order if op.kind == "F"]
    bs = [op.micro for op in order if op.kind == "B"]
    assert sorted(fs) == list(range(8)) and sorted(bs) == list(range(8))
    for m in range(8):
        assert order.index(next(o for o in order if o == o.__class__("F", m))) < \
               order.index(next(o for o in order if o == o.__class__("B", m)))


def test_1f1b_inflight_bound():
    for M in (4, 8, 16):
        for k in (1, 3, 5):
            assert max_inflight(stage_order_1f1b(M, k)) == min(k, M)
    assert max_inflight(stage_order_gpipe(8)) == 8


@given(M=st.integers(1, 32), P=st.integers(1, 8))
@settings(max_examples=20, deadline=None)
def test_schedule_orders_property(M, P):
    orders = schedule_orders(P, M, "ours")
    assert len(orders) == P
    for p, order in enumerate(orders):
        assert max_inflight(order) == min(2 * (P - p) - 1, M)


# ---------------------------------------------------------------------------
# Algorithm 2 + simulator agreement
# ---------------------------------------------------------------------------


def test_plan_hpp_beats_baselines(profile):
    plan = plan_hpp(profile, global_batch=64, micro_batch=8)
    dp = plan_dp(profile, 64, 8)
    pp = plan_gpipe(profile, 64, 8)
    assert plan.latency <= dp.latency
    assert plan.latency <= pp.latency


def test_plan_respects_memory(profile):
    plan = plan_hpp(profile, 64, 8)
    mems = plan.memory_per_device(profile)
    for d, m in mems.items():
        assert m <= profile.cluster.devices[d].mem_bytes


def test_simulator_close_to_estimate(profile):
    plan = plan_hpp(profile, 64, 8)
    res = simulate(plan, profile, policy="ours")
    # dominant-step approximation: within 25% of event-accurate makespan
    assert res.makespan == pytest.approx(plan.latency, rel=0.25)


def test_1f1b_policy_memory_ordering(profile):
    plan = plan_hpp(profile, 64, 8)
    mem = {}
    for policy in ("ours", "a", "c", "gpipe"):
        res = simulate(plan, profile, policy=policy)
        mem[policy] = res.max_peak_mem
    assert mem["ours"] <= mem["a"] <= mem["c"]
    assert mem["ours"] <= mem["gpipe"]


def test_homogeneous_planner_worse_on_heterogeneous(profile):
    ours = plan_hpp(profile, 64, 8)
    pd = plan_homogeneous_hpp(profile, 64, 8)
    assert ours.latency <= pd.latency * 1.001


def test_auto_microbatch_feasible(profile):
    plan = auto_microbatch(profile, 64)
    assert plan.global_batch == 64
