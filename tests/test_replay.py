"""Fault-tolerant pipeline replay (§3.4) tests."""

import pytest

from repro.core.hardware import env_c, env_d
from repro.core.planner import plan_hpp
from repro.core.profiler import LayerTable, Profile
from repro.core.replay import (assign_backups, detection_latency,
                               heavy_rescheduling, lightweight_replay)
from repro.models import AttentionConfig, LayerSpec, ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="toy", n_layers=12, d_model=512, vocab_size=32000,
                      d_ff=2048,
                      attn=AttentionConfig(n_heads=8, n_kv_heads=8, head_dim=64),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=128)
    profile = Profile.analytic(table, env_c().sorted_by_memory(), max_batch=64)
    plan = plan_hpp(profile, 128, 16, arch="toy")
    return profile, plan


def test_backup_assignment_topology(setup):
    profile, plan = setup
    assign = assign_backups(plan, profile)
    P = len(plan.stages)
    for p, st in enumerate(plan.stages):
        if len(st.group) == 1:
            assert p in assign.backup_of_stage
            nxt = plan.stages[(p + 1) % P]
            assert assign.backup_of_stage[p] in nxt.group
        else:
            assert p not in assign.backup_of_stage


def test_detection_latency_bounds():
    lat = detection_latency(10.0)
    # at most heartbeat period + timeout + probe
    assert 0 < lat <= 0.5 + 2.0 + 1.0 + 1e-9


def test_lightweight_faster_than_heavy(setup):
    profile, plan = setup
    fail = plan.stages[-1].group[0]
    light = lightweight_replay(plan, profile, fail)
    heavy = heavy_rescheduling(plan, profile, fail)
    assert light.total_s < heavy.total_s
    # the replanned pipeline keeps most of the throughput
    assert light.new_plan.throughput >= 0.5 * heavy.new_plan.throughput


def test_replay_covers_all_layers_and_devices(setup):
    profile, plan = setup
    fail = plan.stages[0].group[0]
    light = lightweight_replay(plan, profile, fail)
    stages = light.new_plan.stages
    # contiguous full cover of the layer range
    assert stages[0].layers[0] == 0
    assert stages[-1].layers[1] == profile.table.L
    for a, b in zip(stages, stages[1:]):
        assert a.layers[1] == b.layers[0]
    # failed device no longer used
    for st in stages:
        assert fail not in st.group


@pytest.mark.parametrize("fail_stage", [0, 1, -1])
def test_replay_any_stage(setup, fail_stage):
    profile, plan = setup
    fail = plan.stages[fail_stage].group[0]
    rep = lightweight_replay(plan, profile, fail)
    assert rep.total_s > 0
    assert rep.new_plan.latency > 0
