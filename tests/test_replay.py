"""Fault-tolerant pipeline replay (§3.4) tests."""

import pytest

from repro.core.costmodel import kp_policy
from repro.core.hardware import JETSON_NX, JETSON_TX2, Cluster, env_c, env_d
from repro.core.planner import Plan, StagePlan, plan_hpp
from repro.core.profiler import LayerTable, Profile, extend_profile
from repro.core.replay import (AdmissionDecision, DeviceDraining,
                               DeviceEvicted, DeviceJoined, RecoveryReport,
                               MembershipController, admission_replay,
                               assign_backups, departure_replay,
                               detection_latency, heavy_rescheduling,
                               lightweight_replay)
from repro.models import AttentionConfig, LayerSpec, ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="toy", n_layers=12, d_model=512, vocab_size=32000,
                      d_ff=2048,
                      attn=AttentionConfig(n_heads=8, n_kv_heads=8, head_dim=64),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=128)
    profile = Profile.analytic(table, env_c().sorted_by_memory(), max_batch=64)
    plan = plan_hpp(profile, 128, 16, arch="toy")
    return profile, plan


def test_backup_assignment_topology(setup):
    profile, plan = setup
    assign = assign_backups(plan, profile)
    P = len(plan.stages)
    for p, st in enumerate(plan.stages):
        if len(st.group) == 1:
            assert p in assign.backup_of_stage
            nxt = plan.stages[(p + 1) % P]
            assert assign.backup_of_stage[p] in nxt.group
        else:
            assert p not in assign.backup_of_stage


def test_detection_latency_bounds():
    lat = detection_latency(10.0)
    # at most heartbeat period + timeout + probe
    assert 0 < lat <= 0.5 + 2.0 + 1.0 + 1e-9


def test_lightweight_faster_than_heavy(setup):
    profile, plan = setup
    fail = plan.stages[-1].group[0]
    light = lightweight_replay(plan, profile, fail)
    heavy = heavy_rescheduling(plan, profile, fail)
    assert light.total_s < heavy.total_s
    # the replanned pipeline keeps most of the throughput
    assert light.new_plan.throughput >= 0.5 * heavy.new_plan.throughput


def test_replay_covers_all_layers_and_devices(setup):
    profile, plan = setup
    fail = plan.stages[0].group[0]
    light = lightweight_replay(plan, profile, fail)
    stages = light.new_plan.stages
    # contiguous full cover of the layer range
    assert stages[0].layers[0] == 0
    assert stages[-1].layers[1] == profile.table.L
    for a, b in zip(stages, stages[1:]):
        assert a.layers[1] == b.layers[0]
    # failed device no longer used
    for st in stages:
        assert fail not in st.group


@pytest.mark.parametrize("fail_stage", [0, 1, -1])
def test_replay_any_stage(setup, fail_stage):
    profile, plan = setup
    fail = plan.stages[fail_stage].group[0]
    rep = lightweight_replay(plan, profile, fail)
    assert rep.total_s > 0
    assert rep.new_plan.latency > 0


# ---------------------------------------------------------------------------
# Fully-failed stage accounting (regression) + backup link bandwidths
# ---------------------------------------------------------------------------


def _single_device_plan(bw_matrix=None, bandwidth=None):
    """3 single-device stages over 3 identical devices, 12 real layers."""
    cfg = ModelConfig(name="t", n_layers=12, d_model=256, vocab_size=8000,
                      d_ff=1024,
                      attn=AttentionConfig(n_heads=4, n_kv_heads=4,
                                           head_dim=64),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=64)
    kw = {}
    if bandwidth is not None:
        kw["bandwidth"] = bandwidth
    cluster = Cluster((JETSON_NX,) * 3, bw_matrix=bw_matrix, **kw)
    profile = Profile.analytic(table, cluster, max_batch=16)
    stages = (StagePlan((0, 5), (0,), (16,), kp_policy(3, 0)),
              StagePlan((5, 10), (1,), (16,), kp_policy(3, 1)),
              StagePlan((10, 14), (2,), (16,), kp_policy(3, 2)))
    return table, profile, Plan("t", stages, (), 16, 4, 1.0)


def test_fully_failed_stage_not_counted_as_migration():
    """Regression: a fully-failed stage's layer range used to silently drop
    out of the old-cut accounting, charging its (backup-restored) layers to
    boundary migration against misaligned survivor boundaries.  Old
    ownership now follows the ORIGINAL plan partition: the failed range is
    restored, never migrated, and survivors only migrate layers whose own
    assignment moved."""
    table, profile, plan = _single_device_plan()
    rep = lightweight_replay(plan, profile, failed_rank=1)

    # the new plan still covers everything with the two survivors
    stages = rep.new_plan.stages
    assert len(stages) == 2
    assert stages[0].layers[0] == 0 and stages[-1].layers[1] == table.L
    for a, b in zip(stages, stages[1:]):
        assert a.layers[1] == b.layers[0]

    # no boundary move may include a layer of the failed stage's range
    failed_lo, failed_hi = plan.stages[1].layers
    for m in rep.boundary_moves:
        assert m.hi <= failed_lo or m.lo >= failed_hi, (m, (failed_lo, failed_hi))
    # identical devices split the work at the failed range's midpoint: the
    # survivors' own layers keep their owners, so nothing migrates at all —
    # the failed range is restored from backup instead
    assert rep.migration_s == 0.0
    assert rep.restore_s > 0.0


def test_restore_uses_backup_link_bandwidth():
    """Regression: restore cost used the cluster-wide bandwidth; it must be
    priced on the actual backup link bw(backup_rank, new_owner_rank), and a
    restore to the backup holder's own stage is local (free)."""
    bw = [[0.0, 1e6, 2e6],
          [1e6, 0.0, 4e6],
          [5e5, 4e6, 0.0]]
    table, profile, plan = _single_device_plan(
        bw_matrix=tuple(map(tuple, bw)), bandwidth=1e9)
    rep = lightweight_replay(plan, profile, failed_rank=1)

    assign = assign_backups(plan, profile)
    backup_rank = assign.backup_of_stage[1]
    assert backup_rank == 2                      # next stage's lead device
    failed_lo, failed_hi = plan.stages[1].layers
    expect = 0.0
    for st in rep.new_plan.stages:
        lo = max(failed_lo, st.layers[0])
        hi = min(failed_hi, st.layers[1])
        if lo >= hi or backup_rank in st.group:
            continue                             # local to the backup holder
        expect = max(expect,
                     table.param_bytes(lo, hi) / bw[backup_rank][st.group[0]])
    assert expect > 0                            # scenario does restore remotely
    assert rep.restore_s == pytest.approx(expect)
    # the cluster-wide bandwidth (1 GB/s) would give a far smaller number
    assert rep.restore_s > table.param_bytes(failed_lo, failed_hi) / 1e9


def test_boundary_moves_power_migration_time():
    """migration_s == the max over boundary moves of bytes / link bw."""
    profile_plan = _single_device_plan()
    table, profile, plan = profile_plan
    rep = lightweight_replay(plan, profile, failed_rank=plan.stages[0].group[0])
    if rep.boundary_moves:
        assert rep.migration_s == pytest.approx(
            max(m.nbytes / m.link_bw for m in rep.boundary_moves))
    else:
        assert rep.migration_s == 0.0


# ---------------------------------------------------------------------------
# Elastic membership: admission, graceful departure, event dispatch
# ---------------------------------------------------------------------------


def test_extend_profile_appends_newcomer_as_last_rank(setup):
    profile, plan = setup
    n = len(profile.cluster.devices)
    ext = extend_profile(profile, JETSON_TX2)
    assert len(ext.cluster.devices) == n + 1
    assert ext.cluster.devices[-1].name == JETSON_TX2.name
    assert ext.cluster.devices[:n] == profile.cluster.devices
    assert ext.table is profile.table
    # incumbent rows are untouched: any layer timing agrees rank-for-rank
    for r in range(n):
        assert ext.t_fwd(r, 4, 0, 3) == profile.t_fwd(r, 4, 0, 3)


def test_admission_hysteresis_gates_acceptance(setup):
    """The same newcomer is admitted or turned away purely by the
    hysteresis margin; a rejection never produces a plan."""
    profile, plan = setup
    ext = extend_profile(profile, JETSON_TX2)
    new_rank = len(ext.cluster.devices) - 1
    always = admission_replay(plan, ext, new_rank, hysteresis=-10.0)
    never = admission_replay(plan, ext, new_rank, hysteresis=0.99)
    assert always.accepted and always.report is not None
    assert always.report.mode == "admission"
    assert always.report.detection_s == 0.0        # planned, not a crash
    assert always.replan_s > 0.0
    assert always.candidate_latency < always.incumbent_latency * 11.0
    assert not never.accepted and never.report is None
    assert never.replan_s > 0.0                    # pricing work still paid
    assert "hysteresis" in never.reason


def test_admitted_plan_covers_layers_and_uses_newcomer(setup):
    profile, plan = setup
    ext = extend_profile(profile, JETSON_TX2)
    new_rank = len(ext.cluster.devices) - 1
    decision = admission_replay(plan, ext, new_rank, hysteresis=-10.0)
    stages = decision.report.new_plan.stages
    assert stages[0].layers[0] == 0
    assert stages[-1].layers[1] == ext.table.L
    for a, b in zip(stages, stages[1:]):
        assert a.layers[1] == b.layers[0]
    holders = [st for st in stages if new_rank in st.group]
    assert len(holders) == 1                       # joins exactly one stage
    # a DP-peer join replicates the stage model onto the newcomer; an
    # own-stage join pays boundary moves instead — never both zero when
    # the newcomer actually holds layers
    rep = decision.report
    if len(holders[0].group) > 1:
        assert rep.replicate_s > 0.0
    assert rep.total_s >= rep.replan_s + rep.migration_s


def test_departure_replay_drain_overlaps_evict_pauses():
    """The sole owner of a stage leaves: every one of its layers streams
    directly off the leaver; a graceful drain stalls the pipeline only for
    the re-plan, an evict pauses for the migration too."""
    table, profile, plan = _single_device_plan()
    drain = departure_replay(plan, profile, 1, graceful=True)
    evict = departure_replay(plan, profile, 1, graceful=False)
    assert drain.mode == "drain" and evict.mode == "evict"
    # the leaver is alive: no detection, nothing restored from backups
    for rep in (drain, evict):
        assert rep.detection_s == 0.0 and rep.restore_s == 0.0
        assert rep.direct_moves, "fully-departed stage must stream directly"
        assert all(dm.src_rank == 1 for dm in rep.direct_moves)
        assert sum(dm.nbytes for dm in rep.direct_moves) == pytest.approx(
            table.param_bytes(*plan.stages[1].layers))
        for st in rep.new_plan.stages:
            assert 1 not in st.group
    assert drain.overlapped and not evict.overlapped
    assert drain.stall_s == pytest.approx(drain.replan_s)
    assert evict.stall_s == pytest.approx(evict.total_s)
    assert evict.stall_s > drain.stall_s


def test_controller_dispatches_typed_events():
    """handle() routes each event type through its handler, stamping the
    planned-transition state machine (no detection spine) and keeping the
    heartbeat registry in sync with membership."""
    from types import SimpleNamespace

    plan_after_join = SimpleNamespace(
        stages=(SimpleNamespace(group=(0, 1)), SimpleNamespace(group=(2, 3)),))
    calls = []

    class Exec:
        def admit_replan(self, event):
            calls.append(("admit", event.device))
            rep = RecoveryReport(0.0, 0.25, 0.5, 0.0, plan_after_join,
                                 "admission", replicate_s=0.75)
            return AdmissionDecision(True, rep, 1.0, 0.5, 0.05, 0.25, "ok")

        def drain_replan(self, rank):
            calls.append(("drain", rank))
            return RecoveryReport(0.0, 0.25, 2.0, 0.0, plan_after_join,
                                  "drain", overlapped=True)

        def migrate(self, report):
            calls.append(("migrate", report.mode))
            return "mig"

        def resume(self, report, migration):
            calls.append(("resume", migration))

    c = MembershipController([0, 1, 2])
    decision, mig = c.handle(DeviceJoined("newdev"), Exec(), now=10.0)
    assert decision.accepted and mig == "mig"
    assert [s for s, _, _ in c.events] == [
        "monitoring", "admitting", "migrating", "resuming", "monitoring"]
    assert 3 in c.last_beat                       # newcomer now monitored
    times = {s: t for s, t, _ in c.events}
    # migrating starts after pricing; resuming after boundary + replica push
    assert times["migrating"] == pytest.approx(10.25)
    assert times["resuming"] == pytest.approx(10.25 + 0.5 + 0.75)

    report, mig = c.handle(DeviceDraining(2), Exec(), now=20.0)
    assert report.mode == "drain" and 2 not in c.last_beat
    states = [s for s, t, _ in c.events if t >= 20.0]
    assert states == ["draining", "migrating", "resuming", "monitoring"]
    # overlapped drain: resuming advances by the re-plan alone
    t2 = {s: t for s, t, _ in c.events if t >= 20.0}
    assert t2["resuming"] == pytest.approx(20.25)
    assert calls[0] == ("admit", "newdev") and ("drain", 2) in calls


def test_controller_rejected_join_returns_to_monitoring():
    class Exec:
        def admit_replan(self, event):
            return AdmissionDecision(False, None, 1.0, 0.99, 0.05, 0.3,
                                     "candidate misses hysteresis margin")

    c = MembershipController([0, 1])
    decision, mig = c.handle(DeviceJoined("newdev"), Exec(), now=5.0)
    assert not decision.accepted and mig is None
    assert [s for s, _, _ in c.events] == [
        "monitoring", "admitting", "rejected", "monitoring"]
    assert c.last_beat == {0: 0.0, 1: 0.0}        # membership unchanged
    assert c.events[-1][1] == pytest.approx(5.3)  # only the pricing work


def test_controller_planned_transitions_require_quiet_state():
    c = MembershipController([0, 1])
    c.heartbeat(0, 5.0)
    c.poll(5.0)                                   # rank 1 now suspect
    assert c.state == "probing"
    with pytest.raises(RuntimeError):
        c.handle(DeviceJoined("newdev"), object(), now=5.0)
    with pytest.raises(RuntimeError):
        c.handle(DeviceEvicted(1), object(), now=5.0)
