"""benchmarks.trend (perf-trend gate) + the churn benchmark's invariants."""

import json
import sys

import pytest

sys.path.insert(0, ".")          # benchmarks/ is a root-level package

from benchmarks.trend import (check, extract_metrics,  # noqa: E402
                              main, sparkline)


def _fault_doc(churn_tput=128.0, tput_light=73.0):
    return {
        "suite": "fig16", "quick": True,
        "records": [
            {"scenario": "drop_dev0", "failed_rank": 0,
             "light_recovery_s": 0.3, "heavy_recovery_s": 5.0,
             "recovery_speedup": 16.7, "tput_light": tput_light,
             "tput_heavy": 85.0, "base_tput": 105.8,
             "boundary_moves": []},
        ],
        "churn": [
            {"event": 0, "kind": "join", "accepted": True, "stall_s": 0.7,
             "recovery_s": 0.7, "within_replay_bound": True,
             "ftpipehd_s": 9.6, "tput_before": 105.8, "tput_after": 158.5},
        ],
        "churn_summary": {
            "n_events": 6, "accepted_joins": 3,
            "base_tput_samples_s": 105.8,
            "churn_tput_samples_s": churn_tput,
            "replay_bound_s": 1.82, "max_recovery_s": 0.69,
            "asteroid_stall_s": 13.6, "ftpipehd_stall_s": 52.0,
            "stall_speedup": 3.8,
        },
    }


def test_extract_metrics_flattens_fault_doc():
    m = extract_metrics(_fault_doc())
    assert m["fig16.tput_light"] == 73.0
    assert m["churn.stall_s"] == 0.7
    assert m["churn_summary.churn_tput_samples_s"] == 128.0
    # booleans and nested lists are not metrics
    assert "churn.within_replay_bound" not in m
    assert "fig16.boundary_moves" not in m


def test_extract_metrics_groups_throughput_records():
    doc = {"suite": "throughput", "quick": True, "records": [
        {"suite": "table4", "tput_samples_s": 120.0, "stages": 4},
        {"suite": "table4", "tput_samples_s": 140.0, "stages": 2},
        {"suite": "fig15a_runtime", "tok_s": 4242.0, "loss": 6.5},
    ]}
    m = extract_metrics(doc)
    assert m["table4.tput_samples_s"] == pytest.approx(130.0)   # mean
    assert m["fig15a_runtime.tok_s"] == 4242.0


def test_extract_metrics_per_model_series():
    """Records carrying a ``model`` key get their own per-model series in
    addition to the suite aggregate, so one architecture's regression
    can't hide in the mean of the others."""
    doc = {"suite": "throughput", "quick": True, "records": [
        {"suite": "async_overlap", "kind": "measured", "model": "phi3_mini",
         "tok_s_sync": 100.0, "measured_gain": 1.0},
        {"suite": "async_overlap", "kind": "measured", "model": "rwkv6",
         "tok_s_sync": 300.0, "measured_gain": 0.9},
        {"suite": "profile_gap", "model": "phi3_mini_4k",
         "planned_on": "measured", "predicted_s": 0.5},
        {"suite": "profile_gap", "predicted_s": 0.7},   # legacy, no model
    ]}
    m = extract_metrics(doc)
    # plain aggregates survive (legacy series keeps its history)
    assert m["async_overlap.tok_s_sync"] == pytest.approx(200.0)
    assert m["profile_gap.predicted_s"] == pytest.approx(0.6)
    # per-model series picked up automatically from the model key
    assert m["async_overlap.phi3_mini.tok_s_sync"] == 100.0
    assert m["async_overlap.rwkv6.tok_s_sync"] == 300.0
    assert m["async_overlap.rwkv6.measured_gain"] == 0.9
    assert m["profile_gap.phi3_mini_4k.predicted_s"] == 0.5
    # the model-less legacy record contributes only to the aggregate —
    # no empty-model group appears
    assert "profile_gap..predicted_s" not in m


def test_check_passes_within_threshold_and_fails_beyond():
    base = extract_metrics(_fault_doc())
    ok = extract_metrics(_fault_doc(churn_tput=128.0 * 0.95))
    bad = extract_metrics(_fault_doc(churn_tput=128.0 * 0.80))
    _, regressions = check([base, base, ok], threshold=0.10)
    assert regressions == []
    _, regressions = check([base, base, bad], threshold=0.10)
    assert any("churn_tput_samples_s" in r for r in regressions)
    # lower-is-better wall times never gate, even when they blow up
    worse = dict(base, **{"churn_summary.asteroid_stall_s": 1e9})
    _, regressions = check([base, worse], threshold=0.10)
    assert regressions == []


def test_check_uses_rolling_median_window():
    base = extract_metrics(_fault_doc())
    spike = extract_metrics(_fault_doc(churn_tput=990.0))
    # one old spike outside the comparison set must not fail the gate
    series = [spike] + [base] * 9 + [base]
    _, regressions = check(series, window=8, threshold=0.10)
    assert regressions == []


def test_sparkline_shape():
    assert len(sparkline([1.0, 2.0, 3.0])) == 3
    assert sparkline([5.0, 5.0]) == "▄▄"


def test_main_exit_codes(tmp_path):
    good = tmp_path / "a.json"
    good.write_text(json.dumps(_fault_doc()))
    bad = tmp_path / "b.json"
    bad.write_text(json.dumps(_fault_doc(churn_tput=60.0, tput_light=30.0)))
    assert main([str(good)]) == 0                      # nothing to compare
    assert main([str(good), str(good)]) == 0
    assert main([str(good), str(good), str(bad)]) == 1
    # unreadable files are skipped, not fatal
    assert main([str(tmp_path / "missing.json"), str(good)]) == 0


def test_churn_benchmark_structure(monkeypatch):
    """The analytic Poisson churn arm: a mid-training join improves
    throughput-under-churn, every event's recovery latency stays within
    the replay bound, and the FTPipeHD full-redistribution baseline pays
    more cumulative stall."""
    import benchmarks.bench_fig16_17_fault as mod

    monkeypatch.setattr(
        mod, "_launch_churn_session",
        lambda **kw: {"sim_tok_s": 1.0, "base_sim_tok_s": 1.0,
                      "join_accepted": True, "latency_before_s": 1.0,
                      "latency_after_s": 1.0})
    rows, records, summary = mod.run_churn_structured(quick=True)
    assert len(records) == summary["n_events"]
    assert summary["accepted_joins"] >= 1
    assert records[0]["kind"] == "join"                # join guaranteed early
    assert summary["churn_tput_samples_s"] > summary["base_tput_samples_s"]
    assert summary["all_within_replay_bound"]
    assert all(r["recovery_s"] <= r["replay_bound_s"] for r in records)
    assert summary["ftpipehd_stall_s"] > summary["asteroid_stall_s"]
    # deterministic under the fixed seed: every plan-derived quantity is
    # bit-exact across runs
    _, records2, summary2 = mod.run_churn_structured(quick=True)
    assert [r["kind"] for r in records2] == [r["kind"] for r in records]
    assert [(r.get("accepted"), r.get("rank"), r["tput_after"])
            for r in records2] == \
           [(r.get("accepted"), r.get("rank"), r["tput_after"])
            for r in records]
    assert summary2["base_tput_samples_s"] == summary["base_tput_samples_s"]
    # the headline throughput folds measured re-plan wall time into the
    # simulated clock, so it is only approximately reproducible: in a
    # long-lived full-suite process a single gen-2 gc pass over the
    # accumulated heap can land inside one of the two runs and shift it
    # past any per-mille tolerance — bound it loosely
    assert summary2["churn_tput_samples_s"] == pytest.approx(
        summary["churn_tput_samples_s"], rel=2e-2)
