"""Live pipeline replay: migration index maps, coordinator state machine,
and analytical/runtime migration reconciliation (pure CPU — the distributed
end-to-end path is tests/test_distributed.py::test_replay_session)."""

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.costmodel import kp_policy
from repro.core.hardware import JETSON_NX, Cluster
from repro.core.lowering import (LoweredPlan, lower_plan, migrate_opt_state,
                                 migrate_params, migration_index,
                                 period_owner, reconcile_migration, relower,
                                 snap_plan)
from repro.core.planner import Plan, StagePlan
from repro.core.profiler import LayerTable, Profile
from repro.core.replay import ReplayCoordinator, lightweight_replay
from repro.models.model import init_model
from repro.optim import AdamW
from repro.runtime.pipeline import arrange_periods


def _lp(stage_periods, n_periods=8):
    P = len(stage_periods)
    return LoweredPlan(arch="t", stage=P, n_micro=4, micro_batch=2,
                       global_batch=8, n_periods=n_periods,
                       stage_periods=stage_periods,
                       stage_layers=tuple((0, 0) for _ in range(P)),
                       device_groups=tuple((p,) for p in range(P)),
                       micro_alloc=tuple((2,) for _ in range(P)),
                       warmup=tuple(kp_policy(P, p) for p in range(P)))


@pytest.fixture(scope="module")
def arranged():
    cfg = get_smoke_config("phi3-mini-3.8b").replace(n_layers=8)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# ---------------------------------------------------------------------------
# migrate_params / migrate_opt_state
# ---------------------------------------------------------------------------


def _arrange(params, lp):
    out = dict(params)
    out["periods"], _ = arrange_periods(params["periods"], lp.stage_periods)
    return out


def test_migration_round_trip_is_identity(arranged):
    """Migrate A -> B -> A returns the arranged stack bit-identically."""
    cfg, params = arranged
    A, B = _lp(((0, 3), (3, 8))), _lp(((0, 6), (6, 8)))
    pA = _arrange(params, A)
    pB, _ = migrate_params(pA, A, B)
    pA2, _ = migrate_params(pB, B, A)
    for a, b in zip(jax.tree.leaves(pA["periods"]),
                    jax.tree.leaves(pA2["periods"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # non-period leaves pass through untouched
    assert pA2["embed"] is pB["embed"] is pA["embed"]


def test_migration_matches_direct_arrangement(arranged):
    """Migrating an arranged stack == arranging the canonical stack."""
    cfg, params = arranged
    A, B = _lp(((0, 4), (4, 8))), _lp(((0, 2), (2, 5), (5, 8)))
    pA = _arrange(params, A)
    pB, _ = migrate_params(pA, A, B)
    direct = _arrange(params, B)
    for a, b in zip(jax.tree.leaves(pB["periods"]),
                    jax.tree.leaves(direct["periods"])):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_opt_state_uses_same_index_map(arranged):
    """Moments follow exactly the index map the params moved through."""
    cfg, params = arranged
    A, B = _lp(((0, 5), (5, 8))), _lp(((0, 3), (3, 8)))
    pA = _arrange(params, A)
    opt = AdamW(lr=1e-3)
    state = opt.init(pA)
    # stamp each moment row with its arranged position to track moves
    m = dict(state.m)
    m["periods"] = jax.tree.map(
        lambda x: (np.arange(x.shape[0], dtype=np.float32)
                   .reshape(-1, *([1] * (x.ndim - 1)))
                   * np.ones_like(np.asarray(x))),
        state.m["periods"])
    state = state._replace(m=m)
    migrated = migrate_opt_state(state, A, B)
    take, mask = migration_index(A, B)
    for leaf in jax.tree.leaves(migrated.m["periods"]):
        arr = np.asarray(leaf)
        for row, (src, keep) in enumerate(zip(take, mask)):
            expect = float(src) if keep else 0.0
            assert np.all(arr[row] == expect), (row, src, keep)
    assert migrated.step is state.step


def test_migration_report_boundary_accounting(arranged):
    cfg, params = arranged
    A, B = _lp(((0, 5), (5, 8))), _lp(((0, 3), (3, 8)))
    pA = _arrange(params, A)
    _, rep = migrate_params(pA, A, B)
    assert rep.moved_periods == (3, 4)
    assert rep.boundary_periods == ((3, 4),)
    assert rep.restored_periods == ()
    assert rep.total_bytes == rep.period_bytes * 2
    assert rep.boundary_bytes[0] == rep.total_bytes
    # restored periods (owner None) are excluded from boundary accounting
    owner = [None if t in (3, 4) else o
             for t, o in enumerate(period_owner(A))]
    _, rep2 = migrate_params(pA, A, B, old_owner=owner)
    assert rep2.restored_periods == (3, 4)
    assert rep2.moved_periods == ()
    assert rep2.boundary_bytes == (0.0,)


# ---------------------------------------------------------------------------
# relower + analytical/runtime reconciliation
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def replayable():
    """A 3-stage plan (one multi-device stage) on a small transformer whose
    table layers == periods (pattern length 1), so cuts align exactly."""
    from repro.models import AttentionConfig, LayerSpec, ModelConfig

    cfg = ModelConfig(name="t", n_layers=12, d_model=64, vocab_size=256,
                      d_ff=128,
                      attn=AttentionConfig(n_heads=2, n_kv_heads=2,
                                           head_dim=32),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=32)
    cluster = Cluster((JETSON_NX,) * 4)
    prof = Profile.analytic(table, cluster, max_batch=16)
    stages = (StagePlan((0, 5), (0, 1), (8, 8), kp_policy(3, 0)),
              StagePlan((5, 10), (2,), (16,), kp_policy(3, 1)),
              StagePlan((10, 14), (3,), (16,), kp_policy(3, 2)))
    plan = Plan("t", stages, (), 16, 4, 1.0)
    return cfg, table, prof, plan


def test_relower_and_reconcile_migration(replayable):
    """End-to-end analytical/runtime agreement: lightweight_replay with
    layer_quantum -> relower -> migrate_params -> reconcile (exact bytes)."""
    cfg, table, prof, plan = replayable
    old_lp = lower_plan(plan, cfg)
    plan = snap_plan(plan, old_lp, table.L)
    rep = lightweight_replay(plan, prof, failed_rank=1, layer_quantum=1)
    assert rep.mode == "lightweight"
    new_lp = relower(old_lp, rep.new_plan, cfg)

    params = init_model(jax.random.PRNGKey(0), cfg)
    pA = dict(params)
    pA["periods"], _ = arrange_periods(params["periods"],
                                       old_lp.stage_periods)
    _, mig = migrate_params(pA, old_lp, new_lp)
    recon = reconcile_migration(mig, rep, new_lp, table, pattern_len=1)
    for rec in recon.values():
        assert rec["table_bytes"] == rec["analytic_bytes"]
    # every analytical boundary move is visible in the reconciliation
    assert set(recon) == {m.boundary for m in rep.boundary_moves}


def test_relower_rejects_structure_changes(replayable):
    import dataclasses

    from repro.core.lowering import LoweringError

    cfg, table, prof, plan = replayable
    old_lp = lower_plan(plan, cfg)
    rep = lightweight_replay(plan, prof, failed_rank=1, layer_quantum=1)
    bad = dataclasses.replace(rep.new_plan, micro_batch=8)
    with pytest.raises(LoweringError):
        relower(old_lp, bad, cfg)
    with pytest.raises(LoweringError):
        relower(old_lp, dataclasses.replace(rep.new_plan, arch="other"), cfg)


def test_snap_plan_reflects_lowered_cuts(replayable):
    cfg, table, prof, plan = replayable
    low = lower_plan(plan, cfg)
    snapped = snap_plan(plan, low, table.L)
    # pattern length 1: period r ends at table layer 1 + r
    for st, (i, j) in zip(snapped.stages, low.stage_periods):
        assert st.layers[1] in (1 + j, table.L)
    assert snapped.stages[0].layers[0] == 0
    assert snapped.stages[-1].layers[1] == table.L


# ---------------------------------------------------------------------------
# ReplayCoordinator
# ---------------------------------------------------------------------------


def test_coordinator_detects_and_recovers():
    c = ReplayCoordinator([0, 1, 2])
    t = 0.0
    while t < 1.0:
        t = round(t + 0.5, 3)
        for r in (0, 1, 2):
            c.heartbeat(r, t)
        assert c.poll(t) is None
    # rank 2 dies at t=1.0; survivors keep beating
    confirmed, detect_t = None, None
    while confirmed is None:
        t = round(t + 0.5, 3)
        for r in (0, 1):
            c.heartbeat(r, t)
        confirmed = c.poll(t)
        if confirmed is not None:
            detect_t = t
    assert confirmed == 2
    # probe fired after the missed deadline, confirmed a probe-timeout later
    assert detect_t - 1.0 >= c.heartbeat_period + c.timeout + c.probe_timeout
    states = [s for s, _, _ in c.events]
    assert states == ["monitoring", "probing", "confirmed"]

    calls = []

    class Exec:
        def replan(self, rank):
            calls.append(("replan", rank))
            from repro.core.replay import RecoveryReport
            return RecoveryReport(1.0, 0.1, 0.2, 0.3, None, "lightweight")

        def migrate(self, report):
            calls.append(("migrate",))
            return "mig"

        def resume(self, report, migration):
            calls.append(("resume", migration))

    report, mig = c.run_recovery(2, Exec(), now=detect_t)
    assert mig == "mig"
    assert calls == [("replan", 2), ("migrate",), ("resume", "mig")]
    assert [s for s, _, _ in c.events] == [
        "monitoring", "probing", "confirmed", "replanning", "migrating",
        "resuming", "monitoring"]
    assert 2 not in c.last_beat
    # recovery timeline is stamped with the report's own component costs
    times = {s: t for s, t, _ in c.events}
    assert times["resuming"] - times["migrating"] == pytest.approx(0.5)


def test_coordinator_probe_answered_resumes_monitoring():
    c = ReplayCoordinator([0, 1], heartbeat_period=0.5, timeout=1.0,
                          probe_timeout=1.0)
    c.heartbeat(0, 3.0)
    assert c.poll(3.0) is None       # rank 1 silent since t=0
    assert c.state == "probing" and c.suspect == 1
    c.heartbeat(1, 3.5)              # the probe is answered in time
    assert c.poll(3.6) is None
    assert c.state == "monitoring" and c.suspect is None


def test_coordinator_requires_confirmation():
    c = ReplayCoordinator([0, 1])
    with pytest.raises(RuntimeError):
        c.run_recovery(1, object())


# ---------------------------------------------------------------------------
# jitted-step cache across replay replans + bounded-staleness session
# ---------------------------------------------------------------------------


def test_replay_reuses_jitted_step_when_spec_unchanged():
    """A lightweight replay whose re-lowered runtime shape (stages, tp,
    n_micro, period split, collapsed allocation) is unchanged must keep the
    compiled step instead of re-jitting — and, under staleness 1, the
    in-flight gradient round is flushed at the recovery barrier."""
    from jax.sharding import Mesh

    from repro.core.hardware import env_d
    from repro.core.planner import plan_hpp
    from repro.data import SyntheticLM
    from repro.runtime.session import PipelineSession

    cfg = get_smoke_config("phi3-mini-3.8b")
    cfg = cfg.replace(n_layers=2 * len(cfg.pattern))
    B, S = 4, 32
    table = LayerTable.from_model_config(cfg, S)
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=B)
    # single-stage plan over the whole edge group: losing one group member
    # re-allocates samples but keeps the runtime shape on a (1, 1) mesh
    plan = plan_hpp(prof, B, micro_batch=2, arch=cfg.name,
                    allowed_stages={1})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    session = PipelineSession(cfg, mesh, plan, prof, backup_every=0,
                              staleness=1)
    assert session.ts.spec.staleness == 1
    session.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg.vocab_size, S)
    for s in range(2):
        session.step(ds.batch(s, B))
    assert session._grad_buf is not None      # a round is in flight
    old_async_step = session.ts.async_step_fn

    st = session.plan.stages[0]
    assert len(st.group) > 1, st
    session.fail(st.group[-1])
    out = session.recover_now()
    assert out.mode == "lightweight"
    assert session._grad_buf is None          # flushed at the barrier
    assert session.step_cache_hits == 1
    assert session.ts.async_step_fn is old_async_step

    loss, _ = session.step(ds.batch(3, B))
    assert np.isfinite(loss)


# ---------------------------------------------------------------------------
# Elastic membership at the session level (single-stage plans on a (1, 1)
# mesh — the multi-stage, layer-moving paths run on 4 host devices in
# examples/elastic_membership.py, driven by test_elastic_membership_example)
# ---------------------------------------------------------------------------


def _membership_session(staleness=0, backup_every=0):
    from jax.sharding import Mesh

    from repro.core.planner import plan_hpp
    from repro.data import SyntheticLM
    from repro.runtime.session import PipelineSession

    cfg = get_smoke_config("phi3-mini-3.8b")
    cfg = cfg.replace(n_layers=2 * len(cfg.pattern))
    B, S = 8, 32
    table = LayerTable.from_model_config(cfg, S)
    prof = Profile.analytic(table, Cluster((JETSON_NX,) * 3, 1e9 / 8),
                            max_batch=B)
    plan = plan_hpp(prof, B, micro_batch=4, arch=cfg.name,
                    allowed_stages={1})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    session = PipelineSession(cfg, mesh, plan, prof,
                              backup_every=backup_every, staleness=staleness)
    session.init(jax.random.PRNGKey(0))
    return cfg, session, SyntheticLM(cfg.vocab_size, S)


def test_session_drain_evict_and_backup_reseed():
    """Planned departures shrink the membership without a restore, and —
    the stale-backup regression — the backup store is re-seeded for the
    NEW arrangement after every transition: once the surviving stage is
    single-device it is backed up; once a join makes it multi-device
    again, the now-stale single-stage key is dropped (DP peers replicate)."""
    from repro.core.hardware import JETSON_TX2

    cfg, session, ds = _membership_session(backup_every=1)
    session.step(ds.batch(0, 8))
    assert not session.store.has(0)           # multi-device stage: DP peers
    out = session.drain(1)
    assert out.accepted and out.report.mode == "drain"
    assert out.report.detection_s == 0.0 and out.report.restore_s == 0.0
    assert out.stall_s == pytest.approx(out.report.replan_s)
    assert session.live_ranks == (0, 2)
    out = session.evict(2)
    assert out.report.mode == "evict"
    assert out.stall_s == pytest.approx(out.report.total_s)
    assert session.live_ranks == (0,)
    # S1: the single-device survivor stage is backed up for the NEW plan
    assert session.store.has(0)
    loss, _ = session.step(ds.batch(1, 8))
    assert np.isfinite(loss)
    # a join widens the stage again: the stale single-device key must go
    out = session.admit(JETSON_TX2, hysteresis=-10.0)
    assert out.accepted
    assert session.live_ranks == (0, 3)       # newcomer appended as rank 3
    assert len(session.profile.cluster.devices) == 4
    assert not session.store.has(0)
    loss, _ = session.step(ds.batch(2, 8))
    assert np.isfinite(loss)
    # crash path still works after the churn (backups track the new plan)
    session.fail(3)
    rec = session.recover_now()
    assert rec.mode in ("lightweight", "heavy")
    assert session.live_ranks == (0,)
    loss, _ = session.step(ds.batch(3, 8))
    assert np.isfinite(loss)
    # each transition was recorded in order
    assert [o.report.mode if o.report else "admission"
            for o in session.memberships] == [
        "drain", "evict", "admission", rec.mode]


def test_session_rejected_join_changes_nothing():
    from repro.core.hardware import JETSON_TX2

    cfg, session, ds = _membership_session()
    plan0, ts0, prof0 = session.plan, session.ts, session.profile
    out = session.admit(JETSON_TX2, hysteresis=0.99)
    assert not out.accepted and out.mode == "admission"
    assert out.decision is not None and not out.decision.accepted
    assert "hysteresis" in out.decision.reason
    assert out.stall_s == pytest.approx(out.decision.replan_s)
    # the incumbent plan, jitted step and profile all survive untouched
    assert session.plan is plan0 and session.ts is ts0
    assert session.profile is prof0
    assert session.live_ranks == (0, 1, 2)
    assert session.memberships[-1] is out
    loss, _ = session.step(ds.batch(0, 8))
    assert np.isfinite(loss)


def test_session_join_evict_round_trip_bit_identical():
    """Acceptance pin: admit a newcomer, then evict it — params AND Adam
    moments come back bit-identical to the pre-join state (migrations are
    pure data movement; no transition may touch a weight)."""
    from repro.core.hardware import A100

    cfg, session, ds = _membership_session()
    for s in range(2):
        session.step(ds.batch(s, 8))
    snap_p = [np.asarray(x).copy() for x in jax.tree.leaves(session.params)]
    snap_m = [np.asarray(x).copy()
              for x in jax.tree.leaves(session.opt_state.m)]
    snap_v = [np.asarray(x).copy()
              for x in jax.tree.leaves(session.opt_state.v)]
    step0 = int(session.opt_state.step)

    out = session.admit(A100, hysteresis=-10.0)
    assert out.accepted
    new_rank = len(session.profile.cluster.devices) - 1
    assert new_rank in session.live_ranks
    out = session.evict(new_rank)
    assert out.accepted and new_rank not in session.live_ranks

    assert int(session.opt_state.step) == step0
    for a, b in zip(snap_p, jax.tree.leaves(session.params)):
        assert np.array_equal(a, np.asarray(b))
    for a, b in zip(snap_m, jax.tree.leaves(session.opt_state.m)):
        assert np.array_equal(a, np.asarray(b))
    for a, b in zip(snap_v, jax.tree.leaves(session.opt_state.v)):
        assert np.array_equal(a, np.asarray(b))
    loss, _ = session.step(ds.batch(2, 8))
    assert np.isfinite(loss)


def test_membership_transition_flushes_stale_gradients():
    """A planned transition is a staleness barrier exactly like a crash
    recovery: the in-flight gradient round applies before the plan swap."""
    cfg, session, ds = _membership_session(staleness=1)
    for s in range(2):
        session.step(ds.batch(s, 8))
    assert session._grad_buf is not None
    out = session.drain(2)
    assert out.accepted
    assert session._grad_buf is None
    loss, _ = session.step(ds.batch(2, 8))
    assert np.isfinite(loss)


def test_install_itself_flushes_inflight_gradients():
    """The membership paths flush at their own barrier, but rapid
    back-to-back re-lowerings (the portfolio probation loop) reach
    ``_install`` directly — the buffer computed under the OLD step's
    sharding/bucketing must be applied by ``_install`` itself, never
    carried across into the new step (the stale-buffer regression).  The
    resulting state must match a twin that flushed explicitly first."""
    cfg, session, ds = _membership_session(staleness=1)
    _, twin, ds2 = _membership_session(staleness=1)
    for s in range(2):
        session.step(ds.batch(s, 8))
        twin.step(ds2.batch(s, 8))
    assert session._grad_buf is not None

    twin.flush_gradients()
    twin._install(twin.plan, twin.lowered)
    session._install(session.plan, session.lowered)   # no explicit flush
    assert session._grad_buf is None                  # _install flushed it

    for ours, theirs in (
            (session.params, twin.params),
            (session.opt_state.m, twin.opt_state.m),
            (session.opt_state.v, twin.opt_state.v)):
        for a, b in zip(jax.tree.leaves(ours), jax.tree.leaves(theirs)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert int(session.opt_state.step) == int(twin.opt_state.step)
    loss, _ = session.step(ds.batch(2, 8))
    assert np.isfinite(loss)


def test_elastic_membership_example():
    """The 4-host-device walkthrough (mid-training join with on-arrival
    profiling, graceful drain with direct streams, hysteresis rejection,
    join->evict bit-identity, crash-after-churn restore) as a subprocess —
    the XLA host-device flag must be set before jax initializes."""
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(root, "examples",
                                      "elastic_membership.py"), "--quick"],
        capture_output=True, text=True, timeout=560, env=env, cwd=root)
    assert proc.returncode == 0, (f"\nstdout:{proc.stdout}\n"
                                  f"stderr:{proc.stderr[-2000:]}")
    assert "ALL OK" in proc.stdout


def test_install_rejits_only_on_spec_change():
    """Re-installing the same lowered plan is a cache hit; a spec-level
    change (e.g. different staleness spec_kw) rebuilds."""
    from jax.sharding import Mesh

    from repro.core.hardware import env_d
    from repro.core.planner import plan_hpp
    from repro.runtime.session import PipelineSession

    cfg = get_smoke_config("phi3-mini-3.8b")
    cfg = cfg.replace(n_layers=2 * len(cfg.pattern))
    table = LayerTable.from_model_config(cfg, 32)
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=4)
    plan = plan_hpp(prof, 4, micro_batch=2, arch=cfg.name, allowed_stages={1})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    session = PipelineSession(cfg, mesh, plan, prof, backup_every=0)
    old = session.ts
    session._install(session.plan, session.lowered)
    assert session.step_cache_hits == 1 and session.ts is old
    session.spec_kw["staleness"] = 1          # spec change -> re-jit
    session._install(session.plan, session.lowered)
    assert session.step_cache_hits == 1 and session.ts is not old
    assert session.ts.async_step_fn is not None
