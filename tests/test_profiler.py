"""Profiler tests: analytic tables, the *real* measurement path (runs jitted
layers on the local device — the same code would profile a Jetson), and the
non-linear batch-efficiency shape from Fig. 6."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hardware import JETSON_NANO, JETSON_NX, Cluster
from repro.core.profiler import (LayerCost, LayerTable, Profile,
                                 measure_layer_times)
from repro.models import AttentionConfig, LayerSpec, ModelConfig


def test_layer_table_from_model_config():
    cfg = ModelConfig(name="t", n_layers=4, d_model=128, vocab_size=1000,
                      d_ff=512,
                      attn=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=32),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=64)
    assert table.L == 6                      # embed + 4 blocks + head
    assert table.layers[0].name == "embed"
    assert table.layers[-1].name == "head"
    # params accounted: blocks sum to ~model total minus embeddings
    block_params = table.param_bytes(1, 5) / 4
    assert block_params == pytest.approx(
        sum(cfg.layer_param_count(s) for s in cfg.pattern) * 4, rel=1e-6)


def test_nonlinear_batch_curve():
    """Fig. 6: time per sample decreases with batch (sub-linear scaling)."""
    dev = JETSON_NANO
    per_sample = [dev.layer_time(1e8, b) / b for b in (1, 4, 16, 64)]
    assert per_sample == sorted(per_sample, reverse=True)
    # ... but total time still increases
    totals = [dev.layer_time(1e8, b) for b in (1, 4, 16, 64)]
    assert totals == sorted(totals)


def test_profile_range_queries_consistent():
    layers = tuple(LayerCost(f"l{i}", 1e8 * (i + 1), 1e6, 1e5)
                   for i in range(5))
    prof = Profile.analytic(LayerTable("t", layers),
                            Cluster((JETSON_NANO, JETSON_NX)), max_batch=8)
    full = prof.t_fwd(0, 4, 0, 5)
    split = prof.t_fwd(0, 4, 0, 2) + prof.t_fwd(0, 4, 2, 5)
    assert full == pytest.approx(split, rel=1e-9)
    assert prof.t_bwd(0, 4, 0, 5) == pytest.approx(2.0 * full, rel=1e-9)
    # the NX (rank 1) is strictly faster
    assert prof.t_fwd(1, 4, 0, 5) < full


def test_measured_profile_path():
    """The real profiler measures jitted layer fns on the local device."""
    d = 64
    w1 = jnp.ones((d, d)) * 0.01
    w2 = jnp.ones((d, d)) * 0.01
    fns = [lambda x: jnp.tanh(x @ w1), lambda x: jnp.tanh(x @ w2)]
    tf, tb = measure_layer_times(fns, lambda beta, li: jnp.ones((beta, d)),
                                 batch_sizes=(1, 4), repeats=2)
    assert tf.shape == (2, 2) and tb.shape == (2, 2)
    assert (tf > 0).all() and (tb > 0).all()
    # feed the measured samples into a Profile — the sweep covered batches
    # {1, 4}, so interpolate the intermediate rows first: Profile.measured
    # rejects all-zero rows (a zero row means a failed measurement, and
    # would price that batch size as free)
    layers = tuple(LayerCost(f"l{i}", 1e6, 1e4, 1e3) for i in range(2))
    samples_f = np.zeros((1, 5, 2))
    samples_b = np.zeros((1, 5, 2))
    for b in (1, 2, 3, 4):
        w = (b - 1) / 3.0
        samples_f[0, b] = (1 - w) * tf[0] + w * tf[1]
        samples_b[0, b] = (1 - w) * tb[0] + w * tb[1]
    prof = Profile.measured(LayerTable("m", layers), Cluster((JETSON_NANO,)),
                            4, samples_f, samples_b)
    assert prof.t_fwd(0, 1, 0, 2) == pytest.approx(tf[0].sum(), rel=1e-6)
