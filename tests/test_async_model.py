"""Two-stream (async 1F1B) cost model: schedule enumeration, overlapped
round latency, simulator staleness/serialization modes, planner knob."""

import dataclasses

import pytest

from repro.core.costmodel import (Step, exec_phase_latency, hpp_round_latency,
                                  max_allreduce, round_latency,
                                  round_latency_async,
                                  round_latency_serialized,
                                  unhidden_allreduce)
from repro.core.hardware import MBPS_100, env_b, env_d
from repro.core.planner import Plan, plan_hpp
from repro.core.profiler import Profile
from repro.core.schedule import (comm_stream, scan_ticks, schedule_orders,
                                 two_stream_orders)
from repro.core.simulator import reprice_plan, simulate
from repro.configs.paper_models import PAPER_MODELS


def _steps(ta=(0.3, 0.2), comm=0.05):
    """Two exec steps with AllReduce phases, one comm step between."""
    return (Step("exec", 1.0, 2.0, ta[0], (0,), (0, 2), (2,)),
            Step("comm", comm, comm),
            Step("exec", 1.1, 2.1, ta[1], (1,), (2, 4), (2,)))


# ---------------------------------------------------------------------------
# schedule enumeration
# ---------------------------------------------------------------------------


def test_two_stream_orders_counts():
    P, M = 3, 5
    compute, comm = two_stream_orders(P, M, staleness=1)
    assert compute == schedule_orders(P, M)
    for p in range(P):
        sends = [o for o in comm[p] if o.kind == "S"]
        recvs = [o for o in comm[p] if o.kind == "R"]
        ars = [o for o in comm[p] if o.kind == "A"]
        assert len(sends) == (M if p < P - 1 else 0)
        assert len(recvs) == (M if p > 0 else 0)
        assert len(ars) == 1 and comm[p][-1].kind == "A"
        # sends follow compute completion order: micro indices of F ops
        f_order = [o.micro for o in compute[p] if o.kind == "F"]
        if p < P - 1:
            assert [o.micro for o in sends] == f_order


def test_comm_stream_sync_has_no_allreduce_op():
    order = schedule_orders(2, 4)[0]
    assert all(o.kind != "A" for o in comm_stream(order, 0, 2, staleness=0))


def test_scan_ticks():
    assert scan_ticks(4, 8) == 11                    # M + P - 1
    assert scan_ticks(4, 8, double_buffer=True) == 14   # M + 2(P-1)
    assert scan_ticks(1, 8) == scan_ticks(1, 8, True) == 8


# ---------------------------------------------------------------------------
# overlapped round latency
# ---------------------------------------------------------------------------


def test_async_latency_is_max_of_exec_and_allreduce():
    steps, M = _steps(), 4
    assert round_latency_async(steps, M) == pytest.approx(
        max(exec_phase_latency(steps, M), max_allreduce(steps)))
    # small AllReduce: fully hidden, async == pure execution phase
    assert unhidden_allreduce(steps, M) == 0.0
    # huge AllReduce: charged only for the part exceeding the round
    big = tuple(dataclasses.replace(s, ta=100.0) if s.kind == "exec" else s
                for s in steps)
    assert round_latency_async(big, M) == pytest.approx(100.0)
    assert unhidden_allreduce(big, M) == pytest.approx(
        100.0 - exec_phase_latency(steps, M))


def test_latency_ordering_async_le_sync_le_serialized():
    for ta in ((0.0, 0.0), (0.3, 0.2), (5.0, 1.0)):
        for comm in (0.0, 0.05, 2.0):
            steps = _steps(ta, comm)
            for M in (1, 4, 16):
                a = round_latency_async(steps, M)
                s = round_latency(steps, M)
                z = round_latency_serialized(steps, M)
                assert a <= s * (1 + 1e-12), (ta, comm, M)
                assert s <= z * (1 + 1e-12), (ta, comm, M)


def test_hpp_round_latency_dispatch():
    steps, M = _steps(), 4
    assert hpp_round_latency(steps, M, 0) == round_latency(steps, M)
    assert hpp_round_latency(steps, M, 1) == round_latency_async(steps, M)


def test_serialized_merges_comm_into_downstream_stage():
    steps = _steps(ta=(0.0, 0.0), comm=0.5)
    # one-stream: the comm cost rides the second exec step's per-micro time
    M = 8
    merged = (Step("exec", 1.0, 2.0, 0.0), Step("exec", 1.6, 2.6, 0.0))
    assert round_latency_serialized(steps, M) == pytest.approx(
        round_latency(merged, M))


# ---------------------------------------------------------------------------
# simulator two-stream modes
# ---------------------------------------------------------------------------


def _small_plan(staleness=0):
    table = PAPER_MODELS["bert-small"]()
    prof = Profile.analytic(table, env_b(MBPS_100).sorted_by_memory(),
                            max_batch=32)
    return plan_hpp(prof, 32, 8, allowed_stages={2},
                    staleness=staleness), prof


def test_simulate_staleness_hides_allreduce():
    plan, prof = _small_plan()
    sync = simulate(plan, prof)                     # plan.staleness == 0
    asy = simulate(plan, prof, staleness=1)
    assert asy.makespan <= sync.makespan + 1e-12
    assert asy.makespan == pytest.approx(
        max(asy.exec_span_s, asy.allreduce_s))
    assert asy.allreduce_s > 0                      # 2-stage: replicated groups
    assert asy.hidden_comm_s >= 0
    assert sync.staleness == 0 and asy.staleness == 1
    # exec spans agree: staleness changes only the AllReduce charging
    assert asy.exec_span_s == pytest.approx(sync.exec_span_s)


def test_simulate_defaults_to_plan_staleness():
    plan, prof = _small_plan(staleness=1)
    assert plan.staleness == 1
    assert simulate(plan, prof).staleness == 1


def test_simulate_serialize_p2p_is_slower():
    plan, prof = _small_plan()
    overlapped = simulate(plan, prof)
    serialized = simulate(plan, prof, serialize_p2p=True)
    assert serialized.makespan >= overlapped.makespan
    assert serialized.exec_span_s > overlapped.exec_span_s


# ---------------------------------------------------------------------------
# planner knob
# ---------------------------------------------------------------------------


def test_plan_hpp_staleness_never_worse():
    table = PAPER_MODELS["bert-small"]()
    prof = Profile.analytic(table, env_b(MBPS_100).sorted_by_memory(),
                            max_batch=32)
    sync = plan_hpp(prof, 32, 8)
    asy = plan_hpp(prof, 32, 8, staleness=1)
    assert asy.latency <= sync.latency * (1 + 1e-12)
    assert sync.staleness == 0 and asy.staleness == 1


def test_plan_default_staleness_back_compat():
    assert Plan("x", (), (), 1, 1, 0.0).staleness == 0


def test_reprice_preserves_staleness():
    plan, prof = _small_plan(staleness=1)
    rp = reprice_plan(plan, prof)
    assert rp.staleness == 1
    assert rp.latency == pytest.approx(
        round_latency_async(rp.steps, rp.n_micro))


def test_plan_hpp_auto_offload_never_worse():
    table = PAPER_MODELS["bert-small"]()
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=32)
    full = plan_hpp(prof, 32, 8, intra_opt=True)
    base = plan_hpp(prof, 32, 8, intra_opt=False)
    auto = plan_hpp(prof, 32, 8, intra_opt="auto")
    assert auto.latency <= min(full.latency, base.latency) * (1 + 1e-12)
    if auto.latency >= base.latency * (1 - 1e-9):
        # no strict predicted gain: auto must have dropped Phase 2
        assert auto.stages == base.stages
