"""Decode (serve_step) vs full-forward teacher-forcing parity.

For every architecture family, step-by-step decoding with the KV/state cache
must reproduce the full-sequence forward logits.  MoE archs use a capacity
factor large enough that no token drops (capacity-based dropping is the one
legitimate train/decode divergence)."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models.model import (_head_weight, decode_step, init_decode_states,
                                init_model, model_forward)
from repro.models.norms import rmsnorm

ARCHS = ["phi3-mini-3.8b", "gemma-2b", "gemma2-2b", "rwkv6-7b",
         "jamba-1.5-large-398b", "deepseek-v3-671b", "phi3.5-moe-42b-a6.6b",
         "musicgen-large", "internvl2-2b", "deepseek-7b"]

B, S = 2, 32


def _full_logits(params, tokens, cfg):
    h, _, _ = model_forward(params, tokens, cfg, remat=False)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps, cfg.zero_centered_norm)
    if cfg.n_codebooks > 1:
        logits = jnp.stack([h @ _head_weight(params, cfg, cb)
                            for cb in range(cfg.n_codebooks)], axis=2)
    else:
        logits = h @ _head_weight(params, cfg)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    key = jax.random.PRNGKey(1)
    cfg = get_smoke_config(arch).replace(prefix_len=0, mtp_depth=0)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params = init_model(key, cfg)
    shape = (B, cfg.n_codebooks, S) if cfg.n_codebooks > 1 else (B, S)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)

    full = _full_logits(params, tokens, cfg)

    states = init_decode_states(B, S, cfg)
    step = jax.jit(lambda p, t, pos, st: decode_step(p, t, pos, st, cfg))
    outs = []
    for t in range(S):
        tok = tokens[:, :, t] if cfg.n_codebooks > 1 else tokens[:, t]
        logits, states = step(params, tok, jnp.int32(t), states)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)

    err = float(jnp.max(jnp.abs(dec - full.astype(dec.dtype))))
    assert err < 2e-3, f"{arch}: decode/forward mismatch {err}"


def test_sliding_window_ring_cache():
    """Gemma2-style local layer: ring cache must equal a full cache + mask."""
    key = jax.random.PRNGKey(2)
    cfg = get_smoke_config("gemma2-2b").replace(prefix_len=0)
    params = init_model(key, cfg)
    S_long = 96  # > smoke window of 64 so eviction actually happens
    tokens = jax.random.randint(key, (B, S_long), 0, cfg.vocab_size)

    full = _full_logits(params, tokens, cfg)

    states = init_decode_states(B, S_long, cfg)
    step = jax.jit(lambda p, t, pos, st: decode_step(p, t, pos, st, cfg))
    for t in range(S_long):
        logits, states = step(params, tokens[:, t], jnp.int32(t), states)
    err = float(jnp.max(jnp.abs(logits - full[:, -1].astype(logits.dtype))))
    assert err < 2e-3, f"ring-cache mismatch at final position: {err}"
