"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting output shapes and finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.frontend import stub_prefix_embeddings
from repro.models.model import init_model, loss_fn, model_forward

B, S = 2, 64


def make_batch(key, cfg, batch=B, seq=S):
    shape = (batch, cfg.n_codebooks, seq) if cfg.n_codebooks > 1 else (batch, seq)
    batch_d = {"tokens": jax.random.randint(key, shape, 0, cfg.vocab_size)}
    if cfg.prefix_len:
        batch_d["prefix"] = stub_prefix_embeddings(key, batch, cfg)
    return batch_d


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_config_reduced(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512
    assert cfg.n_layers <= max(2, len(cfg.pattern))
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    # reduced config stays in the same family: same pattern kinds
    full = get_config(arch)
    assert [s.kind for s in cfg.pattern] == [s.kind for s in full.pattern]
    assert [s.mlp for s in cfg.pattern] == [s.mlp for s in full.pattern]


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_smoke_config(arch)
    params = init_model(key, cfg)
    batch = make_batch(key, cfg)
    h, aux, positions = model_forward(params, batch["tokens"], cfg,
                                      prefix=batch.get("prefix"), remat=False)
    S_total = S + cfg.prefix_len
    assert h.shape == (B, S_total, cfg.d_model)
    assert bool(jnp.isfinite(h).all()), f"{arch}: non-finite activations"
    assert bool(jnp.isfinite(aux)), f"{arch}: non-finite aux loss"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step(arch, key):
    cfg = get_smoke_config(arch)
    params = init_model(key, cfg)
    batch = make_batch(key, cfg)

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda q: loss_fn(q, b, cfg), has_aux=True)(p)
        new_p = jax.tree.map(lambda w, g: w - 1e-3 * g, p, grads)
        return loss, metrics, new_p

    loss, metrics, new_params = step(params, batch)
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert float(loss) > 0
    for leaf in jax.tree.leaves(new_params):
        assert bool(jnp.isfinite(leaf).all()), f"{arch}: non-finite updated params"
    # a second step must reduce nothing structurally (shapes stable)
    assert jax.tree.structure(new_params) == jax.tree.structure(params)


def test_full_configs_param_counts():
    """Analytic parameter counts match the published model sizes."""
    expected = {
        "phi3.5-moe-42b-a6.6b": (42e9, 0.05),
        "gemma-2b": (2.5e9, 0.06),
        "rwkv6-7b": (7.6e9, 0.10),
        "jamba-1.5-large-398b": (398e9, 0.05),
        "phi3-mini-3.8b": (3.8e9, 0.05),
        "deepseek-v3-671b": (671e9, 0.08),   # all-MoE simplification adds ~4%
        "internvl2-2b": (1.9e9, 0.10),
        "deepseek-7b": (7e9, 0.05),
        "gemma2-2b": (2.6e9, 0.06),
    }
    for arch, (target, tol) in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - target) / target < tol, f"{arch}: {got/1e9:.2f}B vs {target/1e9:.0f}B"


def test_active_params_phi35():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    active = cfg.active_param_count()
    assert abs(active - 6.6e9) / 6.6e9 < 0.05
