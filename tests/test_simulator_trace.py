"""Simulator trace-level invariants (beyond the makespan checks)."""

import pytest

from repro.core.hardware import env_d
from repro.core.planner import plan_hpp
from repro.core.profiler import LayerTable, Profile
from repro.core.simulator import simulate
from repro.models import AttentionConfig, LayerSpec, ModelConfig


@pytest.fixture(scope="module")
def sim_setup():
    cfg = ModelConfig(name="t", n_layers=8, d_model=256, vocab_size=8000,
                      d_ff=1024,
                      attn=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=64),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=128)
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=32)
    plan = plan_hpp(prof, 64, 8, arch="t")
    return prof, plan


def test_trace_completeness(sim_setup):
    """Every (stage, micro) runs exactly one F and one B, F before B."""
    prof, plan = sim_setup
    res = simulate(plan, prof, policy="ours")
    P, M = len(plan.stages), plan.n_micro
    seen = {}
    for t0, t1, stage, op in res.trace:
        assert t1 >= t0
        seen.setdefault((stage, op), []).append((t0, t1))
    for p in range(P):
        for m in range(M):
            assert len(seen[(p, f"F{m}")]) == 1
            assert len(seen[(p, f"B{m}")]) == 1
            assert seen[(p, f"F{m}")][0][1] <= seen[(p, f"B{m}")][0][0]


def test_trace_causality_across_stages(sim_setup):
    """Micro m cannot start on stage p+1 before finishing on stage p."""
    prof, plan = sim_setup
    res = simulate(plan, prof, policy="ours")
    start = {}
    end = {}
    for t0, t1, stage, op in res.trace:
        if op.startswith("F"):
            start[(stage, int(op[1:]))] = t0
            end[(stage, int(op[1:]))] = t1
    P, M = len(plan.stages), plan.n_micro
    for p in range(P - 1):
        for m in range(M):
            assert start[(p + 1, m)] >= end[(p, m)]


def test_no_stage_overlap(sim_setup):
    """A stage's device group executes one op at a time."""
    prof, plan = sim_setup
    res = simulate(plan, prof, policy="ours")
    by_stage = {}
    for t0, t1, stage, op in res.trace:
        by_stage.setdefault(stage, []).append((t0, t1))
    for stage, spans in by_stage.items():
        spans.sort()
        for (a0, a1), (b0, b1) in zip(spans, spans[1:]):
            assert b0 >= a1 - 1e-12


def test_gpipe_policy_no_interleave(sim_setup):
    """Under the gpipe policy, every F on a stage precedes every B."""
    prof, plan = sim_setup
    res = simulate(plan, prof, policy="gpipe")
    for stage in range(len(plan.stages)):
        ops = sorted((t0, op) for t0, t1, s, op in res.trace if s == stage)
        first_b = next(i for i, (_, op) in enumerate(ops) if op.startswith("B"))
        assert all(op.startswith("B") for _, op in ops[first_b:])
