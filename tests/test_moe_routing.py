"""MoE routing invariants (single-device semantics + properties)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the 'test' extra")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.models.moe import MoEConfig, init_moe, moe
from repro.models.mlp import ACTIVATIONS


def setup_moe(key, d=64, e=8, k=2, cap=8.0, score="softmax", shared=0):
    cfg = MoEConfig(n_experts=e, top_k=k, d_ff=128, capacity_factor=cap,
                    score_fn=score, n_shared_experts=shared)
    params = init_moe(key, d, cfg)
    return cfg, params


def test_moe_output_shape_and_finite():
    key = jax.random.PRNGKey(0)
    cfg, params = setup_moe(key)
    x = jax.random.normal(key, (4, 16, 64)) * 0.5
    out, aux = moe(params, x, cfg, cfg.n_experts)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(aux))


def test_moe_no_drop_equals_dense_mixture():
    """With unbounded capacity, the MoE output equals the explicit top-k
    weighted mixture of expert MLPs."""
    key = jax.random.PRNGKey(1)
    cfg, params = setup_moe(key, cap=64.0)
    x = jax.random.normal(key, (2, 8, 64)) * 0.5
    out, _ = moe(params, x, cfg, cfg.n_experts)

    x2 = x.reshape(-1, 64)
    logits = x2 @ params["router"]
    scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(scores, cfg.top_k)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    ex = params["experts"]
    act = ACTIVATIONS[cfg.act]

    def expert(e_idx, rows):
        h = act(rows @ ex["gate"][e_idx]) * (rows @ ex["up"][e_idx])
        return h @ ex["down"][e_idx]

    ref = jnp.zeros_like(x2)
    for i in range(x2.shape[0]):
        acc = sum(top_w[i, j] * expert(top_e[i, j], x2[i][None])[0]
                  for j in range(cfg.top_k))
        ref = ref.at[i].set(acc)
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 64)), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)


def test_moe_capacity_drops_tokens():
    """Tiny capacity must reduce the output norm (dropped tokens emit 0)."""
    key = jax.random.PRNGKey(2)
    cfg_full, params = setup_moe(key, cap=64.0)
    cfg_tight = dataclasses.replace(cfg_full, capacity_factor=0.25)
    x = jax.random.normal(key, (2, 32, 64)) * 0.5
    out_full, _ = moe(params, x, cfg_full, cfg_full.n_experts)
    out_tight, _ = moe(params, x, cfg_tight, cfg_tight.n_experts)
    assert float(jnp.linalg.norm(out_tight)) < float(jnp.linalg.norm(out_full))


def test_moe_sigmoid_scores_and_shared_expert():
    key = jax.random.PRNGKey(3)
    cfg, params = setup_moe(key, score="sigmoid", shared=1)
    assert "shared" in params
    x = jax.random.normal(key, (2, 8, 64)) * 0.5
    out, aux = moe(params, x, cfg, cfg.n_experts)
    assert bool(jnp.isfinite(out).all())


@given(e=st.sampled_from([4, 8]), k=st.integers(1, 3),
       t=st.sampled_from([8, 32]))
@settings(max_examples=8, deadline=None)
def test_moe_grad_finite_property(e, k, t):
    key = jax.random.PRNGKey(e * 10 + k)
    cfg, params = setup_moe(key, e=e, k=min(k, e))
    x = jax.random.normal(key, (1, t, 64)) * 0.5

    def loss(p):
        out, aux = moe(p, x, cfg, cfg.n_experts)
        return jnp.sum(out ** 2) + aux

    grads = jax.grad(loss)(params)
    for g in jax.tree.leaves(grads):
        assert bool(jnp.isfinite(g).all())
