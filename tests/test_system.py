"""End-to-end behaviour tests for the Asteroid system (paper-level claims
validated on the simulator/planner; heavy distributed paths are covered by
test_distributed.py)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_models import PAPER_MODELS
from repro.core.hardware import env_b, env_c, env_d
from repro.core.planner import (auto_microbatch, plan_dp, plan_gpipe,
                                plan_hetpipe_hdp)
from repro.core.profiler import Profile
from repro.core.replay import heavy_rescheduling, lightweight_replay
from repro.core.simulator import simulate


@pytest.fixture(scope="module")
def effnet_env_c():
    prof = Profile.analytic(PAPER_MODELS["efficientnet-b1"](),
                            env_c().sorted_by_memory(), max_batch=64)
    plan = auto_microbatch(prof, 2048, arch="efficientnet-b1")
    return prof, plan


def test_paper_claim_hpp_beats_dp_and_pp(effnet_env_c):
    """Table 4: Asteroid outperforms DP and PP on heterogeneous edge envs."""
    prof, plan = effnet_env_c
    dp = plan_dp(prof, 2048, plan.micro_batch)
    pp = plan_gpipe(prof, 2048, plan.micro_batch)
    assert plan.latency < dp.latency
    assert plan.latency < pp.latency


def test_paper_claim_hdp_volume_exceeds_hpp():
    """Table 2: HetPipe-style HDP moves more bytes than a volume-lean HPP."""
    prof = Profile.analytic(PAPER_MODELS["resnet50"](),
                            env_b().sorted_by_memory(), max_batch=32)
    plan = auto_microbatch(prof, 256, arch="resnet50")
    _, v_hdp = plan_hetpipe_hdp(prof, 256, plan.micro_batch)
    assert v_hdp > plan.comm_volume(prof)


def test_paper_claim_memory_within_budget(effnet_env_c):
    """No OOM: the plan respects every device's memory budget (Fig. 13 x)."""
    prof, plan = effnet_env_c
    sim = simulate(plan, prof, policy="ours")
    for d, m in sim.peak_mem.items():
        assert m <= prof.cluster.devices[d].mem_bytes


def test_paper_claim_1f1b_memory(effnet_env_c):
    """Fig. 15b: ours-K_p minimizes peak memory vs neighbor policies."""
    prof, plan = effnet_env_c
    mems = {p: simulate(plan, prof, policy=p).max_peak_mem
            for p in ("ours", "a", "c", "gpipe")}
    assert mems["ours"] <= min(mems["a"], mems["c"], mems["gpipe"]) * 1.001


def test_paper_claim_lightweight_recovery(effnet_env_c):
    """Fig. 16/17: replay recovers much faster at comparable throughput."""
    prof, plan = effnet_env_c
    fail = plan.stages[-1].group[0]
    light = lightweight_replay(plan, prof, fail)
    heavy = heavy_rescheduling(plan, prof, fail, replan_compute_scale=8.0)
    light_rec = light.total_s - light.detection_s
    heavy_rec = heavy.total_s - heavy.detection_s
    assert heavy_rec > 2.0 * light_rec
    assert light.new_plan.throughput > 0.5 * heavy.new_plan.throughput


def test_simulator_validates_dominant_step(effnet_env_c):
    """Eq. 4-6 estimate agrees with the event-accurate execution."""
    prof, plan = effnet_env_c
    sim = simulate(plan, prof, policy="ours")
    assert sim.makespan == pytest.approx(plan.latency, rel=0.3)


def test_scalability_monotone():
    """Fig. 18: throughput grows with cluster size under Asteroid."""
    from repro.core.hardware import JETSON_NANO, Cluster
    table = PAPER_MODELS["mobilenetv2"]()
    prev = 0.0
    for n in (1, 2, 4, 8):
        prof = Profile.analytic(table, Cluster((JETSON_NANO,) * n), max_batch=64)
        plan = auto_microbatch(prof, 32 * n, arch="mobilenetv2")
        assert plan.throughput > prev
        prev = plan.throughput
