"""Substrate tests: data pipeline, optimizer, checkpointing."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.data import SyntheticLM, delay_pattern
from repro.optim import AdamW, SGD, cosine_schedule, global_norm


def test_synthetic_lm_deterministic_and_resumable():
    ds = SyntheticLM(vocab_size=512, seq_len=32, seed=3)
    a = ds.batch(7, 4)["tokens"]
    b = SyntheticLM(vocab_size=512, seq_len=32, seed=3).batch(7, 4)["tokens"]
    np.testing.assert_array_equal(a, b)
    c = ds.batch(8, 4)["tokens"]
    assert not np.array_equal(a, c)


def test_synthetic_lm_learnable_structure():
    """Bigram structure: successors must be concentrated (learnable)."""
    ds = SyntheticLM(vocab_size=128, seq_len=256, seed=0)
    toks = ds.batch(0, 8)["tokens"]
    # count distinct successors of the most common token
    from collections import Counter, defaultdict
    succ = defaultdict(Counter)
    for row in toks:
        for a, b in zip(row[:-1], row[1:]):
            succ[int(a)][int(b)] += 1
    biggest = max(succ, key=lambda k: sum(succ[k].values()))
    top4 = sum(v for _, v in succ[biggest].most_common(4))
    total = sum(succ[biggest].values())
    assert top4 / total > 0.5   # >50% of transitions in 4 successors


def test_delay_pattern():
    toks = np.arange(2 * 3 * 8).reshape(2, 3, 8).astype(np.int32)
    out = delay_pattern(toks, pad_id=-1)
    np.testing.assert_array_equal(out[:, 0], toks[:, 0])       # cb0: no delay
    assert (out[:, 1, 0] == -1).all()                          # cb1: shift 1
    np.testing.assert_array_equal(out[:, 1, 1:], toks[:, 1, :-1])
    assert (out[:, 2, :2] == -1).all()                         # cb2: shift 2


def test_adamw_decreases_quadratic():
    opt = AdamW(lr=0.1)
    params = {"w": jnp.ones((8,)) * 3.0}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": 2 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_sgd_momentum_decreases_quadratic():
    opt = SGD(lr=0.05, momentum=0.9)
    params = {"w": jnp.ones((8,)) * 3.0}
    state = opt.init(params)
    for _ in range(60):
        params, state = opt.update({"w": 2 * params["w"]}, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_grad_clip():
    opt = AdamW(lr=0.0, grad_clip=1.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    huge = {"w": jnp.full((4,), 1e6)}
    _, new_state = opt.update(huge, state, params)
    assert float(global_norm(new_state.m)) < 1.0  # clipped before moments


def test_cosine_schedule_shape():
    f = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(f(jnp.int32(0))) == 0.0
    assert float(f(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-3)
    assert float(f(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.int32(7)}}
    with tempfile.TemporaryDirectory() as d:
        checkpoint.save(d, "ckpt", tree)
        like = jax.tree.map(jnp.zeros_like, tree)
        restored = checkpoint.restore(d, "ckpt", like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_stage_backup_store():
    store = checkpoint.StageBackupStore()
    params = {"w": jnp.ones((4, 4))}
    store.backup(2, params)
    assert store.has(2) and not store.has(0)
    restored = store.restore(2)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(params["w"]))
    assert store.bytes_transferred == 64


def test_zero_moment_shardings_avoid_duplicate_axes():
    """ZeRO-1 moment specs must not reuse an axis the param already uses."""
    import os
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    from repro.runtime.train import _zero_moment_shardings

    devs = np.array(jax.devices()[:1]).reshape(1, 1, 1, 1)
    mesh = Mesh(devs, ("pod", "data", "stage", "tp"))
    params = {
        "expert": jnp.zeros((4, 8, 8)),    # already data-sharded (EP)
        "dense": jnp.zeros((8, 8)),        # replicated over dp
        "tiny": jnp.zeros((3,)),           # indivisible
    }
    shardings = {
        "expert": NamedSharding(mesh, P("data", None, "tp")),
        "dense": NamedSharding(mesh, P(None, "tp")),
        "tiny": NamedSharding(mesh, P(None)),
    }
    out = _zero_moment_shardings(params, shardings)
    for name, sh in out.items():
        seen = []
        for entry in sh.spec:
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                if ax is not None:
                    assert ax not in seen, (name, sh.spec)
                    seen.append(ax)
