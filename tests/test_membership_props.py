"""Property tests: elastic-membership transitions are pure data movement.

Two levels, both pinning the same invariant — **no sequence of membership
transitions may touch a weight or an Adam moment**:

* migration chains (fast, heavily randomized): arbitrary sequences of
  stage re-splits ``A -> X1 -> ... -> A`` move the arranged period stack
  and stamped optimizer moments around and must hand every row back
  bit-identically (uses hypothesis when installed, seeded ``random``
  chains otherwise — same test body either way);
* live sessions (seeded): random join/drain/evict/fail sequences driven
  through ``PipelineSession`` between training steps leave params + Adam
  moments bit-identical to a never-churned twin trained on the same
  batches, and the pipeline still trains afterwards.
"""

import random

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.core.costmodel import kp_policy
from repro.core.hardware import A100, JETSON_NX, JETSON_TX2, Cluster
from repro.core.lowering import (LoweredPlan, migrate_opt_state,
                                 migrate_params, period_positions)
from repro.core.profiler import LayerTable, Profile
from repro.models.model import init_model
from repro.optim import AdamW
from repro.runtime.pipeline import arrange_periods

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as hst
    HAVE_HYPOTHESIS = True
except ImportError:          # container without hypothesis: seeded fallback
    HAVE_HYPOTHESIS = False

N_PERIODS = 8


def _lp(stage_periods):
    P = len(stage_periods)
    return LoweredPlan(arch="t", stage=P, n_micro=4, micro_batch=2,
                       global_batch=8, n_periods=N_PERIODS,
                       stage_periods=tuple(stage_periods),
                       stage_layers=tuple((0, 0) for _ in range(P)),
                       device_groups=tuple((p,) for p in range(P)),
                       micro_alloc=tuple((2,) for _ in range(P)),
                       warmup=tuple(kp_policy(P, p) for p in range(P)))


def _split_from_cuts(cuts) -> tuple:
    pts = sorted({0, N_PERIODS, *cuts})
    return tuple((a, b) for a, b in zip(pts, pts[1:]))


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("phi3-mini-3.8b").replace(n_layers=N_PERIODS)
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _check_chain(model, cut_sets) -> None:
    """Migrate the arranged stack + stamped moments through every split in
    the chain and back to the start; everything must return bit-identical."""
    cfg, params = model
    start = _split_from_cuts(cut_sets[0])
    lps = [_lp(_split_from_cuts(c)) for c in cut_sets[1:]]
    A = _lp(start)
    pA = dict(params)
    pA["periods"], _ = arrange_periods(params["periods"], A.stage_periods)
    state = AdamW(lr=1e-3).init(pA)
    # stamp each moment row with its arranged position so moves are visible
    m = dict(state.m)
    m["periods"] = jax.tree.map(
        lambda x: (np.arange(x.shape[0], dtype=np.float32)
                   .reshape(-1, *([1] * (x.ndim - 1)))
                   * np.ones_like(np.asarray(x))),
        state.m["periods"])
    state = state._replace(m=m)
    stamp = [np.asarray(x).copy() for x in jax.tree.leaves(state.m["periods"])]

    cur_p, cur_s, cur_lp = pA, state, A
    for lp in [*lps, A]:
        cur_p, _ = migrate_params(cur_p, cur_lp, lp)
        cur_s = migrate_opt_state(cur_s, cur_lp, lp)
        cur_lp = lp
    # compare the rows real periods live in (stage padding is don't-care)
    pos = period_positions(A)
    rows = [pos[t] for t in range(N_PERIODS)]
    for a, b in zip(jax.tree.leaves(pA["periods"]),
                    jax.tree.leaves(cur_p["periods"])):
        a, b = np.asarray(a), np.asarray(b)
        for r in rows:
            assert np.array_equal(a[r], b[r])
    for a, b in zip(stamp, jax.tree.leaves(cur_s.m["periods"])):
        b = np.asarray(b)
        for r in rows:
            assert np.array_equal(a[r], b[r])
    assert cur_s.step is state.step


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.function_scoped_fixture])
    @given(cut_sets=hst.lists(
        hst.sets(hst.integers(1, N_PERIODS - 1), max_size=3),
        min_size=2, max_size=5))
    def test_random_migration_chain_round_trips(model, cut_sets):
        _check_chain(model, cut_sets)
else:
    @pytest.mark.parametrize("seed", range(8))
    def test_random_migration_chain_round_trips(model, seed):
        rng = random.Random(seed)
        cut_sets = [set(rng.sample(range(1, N_PERIODS), rng.randint(0, 3)))
                    for _ in range(rng.randint(2, 5))]
        _check_chain(model, cut_sets)


# ---------------------------------------------------------------------------
# live sessions: random event sequences vs a never-churned twin
# ---------------------------------------------------------------------------

_B, _S = 8, 32
_STEPS_BEFORE = 2
_JOINERS = (JETSON_TX2, JETSON_NX, A100)


def _make_session():
    from jax.sharding import Mesh

    from repro.core.planner import plan_hpp
    from repro.runtime.session import PipelineSession

    cfg = get_smoke_config("phi3-mini-3.8b")
    cfg = cfg.replace(n_layers=2 * len(cfg.pattern))
    table = LayerTable.from_model_config(cfg, _S)
    prof = Profile.analytic(table, Cluster((JETSON_NX,) * 3, 1e9 / 8),
                            max_batch=_B)
    plan = plan_hpp(prof, _B, micro_batch=4, arch=cfg.name,
                    allowed_stages={1})
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    session = PipelineSession(cfg, mesh, plan, prof, backup_every=1)
    session.init(jax.random.PRNGKey(0))
    return cfg, session


def _leaves(session):
    return ([np.asarray(jax.device_get(x)).copy()
             for x in jax.tree.leaves(session.params)],
            [np.asarray(jax.device_get(x)).copy()
             for x in jax.tree.leaves(session.opt_state.m)],
            [np.asarray(jax.device_get(x)).copy()
             for x in jax.tree.leaves(session.opt_state.v)])


@pytest.fixture(scope="module")
def never_churned_twin():
    """The reference state: same init, same batches, zero membership
    events."""
    from repro.data import SyntheticLM

    cfg, session = _make_session()
    ds = SyntheticLM(cfg.vocab_size, _S)
    for s in range(_STEPS_BEFORE):
        session.step(ds.batch(s, _B))
    return _leaves(session)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_random_event_sequences_preserve_state(never_churned_twin, seed):
    from repro.data import SyntheticLM

    cfg, session = _make_session()
    ds = SyntheticLM(cfg.vocab_size, _S)
    for s in range(_STEPS_BEFORE):
        session.step(ds.batch(s, _B))

    rng = random.Random(seed)
    n_events = rng.randint(3, 5)
    applied = []
    for _ in range(n_events):
        live = list(session.live_ranks)
        kinds = []
        if len(live) < 4:                      # keep the DP group feedable
            kinds.append("join")
        if len(live) > 1:
            kinds += ["drain", "evict", "fail"]
        kind = rng.choice(kinds)
        if kind == "join":
            out = session.admit(rng.choice(_JOINERS), hysteresis=-10.0)
            assert out.accepted, out.decision.reason
        elif kind == "fail":
            session.fail(rng.choice(live))
            out = session.recover_now()
        elif kind == "drain":
            out = session.drain(rng.choice(live))
        else:
            out = session.evict(rng.choice(live))
        applied.append((kind, out.mode))
    assert len(session.memberships) == n_events, applied

    # the churn was pure data movement: bit-identical to the twin
    churned = _leaves(session)
    for ours, theirs in zip(churned, never_churned_twin):
        assert len(ours) == len(theirs)
        for a, b in zip(ours, theirs):
            assert np.array_equal(a, b), applied

    # and the surviving membership still trains
    loss, _ = session.step(ds.batch(_STEPS_BEFORE, _B))
    assert np.isfinite(loss), applied
