"""Plan -> runtime lowering: round-trip invariants and simulator
consistency (pure-python; the jax end-to-end path is covered by
tests/test_distributed.py::test_train_planned_lowering)."""

import dataclasses

import numpy as np
import pytest

from repro.core.costmodel import kp_policy
from repro.core.hardware import env_b, env_d
from repro.core.lowering import (LoweredPlan, LoweringError,
                                 check_against_simulator, lower_micro_alloc,
                                 lower_plan)
from repro.core.planner import plan_gpipe, plan_hpp
from repro.core.profiler import LayerTable, Profile
from repro.core.schedule import max_inflight, schedule_orders
from repro.core.simulator import simulate
from repro.data import pack_batch, pack_indices
from repro.models import AttentionConfig, LayerSpec, ModelConfig


@pytest.fixture(scope="module")
def setup():
    cfg = ModelConfig(name="t", n_layers=8, d_model=256, vocab_size=8000,
                      d_ff=1024,
                      attn=AttentionConfig(n_heads=4, n_kv_heads=4, head_dim=64),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=128)
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=32)
    plan = plan_hpp(prof, 64, 8, arch="t")
    return cfg, prof, plan


def test_round_trip_invariants(setup):
    cfg, prof, plan = setup
    low = lower_plan(plan, cfg)
    P = len(plan.stages)
    n_periods = cfg.n_layers // len(cfg.pattern)

    # stage period ranges partition [0, n_periods)
    assert low.stage_periods[0][0] == 0
    assert low.stage_periods[-1][1] == n_periods
    for (a, b), (c, d) in zip(low.stage_periods[:-1], low.stage_periods[1:]):
        assert b == c and a < b and c < d

    # allocations: per-stage sums = micro-batch; rounds cover the batch
    for alloc in low.micro_alloc:
        assert sum(alloc) == low.micro_batch
    assert low.n_micro * low.micro_batch == plan.global_batch == low.global_batch

    # warm-up depths come from the schedule policy and match the plan
    assert low.warmup == tuple(kp_policy(P, p) for p in range(P))
    assert low.warmup == tuple(st.k_p for st in plan.stages)

    # runtime tick counts
    assert low.forward_ticks == low.n_micro + P - 1
    assert low.total_ticks == 2 * low.forward_ticks


def test_lowered_orders_match_schedule(setup):
    cfg, prof, plan = setup
    low = lower_plan(plan, cfg)
    assert low.orders() == schedule_orders(low.stage, low.n_micro, "ours")
    assert low.peak_inflight() == tuple(
        max_inflight(o) for o in low.orders())
    # 1F1B bounds resident activations by K_p; GPipe by M
    assert all(i <= min(max(1, k), low.n_micro)
               for i, k in zip(low.peak_inflight(), low.warmup))
    assert all(i == low.n_micro for i in low.peak_inflight("gpipe"))


def test_simulator_consistency(setup):
    cfg, prof, plan = setup
    # asserts: per-stage op counts, unit-cost makespan == tick_makespan,
    # peak in-flight == min(max(1, K_p), M), Eq. 3 memory bound
    sim = check_against_simulator(lower_plan(plan, cfg), plan, prof)
    assert sim.makespan > 0


def test_gpipe_ticks_equal_runtime_scan(setup):
    """The runtime executes a GPipe-ordered scan: M + P - 1 forward ticks
    and the grad-reversed backward, 2(M + P - 1) in total — the unit-cost
    GPipe schedule has the same makespan."""
    cfg, prof, plan = setup
    low = lower_plan(plan, cfg)
    assert low.tick_makespan("gpipe") == low.total_ticks


def test_memory_bound_tracks_simulator(setup):
    cfg, prof, plan = setup
    low = lower_plan(plan, cfg)
    sim = simulate(plan, prof)
    bound = low.memory_bound(prof)
    assert set(bound) == set(sim.peak_mem)
    for d in bound:
        assert sim.peak_mem[d] <= bound[d] * (1 + 1e-6)


def test_infeasible_mesh_raises(setup):
    cfg, prof, plan = setup
    P = len(plan.stages)
    bad_axis = P + 1 if P > 1 else 3       # never divisible by P... unless P=1
    if P == 1:
        pytest.skip("single-stage plan divides everything")
    assert bad_axis % P != 0
    with pytest.raises(LoweringError):
        lower_plan(plan, cfg, model_axis=bad_axis)


def test_too_many_stages_raises(setup):
    cfg, prof, plan = setup
    small = cfg.replace(n_layers=1)        # 1 period < plan stages
    if len(plan.stages) == 1:
        pytest.skip("single-stage plan fits any model")
    with pytest.raises(LoweringError):
        lower_plan(plan, small)


def test_warmup_mismatch_raises(setup):
    cfg, prof, plan = setup
    if len(plan.stages) == 1:
        pytest.skip("K_p is trivially 1")
    bad = dataclasses.replace(
        plan, stages=tuple(
            dataclasses.replace(st, k_p=st.k_p + 1) for st in plan.stages))
    with pytest.raises(LoweringError):
        lower_plan(bad, cfg)


def _lp_alloc(micro_alloc, micro_batch):
    """Minimal LoweredPlan carrying only allocation structure."""
    P = len(micro_alloc)
    return LoweredPlan(
        arch="t", stage=P, n_micro=4, micro_batch=micro_batch,
        global_batch=4 * micro_batch, n_periods=P,
        stage_periods=tuple((p, p + 1) for p in range(P)),
        stage_layers=tuple((0, 0) for _ in range(P)),
        device_groups=tuple(tuple(range(len(a))) for a in micro_alloc),
        micro_alloc=tuple(tuple(a) for a in micro_alloc),
        warmup=tuple(kp_policy(P, p) for p in range(P)))


def test_lower_micro_alloc_direct_and_blocks():
    # group size == dp: exact
    assert lower_micro_alloc(_lp_alloc([(3, 1), (3, 1)], 4), 2) == (3, 1)
    # group larger than dp: contiguous device blocks aggregate
    assert lower_micro_alloc(_lp_alloc([(2, 1, 1)], 4), 2) == (2, 2)
    assert lower_micro_alloc(_lp_alloc([(4, 1, 1, 0)], 6), 2) == (5, 1)
    # group smaller than dp: a device's share splits across its shards
    assert lower_micro_alloc(_lp_alloc([(5,)], 5), 2) == (3, 2)
    assert lower_micro_alloc(_lp_alloc([(4, 2)], 6), 4) == (2, 2, 1, 1)


def test_lower_micro_alloc_disagreeing_stages():
    # disagreeing stages: largest-remainder rounding of the mean, still
    # summing to the micro-batch
    out = lower_micro_alloc(_lp_alloc([(4, 0), (2, 2)], 4), 2)
    assert sum(out) == 4 and out == (3, 1)
    out = lower_micro_alloc(_lp_alloc([(3, 1), (1, 3)], 4), 2)
    assert sum(out) == 4 and out == (2, 2)
    # agreement after projection collapses exactly
    assert lower_micro_alloc(_lp_alloc([(2, 2), (2, 1, 1)], 4), 2) == (2, 2)


def test_lower_micro_alloc_sum_preserved():
    # explicit cases; the hypothesis suite fuzzes this in
    # tests/test_allocation_props.py
    for allocs, dp in [
            ([(7, 3, 2), (6, 4, 2)], 4),
            ([(1, 1, 1)], 2),
            ([(5, 0), (0, 5)], 3),
    ]:
        mb = sum(allocs[0])
        out = lower_micro_alloc(_lp_alloc(allocs, mb), dp)
        assert len(out) == dp and sum(out) == mb and min(out) >= 0


def test_pack_batch_round_trip():
    """Every input sample appears exactly once at its indexed slot; padding
    slots are zero; valid counts match the allocation."""
    alloc, M = (3, 1), 4
    mb, b_max = sum(alloc), max(alloc)
    B = M * mb
    batch = {"tokens": np.arange(B * 5, dtype=np.int32).reshape(B, 5) + 1}
    out = pack_batch(batch, alloc, M)
    idx, valid = pack_indices(alloc, M)
    assert out["tokens"].shape == (len(alloc) * M * b_max, 5)
    assert valid.sum() == B
    got = out["tokens"].reshape(len(alloc), M, b_max, 5)
    seen = []
    for d in range(len(alloc)):
        for m in range(M):
            for b in range(b_max):
                if valid[d, m, b]:
                    assert (got[d, m, b] == batch["tokens"][idx[d, m, b]]).all()
                    seen.append(idx[d, m, b])
                else:
                    assert (got[d, m, b] == 0).all()
    assert sorted(seen) == list(range(B))
    # micro-batch m draws exactly from input rows [m*mb, (m+1)*mb)
    for m in range(M):
        rows = sorted(idx[d, m, b] for d in range(len(alloc))
                      for b in range(b_max) if valid[d, m, b])
        assert rows == list(range(m * mb, (m + 1) * mb))


def test_pack_batch_rejects_bad_shapes():
    with pytest.raises(ValueError):
        pack_batch({"tokens": np.zeros((7, 2))}, (3, 1), 2)
    with pytest.raises(ValueError):
        pack_indices((0, 0), 2)


def test_eq8_stale_steps_raise(setup):
    """check_against_simulator rejects a plan whose step times went stale
    against its allocations (Eq. 8 consistency)."""
    cfg, prof, plan = setup
    low = lower_plan(plan, cfg)
    steps = tuple(
        dataclasses.replace(s, ef=s.ef * 1.5) if s.kind == "exec" else s
        for s in plan.steps)
    bad = dataclasses.replace(plan, steps=steps)
    with pytest.raises(AssertionError):
        check_against_simulator(low, bad, prof)


def test_simulator_device_busy_scales_with_allocation(setup):
    """Per-device busy time is M * (t_f + t_b) at the device's allocated
    sample count, bounded by the stage's lockstep busy time."""
    cfg, prof, plan = setup
    sim = simulate(plan, prof)
    M = plan.n_micro
    assert set(sim.device_busy) == {d for st in plan.stages for d in st.group}
    for p, st in enumerate(plan.stages):
        i, j = st.layers
        for d, y in zip(st.group, st.alloc):
            t_dev = M * (prof.t_fwd(d, y, i, j) + prof.t_bwd(d, y, i, j))
            assert sim.device_busy[d] == pytest.approx(t_dev)
            assert sim.device_busy[d] <= sim.stage_busy[p] * (1 + 1e-9)
            assert 0.0 <= sim.device_util(d) <= 1.0


def test_heterogeneous_cluster_envs(setup):
    """Lowering holds across planners and environments."""
    cfg, _, _ = setup
    table = LayerTable.from_model_config(cfg, seq_len=128)
    for env in (env_b, env_d):
        prof = Profile.analytic(table, env().sorted_by_memory(), max_batch=32)
        for mk in (lambda: plan_hpp(prof, 64, 8, arch="t"),
                   lambda: plan_gpipe(prof, 64, 8, arch="t", n_stages=2)):
            plan = mk()
            low = lower_plan(plan, cfg)
            check_against_simulator(low, plan, prof)
