"""Tests for the analysis stack: loop-aware jaxpr costs, HLO collective
parsing, roofline construction."""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.analysis.hlo import _shape_bytes, collective_bytes
from repro.analysis.jaxpr_cost import (Cost, collective_payload, cost_of_fn,
                                       jaxpr_cost)
from repro.analysis.roofline import Roofline, from_record, model_flops


def test_dot_flops_exact():
    f = lambda a, b: a @ b
    c = cost_of_fn(f, jax.ShapeDtypeStruct((64, 128), jnp.float32),
                   jax.ShapeDtypeStruct((128, 32), jnp.float32))
    assert c.flops == 2 * 64 * 128 * 32


def test_scan_multiplies_trip_count():
    def scanned(a, b):
        def body(c, _):
            return jnp.tanh(c @ b), None
        c, _ = lax.scan(body, a, None, length=10)
        return c

    one = cost_of_fn(lambda a, b: jnp.tanh(a @ b),
                     jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 64), jnp.float32))
    ten = cost_of_fn(scanned, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert ten.flops == pytest.approx(10 * one.flops, rel=1e-6)


def test_nested_scan():
    def nested(a):
        def outer(c, _):
            def inner(d, _):
                return d * 2.0, None
            d, _ = lax.scan(inner, c, None, length=5)
            return d, None
        c, _ = lax.scan(outer, a, None, length=3)
        return c

    c = cost_of_fn(nested, jax.ShapeDtypeStruct((8,), jnp.float32))
    assert c.flops == 3 * 5 * 8   # 15 multiplies of 8 elements


def test_collective_payload_factors():
    assert collective_payload("psum", 100, 1) == 0.0           # trivial axis
    assert collective_payload("psum", 100, 4) == pytest.approx(150.0)
    assert collective_payload("all_to_all", 100, 4) == pytest.approx(75.0)
    assert collective_payload("ppermute", 100, 4) == 100.0


def test_grad_includes_backward():
    f = lambda a, b: jnp.sum(a @ b)
    g = jax.grad(f)
    c_f = cost_of_fn(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 64), jnp.float32))
    c_g = cost_of_fn(g, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                     jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert c_g.flops >= 2 * c_f.flops * 0.9   # bwd of matmul ~= 2x fwd


def test_hlo_shape_bytes():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[4]") == 16
    assert _shape_bytes("pred[8]") == 8


def test_roofline_from_record():
    rec = {"arch": "a", "shape": "train_4k", "mesh": "16x16", "kind": "train",
           "n_devices": 256, "tokens_global": 256 * 4096,
           "active_params": 1e9,
           "jcost": {"flops": 1e13, "bytes": 1e12, "collective_bytes": 1e10}}
    r = from_record(rec)
    assert r.compute_s == pytest.approx(1e13 / 197e12)
    assert r.memory_s == pytest.approx(1e12 / 819e9)
    assert r.collective_s == pytest.approx(1e10 / 50e9)
    assert r.dominant == "memory"
    # model flops: 6 * 1e9 * (256*4096/256)
    assert r.model_flops_per_device == pytest.approx(6e9 * 4096)


def test_model_flops_train_vs_infer():
    assert model_flops(1e9, 100, True) == 3 * model_flops(1e9, 100, False)
