"""Vocab-parallel embed/CE: single-shard semantics must equal plain jnp
(the multi-shard path is covered by launch/dist_selftest)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.module import NO_PARALLEL
from repro.runtime.vocab_parallel import vp_chunked_ce, vp_embed


def test_vp_embed_single_shard():
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (100, 16))
    ids = jnp.array([[0, 5, 99], [7, 7, 1]])
    out = vp_embed(table, ids, NO_PARALLEL)
    np.testing.assert_allclose(np.asarray(out), np.asarray(table[ids]),
                               atol=1e-6)


@pytest.mark.parametrize("chunk", [3, 7, 16, 100])
def test_vp_ce_matches_plain(chunk):
    key = jax.random.PRNGKey(1)
    B, S, D, V = 2, 13, 16, 50
    h = jax.random.normal(key, (B, S, D)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (D, V)) * 0.1
    tgt = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    mask = (jax.random.uniform(jax.random.fold_in(key, 3), (B, S)) > 0.3)
    loss, cnt = vp_chunked_ce(h, w, tgt, mask.astype(jnp.float32),
                              NO_PARALLEL, chunk=chunk)

    logits = (h @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    ref = ((lse - gold) * mask).sum()
    assert float(cnt) == float(mask.sum())
    np.testing.assert_allclose(float(loss), float(ref), rtol=1e-5)


def test_vp_ce_vocab_padding_mask():
    """Padded vocab columns must not affect the loss (v_valid masking)."""
    key = jax.random.PRNGKey(2)
    B, S, D, V = 2, 8, 16, 50
    h = jax.random.normal(key, (B, S, D)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (D, V)) * 0.1
    w_pad = jnp.pad(w, ((0, 0), (0, 14)))  # pad with zero columns
    tgt = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    mask = jnp.ones((B, S), jnp.float32)
    l_ref, _ = vp_chunked_ce(h, w, tgt, mask, NO_PARALLEL)
    l_pad, _ = vp_chunked_ce(h, w_pad, tgt, mask, NO_PARALLEL, v_valid=V)
    np.testing.assert_allclose(float(l_pad), float(l_ref), rtol=1e-6)


def test_vp_ce_softcap():
    key = jax.random.PRNGKey(3)
    B, S, D, V = 1, 4, 8, 20
    h = jax.random.normal(key, (B, S, D)) * 2.0
    w = jax.random.normal(jax.random.fold_in(key, 1), (D, V)) * 0.5
    tgt = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0, V)
    mask = jnp.ones((B, S), jnp.float32)
    cap = 5.0
    loss, _ = vp_chunked_ce(h, w, tgt, mask, NO_PARALLEL, softcap=cap)
    logits = cap * jnp.tanh((h @ w).astype(jnp.float32) / cap)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    np.testing.assert_allclose(float(loss), float((lse - gold).sum()), rtol=1e-5)
