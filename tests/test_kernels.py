"""Pallas kernel correctness: shape/dtype sweeps + hypothesis properties,
asserting allclose against the pure-jnp oracles (interpret=True on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis",
                    reason="property tests need the 'test' extra")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_swiglu import fused_swiglu
from repro.kernels.ref import (naive_attention, naive_decode, naive_swiglu,
                               naive_wkv6)
from repro.kernels.rwkv6_wkv import rwkv6_wkv


def rand(key, shape, dtype=jnp.float32, scale=0.5):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_CASES = [
    # (BH, BHkv, S, D, window, softcap, bq, bk, dtype)
    (4, 4, 256, 64, None, None, 128, 128, jnp.float32),
    (8, 2, 192, 64, None, None, 64, 64, jnp.float32),     # GQA, ragged S
    (4, 1, 256, 128, 64, None, 128, 64, jnp.float32),     # MQA + window
    (2, 2, 128, 64, None, 50.0, 64, 128, jnp.float32),    # softcap
    (2, 2, 160, 64, None, None, 64, 64, jnp.bfloat16),    # bf16, ragged
    (2, 2, 64, 32, 32, 30.0, 32, 32, jnp.float32),        # window + cap
]


@pytest.mark.parametrize("case", ATTN_CASES)
def test_flash_attention_matches_ref(case):
    BH, BHkv, S, D, win, cap, bq, bk, dtype = case
    key = jax.random.PRNGKey(0)
    q = rand(key, (BH, S, D), dtype)
    k = rand(jax.random.fold_in(key, 1), (BHkv, S, D), dtype)
    v = rand(jax.random.fold_in(key, 2), (BHkv, S, D), dtype)
    out = flash_attention(q, k, v, window=win, softcap=cap, block_q=bq,
                          block_k=bk, interpret=True)
    ref = naive_attention(q, k, v, window=win, softcap=cap)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


@given(s_blocks=st.integers(1, 4), d_pow=st.integers(5, 7),
       heads=st.sampled_from([(2, 1), (4, 2), (4, 4)]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(s_blocks, d_pow, heads):
    BH, BHkv = heads
    S, D = 64 * s_blocks, 2 ** d_pow
    key = jax.random.PRNGKey(s_blocks * 100 + d_pow)
    q = rand(key, (BH, S, D))
    k = rand(jax.random.fold_in(key, 1), (BHkv, S, D))
    v = rand(jax.random.fold_in(key, 2), (BHkv, S, D))
    out = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    ref = naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (4, 4, 512, 64, None, 128, jnp.float32),
    (8, 2, 1024, 64, None, 256, jnp.float32),
    (4, 1, 512, 128, 128, 128, jnp.float32),   # windowed
    (2, 2, 384, 64, None, 128, jnp.bfloat16),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("fill", [0.3, 1.0])
def test_flash_decode_matches_ref(case, fill):
    BH, BHkv, S, D, win, bk, dtype = case
    key = jax.random.PRNGKey(1)
    q = rand(key, (BH, D), dtype)
    k = rand(jax.random.fold_in(key, 1), (BHkv, S, D), dtype)
    v = rand(jax.random.fold_in(key, 2), (BHkv, S, D), dtype)
    clen = jnp.int32(max(1, int(S * fill)))
    out = flash_decode(q, k, v, clen, window=win, block_k=bk, interpret=True)
    ref = naive_decode(q, k, v, clen, window=win)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# RWKV6 WKV
# ---------------------------------------------------------------------------

WKV_CASES = [
    (2, 128, 64, 32),
    (4, 256, 64, 64),
    (1, 64, 32, 16),
    (2, 192, 64, 64),
]


@pytest.mark.parametrize("case", WKV_CASES)
def test_rwkv6_wkv_matches_ref(case):
    BH, S, d, chunk = case
    key = jax.random.PRNGKey(2)
    r = rand(key, (BH, S, d))
    k = rand(jax.random.fold_in(key, 1), (BH, S, d))
    v = rand(jax.random.fold_in(key, 2), (BH, S, d))
    # decay in (0, 1) matching the model's clamped parameterization
    logit = jax.random.uniform(jax.random.fold_in(key, 3), (BH, S, d),
                               minval=-6.0, maxval=0.0)
    w = jnp.exp(-jnp.exp(logit))
    u = rand(jax.random.fold_in(key, 4), (BH, d), scale=0.3)
    out = rwkv6_wkv(r, k, v, w, u, chunk=chunk, interpret=True)
    ref = naive_wkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-4,
                               rtol=3e-4)


@given(chunk_pow=st.integers(4, 6), n_chunks=st.integers(1, 3))
@settings(max_examples=6, deadline=None)
def test_rwkv6_wkv_chunk_invariance(chunk_pow, n_chunks):
    """The chunked kernel must be invariant to the chunk size."""
    chunk = 2 ** chunk_pow
    S = chunk * n_chunks
    BH, d = 2, 32
    key = jax.random.PRNGKey(chunk + S)
    r = rand(key, (BH, S, d))
    k = rand(jax.random.fold_in(key, 1), (BH, S, d))
    v = rand(jax.random.fold_in(key, 2), (BH, S, d))
    w = jnp.exp(-jnp.exp(jax.random.uniform(jax.random.fold_in(key, 3),
                                            (BH, S, d), minval=-5.0, maxval=0.0)))
    u = rand(jax.random.fold_in(key, 4), (BH, d), scale=0.3)
    a = rwkv6_wkv(r, k, v, w, u, chunk=chunk, interpret=True)
    b = naive_wkv6(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4, rtol=3e-4)


def test_wkv_kernel_matches_model_layer():
    """The kernel agrees with the XLA-native rwkv chunked path (_wkv_chunk)."""
    from repro.models.rwkv import _wkv_chunk
    B, H, S, d = 1, 2, 64, 32
    key = jax.random.PRNGKey(5)
    shape = (B, H, S, d)
    r = rand(key, shape)
    k = rand(jax.random.fold_in(key, 1), shape)
    v = rand(jax.random.fold_in(key, 2), shape)
    w = jnp.exp(-jnp.exp(jax.random.uniform(jax.random.fold_in(key, 3), shape,
                                            minval=-5.0, maxval=0.0)))
    u = rand(jax.random.fold_in(key, 4), (H, d), scale=0.3)
    s0 = jnp.zeros((B, H, d, d), jnp.float32)
    out_model, _ = _wkv_chunk(r, k, v, w, u, s0)          # (B, H, S, d)
    out_kernel = rwkv6_wkv(r.reshape(B * H, S, d), k.reshape(B * H, S, d),
                           v.reshape(B * H, S, d), w.reshape(B * H, S, d),
                           jnp.tile(u, (B, 1)), chunk=S, interpret=True)
    np.testing.assert_allclose(np.asarray(out_model.reshape(B * H, S, d)),
                               np.asarray(out_kernel), atol=3e-4, rtol=3e-4)


# ---------------------------------------------------------------------------
# fused swiglu
# ---------------------------------------------------------------------------

SWIGLU_CASES = [
    (128, 64, 256, 128, 128, "silu", jnp.float32),
    (256, 128, 512, 128, 256, "silu", jnp.float32),
    (128, 64, 256, 64, 128, "gelu_tanh", jnp.float32),
    (128, 64, 512, 128, 256, "silu", jnp.bfloat16),
]


@pytest.mark.parametrize("case", SWIGLU_CASES)
def test_fused_swiglu_matches_ref(case):
    T, D, F, bm, bf, act, dtype = case
    key = jax.random.PRNGKey(3)
    x = rand(key, (T, D), dtype)
    wg = rand(jax.random.fold_in(key, 1), (D, F), dtype, scale=0.1)
    wu = rand(jax.random.fold_in(key, 2), (D, F), dtype, scale=0.1)
    wd = rand(jax.random.fold_in(key, 3), (F, D), dtype, scale=0.1)
    out = fused_swiglu(x, wg, wu, wd, block_m=bm, block_f=bf, act=act,
                       interpret=True)
    ref = naive_swiglu(x, wg, wu, wd, act)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------

from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.ref import naive_mamba_scan

MAMBA_CASES = [
    (2, 128, 64, 16, 64),
    (1, 256, 128, 16, 128),
    (2, 64, 32, 8, 32),
]


@pytest.mark.parametrize("case", MAMBA_CASES)
def test_mamba_scan_matches_ref(case):
    B, S, d, N, chunk = case
    key = jax.random.PRNGKey(7)
    dt = jax.nn.softplus(rand(key, (B, S, d)))
    b = rand(jax.random.fold_in(key, 1), (B, S, N))
    c = rand(jax.random.fold_in(key, 2), (B, S, N))
    x = rand(jax.random.fold_in(key, 3), (B, S, d))
    a = -jnp.exp(rand(jax.random.fold_in(key, 4), (d, N), scale=0.2))
    out = mamba_scan(dt, b, c, x, a, chunk=chunk, interpret=True)
    ref = naive_mamba_scan(dt, b, c, x, a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_mamba_scan_matches_model_layer():
    """Kernel agrees with the XLA-native associative-scan path."""
    from repro.models.ssm import MambaConfig, _chunk_scan
    B, S, d, N = 1, 64, 32, 8
    key = jax.random.PRNGKey(8)
    dt = jax.nn.softplus(rand(key, (B, S, d)))
    b = rand(jax.random.fold_in(key, 1), (B, S, N))
    c = rand(jax.random.fold_in(key, 2), (B, S, N))
    x = rand(jax.random.fold_in(key, 3), (B, S, d))
    a = -jnp.exp(rand(jax.random.fold_in(key, 4), (d, N), scale=0.2))
    # model path: one chunk of the associative scan
    decay = jnp.exp(dt[..., None] * a)
    contrib = (dt * x)[..., None] * b[:, :, None, :]
    states, _ = _chunk_scan(jnp.zeros((B, d, N)), decay, contrib)
    y_model = jnp.einsum("bcdn,bcn->bcd", states, c)
    y_kernel = mamba_scan(dt, b, c, x, a, chunk=S, interpret=True)
    np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_model),
                               atol=2e-4, rtol=2e-4)


# ---------------------------------------------------------------------------
# quantized transfers (tile-exact parity; full suite in test_quant_transfer)
# ---------------------------------------------------------------------------


@given(rows=st.integers(1, 24), tile_pow=st.integers(4, 8),
       fmt=st.sampled_from(["int8", "fp8"]),
       scale=st.sampled_from([1e-3, 1.0, 1e3]))
@settings(max_examples=16, deadline=None)
def test_quantize_tiles_property(rows, tile_pow, fmt, scale):
    """Kernel(interpret) is bitwise-identical to the jnp oracle for any
    row count / tile size / dynamic range, and the round trip stays within
    the per-format bound."""
    from repro.kernels.quant_transfer import (dequantize_tiles,
                                              quantize_tiles)
    from repro.kernels.ref import (naive_dequantize_tiles,
                                   naive_quantize_tiles)
    T = 2 ** tile_pow
    x = rand(jax.random.PRNGKey(rows * T), (rows, T), scale=scale)
    qk, sk = quantize_tiles(x, fmt=fmt, interpret=True)
    qr, sr = naive_quantize_tiles(x, fmt=fmt)
    assert np.array_equal(np.asarray(qk, np.float32),
                          np.asarray(qr, np.float32))
    assert np.array_equal(np.asarray(sk), np.asarray(sr))
    dk = dequantize_tiles(qk, sk, interpret=True)
    assert np.array_equal(np.asarray(dk),
                          np.asarray(naive_dequantize_tiles(qr, sr)))
    tol = 0.02 if fmt == "int8" else 0.06
    rel = (np.linalg.norm(np.asarray(dk) - np.asarray(x))
           / max(np.linalg.norm(np.asarray(x)), 1e-30))
    assert rel < tol, (fmt, rel)
