"""Serve-mode planner (DESIGN.md §11): decode pricing, the M/M/1 latency
objective, admission-control memory caps, and the plan_serve vs
plan_serve_uniform p99 ordering on a heterogeneous cluster."""

import math

import pytest

from repro.core.costmodel import (decode_boundary_bytes, decode_step_time,
                                  queue_wait_quantile, serve_latency_quantile,
                                  serve_stage_slots, slot_cache_bytes)
from repro.core.hardware import (Cluster, DeviceProfile, JETSON_NX,
                                 JETSON_TX2, MBPS_100)
from repro.core.planner import (AllocationError, plan_serve,
                                plan_serve_uniform, serve_stage_candidates)
from repro.core.profiler import LayerCost, LayerTable, Profile
from repro.core.simulator import reprice_serve_plan, serve_prediction_gap


def _table(L=8, param=1e6, act=1e4):
    layers = tuple(LayerCost(f"l{i}", 1e8, param, act) for i in range(L))
    return LayerTable("toy", layers)


def _hetero_profile(seq=128, max_batch=32):
    cluster = Cluster((JETSON_NX,) * 2 + (JETSON_TX2,) * 2,
                      bandwidth=MBPS_100)
    return Profile.analytic(_table(), cluster, max_batch)


# ---------------------------------------------------------------------------
# stage candidates (the pick_serve_stage divisor fix)
# ---------------------------------------------------------------------------


def test_stage_candidates_are_divisors():
    assert serve_stage_candidates(4, 8) == [1, 2, 4]
    # 6-wide model axis: the legacy {1,2,4,8,16} probe missed 3 and 6
    assert serve_stage_candidates(6, 4) == [3, 6]
    assert serve_stage_candidates(6, 12) == [1, 2, 3, 6]
    # odd head count: only tp=1 works
    assert serve_stage_candidates(4, 3) == [4]


def test_stage_candidates_every_axis_feasible():
    # stage=model_axis (tp=1) is always a candidate -> never empty
    for axis in range(1, 9):
        for heads in (1, 3, 7, 8):
            cands = serve_stage_candidates(axis, heads)
            assert cands, (axis, heads)
            for s in cands:
                assert axis % s == 0
                assert heads % (axis // s) == 0


# ---------------------------------------------------------------------------
# decode pricing units
# ---------------------------------------------------------------------------


def test_decode_step_time_is_per_token_slice():
    prof = _hetero_profile()
    seq = 128
    full = prof.t_fwd(0, 4, 0, prof.table.L)
    assert decode_step_time(prof, 0, 4, 0, prof.table.L, seq) == \
        pytest.approx(full / seq)
    with pytest.raises(ValueError):
        decode_step_time(prof, 0, 4, 0, prof.table.L, 0)


def test_decode_boundary_bytes_scale_with_batch():
    t = _table(act=1e4)
    one = decode_boundary_bytes(t, 4, 1, 128)
    assert one == pytest.approx(1e4 / 128)
    assert decode_boundary_bytes(t, 4, 6, 128) == pytest.approx(6 * one)


def test_slot_cache_and_admission_cap():
    t = _table(L=4, param=1e6, act=1e4)
    per_slot = slot_cache_bytes(t, 0, 4, cache_len=64, seq_len=128)
    assert per_slot == pytest.approx(4 * 1e4 / 128 * 64)
    # memory sized so that 0.9 * mem == params + 10 cache slots
    mem = (4 * 1e6 + 10 * per_slot) / 0.9
    assert serve_stage_slots(t, 0, 4, mem, 64, 128) == 10
    # params alone exhaust memory -> zero slots, never negative
    assert serve_stage_slots(t, 0, 4, 1e6, 64, 128) == 0


# ---------------------------------------------------------------------------
# M/M/1 latency objective
# ---------------------------------------------------------------------------


def test_queue_wait_quantile_properties():
    mu = 100.0
    assert queue_wait_quantile(0.0, mu, 0.99) == 0.0
    assert queue_wait_quantile(mu, mu, 0.99) == math.inf
    assert queue_wait_quantile(50.0, 0.0, 0.99) == math.inf
    w95 = queue_wait_quantile(80.0, mu, 0.95)
    w99 = queue_wait_quantile(80.0, mu, 0.99)
    assert 0 < w95 < w99
    # closed form: log(rho/(1-p)) / (mu (1-rho))
    assert w99 == pytest.approx(math.log(0.8 / 0.01) / (mu * 0.2))
    # light load: tail already below 1-p at t=0 -> zero wait
    assert queue_wait_quantile(0.5, mu, 0.99) == 0.0


def test_serve_latency_quantile_monotone_in_load():
    lats = [serve_latency_quantile(0.01, 8, lam) for lam in (100, 400, 780)]
    assert lats == sorted(lats)
    assert serve_latency_quantile(0.01, 8, 900) == math.inf  # rho > 1
    assert serve_latency_quantile(0.0, 8, 100) == math.inf


# ---------------------------------------------------------------------------
# plan_serve
# ---------------------------------------------------------------------------


def _plan_kw(prof, **over):
    kw = dict(dp_shards=2, model_axis=2, n_heads=8, cache_len=128,
              seq_len=128, arch="toy")
    kw.update(over)
    return kw


def test_plan_serve_beats_uniform_on_hetero_cluster():
    prof = _hetero_profile()
    uni = plan_serve_uniform(prof, 1e5, **_plan_kw(prof))
    plan = plan_serve(prof, 1e5, **_plan_kw(prof))
    assert plan.predicted_p99 <= uni.predicted_p99
    # fast NX shard absorbs more slots than the slow TX2 shard
    assert plan.shard_alloc[0] > plan.shard_alloc[1]
    assert uni.shard_alloc[0] == uni.shard_alloc[1]
    assert plan.planner == "asteroid-serve"
    assert uni.planner == "uniform-serve"
    for y, cap in zip(plan.shard_alloc, plan.max_slots):
        assert 0 <= y <= cap
    assert plan.utilization < 1.0
    assert plan.predicted_p50 <= plan.predicted_p95 <= plan.predicted_p99


def test_plan_serve_respects_memory_caps():
    tiny = DeviceProfile("tiny", mem_bytes=5.5e6, flops=1e12)
    cluster = Cluster((tiny,) * 4)
    prof = Profile.analytic(_table(), cluster, 32)
    plan = plan_serve(prof, 1e4, **_plan_kw(prof))
    for y, cap in zip(plan.shard_alloc, plan.max_slots):
        assert y <= cap
    assert max(plan.max_slots) < 32   # the cap bound, not max_batch


def test_plan_serve_infeasible_memory_raises():
    nomem = DeviceProfile("nomem", mem_bytes=1e5, flops=1e12)
    prof = Profile.analytic(_table(), Cluster((nomem,) * 4), 32)
    with pytest.raises(AllocationError):
        plan_serve(prof, 1e4, **_plan_kw(prof))


def test_plan_serve_mesh_larger_than_cluster_raises():
    prof = _hetero_profile()
    with pytest.raises(AllocationError):
        plan_serve(prof, 1e4, **_plan_kw(prof, dp_shards=4, model_axis=2))


def test_plan_serve_overload_still_returns_best_effort():
    """Offered load beyond every config's capacity: percentiles are inf but
    a plan (the max-throughput split) is still returned."""
    prof = _hetero_profile()
    plan = plan_serve(prof, 1e12, **_plan_kw(prof))
    assert plan.predicted_p99 == math.inf
    assert plan.slots > 0


# ---------------------------------------------------------------------------
# cross-profile repricing
# ---------------------------------------------------------------------------


def test_reprice_serve_plan_keeps_decisions():
    prof = _hetero_profile()
    plan = plan_serve(prof, 1e5, **_plan_kw(prof))
    slow = Cluster(tuple(
        DeviceProfile(d.name, d.mem_bytes, d.flops / 2, d.sat_batch,
                      d.sat_flops, d.overhead)
        for d in prof.cluster.devices), bandwidth=prof.cluster.bandwidth)
    ref = Profile.analytic(prof.table, slow, prof.max_batch)
    re = reprice_serve_plan(plan, ref)
    assert re.shard_alloc == plan.shard_alloc
    assert (re.stage, re.tp, re.cuts) == (plan.stage, plan.tp, plan.cuts)
    assert re.step_time > plan.step_time
    gap = serve_prediction_gap(plan, ref)
    assert gap["gap_ratio"] > 1.0
    assert gap["predicted_p99_s"] == plan.predicted_p99
    assert gap["reference_p99_s"] == re.predicted_p99
