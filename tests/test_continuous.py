"""Continuous batching (runtime.continuous): determinism under admission
order / slot assignment / timing, admission control, and the open-loop
Poisson workload."""

import numpy as np

import jax.numpy as jnp

from repro.runtime.continuous import (ContinuousBatcher, Request,
                                      engine_from_decode_step,
                                      poisson_requests, slot_rows)

VOCAB = 17


def fake_step(tokens, positions, reset):
    """Row-independent deterministic logits: a pure function of each row's
    (token, position) — the property the real decode path provides."""
    tok = np.asarray(tokens)[:, None]
    pos = np.asarray(positions)[:, None]
    v = np.arange(VOCAB)[None, :]
    return jnp.asarray((tok * 31 + pos * 7 + v * 3) % 13, jnp.float32)


def make_timer(dt):
    t = [0.0]

    def timer():
        t[0] += dt / 2
        return t[0]

    return timer


def _requests(n=9, rate=50.0, n_tokens=5):
    rng = np.random.RandomState(3)
    t = 0.0
    out = []
    for rid in range(n):
        t += float(rng.exponential(1.0 / rate))
        out.append(Request(rid=rid, arrival=t,
                           prompt_token=int(rng.randint(VOCAB)),
                           n_tokens=n_tokens))
    return out


def _tokens(completions):
    return {c.rid: tuple(c.tokens) for c in completions}


def test_tokens_invariant_to_slot_assignment_and_timing():
    reqs = _requests()
    runs = []
    for slots, dt in [([0, 1, 2, 3], 0.01), ([3, 1, 0, 2], 0.01),
                      ([0, 1, 2, 3], 2.0), ([5, 2], 0.05)]:
        bat = ContinuousBatcher(fake_step, slots=slots, batch=8,
                                cache_len=16, seed=0, timer=make_timer(dt))
        runs.append(_tokens(bat.run(reqs)))
    assert all(len(r) == len(reqs) for r in runs)
    for other in runs[1:]:
        assert other == runs[0]


def test_latencies_do_depend_on_capacity():
    """Same tokens, different latencies: fewer slots => more queueing."""
    reqs = _requests(n=12)
    out = {}
    for name, slots in [("wide", [0, 1, 2, 3]), ("narrow", [0])]:
        bat = ContinuousBatcher(fake_step, slots=slots, batch=8,
                                cache_len=16, seed=0, timer=make_timer(0.01))
        done = bat.run(reqs)
        out[name] = done
        assert _tokens(done) == _tokens(out["wide"])
    wide = sum(c.latency for c in out["wide"])
    narrow = sum(c.latency for c in out["narrow"])
    assert narrow > wide


def test_admission_respects_slot_cap():
    reqs = _requests(n=10, rate=1e6)   # everything arrives at once
    bat = ContinuousBatcher(fake_step, slots=[0, 1], batch=8, cache_len=16,
                            seed=0, timer=make_timer(0.01))
    seen = []
    orig = bat._admit

    def spy(queue):
        orig(queue)
        seen.append(len(bat.active))

    bat._admit = spy
    done = bat.run(reqs)
    assert len(done) == 10
    assert max(seen) <= 2


def test_sampling_key_is_request_scoped():
    """A request keeps its token stream when unrelated requests are added
    to the workload (fold_in(rid, pos) — no cross-request coupling)."""
    reqs = _requests(n=4)
    extra = reqs + [Request(rid=100 + i, arrival=0.01 * i, prompt_token=3,
                            n_tokens=4) for i in range(3)]
    a = ContinuousBatcher(fake_step, slots=[0, 1, 2, 3], batch=8,
                          cache_len=16, seed=0, timer=make_timer(0.01))
    b = ContinuousBatcher(fake_step, slots=[0, 1, 2, 3], batch=8,
                          cache_len=16, seed=0, timer=make_timer(0.01))
    ta = _tokens(a.run(reqs))
    tb = _tokens(b.run(extra))
    for rid, toks in ta.items():
        assert tb[rid] == toks


def test_completion_bookkeeping():
    reqs = _requests(n=6, n_tokens=3)
    bat = ContinuousBatcher(fake_step, slots=[0, 1, 2], batch=4,
                            cache_len=16, seed=0, timer=make_timer(0.01))
    done = bat.run(reqs)
    assert [c.rid for c in done] == sorted(r.rid for r in reqs)
    for c in done:
        assert len(c.tokens) == 3
        assert len(c.token_latencies) == 3
        assert c.finish >= c.arrival
        assert all(l >= 0 for l in c.token_latencies)
    # generation is bounded by the cache
    short = ContinuousBatcher(fake_step, slots=[0], batch=4, cache_len=2,
                              seed=0, timer=make_timer(0.01))
    done = short.run([Request(rid=0, arrival=0.0, prompt_token=1,
                              n_tokens=50)])
    assert len(done[0].tokens) == 2


def test_poisson_requests_reproducible():
    a = poisson_requests(20.0, 1.0, n_tokens=4, seed=7)
    b = poisson_requests(20.0, 1.0, n_tokens=4, seed=7)
    assert a == b
    assert [r.rid for r in a] == list(range(len(a)))
    assert all(0 <= r.arrival < 1.0 for r in a)
    assert all(a[i].arrival < a[i + 1].arrival for i in range(len(a) - 1))
    c = poisson_requests(20.0, 1.0, n_tokens=4, seed=8)
    assert c != a


def test_slot_rows_shard_major_layout():
    assert slot_rows((3, 1)) == [0, 1, 2, 3]
    assert slot_rows((2, 2)) == [0, 1, 2, 3]
    assert slot_rows((1, 3)) == [0, 3, 4, 5]
    assert slot_rows((4,)) == [0, 1, 2, 3]


def test_real_engine_determinism():
    """The full decode path (KV cache, per-row positions, reset) honors the
    determinism contract: identical tokens under different step timing and
    admission order."""
    import jax

    from repro.configs import get_smoke_config
    from repro.models.model import init_model

    cfg = get_smoke_config("phi3-mini-3.8b").replace(prefix_len=0,
                                                     mtp_depth=0)
    params = init_model(jax.random.PRNGKey(0), cfg)
    reqs = [Request(rid=i, arrival=0.02 * i,
                    prompt_token=(7 * i + 3) % cfg.vocab_size, n_tokens=4)
            for i in range(6)]
    runs = []
    for slots, dt in [([0, 1, 2, 3], 0.01), ([2, 0], 1.0)]:
        engine = engine_from_decode_step(params, cfg, batch=4, cache_len=16)
        bat = ContinuousBatcher(engine, slots=slots, batch=4, cache_len=16,
                                seed=0, timer=make_timer(dt))
        runs.append(_tokens(bat.run(reqs)))
    assert runs[0] == runs[1]
    assert len(runs[0]) == 6
