"""Serving benchmark: planner-driven heterogeneous decode vs the uniform
baseline (BENCH_serve.json).

Two parts:

* ``plan`` records — predicted p99 comparison on the heterogeneous smoke
  cluster (2 Jetson NX + 2 TX2 shards, §10 link model): ``plan_serve``'s
  greedy unbalanced slot split vs ``plan_serve_uniform`` (legacy
  power-of-two stage probe + equal per-shard slots) at the same offered
  load (75% of the uniform config's capacity).  The planner must win or
  tie on predicted p99 — asserted here and again in CI.

* ``measured`` records — a real continuous-batching run on the host:
  the engine step is timed, a measured ``Profile`` is built from it, the
  planner picks the slot count, and an open-loop Poisson token stream is
  served through ``ContinuousBatcher``.  Records measured tokens/s +
  p50/p95/p99 against the plan's predictions (``gap_ratio``), plus an
  under-provisioned baseline arm (half the planned slots) at the same
  offered load.

Archs cover one attention family (phi3-mini) and one RWKV family
(rwkv6) — decode pricing must hold for both KV-cache and recurrent-state
models.
"""

from __future__ import annotations

import numpy as np

from .common import row

PLAN_ARCHS = ("phi3-mini-3.8b", "rwkv6-7b")
UTILIZATION = 0.75            # offered load as a fraction of uniform capacity


def _uniform_capacity(prof, *, dp_shards: int, model_axis: int,
                      cache_len: int, seq_len: int) -> float:
    """Max decode throughput (tokens/s) of the legacy uniform config
    (stage=1, equal slots) — the load both arms are priced against."""
    from repro.core.planner import (_price_serve_alloc, _serve_cuts,
                                    _shard_slot_cap)

    stage, tp = 1, model_axis
    cuts = _serve_cuts(prof.table.L, stage)
    caps = [_shard_slot_cap(prof, g, stage=stage, tp=tp, cuts=cuts,
                            cache_len=cache_len, seq_len=seq_len,
                            mem_fraction=0.9)
            for g in range(dp_shards)]
    best = 0.0
    for y in range(1, max(min(caps), 0) + 1):
        st, _, _ = _price_serve_alloc(prof, [y] * dp_shards, stage=stage,
                                      tp=tp, cuts=cuts, seq_len=seq_len,
                                      arrival_rate=0.0, compress=None)
        if st > 0:
            best = max(best, dp_shards * y / st)
    return best


def _plan_records(quick: bool) -> tuple[list[str], list[dict]]:
    from repro.configs import get_smoke_config
    from repro.core.hardware import Cluster, JETSON_NX, JETSON_TX2, MBPS_100
    from repro.core.planner import plan_serve, plan_serve_uniform
    from repro.core.profiler import LayerTable, Profile
    from repro.runtime.serve import serve_head_count

    lines, records = [], []
    seq = 128 if quick else 256
    cluster = Cluster((JETSON_NX,) * 2 + (JETSON_TX2,) * 2,
                      bandwidth=MBPS_100)
    for arch in PLAN_ARCHS:
        cfg = get_smoke_config(arch)
        table = LayerTable.from_model_config(cfg, seq_len=seq)
        prof = Profile.analytic(table, cluster, max_batch=32)
        kw = dict(dp_shards=2, model_axis=2, n_heads=serve_head_count(cfg),
                  cache_len=seq, seq_len=seq, arch=arch)
        lam = UTILIZATION * _uniform_capacity(prof, dp_shards=2, model_axis=2,
                                              cache_len=seq, seq_len=seq)
        uni = plan_serve_uniform(prof, lam, **kw)
        plan = plan_serve(prof, lam, **kw)
        if plan.predicted_p99 > uni.predicted_p99 * (1 + 1e-9):
            raise AssertionError(
                f"{arch}: planner p99 {plan.predicted_p99:.3e} worse than "
                f"uniform {uni.predicted_p99:.3e} at load {lam:.0f} tok/s")
        gain = uni.predicted_p99 / plan.predicted_p99
        records.append({
            "kind": "plan", "arch": arch, "env": "NXx2+TX2x2@100Mbps",
            "arrival_tok_s": lam,
            "uniform_alloc": list(uni.shard_alloc),
            "planner_alloc": list(plan.shard_alloc),
            "uniform_stage": uni.stage, "planner_stage": plan.stage,
            "uniform_p99_s": uni.predicted_p99,
            "planner_p99_s": plan.predicted_p99,
            "uniform_tok_s": uni.throughput, "planner_tok_s": plan.throughput,
            "p99_gain": gain, "plan_time_s": plan.plan_time,
        })
        lines.append(row(f"serve_plan/{arch}", plan.predicted_p99,
                         uniform_p99_us=f"{uni.predicted_p99 * 1e6:.1f}",
                         alloc="/".join(map(str, plan.shard_alloc)),
                         p99_gain=f"{gain:.2f}x",
                         load_tok_s=f"{lam:.0f}"))
    return lines, records


def _measure_step(engine, batch: int, reps: int) -> float:
    """Median wall time of one full-batch engine step (post-warmup)."""
    import time

    import jax
    import jax.numpy as jnp

    tok = jnp.zeros(batch, jnp.int32)
    pos = jnp.zeros(batch, jnp.int32)
    rst = jnp.ones(batch, bool)
    jax.device_get(engine(tok, pos, rst))       # compile
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        # device_get, not block_until_ready: the batcher's timed window
        # fetches the logits to host, so the profile must price that too
        jax.device_get(engine(tok, pos, rst))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _measured_profile(cfg, step_s: float, *, batch: int, seq_len: int):
    """Single-host Profile whose forward slices reproduce the measured
    engine step: the padded SPMD engine computes every row each step, so
    the time-vs-batch curve is flat at ``step_s``."""
    from repro.core.hardware import Cluster, DeviceProfile
    from repro.core.profiler import LayerTable, Profile

    table = LayerTable.from_model_config(cfg, seq_len=seq_len)
    host = DeviceProfile("host", mem_bytes=64e9, flops=1e12)
    L = table.L
    tf = np.zeros((1, batch + 1, L + 1))
    for b in range(batch + 1):
        tf[0, b] = step_s * seq_len * np.arange(L + 1) / L
    return Profile(table, Cluster((host,)), batch, tf, np.zeros_like(tf),
                   source="measured-serve")


def _run_batcher(engine, *, slots: int, batch: int, cache_len: int,
                 rate_tok_s: float, n_requests: int, n_tokens: int):
    """Serve an open-loop Poisson stream; returns (tok_s, p50, p95, p99)."""
    from repro.runtime.continuous import ContinuousBatcher, poisson_requests

    reqs = poisson_requests(rate_tok_s / n_tokens,
                            horizon=n_requests * n_tokens / rate_tok_s,
                            n_tokens=n_tokens, seed=0)
    if not reqs:
        raise AssertionError("empty arrival trace")
    bat = ContinuousBatcher(engine, slots=list(range(slots)), batch=batch,
                            cache_len=cache_len, seed=0)
    done = bat.run(reqs)
    lats = [l for c in done for l in c.token_latencies]
    total = sum(len(c.tokens) for c in done)
    span = max(c.finish for c in done) - min(c.arrival for c in done)
    p50, p95, p99 = np.percentile(lats, [50, 95, 99])
    return total / span, float(p50), float(p95), float(p99)


def _measured_records(quick: bool) -> tuple[list[str], list[dict]]:
    import jax

    from repro.configs import get_smoke_config
    from repro.core.planner import plan_serve
    from repro.models.model import init_model
    from repro.runtime.continuous import engine_from_decode_step
    from repro.runtime.serve import serve_head_count

    lines, records = [], []
    batch = 4 if quick else 8
    cache_len = 48
    n_tokens = 8 if quick else 16
    n_requests = 10 if quick else 24
    for arch in PLAN_ARCHS:
        cfg = get_smoke_config(arch).replace(prefix_len=0, mtp_depth=0)
        params = init_model(jax.random.PRNGKey(0), cfg)
        engine = engine_from_decode_step(params, cfg, batch=batch,
                                         cache_len=cache_len)
        step_s = _measure_step(engine, batch, reps=3 if quick else 6)
        prof = _measured_profile(cfg, step_s, batch=batch, seq_len=cache_len)
        lam = UTILIZATION * batch / step_s
        plan = plan_serve(prof, lam, dp_shards=1, model_axis=1,
                          n_heads=serve_head_count(cfg), cache_len=cache_len,
                          seq_len=cache_len, arch=arch)
        slots = plan.shard_alloc[0]
        tok_s, p50, p95, p99 = _run_batcher(
            engine, slots=slots, batch=batch, cache_len=cache_len,
            rate_tok_s=lam, n_requests=n_requests, n_tokens=n_tokens)
        base_slots = max(1, slots // 2)
        b_tok_s, _, _, b_p99 = _run_batcher(
            engine, slots=base_slots, batch=batch, cache_len=cache_len,
            rate_tok_s=lam, n_requests=n_requests, n_tokens=n_tokens)
        gap = p99 / plan.predicted_p99 if plan.predicted_p99 > 0 else 0.0
        records.append({
            "kind": "measured", "arch": arch, "slots": slots,
            "baseline_slots": base_slots, "arrival_tok_s": lam,
            "step_time_s": step_s, "tok_s": tok_s,
            "measured_p50_s": p50, "measured_p95_s": p95,
            "measured_p99_s": p99,
            "predicted_p50_s": plan.predicted_p50,
            "predicted_p99_s": plan.predicted_p99,
            "baseline_tok_s": b_tok_s, "baseline_p99_s": b_p99,
            "gap_ratio": gap,
        })
        lines.append(row(f"serve_measured/{arch}", p99,
                         tok_s=f"{tok_s:.1f}", slots=slots,
                         predicted_p99_us=f"{plan.predicted_p99 * 1e6:.1f}",
                         gap=f"{gap:.2f}x",
                         baseline_p99_us=f"{b_p99 * 1e6:.1f}"))
    return lines, records


def run_structured(quick: bool = False) -> tuple[list[str], list[dict]]:
    plan_lines, plan_recs = _plan_records(quick)
    meas_lines, meas_recs = _measured_records(quick)
    return plan_lines + meas_lines, plan_recs + meas_recs


def run() -> list[str]:
    return run_structured(False)[0]
