"""Fig. 13: throughput vs existing systems (EDDL, PipeDream, Dapple, HetPipe)
on Env B and Env C.

Paper: Asteroid gains 1.6x-6.9x over EDDL, 1.3x-2.1x over PipeDream,
1.2x-1.8x over Dapple, 1.2x-1.9x over HetPipe."""

from __future__ import annotations

from repro.core.hardware import env_b, env_c
from repro.core.planner import (auto_microbatch, plan_dp, plan_hetpipe_hdp,
                                plan_homogeneous_hpp)
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_BATCH, PAPER_MODELS

from .common import row

ENVS = [("B", env_b), ("C", env_c)]


def run(models=("efficientnet-b1", "mobilenetv2", "resnet50", "bert-small")) -> list[str]:
    rows = []
    for model in models:
        B = PAPER_BATCH[model]
        for env_name, mk in ENVS:
            cluster = mk().sorted_by_memory()
            prof = Profile.analytic(PAPER_MODELS[model](), cluster, max_batch=64)
            ours = auto_microbatch(prof, B, arch=model)
            mb = ours.micro_batch
            eddl = plan_dp(prof, B, mb, heterogeneous=True)
            pipedream = plan_homogeneous_hpp(prof, B, mb, name="pipedream")
            dapple = plan_homogeneous_hpp(prof, B, mb, include_allreduce=True,
                                          name="dapple")
            het_lat, _ = plan_hetpipe_hdp(prof, B, mb, n_groups=2)
            rows.append(row(
                f"fig13/{model}/env{env_name}", ours.latency,
                tput=f"{ours.throughput:.1f}",
                vs_eddl=f"{eddl.latency / ours.latency:.1f}x",
                vs_pipedream=f"{pipedream.latency / ours.latency:.1f}x",
                vs_dapple=f"{dapple.latency / ours.latency:.1f}x",
                vs_hetpipe=f"{het_lat / ours.latency:.1f}x"))
    return rows
