"""Fig. 16/17: fault-tolerant pipeline replay vs heavy rescheduling.

Paper: on Env D (1x TX2 + 3x Nano, EfficientNet-B1), the lightweight replay
recovers ~14x faster than heavy rescheduling while keeping ~90% of its
post-recovery throughput.  Heavy rescheduling's re-planning runs on the most
powerful remaining device — our planner executes on this host, so its wall
time is additionally scaled to Jetson-NX speed for the derived ratio
(factor = host/NX planner throughput, calibrated at 8x; the raw host time
is reported too)."""

from __future__ import annotations

from repro.core.hardware import env_d
from repro.core.planner import auto_microbatch
from repro.core.profiler import Profile
from repro.core.replay import heavy_rescheduling, lightweight_replay
from repro.configs.paper_models import efficientnet_b1_fine

from .common import row

JETSON_REPLAN_SCALE = 8.0


def run() -> list[str]:
    rows = []
    # fine-grained table: the paper plans EfficientNet-B1 at 213-layer
    # granularity, which is what makes full re-planning expensive
    prof = Profile.analytic(efficientnet_b1_fine(),
                            env_d().sorted_by_memory(), max_batch=64)
    plan = auto_microbatch(prof, 512, arch="efficientnet-b1",
                           candidates=(16, 32))
    base_tput = plan.throughput
    for fail_rank in sorted({st.group[0] for st in plan.stages}):
        light = lightweight_replay(plan, prof, fail_rank)
        heavy = heavy_rescheduling(plan, prof, fail_rank,
                                   replan_compute_scale=JETSON_REPLAN_SCALE)
        # recovery measured from confirmed failure detection (identical for
        # both mechanisms), matching the paper's Fig. 17 definition
        light_rec = light.total_s - light.detection_s
        heavy_rec = heavy.total_s - heavy.detection_s
        rows.append(row(
            f"fig16/drop_dev{fail_rank}", light_rec,
            light_s=f"{light_rec:.2f}",
            heavy_s=f"{heavy_rec:.2f}",
            recovery_speedup=f"{heavy_rec / light_rec:.1f}x",
            tput_light=f"{light.new_plan.throughput:.1f}",
            tput_heavy=f"{heavy.new_plan.throughput:.1f}",
            tput_keep=f"{light.new_plan.throughput / max(heavy.new_plan.throughput, 1e-9):.2f}",
            base_tput=f"{base_tput:.1f}"))
    return rows
