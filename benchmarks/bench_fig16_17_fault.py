"""Fig. 16/17: fault-tolerant pipeline replay vs heavy rescheduling.

Paper: on Env D (1x TX2 + 3x Nano, EfficientNet-B1), the lightweight replay
recovers ~14x faster than heavy rescheduling while keeping ~90% of its
post-recovery throughput.  Heavy rescheduling's re-planning runs on the most
powerful remaining device — our planner executes on this host, so its wall
time is additionally scaled to Jetson-NX speed (``JETSON_REPLAN_SCALE``,
shared with ``core.replay``'s default; the raw host time is reported too).

``run_structured`` also returns machine-readable records (one per dropped
device) which ``benchmarks.run`` serializes to ``BENCH_fault.json`` so the
recovery-time / post-recovery-throughput trajectory is tracked across PRs.
``quick=True`` uses the coarse 25-layer EfficientNet table and a single
micro-batch candidate (CI-friendly; the fine 213-layer table is what makes
full re-planning expensive and the paper ratio large)."""

from __future__ import annotations

from repro.core.hardware import env_d
from repro.core.planner import auto_microbatch
from repro.core.profiler import Profile
from repro.core.replay import (JETSON_REPLAN_SCALE, heavy_rescheduling,
                               lightweight_replay)
from repro.configs.paper_models import efficientnet_b1, efficientnet_b1_fine

from .common import row


def run_structured(quick: bool = False) -> tuple[list[str], list[dict]]:
    rows: list[str] = []
    records: list[dict] = []
    # fine-grained table: the paper plans EfficientNet-B1 at 213-layer
    # granularity, which is what makes full re-planning expensive
    table = efficientnet_b1(32) if quick else efficientnet_b1_fine()
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=64)
    plan = auto_microbatch(prof, 512, arch="efficientnet-b1",
                           candidates=(32,) if quick else (16, 32))
    base_tput = plan.throughput
    for fail_rank in sorted({st.group[0] for st in plan.stages}):
        light = lightweight_replay(plan, prof, fail_rank)
        heavy = heavy_rescheduling(plan, prof, fail_rank,
                                   replan_compute_scale=JETSON_REPLAN_SCALE)
        # recovery measured from confirmed failure detection (identical for
        # both mechanisms), matching the paper's Fig. 17 definition
        light_rec = light.total_s - light.detection_s
        heavy_rec = heavy.total_s - heavy.detection_s
        records.append({
            "scenario": f"drop_dev{fail_rank}",
            "failed_rank": fail_rank,
            "light_recovery_s": light_rec,
            "heavy_recovery_s": heavy_rec,
            "recovery_speedup": heavy_rec / light_rec,
            "light_migration_s": light.migration_s,
            "light_restore_s": light.restore_s,
            "tput_light": light.new_plan.throughput,
            "tput_heavy": heavy.new_plan.throughput,
            "tput_keep": (light.new_plan.throughput
                          / max(heavy.new_plan.throughput, 1e-9)),
            "base_tput": base_tput,
            "boundary_moves": [
                {"boundary": m.boundary, "layers": [m.lo, m.hi],
                 "nbytes": m.nbytes, "link_bw": m.link_bw}
                for m in light.boundary_moves],
        })
        rows.append(row(
            f"fig16/drop_dev{fail_rank}", light_rec,
            light_s=f"{light_rec:.2f}",
            heavy_s=f"{heavy_rec:.2f}",
            recovery_speedup=f"{heavy_rec / light_rec:.1f}x",
            tput_light=f"{light.new_plan.throughput:.1f}",
            tput_heavy=f"{heavy.new_plan.throughput:.1f}",
            tput_keep=f"{light.new_plan.throughput / max(heavy.new_plan.throughput, 1e-9):.2f}",
            base_tput=f"{base_tput:.1f}"))
    return rows, records


def run(quick: bool = False) -> list[str]:
    return run_structured(quick)[0]
