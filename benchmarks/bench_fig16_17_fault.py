"""Fig. 16/17: fault-tolerant pipeline replay vs heavy rescheduling, plus
the elastic-membership churn extension.

Paper: on Env D (1x TX2 + 3x Nano, EfficientNet-B1), the lightweight replay
recovers ~14x faster than heavy rescheduling while keeping ~90% of its
post-recovery throughput.  Heavy rescheduling's re-planning runs on the most
powerful remaining device — our planner executes on this host, so its wall
time is additionally scaled to Jetson-NX speed (``JETSON_REPLAN_SCALE``,
shared with ``core.replay``'s default; the raw host time is reported too).

``run_structured`` also returns machine-readable records (one per dropped
device) which ``benchmarks.run`` serializes to ``BENCH_fault.json`` so the
recovery-time / post-recovery-throughput trajectory is tracked across PRs.
``quick=True`` uses the coarse 25-layer EfficientNet table and a single
micro-batch candidate (CI-friendly; the fine 213-layer table is what makes
full re-planning expensive and the paper ratio large).

``run_churn_structured`` subjects the same Env-D pipeline to a seeded
Poisson join/leave/fail schedule driven through the membership replays
(``admission_replay``/``departure_replay``/``lightweight_replay``),
recording throughput-under-churn and per-event recovery latency against
(a) the no-churn baseline and (b) an FTPipeHD-style handler that reacts to
*every* membership change with full weight redistribution (aggregate ->
re-plan from scratch -> redistribute).  Under ``quick`` it additionally
runs a real 4-host-device training subprocess through a join+drain
schedule (``launch/train.py --events``) and records the simulated-clock
throughput improvement the accepted join bought."""

from __future__ import annotations

import time

import numpy as np

from repro.core.hardware import JETSON_NX, JETSON_TX2, env_d
from repro.core.planner import auto_microbatch, plan_hpp
from repro.core.profiler import Profile, extend_profile
from repro.core.replay import (JETSON_REPLAN_SCALE, admission_replay,
                               departure_replay, heavy_rescheduling,
                               lightweight_replay)
from repro.configs.paper_models import efficientnet_b1, efficientnet_b1_fine

from .common import row


def run_structured(quick: bool = False) -> tuple[list[str], list[dict]]:
    rows: list[str] = []
    records: list[dict] = []
    # fine-grained table: the paper plans EfficientNet-B1 at 213-layer
    # granularity, which is what makes full re-planning expensive
    table = efficientnet_b1(32) if quick else efficientnet_b1_fine()
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=64)
    plan = auto_microbatch(prof, 512, arch="efficientnet-b1",
                           candidates=(32,) if quick else (16, 32))
    base_tput = plan.throughput
    for fail_rank in sorted({st.group[0] for st in plan.stages}):
        light = lightweight_replay(plan, prof, fail_rank)
        heavy = heavy_rescheduling(plan, prof, fail_rank,
                                   replan_compute_scale=JETSON_REPLAN_SCALE)
        # recovery measured from confirmed failure detection (identical for
        # both mechanisms), matching the paper's Fig. 17 definition
        light_rec = light.total_s - light.detection_s
        heavy_rec = heavy.total_s - heavy.detection_s
        records.append({
            "scenario": f"drop_dev{fail_rank}",
            "failed_rank": fail_rank,
            "light_recovery_s": light_rec,
            "heavy_recovery_s": heavy_rec,
            "recovery_speedup": heavy_rec / light_rec,
            "light_migration_s": light.migration_s,
            "light_restore_s": light.restore_s,
            "tput_light": light.new_plan.throughput,
            "tput_heavy": heavy.new_plan.throughput,
            "tput_keep": (light.new_plan.throughput
                          / max(heavy.new_plan.throughput, 1e-9)),
            "base_tput": base_tput,
            "boundary_moves": [
                {"boundary": m.boundary, "layers": [m.lo, m.hi],
                 "nbytes": m.nbytes, "link_bw": m.link_bw}
                for m in light.boundary_moves],
        })
        rows.append(row(
            f"fig16/drop_dev{fail_rank}", light_rec,
            light_s=f"{light_rec:.2f}",
            heavy_s=f"{heavy_rec:.2f}",
            recovery_speedup=f"{heavy_rec / light_rec:.1f}x",
            tput_light=f"{light.new_plan.throughput:.1f}",
            tput_heavy=f"{heavy.new_plan.throughput:.1f}",
            tput_keep=f"{light.new_plan.throughput / max(heavy.new_plan.throughput, 1e-9):.2f}",
            base_tput=f"{base_tput:.1f}"))
    return rows, records


def run(quick: bool = False) -> list[str]:
    return run_structured(quick)[0]


# --------------------------------------------------------------------------
# elastic-membership churn: Poisson join/leave schedule over the same plan
# --------------------------------------------------------------------------

#: devices that attempt to join Env D during the churn run (cycled)
_JOIN_POOL = (JETSON_NX, JETSON_TX2)

#: mean inter-event gap, in training rounds (exponential / Poisson process)
_MEAN_GAP_ROUNDS = 20.0


def _ftpipehd_event_s(plan, profile: Profile, member_ranks) -> float:
    """FTPipeHD-style reaction to *any* membership change: aggregate every
    stage model to the coordinator, re-plan from scratch on the new member
    set (Jetson-scaled wall time), redistribute all weights."""
    from repro.core.hardware import Cluster

    table = profile.table
    bw = profile.cluster.bandwidth
    aggregate = sum(table.param_bytes(*st.layers) for st in plan.stages) / bw
    devs = tuple(profile.cluster.devices[r] for r in sorted(member_ranks))
    sub = Profile.analytic(table, Cluster(devs, bw), profile.max_batch)
    t0 = time.perf_counter()
    new_plan = plan_hpp(sub, plan.global_batch, plan.micro_batch,
                        arch=plan.arch)
    replan = (time.perf_counter() - t0) * JETSON_REPLAN_SCALE
    redistribute = sum(table.param_bytes(*st.layers)
                       for st in new_plan.stages) / bw
    return aggregate + replan + redistribute


def run_churn_structured(quick: bool = False, n_events: int | None = None,
                         seed: int = 0) -> tuple[list[str], list[dict], dict]:
    """Poisson join/drain/fail/evict churn over the Env-D pipeline.

    Simulated clock: training rounds accumulate samples at the *current*
    plan's latency; each membership event charges its recovery stall (the
    same quantities ``runtime.session`` blocks on).  Returns per-event
    records plus a summary comparing throughput-under-churn against the
    never-churned baseline and the cumulative FTPipeHD stall."""
    rng = np.random.default_rng(seed)
    rows: list[str] = []
    records: list[dict] = []
    table = efficientnet_b1(32) if quick else efficientnet_b1_fine()
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=64)
    plan = auto_microbatch(prof, 512, arch="efficientnet-b1",
                           candidates=(32,) if quick else (16, 32))
    base_tput = plan.throughput
    # replay bound: worst lightweight-replay recovery (sans detection) on
    # the base plan — the yardstick Fig. 17 records; every churn event's
    # recovery latency must stay within it
    replay_bound = max(
        rep.total_s - rep.detection_s
        for rep in (lightweight_replay(plan, prof, r)
                    for r in sorted({st.group[0] for st in plan.stages})))
    n_events = n_events if n_events is not None else (6 if quick else 10)
    members = set(range(len(prof.cluster.devices)))
    extras: list[int] = []          # joined ranks still serving
    t = 0.0
    samples = 0.0
    stall_total = 0.0
    ftpipehd_total = 0.0
    accepted_joins = 0
    join_i = 0
    for i in range(n_events):
        gap_rounds = 1.0 + rng.exponential(_MEAN_GAP_ROUNDS)
        samples += gap_rounds * plan.global_batch
        t += gap_rounds * plan.latency
        if i == 0 or not extras:
            kind = "join"           # guarantee a mid-training join early
        else:
            kind = str(rng.choice(["join", "drain", "fail", "evict"],
                                  p=[0.4, 0.25, 0.2, 0.15]))
        tput_before = plan.throughput
        rec = {"event": i, "kind": kind, "t_s": t,
               "tput_before": tput_before}
        if kind == "join":
            dev = _JOIN_POOL[join_i % len(_JOIN_POOL)]
            join_i += 1
            ext = extend_profile(prof, dev)
            new_rank = len(ext.cluster.devices) - 1
            decision = admission_replay(plan, ext, new_rank)
            ftpipehd_s = _ftpipehd_event_s(plan, ext,
                                           members | {new_rank})
            rec.update(device=dev.name, accepted=decision.accepted,
                       reason=decision.reason,
                       incumbent_latency_s=decision.incumbent_latency,
                       candidate_latency_s=decision.candidate_latency)
            if decision.accepted:
                rep = decision.report
                stall = rep.total_s
                recovery = rep.total_s
                prof, plan = ext, rep.new_plan
                members.add(new_rank)
                extras.append(new_rank)
                accepted_joins += 1
                rec.update(rank=new_rank, replan_s=rep.replan_s,
                           migration_s=rep.migration_s,
                           replicate_s=rep.replicate_s)
            else:
                stall = recovery = decision.replan_s
        else:
            rank = int(rng.choice(sorted(extras)))
            ftpipehd_s = _ftpipehd_event_s(plan, prof, members - {rank})
            if kind == "fail":
                rep = lightweight_replay(plan, prof, rank)
                stall = rep.total_s
                recovery = rep.total_s - rep.detection_s
            else:
                rep = departure_replay(plan, prof, rank,
                                       graceful=(kind == "drain"))
                stall = rep.stall_s
                recovery = rep.stall_s
            plan = rep.new_plan
            members.discard(rank)
            extras.remove(rank)
            rec.update(rank=rank, replan_s=rep.replan_s,
                       migration_s=rep.migration_s,
                       overlapped=rep.overlapped)
        t += stall
        stall_total += stall
        ftpipehd_total += ftpipehd_s
        rec.update(stall_s=stall, recovery_s=recovery,
                   replay_bound_s=replay_bound,
                   within_replay_bound=recovery <= replay_bound,
                   ftpipehd_s=ftpipehd_s, tput_after=plan.throughput)
        records.append(rec)
        rows.append(row(
            f"churn/ev{i}_{kind}", recovery,
            stall_s=f"{stall:.2f}", ftpipehd_s=f"{ftpipehd_s:.2f}",
            within_bound=str(recovery <= replay_bound),
            tput=f"{tput_before:.1f}->{plan.throughput:.1f}"))
    # drain the tail so the last event's plan contributes throughput too
    tail_rounds = 1.0 + rng.exponential(_MEAN_GAP_ROUNDS)
    samples += tail_rounds * plan.global_batch
    t += tail_rounds * plan.latency
    churn_tput = samples / t
    summary = {
        "n_events": n_events,
        "accepted_joins": accepted_joins,
        "base_tput_samples_s": base_tput,
        "churn_tput_samples_s": churn_tput,
        "replay_bound_s": replay_bound,
        "max_recovery_s": max(r["recovery_s"] for r in records),
        "all_within_replay_bound": all(r["within_replay_bound"]
                                       for r in records),
        "asteroid_stall_s": stall_total,
        "ftpipehd_stall_s": ftpipehd_total,
        "stall_speedup": ftpipehd_total / max(stall_total, 1e-9),
    }
    rows.append(row(
        "churn/summary", churn_tput,
        base_tput=f"{base_tput:.1f}", churn_tput=f"{churn_tput:.1f}",
        accepted_joins=str(accepted_joins),
        stall_s=f"{stall_total:.2f}", ftpipehd_s=f"{ftpipehd_total:.2f}",
        stall_speedup=f"{ftpipehd_total / max(stall_total, 1e-9):.1f}x"))
    if quick:
        try:
            live = _launch_churn_session()
        except Exception as exc:          # noqa: BLE001 — optional arm
            rows.append(row("churn/runtime_session", 0.0,
                            error=repr(exc)[:120]))
        else:
            summary["runtime_session"] = live
            rows.append(row(
                "churn/runtime_session", live["sim_tok_s"],
                base_sim_tok_s=f"{live['base_sim_tok_s']:.1f}",
                sim_tok_s=f"{live['sim_tok_s']:.1f}",
                join_accepted=str(live["join_accepted"]),
                round_s=(f"{live['latency_before_s']:.3f}->"
                         f"{live['latency_after_s']:.3f}")))
    return rows, records, summary


def _launch_churn_session(steps: int = 10, timeout: int = 1200) -> dict:
    """Drive the *real* runtime through a join+drain schedule on 4 host
    devices (``launch/train.py --events``) and parse the simulated-clock
    throughput plus the accepted join's latency improvement."""
    import os
    import re
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    args = [sys.executable, "-m", "repro.launch.train", "--smoke",
            "--devices", "4", "--plan", "--steps", str(steps),
            "--global-batch", "4", "--seq", "32", "--n-layers", "8",
            "--backup-every", "3", "--env", "A", "--bandwidth", "1000",
            "--events", "join@3:a100,drain@7:4"]
    proc = subprocess.run(args, capture_output=True, text=True,
                          timeout=timeout, env=env, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(f"launch.train --events failed:\n"
                           f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    sim = re.search(r"FINAL sim_tok_s=([0-9.]+)", proc.stdout)
    joined = re.search(r"joined \(accepted.*?([0-9.]+)s -> ([0-9.]+)s/round",
                       proc.stdout)
    assert sim, proc.stdout[-2000:]
    lat0 = float(joined.group(1)) if joined else float("nan")
    lat1 = float(joined.group(2)) if joined else float("nan")
    # never-churned simulated throughput: every round at the initial latency
    tokens_per_round = 4 * 32
    return {"sim_tok_s": float(sim.group(1)),
            "base_sim_tok_s": tokens_per_round / lat0 if joined else
            float("nan"),
            "join_accepted": bool(joined),
            "latency_before_s": lat0, "latency_after_s": lat1}


def run_churn(quick: bool = False) -> list[str]:
    return run_churn_structured(quick)[0]
