"""Table 7/8: planning + profiling overhead.

Paper: planning on a Jetson NX takes 480s (EffNet-B1, 213 layers) down to
69s (BERT-small); both planning and profiling are one-shot offline steps.
Our planner runs here on the container host; the derived column includes
the raw wall time and the layer count (the paper's scaling driver)."""

from __future__ import annotations

import time

from repro.core.hardware import env_c
from repro.core.planner import plan_hpp
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_BATCH, PAPER_MODELS

from .common import row


def run() -> list[str]:
    rows = []
    for model in ("efficientnet-b1", "mobilenetv2", "resnet50", "bert-small"):
        prof = Profile.analytic(PAPER_MODELS[model](),
                                env_c().sorted_by_memory(), max_batch=64)
        t0 = time.perf_counter()
        plan = plan_hpp(prof, PAPER_BATCH[model], 32, arch=model)
        wall = time.perf_counter() - t0
        rows.append(row(
            f"table7/{model}", wall,
            layers=prof.table.L,
            plan_s=f"{wall:.2f}",
            stages=len(plan.stages)))
    return rows
