"""Roofline table from dry-run artifacts (§Roofline deliverable).

Reads artifacts/dryrun/*.json and reports, per (arch × shape × mesh):
compute / memory / collective roofline terms (seconds), the dominant
bottleneck, MODEL_FLOPS / HLO_FLOPs, and the MFU bound."""

from __future__ import annotations

import glob
import json
import os

from repro.analysis.roofline import Roofline, from_record, table

from .common import row

ART_DIR = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def load_rooflines() -> list[Roofline]:
    out = []
    for path in sorted(glob.glob(os.path.join(ART_DIR, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok" or rec.get("tag"):
            continue
        out.append(from_record(rec))
    return out


def run() -> list[str]:
    rows = []
    for r in load_rooflines():
        rows.append(row(
            f"roofline/{r.arch}/{r.shape}/{r.mesh}", r.bound_s,
            compute_s=f"{r.compute_s:.4f}",
            memory_s=f"{r.memory_s:.4f}",
            collective_s=f"{r.collective_s:.4f}",
            dominant=r.dominant,
            useful=f"{r.useful_ratio:.2f}",
            mfu_bound=f"{r.mfu_bound:.3f}"))
    if not rows:
        rows.append(row("roofline/NO_ARTIFACTS", 0.0,
                        hint="run python -m repro.launch.dryrun --all first"))
    return rows


def print_table():
    print(table(load_rooflines()))
