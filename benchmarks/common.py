"""Shared benchmark utilities.

Every benchmark emits CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the primary measured latency in microseconds and
``derived`` packs the paper-comparison quantities as ``k=v`` pairs.
"""

from __future__ import annotations

from repro.core.hardware import Cluster
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_MODELS


def make_profile(model: str, cluster: Cluster, max_batch: int = 64) -> Profile:
    table = PAPER_MODELS[model]()
    return Profile.analytic(table, cluster.sorted_by_memory(), max_batch)


def row(name: str, seconds: float, **derived) -> str:
    d = " ".join(f"{k}={v}" for k, v in derived.items())
    return f"{name},{seconds * 1e6:.1f},{d}"


def fmt_x(x: float) -> str:
    return f"{x:.2f}x"
