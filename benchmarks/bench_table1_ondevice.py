"""Table 1: single-device training epoch time across device classes.

Validates the device cost model: the paper reports ~160x (Nano) and ~67x
(TX2) slowdowns vs an A100 on MobileNetV2."""

from __future__ import annotations

from repro.core.hardware import A100, JETSON_NANO, JETSON_TX2, Cluster
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_MODELS

from .common import row

EPOCH_SAMPLES = {"efficientnet-b1": 50000, "mobilenetv2": 50000,
                 "resnet50": 38400}
BATCH = {"efficientnet-b1": 64, "mobilenetv2": 64, "resnet50": 32}


def run() -> list[str]:
    rows = []
    for model in ("efficientnet-b1", "mobilenetv2", "resnet50"):
        times = {}
        for dev in (A100, JETSON_TX2, JETSON_NANO):
            prof = Profile.analytic(PAPER_MODELS[model](), Cluster((dev,)),
                                    max_batch=BATCH[model])
            b = BATCH[model]
            step = prof.t_both(0, b, 0, prof.table.L)
            times[dev.name] = step * (EPOCH_SAMPLES[model] / b)
        rows.append(row(
            f"table1/{model}", times["nano"],
            epoch_a100_s=f"{times['a100']:.1f}",
            epoch_tx2_min=f"{times['tx2'] / 60:.1f}",
            epoch_nano_min=f"{times['nano'] / 60:.1f}",
            slowdown_nano=f"{times['nano'] / times['a100']:.0f}x",
            slowdown_tx2=f"{times['tx2'] / times['a100']:.0f}x"))
    return rows
