"""Benchmark aggregator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only table4 fig13 ...]
        [--quick] [--json-out BENCH_fault.json]

The fault-family suites (fig16, churn) additionally write a machine-readable
``BENCH_fault.json`` (fig16: recovery times + post-recovery throughput for
lightweight vs heavy; churn: per-membership-event recovery latency +
throughput-under-churn, merged into the same document under the ``churn`` /
``churn_summary`` keys) and the throughput suite (table4) writes
``BENCH_throughput.json`` (Table 4 + Fig. 15a variants + the measured
runtime ablation + the profile_gap predicted-vs-measured records) and the
serving suite (serve) writes ``BENCH_serve.json`` (planner-vs-uniform
predicted p99 on the heterogeneous smoke cluster + measured continuous
batching with its predicted-vs-measured gap) so the perf trajectory is
recorded across PRs; ``--quick`` runs CI-friendly sizes.  Record schemas:
benchmarks/README.md.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from . import (bench_fig13_systems, bench_fig14_convergence,
               bench_fig15_ablation, bench_fig16_17_fault,
               bench_fig18_scalability, bench_roofline, bench_serve,
               bench_table1_ondevice, bench_table2_comm_volume,
               bench_table4_throughput, bench_table7_overhead)

SUITES = {
    "table1": bench_table1_ondevice.run,
    "table2": bench_table2_comm_volume.run,
    "table4": bench_table4_throughput.run,
    "fig13": bench_fig13_systems.run,
    "fig14": bench_fig14_convergence.run,
    "fig15": bench_fig15_ablation.run,
    "fig16": bench_fig16_17_fault.run,
    "churn": bench_fig16_17_fault.run_churn,
    "fig18": bench_fig18_scalability.run,
    "serve": bench_serve.run,
    "table7": bench_table7_overhead.run,
    "roofline": bench_roofline.run,
}


def _merge_fault_json(path: str, quick: bool, **sections) -> None:
    """fig16 and churn share one BENCH_fault.json; each suite overwrites
    only its own keys so ``--only churn`` extends an existing fig16 doc."""
    doc: dict = {}
    try:
        with open(path) as f:
            existing = json.load(f)
        if isinstance(existing, dict) and existing.get("suite") == "fig16":
            doc = existing
    except (OSError, ValueError):
        pass
    doc.update({"suite": "fig16", "quick": quick, **sections})
    with open(path, "w") as f:
        json.dump(doc, f, indent=2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None, choices=list(SUITES))
    ap.add_argument("--quick", action="store_true",
                    help="reduced problem sizes where supported "
                         "(fig16, churn, table4, serve)")
    ap.add_argument("--json-out", default="BENCH_fault.json",
                    help="where the fault-family suites (fig16, churn) "
                         "write/merge their JSON record")
    ap.add_argument("--throughput-json-out", default="BENCH_throughput.json",
                    help="where the throughput suite (table4 + Fig. 15a "
                         "variants + measured runtime ablation) writes its "
                         "JSON record")
    ap.add_argument("--serve-json-out", default="BENCH_serve.json",
                    help="where the serving suite (planner-vs-uniform "
                         "predicted p99 + measured continuous batching) "
                         "writes its JSON record")
    ap.add_argument("--runtime-bench", action="store_true",
                    help="include the measured runtime ablation (two "
                         "8-host-device subprocess trainings) in table4 "
                         "even without --quick")
    args = ap.parse_args()
    names = args.only or list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.perf_counter()
        try:
            if name == "fig16":
                lines, records = bench_fig16_17_fault.run_structured(args.quick)
                _merge_fault_json(args.json_out, args.quick,
                                  records=records)
                print(f"# fig16 records -> {args.json_out}", file=sys.stderr)
            elif name == "churn":
                lines, churn_records, churn_summary = \
                    bench_fig16_17_fault.run_churn_structured(args.quick)
                _merge_fault_json(args.json_out, args.quick,
                                  churn=churn_records,
                                  churn_summary=churn_summary)
                print(f"# churn records -> {args.json_out}", file=sys.stderr)
            elif name == "table4":
                # the measured (subprocess) ablation only under --quick (CI
                # sizes) or by explicit request — the plain analytic sweep
                # stays cheap
                lines, records = bench_table4_throughput.run_structured(
                    args.quick, runtime=args.quick or args.runtime_bench)
                with open(args.throughput_json_out, "w") as f:
                    json.dump({"suite": "throughput", "quick": args.quick,
                               "records": records}, f, indent=2)
                print(f"# throughput records -> {args.throughput_json_out}",
                      file=sys.stderr)
            elif name == "serve":
                lines, records = bench_serve.run_structured(args.quick)
                with open(args.serve_json_out, "w") as f:
                    json.dump({"suite": "serve", "quick": args.quick,
                               "records": records}, f, indent=2)
                print(f"# serve records -> {args.serve_json_out}",
                      file=sys.stderr)
            else:
                lines = SUITES[name]()
            for line in lines:
                print(line)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suite(s) failed")


if __name__ == "__main__":
    main()
