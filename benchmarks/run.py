"""Benchmark aggregator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Usage:

    PYTHONPATH=src python -m benchmarks.run [--only table4 fig13 ...]
"""

from __future__ import annotations

import argparse
import sys
import time

from . import (bench_fig13_systems, bench_fig14_convergence,
               bench_fig15_ablation, bench_fig16_17_fault,
               bench_fig18_scalability, bench_roofline, bench_table1_ondevice,
               bench_table2_comm_volume, bench_table4_throughput,
               bench_table7_overhead)

SUITES = {
    "table1": bench_table1_ondevice.run,
    "table2": bench_table2_comm_volume.run,
    "table4": bench_table4_throughput.run,
    "fig13": bench_fig13_systems.run,
    "fig14": bench_fig14_convergence.run,
    "fig15": bench_fig15_ablation.run,
    "fig16": bench_fig16_17_fault.run,
    "fig18": bench_fig18_scalability.run,
    "table7": bench_table7_overhead.run,
    "roofline": bench_roofline.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None, choices=list(SUITES))
    args = ap.parse_args()
    names = args.only or list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        t0 = time.perf_counter()
        try:
            for line in SUITES[name]():
                print(line)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name}/ERROR,0,{type(e).__name__}: {e}")
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark suite(s) failed")


if __name__ == "__main__":
    main()
