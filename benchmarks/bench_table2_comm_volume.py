"""Table 2: communication volume per global mini-batch, HDP vs HPP.

Paper: on five Jetson Nanos, HDP (HetPipe allocation) moves 1.9x-2.7x more
bytes than Asteroid's HPP plan (EffNet 171.4 vs 76.2 MB, MobileNet 98.0 vs
52.1 MB, ResNet50 576.2 vs 212.4 MB)."""

from __future__ import annotations

from repro.core.hardware import env_a
from repro.core.planner import auto_microbatch, plan_hetpipe_hdp

from .common import make_profile, row

BATCH = {"efficientnet-b1": 2048, "mobilenetv2": 2048, "resnet50": 256}


def _min_volume_pipeline(table, P: int, batch: int) -> float:
    """Eq. (2) for a straight P-stage pipeline whose cut points sit at the
    smallest boundary activations (§2.3: HPP's planner avoids huge-activation
    boundaries and keeps AllReduce away from parameter-dense layers)."""
    L = table.L
    bounds = sorted(range(2, L - 1), key=lambda j: table.boundary_act(j))
    cuts: list[int] = []
    for j in bounds:
        if all(abs(j - c) >= max(1, L // (2 * P)) for c in cuts):
            cuts.append(j)
        if len(cuts) == P - 1:
            break
    acts = [table.boundary_act(j) for j in cuts]
    return 2.0 * batch * sum(acts)


def run() -> list[str]:
    rows = []
    for model, B in BATCH.items():
        prof = make_profile(model, env_a())
        plan = auto_microbatch(prof, B, arch=model)
        v_planned = plan.comm_volume(prof)
        # the paper's testbed plans are volume-lean straight pipelines; our
        # calibrated profile sometimes trades volume for latency with
        # intra-stage DP groups, so both readings are reported
        # compute must stay balanced, so a full 5-stage pipeline is the
        # realistic volume-lean plan; the latency-planned volume caps it
        v_hpp = min(_min_volume_pipeline(prof.table, 5, B), v_planned)
        _, v_hdp = plan_hetpipe_hdp(prof, B, plan.micro_batch, n_groups=2)
        rows.append(row(
            f"table2/{model}", plan.latency,
            v_hdp_mb=f"{v_hdp / 1e6:.1f}",
            v_hpp_mb=f"{v_hpp / 1e6:.1f}",
            v_hpp_latency_planned_mb=f"{v_planned / 1e6:.1f}",
            ratio=f"{v_hdp / max(v_hpp, 1):.2f}x",
            paper_ratio_range="1.9x-2.7x"))
    return rows
