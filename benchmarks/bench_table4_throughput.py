"""Table 4: training throughput — Asteroid HPP vs single device / DP / PP.

Paper: 2.1x-6.8x over DP, 1.3x-12.2x over PP across Env A (100Mbps),
Env B (100Mbps), Env B (1000Mbps) for EfficientNet-B1 / MobileNetV2 /
ResNet-50 / BERT-small.

``run_structured`` additionally emits machine-readable records — the
Table 4 planner throughputs, the Fig. 15a intra-stage-planning ablation
(Algorithm 1 Phase 2 on/off, predicted), a *measured* ablation on the
real shard_map runtime (``repro.launch.train --plan [--no-offload]`` in a
subprocess with 8 host devices), the ``async_overlap`` suite (two-stream
overlapped vs sync vs one-stream-serialized round latencies on the
bandwidth-constrained Env B, plus measured sync/staleness-1 runtime
arms — DESIGN.md §8), the ``profile_gap`` suite (the host is
profiled for real via ``repro.launch.profile.measure_model`` for the
smoke attention, RWKV and train_4k-shaped configs, and plans made on the
analytic vs the measured profile are both evaluated against the measured
times — quantifying what measured profiling buys), and the ``portfolio``
suite (the DESIGN.md §12 closed-loop auction: a predicted record of the
enumerated candidate set plus a measured ``--portfolio 3`` subprocess
run gating winner-no-slower-than-first-choice and probation
bit-identity) — which
``benchmarks/run.py`` writes to ``BENCH_throughput.json`` so the
throughput trajectory is recorded across PRs (CI artifact).  See
benchmarks/README.md for the record schemas.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

from repro.core.hardware import MBPS_100, MBPS_1000, env_a, env_b, env_c
from repro.core.planner import auto_microbatch, plan_dp, plan_gpipe, plan_hpp
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_BATCH, PAPER_MODELS

from .common import row

ENVS = [("A_100Mbps", lambda: env_a()),
        ("B_100Mbps", lambda: env_b(MBPS_100)),
        ("B_1000Mbps", lambda: env_b(MBPS_1000))]

ALL_MODELS = ("efficientnet-b1", "mobilenetv2", "resnet50", "bert-small")


def _table4(models, envs):
    lines, records = [], []
    for model in models:
        B = PAPER_BATCH[model]
        for env_name, mk in envs:
            cluster = mk().sorted_by_memory()
            prof = Profile.analytic(PAPER_MODELS[model](), cluster, max_batch=64)
            ours = auto_microbatch(prof, B, arch=model)
            mb = ours.micro_batch
            dp = plan_dp(prof, B, mb, heterogeneous=True)
            pp = plan_gpipe(prof, B, mb)
            # single strongest device (rank 0 after the memory sort)
            dev_t = prof.t_both(0, mb, 0, prof.table.L) * (B // mb)
            lines.append(row(
                f"table4/{model}/{env_name}", ours.latency,
                tput=f"{ours.throughput:.1f}",
                stages=len(ours.stages),
                speedup_device=f"{dev_t / ours.latency:.1f}x",
                speedup_dp=f"{dp.latency / ours.latency:.1f}x",
                speedup_pp=f"{pp.latency / ours.latency:.1f}x"))
            records.append({
                "suite": "table4", "model": model, "env": env_name,
                "tput_samples_s": ours.throughput, "stages": len(ours.stages),
                "speedup_vs_device": dev_t / ours.latency,
                "speedup_vs_dp": dp.latency / ours.latency,
                "speedup_vs_pp": pp.latency / ours.latency})
    return lines, records


def _fig15a_quick(models):
    """Fig. 15a intra-stage ablation, predicted: Algorithm 1 with and
    without Phase 2 (straggler workload offloading)."""
    lines, records = [], []
    for model in models:
        prof = Profile.analytic(PAPER_MODELS[model](),
                                env_c().sorted_by_memory(), max_batch=64)
        B = 2048
        full = plan_hpp(prof, B, 32, intra_opt=True)
        no_off = plan_hpp(prof, B, 32, intra_opt=False)
        lines.append(row(
            f"fig15a_quick/{model}", full.latency,
            full_tput=f"{full.throughput:.1f}",
            no_offload_tput=f"{no_off.throughput:.1f}",
            offload_gain=f"{no_off.latency / full.latency:.3f}x"))
        records.append({
            "suite": "fig15a", "model": model,
            "full_tput_samples_s": full.throughput,
            "no_offload_tput_samples_s": no_off.throughput,
            "offload_gain": no_off.latency / full.latency})
    return lines, records


def _launch(extra_args, steps: int, timeout: int = 1200,
            global_batch: int = 8, seq: int = 64) -> str:
    """Run ``repro.launch.train --smoke --plan`` in a subprocess on 8 host
    devices and return its stdout.  ``global_batch``/``seq`` default to the
    smoke shape; the train_4k-shaped arms widen them."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    args = [sys.executable, "-m", "repro.launch.train", "--smoke",
            "--devices", "8", "--plan", "--steps", str(steps),
            "--global-batch", str(global_batch), "--seq", str(seq),
            *extra_args]
    proc = subprocess.run(args, capture_output=True, text=True,
                          timeout=timeout, env=env, cwd=root)
    if proc.returncode != 0:
        raise RuntimeError(
            f"launch.train {extra_args} failed:\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
    return proc.stdout


def _launch_tok_s(extra_args, steps: int, timeout: int = 1200,
                  global_batch: int = 8, seq: int = 64):
    """``_launch`` + parse: (tok_s, loss, shard_alloc from the plan line)."""
    out = _launch(extra_args, steps, timeout, global_batch, seq)
    m = re.search(r"FINAL tok_s=([0-9.]+) loss=([0-9.]+)", out)
    assert m, out[-2000:]
    # a heterogeneous allocation prints as a tuple with spaces: "(2, 1, 1)"
    alloc = re.search(r"shard_alloc=(\([^)]*\)|\S+)", out)
    return (float(m.group(1)), float(m.group(2)),
            alloc.group(1) if alloc else "?")


def _runtime_ablation(quick: bool):
    """Measured Fig. 15a on the real runtime: the planner's allocation with
    Phase 2 in 'auto' mode (the default — heterogeneous padding kept only
    when it predicts a strict gain) vs Phase 2 disabled, executed by the
    shard_map pipeline on 8 host devices.

    The PR-3 recording of this suite compared *forced* Phase 2 against
    no-Phase-2 over 5 steady-state steps; on the homogeneous host the
    padded layout can only cost (there is no straggler to offload), and
    5-step timings carry ~10% run-to-run noise, so the recorded 16% gap
    was the padding tax plus noise.  'auto' plans fall back to the
    no-offload allocation whenever the simulator predicts no gain, and the
    quick run now times more steady steps."""
    steps = 14 if quick else 20
    lines, records = [], []
    for offload in (True, False):
        tok_s, loss, alloc = _launch_tok_s(
            [] if offload else ["--no-offload"], steps)
        tag = "offload" if offload else "no_offload"
        lines.append(row(f"fig15a_runtime/{tag}", 1.0 / max(tok_s, 1e-9),
                         tok_s=f"{tok_s:.1f}", loss=f"{loss:.4f}",
                         alloc=alloc))
        records.append({"suite": "fig15a_runtime", "offload": offload,
                        "offload_mode": "auto" if offload else "off",
                        "shard_alloc": alloc,
                        "tok_s": tok_s, "loss": loss, "steps": steps})
    return lines, records


def _async_overlap(models, quick: bool, runtime: bool = True):
    """Async 1F1B overlap suite: what taking the gradient AllReduce and
    boundary transfers off the critical path buys.

    *Predicted* (deterministic): plans on the bandwidth-constrained Env B @
    100 Mbps, priced sync (Eq. 4 charges every AllReduce) vs staleness-1
    (``round_latency_async`` charges only un-hidden comm), plus the
    one-stream ``round_latency_serialized`` bound the pre-double-buffer
    runtime realized.  The CI gate asserts async >= sync here — it holds by
    construction (overlap can only remove charged comm) so a violation
    means the two-stream model regressed.

    *Measured* (recorded, loosely gated): sync vs staleness-1 tok/s of the
    real shard_map runtime on 8 host devices.  Host links are shared
    memory — there is effectively no comm to hide — so the honest
    prediction for this hardware is gain ~= 1.0 and the measured arms are
    a semantics/overhead check, not a bandwidth experiment; run-to-run
    noise on CI boxes is ~10%, hence the loose bound."""
    from repro.core.costmodel import (exec_phase_latency, max_allreduce,
                                      round_latency, round_latency_async,
                                      round_latency_serialized)

    lines, records = [], []
    # free-depth plans tend to singleton stage groups (no intra-stage DP,
    # so no AllReduce to hide); the 2-stage variant replicates each stage
    # over a multi-device group, which is where staleness-1 pays
    for model in models:
        prof = Profile.analytic(PAPER_MODELS[model](),
                                env_b(MBPS_100).sorted_by_memory(),
                                max_batch=64)
        B = PAPER_BATCH[model]
        for tag, kw in (("free", {}), ("2stage", {"allowed_stages": {2}})):
            sync = auto_microbatch(prof, B, arch=model, **kw)
            asy = auto_microbatch(prof, B, arch=model, staleness=1, **kw)
            comp = auto_microbatch(prof, B, arch=model, staleness=1,
                                   compress="int8", **kw)
            serial = round_latency_serialized(sync.steps, sync.n_micro)
            rec = {
                "suite": "async_overlap", "kind": "predicted",
                "model": model, "env": "B_100Mbps", "stages_mode": tag,
                # one-stream (pre-double-buffer runtime), two-stream sync,
                # two-stream + staleness-1, + int8-compressed wire — in
                # that order
                "serialized_s": serial,
                "sync_s": sync.latency, "async_s": asy.latency,
                "compressed_s": comp.latency,
                "double_buffer_gain": serial / sync.latency,
                "staleness_gain": sync.latency / asy.latency,
                "compression_gain": asy.latency / comp.latency,
                "total_gain": serial / asy.latency,
                "sync_stages": len(sync.stages),
                "async_stages": len(asy.stages),
                "async_exec_phase_s": exec_phase_latency(asy.steps,
                                                         asy.n_micro),
                "async_allreduce_s": max_allreduce(asy.steps),
                # what the async plan would cost under sync charging
                "async_plan_sync_s": round_latency(asy.steps, asy.n_micro),
            }
            # overlap only ever removes charged comm, and quantizing the
            # wire only ever shrinks it (the planner charges the quant
            # cost, so this is a real check of the §10 pricing, not a
            # tautology): the CI gates
            assert rec["compressed_s"] <= rec["async_s"] * (1 + 1e-9), rec
            assert rec["async_s"] <= rec["sync_s"] * (1 + 1e-9), rec
            assert rec["sync_s"] <= rec["serialized_s"] * (1 + 1e-9), rec
            lines.append(row(
                f"async_overlap/{model}/{tag}", asy.latency,
                serialized_s=f"{serial:.3f}", sync_s=f"{sync.latency:.3f}",
                async_s=f"{asy.latency:.3f}",
                compressed_s=f"{comp.latency:.3f}",
                gain=f"{rec['total_gain']:.2f}x",
                stages=f"{len(sync.stages)}->{len(asy.stages)}"))
            records.append(rec)

    if runtime:
        steps = 14 if quick else 24
        tok_sync, loss_sync, _ = _launch_tok_s(["--staleness", "0"], steps)
        tok_async, loss_async, _ = _launch_tok_s(["--staleness", "1"], steps)
        tok_nodb, _, _ = _launch_tok_s(
            ["--staleness", "1", "--no-double-buffer"], steps)
        tok_comp, loss_comp, _ = _launch_tok_s(
            ["--staleness", "1", "--compress", "int8"], steps)
        measured_gain = tok_nodb / max(tok_sync, 1e-9)
        db_gain = tok_async / max(tok_sync, 1e-9)
        comp_gain = tok_comp / max(tok_async, 1e-9)
        # the two-stream prediction for the plan the subprocesses ran:
        # same planning inputs as repro.launch.train (analytic env D,
        # smoke config).  The runtime executes on shared-memory host
        # devices, so the honest staleness prediction for this hardware is
        # the AllReduce fraction of the emulated plan — compared against
        # the no-double-buffer arm (pure staleness semantics; the 2-tick
        # hop is warm-up tax with nothing to hide on a host link).
        from repro.configs import get_smoke_config
        from repro.core.hardware import ENVS
        from repro.core.planner import plan_hpp
        from repro.core.profiler import LayerTable
        cfg = get_smoke_config("phi3-mini-3.8b")
        table = LayerTable.from_model_config(cfg, 64)
        prof_d = Profile.analytic(table, ENVS["D"]().sorted_by_memory(),
                                  max_batch=8)
        # replicate BOTH arms' planning (the staleness knob can shift the
        # chosen stage cut): the sync arm ran plan_0 under sync charging,
        # the async arms ran plan_1 under overlapped charging.  Stage
        # choices restricted exactly as repro.launch.train restricts them
        # (divisors of the 8-device mesh's model axis, capped at the
        # period count).
        model_axis = 4                       # --devices 8 -> (data=2, model=4)
        n_periods = cfg.n_layers // len(cfg.pattern)
        divisors = {d for d in range(1, model_axis + 1)
                    if model_axis % d == 0 and d <= n_periods}
        plan_0 = plan_hpp(prof_d, 8, 2, arch=cfg.name, intra_opt="auto",
                          allowed_stages=divisors)
        plan_1 = plan_hpp(prof_d, 8, 2, arch=cfg.name, intra_opt="auto",
                          allowed_stages=divisors, staleness=1)
        predicted_gain = (round_latency(plan_0.steps, plan_0.n_micro)
                          / round_latency_async(plan_1.steps, plan_1.n_micro))
        rec = {"suite": "async_overlap", "kind": "measured",
               "model": "phi3_mini",
               "tok_s_sync": tok_sync, "tok_s_async": tok_async,
               "tok_s_async_nodb": tok_nodb,
               "tok_s_compressed": tok_comp,
               "loss_sync": loss_sync, "loss_async": loss_async,
               "loss_compressed": loss_comp,
               "measured_gain": measured_gain,
               "measured_gain_double_buffer": db_gain,
               "measured_gain_compression": comp_gain,
               "predicted_gain": predicted_gain,
               "prediction_within_20pct":
                   abs(predicted_gain - measured_gain) <= 0.2,
               "steps": steps}
        # loose floors (CI boxes carry ~10% timing noise): pure staleness
        # must be ~free; the double-buffer arm additionally pays its
        # warm-up ticks with no link latency to hide on host devices; the
        # compressed arm pays the (de)quantization kernels on top with no
        # wire to shrink on shared memory, so it only gets a sanity floor
        assert measured_gain >= 0.7, rec
        assert db_gain >= 0.5, rec
        assert comp_gain >= 0.3, rec
        lines.append(row("async_overlap/runtime", 1.0 / max(tok_async, 1e-9),
                         sync_tok_s=f"{tok_sync:.1f}",
                         async_tok_s=f"{tok_async:.1f}",
                         nodb_tok_s=f"{tok_nodb:.1f}",
                         comp_tok_s=f"{tok_comp:.1f}",
                         gain=f"{measured_gain:.2f}x",
                         predicted=f"{predicted_gain:.2f}x"))
        records.append(rec)

        # beyond the smoke config (ROADMAP "grow the trend gate's reach"):
        # one SSM/RWKV architecture and one train_4k-shaped run, sync vs
        # pure staleness-1 (no double buffer: its warm-up ticks have
        # nothing to hide on host links), so attention- or scan-kernel
        # regressions surface in the per-model trend series.  Floors are
        # looser than the primary arm — these configs run fewer steady
        # steps under the same ~10% CI timing noise.
        extra_steps = 10 if quick else 20
        for slug, arch, gb, seq in (
                ("rwkv6", "rwkv6-7b", 8, 64),
                ("phi3_mini_4k", "phi3-mini-3.8b", 16, 256)):
            t_sync, l_sync, _ = _launch_tok_s(
                ["--arch", arch, "--staleness", "0"], extra_steps,
                global_batch=gb, seq=seq)
            t_async, l_async, _ = _launch_tok_s(
                ["--arch", arch, "--staleness", "1", "--no-double-buffer"],
                extra_steps, global_batch=gb, seq=seq)
            gain = t_async / max(t_sync, 1e-9)
            mrec = {"suite": "async_overlap", "kind": "measured",
                    "model": slug, "arch": arch,
                    "global_batch": gb, "seq": seq,
                    "tok_s_sync": t_sync, "tok_s_async_nodb": t_async,
                    "loss_sync": l_sync, "loss_async": l_async,
                    "measured_gain": gain, "steps": extra_steps}
            assert gain >= 0.6, mrec
            assert t_sync > 0 and t_async > 0, mrec
            lines.append(row(f"async_overlap/runtime/{slug}",
                             1.0 / max(t_async, 1e-9),
                             sync_tok_s=f"{t_sync:.1f}",
                             nodb_tok_s=f"{t_async:.1f}",
                             gain=f"{gain:.2f}x"))
            records.append(mrec)
    return lines, records


def _profile_gap(quick: bool):
    """Predicted-vs-measured latency gap, for both profile sources.

    The host is profiled for real (jitted per-layer sweeps, replicated to a
    4-device virtual cluster); one plan is made on the *analytic* model of
    those same devices (effective FLOP rate, Fig. 6 efficiency curve) and
    one on the *measured* tables.  Both are re-priced and simulated on the
    measured profile — the gap of the analytic plan is the misprediction
    that measured profiling removes (cf. AccEPT's observation that analytic
    edge estimates diverge on real devices).
    """
    from repro.configs import get_smoke_config
    from repro.core.profiler import LayerTable, Profile
    from repro.core.simulator import prediction_gap
    from repro.launch.profile import measure_model

    # smoke attention + RWKV + a train_4k-shaped sequence, so both kernel
    # families and the long-sequence regime feed the per-model trend series
    configs = [("phi3_mini", "phi3-mini-3.8b", 64),
               ("rwkv6", "rwkv6-7b", 64),
               ("phi3_mini_4k", "phi3-mini-3.8b", 256)]
    lines, records = [], []
    for slug, arch, seq in configs:
        cfg = get_smoke_config(arch)
        B, mb, max_batch = 8, 2, 8
        mp = measure_model(cfg, seq, batch_sizes=(1, 2, 4),
                           repeats=1 if quick else 3, replicate=4)
        table = LayerTable.from_model_config(cfg, seq)
        measured = mp.to_profile(table, max_batch)
        analytic = Profile.analytic(table, measured.cluster, max_batch)

        for src, prof in (("analytic", analytic), ("measured", measured)):
            plan = plan_hpp(prof, B, mb, arch=cfg.name)
            gap = prediction_gap(plan, measured)
            lines.append(row(
                f"profile_gap/{slug}/{src}", plan.latency,
                predicted_s=f"{gap['predicted_s']:.4f}",
                measured_s=f"{gap['reference_s']:.4f}",
                gap=f"{gap['gap_ratio']:.2f}x",
                stages=len(plan.stages)))
            records.append({"suite": "profile_gap", "model": slug,
                            "planned_on": src,
                            "arch": cfg.name, "seq": seq, "global_batch": B,
                            "stages": len(plan.stages), **gap})
    return lines, records


def _portfolio(quick: bool, runtime: bool = True):
    """Closed-loop portfolio suite (DESIGN.md §12).

    *Predicted* (deterministic): ``PlanPortfolio.enumerate`` on the same
    planning inputs ``repro.launch.train --smoke --devices 8 --plan``
    uses (analytic env D, smoke config) — records the candidate set, the
    dedupe rate and the analytic first choice, so a planner change that
    silently drops a strategy family moves this record.

    *Measured* (recorded + gated): a ``--portfolio 3 --probation-rounds
    2`` subprocess; its ``PORTFOLIO {json}`` line carries the probation
    outcome.  The gates — measured winner no slower than the analytic
    first choice's measured time, and training state bit-identical after
    the full K-plan probation — are the two invariants the tentpole
    promises."""
    import json

    from repro.configs import get_smoke_config
    from repro.core.hardware import ENVS as HW_ENVS
    from repro.core.portfolio import PlanPortfolio
    from repro.core.profiler import LayerTable

    cfg = get_smoke_config("phi3-mini-3.8b")
    table = LayerTable.from_model_config(cfg, 64)
    prof_d = Profile.analytic(table, HW_ENVS["D"]().sorted_by_memory(),
                              max_batch=8)
    model_axis = 4                       # --devices 8 -> (data=2, model=4)
    n_periods = cfg.n_layers // len(cfg.pattern)
    divisors = {d for d in range(1, model_axis + 1)
                if model_axis % d == 0 and d <= n_periods}
    pf = PlanPortfolio.enumerate(prof_d, 8, 2, arch=cfg.name,
                                 allowed_stages=divisors)
    finalists = pf.finalists(3)
    first = finalists[0]
    rec = {"suite": "portfolio", "kind": "predicted",
           "candidates": len(pf.candidates),
           "enumerated": pf.n_enumerated,
           "runnable": sum(1 for c in pf.candidates if c.runnable),
           "families": [c.family for c in pf.candidates],
           "first_choice": first.family,
           "first_choice_predicted_s": first.predicted_s,
           "finalist_spread":
               finalists[-1].predicted_s / max(first.predicted_s, 1e-12)}
    assert rec["candidates"] >= 3, rec
    lines = [row("portfolio/predicted", first.predicted_s,
                 candidates=rec["candidates"],
                 enumerated=rec["enumerated"],
                 first=first.family,
                 spread=f"{rec['finalist_spread']:.2f}x")]
    records = [rec]

    if runtime:
        steps = 6 if quick else 12
        out = _launch(["--portfolio", "3", "--probation-rounds", "2"], steps)
        m = re.search(r"^PORTFOLIO (\{.*\})$", out, re.M)
        assert m, out[-2000:]
        prec = json.loads(m.group(1))
        mrec = {"suite": "portfolio", "kind": "measured",
                "model": "phi3_mini", "steps": steps, **prec}
        # the two tentpole invariants, gated in CI
        assert mrec["winner_measured_s"] <= \
            mrec["first_choice_measured_s"] * (1 + 1e-9), mrec
        assert mrec["bit_identical"], mrec
        lines.append(row(
            "portfolio/runtime", mrec["winner_measured_s"],
            winner=mrec["winner"],
            first=mrec["first_choice"],
            gain=f"{mrec['measured_winner_gain']:.2f}x",
            finalists=mrec["finalists"],
            bit_identical=mrec["bit_identical"]))
        records.append(mrec)
    return lines, records


def run_structured(quick: bool = False, runtime: bool = True):
    models = ALL_MODELS[:1] if quick else ALL_MODELS
    envs = ENVS[:1] if quick else ENVS
    lines, records = _table4(models, envs)
    l2, r2 = _fig15a_quick(models)
    lines += l2
    records += r2
    if runtime:
        l3, r3 = _runtime_ablation(quick)
        lines += l3
        records += r3
    l5, r5 = _async_overlap(models, quick, runtime=runtime)
    lines += l5
    records += r5
    l4, r4 = _profile_gap(quick)
    lines += l4
    records += r4
    l6, r6 = _portfolio(quick, runtime=runtime)
    lines += l6
    records += r6
    return lines, records


def run(models=ALL_MODELS) -> list[str]:
    # analytic-only view for the plain CSV aggregator path
    lines, _ = _table4(models, ENVS)
    return lines
