"""Table 4: training throughput — Asteroid HPP vs single device / DP / PP.

Paper: 2.1x-6.8x over DP, 1.3x-12.2x over PP across Env A (100Mbps),
Env B (100Mbps), Env B (1000Mbps) for EfficientNet-B1 / MobileNetV2 /
ResNet-50 / BERT-small.

``run_structured`` additionally emits machine-readable records — the
Table 4 planner throughputs, the Fig. 15a intra-stage-planning ablation
(Algorithm 1 Phase 2 on/off, predicted), a *measured* ablation on the
real shard_map runtime (``repro.launch.train --plan [--no-offload]`` in a
subprocess with 8 host devices), and the ``profile_gap`` suite (the host
is profiled for real via ``repro.launch.profile.measure_model`` and plans
made on the analytic vs the measured profile are both evaluated against
the measured times — quantifying what measured profiling buys) — which
``benchmarks/run.py`` writes to ``BENCH_throughput.json`` so the
throughput trajectory is recorded across PRs (CI artifact).  See
benchmarks/README.md for the record schemas.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys

from repro.core.hardware import MBPS_100, MBPS_1000, env_a, env_b, env_c
from repro.core.planner import auto_microbatch, plan_dp, plan_gpipe, plan_hpp
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_BATCH, PAPER_MODELS

from .common import row

ENVS = [("A_100Mbps", lambda: env_a()),
        ("B_100Mbps", lambda: env_b(MBPS_100)),
        ("B_1000Mbps", lambda: env_b(MBPS_1000))]

ALL_MODELS = ("efficientnet-b1", "mobilenetv2", "resnet50", "bert-small")


def _table4(models, envs):
    lines, records = [], []
    for model in models:
        B = PAPER_BATCH[model]
        for env_name, mk in envs:
            cluster = mk().sorted_by_memory()
            prof = Profile.analytic(PAPER_MODELS[model](), cluster, max_batch=64)
            ours = auto_microbatch(prof, B, arch=model)
            mb = ours.micro_batch
            dp = plan_dp(prof, B, mb, heterogeneous=True)
            pp = plan_gpipe(prof, B, mb)
            # single strongest device (rank 0 after the memory sort)
            dev_t = prof.t_both(0, mb, 0, prof.table.L) * (B // mb)
            lines.append(row(
                f"table4/{model}/{env_name}", ours.latency,
                tput=f"{ours.throughput:.1f}",
                stages=len(ours.stages),
                speedup_device=f"{dev_t / ours.latency:.1f}x",
                speedup_dp=f"{dp.latency / ours.latency:.1f}x",
                speedup_pp=f"{pp.latency / ours.latency:.1f}x"))
            records.append({
                "suite": "table4", "model": model, "env": env_name,
                "tput_samples_s": ours.throughput, "stages": len(ours.stages),
                "speedup_vs_device": dev_t / ours.latency,
                "speedup_vs_dp": dp.latency / ours.latency,
                "speedup_vs_pp": pp.latency / ours.latency})
    return lines, records


def _fig15a_quick(models):
    """Fig. 15a intra-stage ablation, predicted: Algorithm 1 with and
    without Phase 2 (straggler workload offloading)."""
    lines, records = [], []
    for model in models:
        prof = Profile.analytic(PAPER_MODELS[model](),
                                env_c().sorted_by_memory(), max_batch=64)
        B = 2048
        full = plan_hpp(prof, B, 32, intra_opt=True)
        no_off = plan_hpp(prof, B, 32, intra_opt=False)
        lines.append(row(
            f"fig15a_quick/{model}", full.latency,
            full_tput=f"{full.throughput:.1f}",
            no_offload_tput=f"{no_off.throughput:.1f}",
            offload_gain=f"{no_off.latency / full.latency:.3f}x"))
        records.append({
            "suite": "fig15a", "model": model,
            "full_tput_samples_s": full.throughput,
            "no_offload_tput_samples_s": no_off.throughput,
            "offload_gain": no_off.latency / full.latency})
    return lines, records


def _runtime_ablation(quick: bool):
    """Measured Fig. 15a on the real runtime: the planner's allocation with
    and without Phase 2, executed by the shard_map pipeline (heterogeneous
    shard_alloc padding + weighted reduce) on 8 host devices."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    steps = "6" if quick else "20"
    lines, records = [], []
    for offload in (True, False):
        args = [sys.executable, "-m", "repro.launch.train", "--smoke",
                "--devices", "8", "--plan", "--steps", steps,
                "--global-batch", "8", "--seq", "64"]
        if not offload:
            args.append("--no-offload")
        proc = subprocess.run(args, capture_output=True, text=True,
                              timeout=1200, env=env, cwd=root)
        if proc.returncode != 0:
            raise RuntimeError(
                f"runtime ablation (offload={offload}) failed:\n"
                f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
        m = re.search(r"FINAL tok_s=([0-9.]+) loss=([0-9.]+)", proc.stdout)
        assert m, proc.stdout[-2000:]
        tok_s, loss = float(m.group(1)), float(m.group(2))
        tag = "offload" if offload else "no_offload"
        lines.append(row(f"fig15a_runtime/{tag}", 1.0 / max(tok_s, 1e-9),
                         tok_s=f"{tok_s:.1f}", loss=f"{loss:.4f}"))
        records.append({"suite": "fig15a_runtime", "offload": offload,
                        "tok_s": tok_s, "loss": loss, "steps": int(steps)})
    return lines, records


def _profile_gap(quick: bool):
    """Predicted-vs-measured latency gap, for both profile sources.

    The host is profiled for real (jitted per-layer sweeps, replicated to a
    4-device virtual cluster); one plan is made on the *analytic* model of
    those same devices (effective FLOP rate, Fig. 6 efficiency curve) and
    one on the *measured* tables.  Both are re-priced and simulated on the
    measured profile — the gap of the analytic plan is the misprediction
    that measured profiling removes (cf. AccEPT's observation that analytic
    edge estimates diverge on real devices).
    """
    from repro.configs import get_smoke_config
    from repro.core.profiler import LayerTable, Profile
    from repro.core.simulator import prediction_gap
    from repro.launch.profile import measure_model

    cfg = get_smoke_config("phi3-mini-3.8b")
    seq, B, mb, max_batch = 64, 8, 2, 8
    mp = measure_model(cfg, seq, batch_sizes=(1, 2, 4),
                       repeats=1 if quick else 3, replicate=4)
    table = LayerTable.from_model_config(cfg, seq)
    measured = mp.to_profile(table, max_batch)
    analytic = Profile.analytic(table, measured.cluster, max_batch)

    lines, records = [], []
    for src, prof in (("analytic", analytic), ("measured", measured)):
        plan = plan_hpp(prof, B, mb, arch=cfg.name)
        gap = prediction_gap(plan, measured)
        lines.append(row(
            f"profile_gap/{src}", plan.latency,
            predicted_s=f"{gap['predicted_s']:.4f}",
            measured_s=f"{gap['reference_s']:.4f}",
            gap=f"{gap['gap_ratio']:.2f}x",
            stages=len(plan.stages)))
        records.append({"suite": "profile_gap", "planned_on": src,
                        "arch": cfg.name, "seq": seq, "global_batch": B,
                        "stages": len(plan.stages), **gap})
    return lines, records


def run_structured(quick: bool = False, runtime: bool = True):
    models = ALL_MODELS[:1] if quick else ALL_MODELS
    envs = ENVS[:1] if quick else ENVS
    lines, records = _table4(models, envs)
    l2, r2 = _fig15a_quick(models)
    lines += l2
    records += r2
    if runtime:
        l3, r3 = _runtime_ablation(quick)
        lines += l3
        records += r3
    l4, r4 = _profile_gap(quick)
    lines += l4
    records += r4
    return lines, records


def run(models=ALL_MODELS) -> list[str]:
    # analytic-only view for the plain CSV aggregator path
    lines, _ = _table4(models, ENVS)
    return lines
