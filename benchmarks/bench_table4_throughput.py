"""Table 4: training throughput — Asteroid HPP vs single device / DP / PP.

Paper: 2.1x-6.8x over DP, 1.3x-12.2x over PP across Env A (100Mbps),
Env B (100Mbps), Env B (1000Mbps) for EfficientNet-B1 / MobileNetV2 /
ResNet-50 / BERT-small."""

from __future__ import annotations

from repro.core.hardware import MBPS_100, MBPS_1000, env_a, env_b
from repro.core.planner import auto_microbatch, plan_dp, plan_gpipe
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_BATCH, PAPER_MODELS

from .common import row

ENVS = [("A_100Mbps", lambda: env_a()),
        ("B_100Mbps", lambda: env_b(MBPS_100)),
        ("B_1000Mbps", lambda: env_b(MBPS_1000))]


def run(models=("efficientnet-b1", "mobilenetv2", "resnet50", "bert-small")) -> list[str]:
    rows = []
    for model in models:
        B = PAPER_BATCH[model]
        for env_name, mk in ENVS:
            cluster = mk().sorted_by_memory()
            prof = Profile.analytic(PAPER_MODELS[model](), cluster, max_batch=64)
            ours = auto_microbatch(prof, B, arch=model)
            mb = ours.micro_batch
            dp = plan_dp(prof, B, mb, heterogeneous=True)
            pp = plan_gpipe(prof, B, mb)
            # single strongest device (rank 0 after the memory sort)
            dev_t = prof.t_both(0, mb, 0, prof.table.L) * (B // mb)
            rows.append(row(
                f"table4/{model}/{env_name}", ours.latency,
                tput=f"{ours.throughput:.1f}",
                stages=len(ours.stages),
                speedup_device=f"{dev_t / ours.latency:.1f}x",
                speedup_dp=f"{dp.latency / ours.latency:.1f}x",
                speedup_pp=f"{pp.latency / ours.latency:.1f}x"))
    return rows
