"""Fig. 15: ablations.

(a) planning: naive (homogeneous, no memory/bandwidth awareness) ->
    + inter-stage planning -> + intra-stage planning (full Asteroid).
(b) 1F1B micro-batch scheduling: per-stage peak memory and throughput for
    K_p policies a / b / c / ours / gpipe — ours must have the smallest
    peak memory at comparable throughput.

The analytic (a) rows here predict the intra-stage gain; since the runtime
executes the lowered allocation (``TrainSpec.shard_alloc``), the same
ablation is also *measured* on the real shard_map pipeline by
``bench_table4_throughput._runtime_ablation`` (run via
``benchmarks/run.py --only table4 --quick``, which writes the
``BENCH_throughput.json`` CI artifact)."""

from __future__ import annotations

from repro.core.hardware import env_c
from repro.core.planner import auto_microbatch, plan_homogeneous_hpp, plan_hpp
from repro.core.profiler import Profile
from repro.core.simulator import simulate
from repro.core.hardware import JETSON_TX2, Cluster
from repro.configs.paper_models import PAPER_MODELS

from .common import row


def run() -> list[str]:
    rows = []
    # --- (a) planning ablation on Env C ---------------------------------
    for model in ("efficientnet-b1", "mobilenetv2"):
        prof = Profile.analytic(PAPER_MODELS[model](),
                                env_c().sorted_by_memory(), max_batch=64)
        B = 2048
        naive = plan_homogeneous_hpp(prof, B, 32, name="naive")
        inter = plan_hpp(prof, B, 32, intra_opt=False)
        full = plan_hpp(prof, B, 32, intra_opt=True)
        rows.append(row(
            f"fig15a/{model}", full.latency,
            naive_tput=f"{naive.throughput:.1f}",
            inter_tput=f"{inter.throughput:.1f}",
            full_tput=f"{full.throughput:.1f}",
            gain_vs_naive=f"{naive.latency / full.latency:.2f}x"))

    # --- (b) K_p policy comparison (3x TX2, EfficientNet-B1) --------------
    prof = Profile.analytic(PAPER_MODELS["efficientnet-b1"](),
                            Cluster((JETSON_TX2,) * 3).sorted_by_memory(),
                            max_batch=64)
    plan = plan_hpp(prof, 512, 16, max_stages=3)
    for policy in ("ours", "a", "b", "c", "gpipe"):
        res = simulate(plan, prof, policy=policy)
        rows.append(row(
            f"fig15b/kp_{policy}", res.makespan,
            peak_mem_mb=f"{res.max_peak_mem / 1e6:.0f}",
            tput=f"{plan.global_batch / res.makespan:.1f}"))
    return rows
