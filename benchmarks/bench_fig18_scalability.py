"""Fig. 18: scalability on 1-8 homogeneous Jetson Nanos, micro-batch 32 per
device (global batch 32*N), 100 Mbps.

Paper: Asteroid reaches 1.3x-2.2x over DP on EfficientNet-B1 and near-linear
scaling on MobileNetV2, while GPipe PP degrades with more stages and OOMs at
6+ devices."""

from __future__ import annotations

from repro.core.allocation import AllocationError
from repro.core.hardware import JETSON_NANO, Cluster
from repro.core.planner import auto_microbatch, plan_dp, plan_gpipe
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_MODELS

from .common import row


def run() -> list[str]:
    rows = []
    for model in ("efficientnet-b1", "mobilenetv2"):
        table = PAPER_MODELS[model]()
        for n in (1, 2, 4, 8):
            cluster = Cluster((JETSON_NANO,) * n)
            prof = Profile.analytic(table, cluster, max_batch=64)
            B = 32 * n
            ours = auto_microbatch(prof, B, arch=model)
            dp = plan_dp(prof, B, ours.micro_batch)

            def safe_pp():
                try:
                    p = plan_gpipe(prof, B, 32)
                    mems = p.memory_per_device(prof)
                    if any(m > JETSON_NANO.mem_bytes for m in mems.values()):
                        return "OOM"
                    return f"{p.throughput:.1f}"
                except AllocationError:
                    return "OOM"

            rows.append(row(
                f"fig18/{model}/n{n}", ours.latency,
                tput=f"{ours.throughput:.1f}",
                dp_tput=f"{dp.throughput:.1f}",
                pp_tput=safe_pp(),
                vs_dp=f"{dp.latency / ours.latency:.2f}x"))
    return rows
