"""Cross-PR perf-trend gate over the BENCH_*.json series.

Feed it a chronological series of benchmark records (oldest first, the
file under test last) and it renders a sparkline table per metric and
**fails (exit 1) when a throughput-direction metric in the newest file
regresses more than ``--threshold`` (default 10%) below the rolling
median of the previous ``--window`` files**:

    python -m benchmarks.trend artifacts/BENCH_fault_*.json BENCH_fault.json
    python -m benchmarks.trend --threshold 0.10 old1.json old2.json new.json

Ingests the fault-family documents (``suite: fig16`` — Fig. 16/17
records plus the elastic-membership ``churn``/``churn_summary`` keys),
throughput documents (``suite: throughput`` — table4 / fig15a /
fig15a_runtime / profile_gap records) and serving documents (``suite:
serve`` — planner-vs-uniform plan records + measured continuous-batching
records).  Per-record lists are aggregated to their mean per key; nested
summaries are flattened.  Higher-is-better metrics (throughput, tok/s,
speedups, gains) gate the exit code, and so do the serving tail-latency
percentiles (p50/p95/p99 — gated in the *opposite* direction: a >10%
rise fails).  Other wall-clock metrics (re-plan and recovery seconds)
are displayed with a ``v`` direction marker but carry too much host
noise to gate on.  Fewer than two ingestible files is a pass (nothing to
compare against yet).
"""

from __future__ import annotations

import argparse
import json
import sys
from statistics import median

SPARKS = "▁▂▃▄▅▆▇█"

#: higher-is-better name fragments (checked first: "recovery_speedup" gates)
_HIGHER = ("tput", "tok_s", "speedup", "gain", "throughput", "samples_s",
           "keep", "accepted_joins")
#: gated lower-is-better fragments: the serving planner's *predicted* tail
#: latencies are deterministic (analytic profile), so a rise is a real
#: planner/cost-model regression, not host noise
_GATED_LOWER = ("planner_p99", "uniform_p99", "predicted_p99",
                "predicted_p50")
#: lower-is-better fragments — displayed, never gated (host-noise wall time)
_LOWER = ("_s", "recovery", "stall", "latency", "overhead", "loss", "bytes")
#: identifiers / configuration, not performance
_IGNORE = ("event", "rank", "steps", "stages", "n_events", "quick", "seed",
           "boundary", "layers", "slots", "gap_ratio", "arrival")


def _direction(name: str) -> int:
    """+1 gated higher-is-better, -2 gated lower-is-better,
    -1 display-only lower-is-better, 0 skip."""
    leaf = name.rsplit(".", 1)[-1]
    if any(f in leaf for f in _IGNORE):
        return 0
    if any(f in leaf for f in _HIGHER):
        return 1
    if any(f in leaf for f in _GATED_LOWER):
        return -2
    if any(f in leaf for f in _LOWER):
        return -1
    return 0


def _numeric(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _aggregate(out: dict, prefix: str, records: list) -> None:
    """Mean of each numeric key across a list of record dicts."""
    cols: dict[str, list[float]] = {}
    for rec in records:
        if not isinstance(rec, dict):
            continue
        for k, v in rec.items():
            if _numeric(v):
                cols.setdefault(k, []).append(float(v))
    for k, vals in cols.items():
        out[f"{prefix}.{k}"] = sum(vals) / len(vals)


def _scalars(out: dict, prefix: str, doc: dict) -> None:
    """Numeric leaves of a (possibly nested) summary dict."""
    for k, v in doc.items():
        if _numeric(v):
            out[f"{prefix}.{k}"] = float(v)
        elif isinstance(v, dict):
            _scalars(out, f"{prefix}.{k}", v)


def extract_metrics(doc: dict) -> dict[str, float]:
    """Flatten one BENCH_*.json document to ``{metric_name: value}``."""
    out: dict[str, float] = {}
    suite = doc.get("suite")
    records = doc.get("records") or []
    if suite == "fig16":
        _aggregate(out, "fig16", records)
        _aggregate(out, "churn", doc.get("churn") or [])
        _scalars(out, "churn_summary", doc.get("churn_summary") or {})
    elif suite == "throughput":
        groups: dict[str, list] = {}
        for rec in records:
            if isinstance(rec, dict):
                sub = str(rec.get("suite", "rec"))
                groups.setdefault(sub, []).append(rec)
                # per-model series alongside the plain aggregate, so a
                # regression confined to one architecture (e.g. the RWKV
                # scan kernel) isn't averaged away by the others
                if rec.get("model"):
                    groups.setdefault(f"{sub}.{rec['model']}",
                                      []).append(rec)
        for name, recs in groups.items():
            _aggregate(out, name, recs)
    elif suite == "serve":
        groups = {}
        for rec in records:
            if isinstance(rec, dict):
                groups.setdefault(f"serve_{rec.get('kind', 'rec')}",
                                  []).append(rec)
        for name, recs in groups.items():
            _aggregate(out, name, recs)
    elif isinstance(doc, dict):
        _scalars(out, suite or "doc", doc)
    return out


def sparkline(values: list[float]) -> str:
    lo, hi = min(values), max(values)
    if hi - lo < 1e-12:
        return SPARKS[3] * len(values)
    return "".join(SPARKS[int((v - lo) / (hi - lo) * (len(SPARKS) - 1))]
                   for v in values)


def check(series: list[dict[str, float]], window: int = 8,
          threshold: float = 0.10) -> tuple[list[str], list[str]]:
    """Compare the last snapshot against the rolling median of up to
    ``window`` previous ones.  Returns (table_lines, regressions)."""
    lines: list[str] = []
    regressions: list[str] = []
    names = sorted({n for snap in series for n in snap})
    head = (f"{'metric':44s} {'trend':>10s} {'median':>12s} "
            f"{'latest':>12s} {'delta':>8s}  gate")
    lines.append(head)
    lines.append("-" * len(head))
    for name in names:
        vals = [snap[name] for snap in series if name in snap]
        direction = _direction(name)
        if name not in series[-1] or direction == 0:
            continue
        latest = series[-1][name]
        prior = [snap[name] for snap in series[:-1] if name in snap]
        prior = prior[-window:]
        spark = sparkline(vals[-(window + 1):])
        if not prior:
            lines.append(f"{name:44s} {spark:>10s} {'-':>12s} "
                         f"{latest:12.3f} {'-':>8s}  new")
            continue
        med = median(prior)
        delta = (latest - med) / med if med else 0.0
        gated = direction in (1, -2)
        bad = gated and (delta < -threshold if direction == 1
                         else delta > threshold)
        mark = ("REGRESSION" if bad else
                ("^ ok" if direction == 1 else
                 "v ok" if gated else "v info"))
        lines.append(f"{name:44s} {spark:>10s} {med:12.3f} "
                     f"{latest:12.3f} {delta:+7.1%}  {mark}")
        if bad:
            word = "below" if direction == 1 else "above"
            regressions.append(
                f"{name}: {latest:.3f} is {abs(delta):.1%} {word} the "
                f"rolling median {med:.3f} of the previous "
                f"{len(prior)} run(s)")
    return lines, regressions


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="sparkline trend + >threshold throughput-regression "
                    "gate over a chronological BENCH_*.json series")
    ap.add_argument("files", nargs="+",
                    help="benchmark JSON records, oldest first")
    ap.add_argument("--window", type=int, default=8,
                    help="rolling-median window over previous files")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max tolerated fractional throughput drop")
    args = ap.parse_args(argv)
    series: list[dict[str, float]] = []
    for path in args.files:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError) as exc:
            print(f"# skipping {path}: {exc}", file=sys.stderr)
            continue
        metrics = extract_metrics(doc)
        if metrics:
            series.append(metrics)
        else:
            print(f"# skipping {path}: no numeric metrics", file=sys.stderr)
    if len(series) < 2:
        print(f"trend: {len(series)} ingestible file(s) — nothing to "
              f"compare against yet, passing")
        return 0
    lines, regressions = check(series, args.window, args.threshold)
    print("\n".join(lines))
    if regressions:
        print(f"\ntrend: {len(regressions)} metric(s) regressed more than "
              f"{args.threshold:.0%}:", file=sys.stderr)
        for r in regressions:
            print(f"  - {r}", file=sys.stderr)
        return 1
    print(f"\ntrend: ok — no gated metric dropped more than "
          f"{args.threshold:.0%} vs the rolling median")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
