"""Fig. 14: time-to-accuracy comparison.

The paper trains EfficientNet-B1/MobileNetV2 to 85% on CIFAR-10: all
*synchronous* methods need the same number of epochs (identical update
semantics), so time-to-accuracy differences reduce to per-epoch time;
HetPipe's asynchronous staleness costs extra epochs (the paper cites
[55, 56]; we use its reported ~1.3× epoch inflation).  Asteroid reaches the
target 1.2×–6.1× faster than the baselines in the paper."""

from __future__ import annotations

from repro.core.hardware import env_b, env_c
from repro.core.planner import (auto_microbatch, plan_dp, plan_gpipe,
                                plan_hetpipe_hdp, plan_homogeneous_hpp)
from repro.core.profiler import Profile
from repro.configs.paper_models import PAPER_MODELS

from .common import row

EPOCH_SAMPLES = 50000
TARGET_EPOCHS = 40            # epochs to 85% for the sync methods
ASYNC_EPOCH_INFLATION = 1.3   # HetPipe staleness penalty


def run() -> list[str]:
    rows = []
    for model in ("efficientnet-b1", "mobilenetv2"):
        for env_name, mk in (("B", env_b), ("C", env_c)):
            prof = Profile.analytic(PAPER_MODELS[model](),
                                    mk().sorted_by_memory(), max_batch=64)
            B = 2048
            ours = auto_microbatch(prof, B, arch=model)
            rounds = EPOCH_SAMPLES / B * TARGET_EPOCHS

            def tta(latency, inflation=1.0):
                return latency * rounds * inflation

            t_ours = tta(ours.latency)
            t_eddl = tta(plan_dp(prof, B, ours.micro_batch).latency)
            t_pd = tta(plan_homogeneous_hpp(prof, B, ours.micro_batch).latency)
            het_lat, _ = plan_hetpipe_hdp(prof, B, ours.micro_batch)
            t_het = tta(het_lat, ASYNC_EPOCH_INFLATION)
            rows.append(row(
                f"fig14/{model}/env{env_name}", t_ours,
                tta_ours_h=f"{t_ours / 3600:.2f}",
                vs_eddl=f"{t_eddl / t_ours:.1f}x",
                vs_pipedream=f"{t_pd / t_ours:.1f}x",
                vs_hetpipe=f"{t_het / t_ours:.1f}x",
                paper_range="1.2x-6.1x"))
    return rows
