"""Elastic membership on the live runtime: join, drain, evict — not just
crash recovery.

``PipelineSession`` trains a small LM as an Asteroid HPP pipeline under
shard_map, then the membership controller (``core.replay``) drives three
planned transitions end-to-end, with the same analytical/runtime byte
reconciliation the crash path gets:

  1. **Mid-training join with on-arrival profiling** — a newcomer shows up
     with a *measured* layer sweep (``launch.profile.measure_model``, the
     artifact a joining board ships with its join request); admission
     re-prices the pipeline with the measured row appended, the accepted
     plan migrates boundary layers + replicates the joined stage's model
     onto the newcomer, and training continues on the faster plan.  Then
     the newcomer is **evicted** again: the join->evict round trip must
     hand back every parameter AND Adam moment bit-identically.
  2. **Graceful drain** — the sole owner of a stage leaves politely: it
     keeps serving while every one of its layers streams *directly* to the
     survivors (no backup involved), so the pipeline stalls only for the
     re-plan.  A crash after the churn shows the backup/replica story
     still lines up with the NEW arrangement.
  3. **Rejected admission under hysteresis** — an identical twin of the
     incumbents offers to join; the re-priced plan doesn't beat the
     incumbent by the hysteresis margin, so the offer is declined and the
     session keeps its jitted step, plan and profile untouched.

    PYTHONPATH=src python examples/elastic_membership.py [--quick]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import sys  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.core.hardware import (A100, JETSON_NANO, MBPS_1000,  # noqa: E402
                                 Cluster)
from repro.core.planner import plan_hpp  # noqa: E402
from repro.core.profiler import LayerTable, Profile  # noqa: E402
from repro.data import SyntheticLM  # noqa: E402
from repro.launch.profile import measure_model  # noqa: E402
from repro.runtime.session import PipelineSession  # noqa: E402

QUICK = "--quick" in sys.argv
B, S = 8, 32
cfg = get_smoke_config("phi3-mini-3.8b").replace(n_layers=8)
table = LayerTable.from_model_config(cfg, S)
ds = SyntheticLM(cfg.vocab_size, S)


def make_session(n_dev: int, model_axis: int, backup_every: int = 2,
                 allowed=None):
    prof = Profile.analytic(table, Cluster((JETSON_NANO,) * n_dev, MBPS_1000),
                            max_batch=B)
    plan = plan_hpp(prof, B, micro_batch=2, arch=cfg.name,
                    allowed_stages=allowed or {d for d in (1, 2, 4)
                                               if model_axis % d == 0})
    mesh = Mesh(np.array(jax.devices()[:model_axis]).reshape(1, model_axis),
                ("data", "model"))
    session = PipelineSession(cfg, mesh, plan, prof,
                              backup_every=backup_every)
    session.init(jax.random.PRNGKey(0))
    print(f"plan: {[(st.layers, st.group) for st in session.plan.stages]} "
          f"latency {session.plan.latency:.3f}s/round")
    return session


def snapshot(session):
    return ([np.asarray(jax.device_get(x)).copy()
             for x in jax.tree.leaves(session.params)],
            [np.asarray(jax.device_get(x)).copy()
             for x in jax.tree.leaves(session.opt_state.m)],
            [np.asarray(jax.device_get(x)).copy()
             for x in jax.tree.leaves(session.opt_state.v)])


# ===========================================================================
print("\n=== scenario 1: mid-training join (measured arrival) -> evict ===")
session = make_session(n_dev=4, model_axis=4)
losses = [session.step(ds.batch(s, B))[0] for s in range(3)]

# the newcomer's join request carries its on-arrival measured sweep — here
# the sweep runs on this host (a joining board would ship the artifact)
arrival = measure_model(cfg, S, batch_sizes=(1, 2, 4),
                        repeats=1 if QUICK else 2, mem_bytes=A100.mem_bytes)
print(f"on-arrival sweep: {arrival.D} device row(s), measured "
      f"~{arrival.est_flops[0] / 1e9:.1f} GFLOP/s effective")

pre = snapshot(session)
step0 = int(session.opt_state.step)
# permissive hysteresis: the demo pins the measured-arrival plumbing and
# the round trip, not this host's speed relative to a Jetson Nano
out = session.admit(arrival=arrival, hysteresis=-1.0)
assert out.accepted, out.decision.reason
dec = out.decision
rep = out.report
new_rank = len(session.profile.cluster.devices) - 1
holder = next(st for st in session.plan.stages if new_rank in st.group)
print(f"ADMITTED rank {new_rank} ({dec.reason}): re-priced "
      f"{dec.incumbent_latency:.3f}s -> {dec.candidate_latency:.3f}s/round; "
      f"replan {rep.replan_s * 1e3:.1f}ms, boundary moves "
      f"{[(m.lo, m.hi) for m in rep.boundary_moves]}, replica push "
      f"{rep.replicate_s:.3f}s onto stage {holder.layers}")
if out.reconciliation:
    for b, rec in out.reconciliation.items():
        assert rec["table_bytes"] == rec["analytic_bytes"], rec
    print(f"  migration bytes reconcile exactly at boundaries "
          f"{sorted(out.reconciliation)}  OK")

out = session.evict(new_rank)
assert out.accepted and new_rank not in session.live_ranks
print(f"EVICTED rank {new_rank}: stall {out.stall_s:.3f}s, back to "
      f"{[(st.layers, st.group) for st in session.plan.stages]}")

post = snapshot(session)
assert int(session.opt_state.step) == step0
for name, a_list, b_list in zip(("params", "adam.m", "adam.v"), pre, post):
    for a, b in zip(a_list, b_list):
        assert np.array_equal(a, b), f"{name} changed across join->evict"
print("join -> evict round trip: params + Adam moments bit-identical  OK")

losses += [session.step(ds.batch(s, B))[0] for s in range(3, 8)]
assert losses[-1] < losses[0]
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}: still converging  OK")

# ===========================================================================
print("\n=== scenario 2: graceful drain (direct streams) + later crash ===")
session = make_session(n_dev=3, model_axis=2, allowed={2})
losses = [session.step(ds.batch(s, B))[0] for s in range(3)]
leaver = next(st.group[0] for st in session.plan.stages
              if len(st.group) == 1)
out = session.drain(leaver)
assert out.accepted and out.report.mode == "drain"
rep = out.report
assert rep.direct_moves, "sole-owner drain must stream directly"
assert rep.restore_s == 0.0 and rep.detection_s == 0.0
print(f"DRAINED rank {leaver}: kept serving while "
      f"{sum(dm.nbytes for dm in rep.direct_moves) / 1e6:.2f} MB streamed "
      f"directly to {sorted({dm.dst_rank for dm in rep.direct_moves})}; "
      f"stall {out.stall_s:.3f}s (re-plan only, migration overlapped)")
if out.reconciliation and "direct" in out.reconciliation:
    rec = out.reconciliation["direct"]
    assert rec["table_bytes"] == rec["analytic_bytes"], rec
    print(f"  direct-stream bytes reconcile exactly "
          f"({rec['table_bytes'] / 1e6:.2f} MB)  OK")

losses += [session.step(ds.batch(s, B))[0] for s in range(3, 6)]
# the backup story tracks the NEW arrangement: a crash after the churn
# still recovers (DP peers / re-seeded backups, not the old plan's keys)
victim = session.live_ranks[-1]
session.fail(victim)
rec_out = session.recover_now()
print(f"rank {victim} crashed after the churn -> {rec_out.mode} recovery, "
      f"plan {[(st.layers, st.group) for st in session.plan.stages]}")
losses += [session.step(ds.batch(s, B))[0] for s in range(6, 10)]
assert all(np.isfinite(l) for l in losses)
assert losses[-1] < losses[0]
print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}: survived drain + crash  OK")

# ===========================================================================
print("\n=== scenario 3: admission rejected under hysteresis ===")
session = make_session(n_dev=4, model_axis=4)
plan0, ts0, prof0 = session.plan, session.ts, session.profile
[session.step(ds.batch(s, B))[0] for s in range(2)]
# an identical twin of the incumbents: the re-cut can't beat the incumbent
# plan by the (deliberately strict) hysteresis margin
out = session.admit(JETSON_NANO, hysteresis=0.9)
assert not out.accepted
print(f"REJECTED ({out.decision.reason}): priced "
      f"{out.decision.incumbent_latency:.3f}s -> "
      f"{out.decision.candidate_latency:.3f}s/round in "
      f"{out.stall_s * 1e3:.1f}ms of pricing work")
assert session.plan is plan0 and session.ts is ts0 and \
    session.profile is prof0
assert session.live_ranks == (0, 1, 2, 3)
loss, _ = session.step(ds.batch(2, B))
assert np.isfinite(loss)
print("incumbent plan, jitted step and profile untouched  OK")

print("\nALL OK")
