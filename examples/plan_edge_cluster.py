"""Reproduce the paper's planning configurations (Fig. 12 style): show the
HPP plan Asteroid picks for each model x edge environment, illustrating the
paper's qualitative claims — CNNs get DP on early (parameter-light,
activation-heavy) layers and PP on late layers; BERT gets a straight
pipeline.

    PYTHONPATH=src python examples/plan_edge_cluster.py
"""

from repro.configs.paper_models import PAPER_BATCH, PAPER_MODELS
from repro.core.hardware import ENVS, MBPS_1000, env_b
from repro.core.planner import auto_microbatch
from repro.core.profiler import Profile

SETTINGS = [("A", "100Mbps", lambda: ENVS["A"]()),
            ("B", "100Mbps", lambda: ENVS["B"]()),
            ("B", "1000Mbps", lambda: env_b(MBPS_1000))]

for model in ("efficientnet-b1", "mobilenetv2", "resnet50", "bert-small"):
    print(f"\n=== {model} (global batch {PAPER_BATCH[model]}) ===")
    for env_name, bw, mk in SETTINGS:
        cluster = mk().sorted_by_memory()
        prof = Profile.analytic(PAPER_MODELS[model](), cluster, max_batch=64)
        plan = auto_microbatch(prof, PAPER_BATCH[model], arch=model)
        desc = " | ".join(
            f"L{st.layers[0]}-{st.layers[1]}:" +
            "+".join(cluster.devices[d].name[0].upper() for d in st.group)
            for st in plan.stages)
        print(f"  Env {env_name} ({bw}): {len(plan.stages)} stages "
              f"[{desc}] tput={plan.throughput:.0f}/s")
