"""End-to-end driver: train a language model with the distributed HPP
runtime (circular pipeline x data parallel x tensor parallel) on virtual
devices, demonstrating loss convergence and checkpointing.

Default is CPU-sized; ``--full`` trains a ~100M-parameter model for a few
hundred steps (the assignment's reference workload — slow on one CPU core,
exactly the same code on a TPU slice).

    PYTHONPATH=src python examples/train_hpp.py [--full]
"""

import argparse
import os
import sys

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params, 200 steps (slow on CPU)")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.argv = [sys.argv[0], "--arch", "phi3-mini-3.8b", "--smoke",
            "--global-batch", "16", "--seq", "128",
            "--steps", str(args.steps or (200 if args.full else 30)),
            "--log-every", "5",
            "--checkpoint-dir", "/tmp/repro_ckpt"]
if args.full:
    # ~100M params: 12 layers x d_model 768 on the phi3-mini skeleton
    sys.argv += ["--d-model", "768", "--n-layers", "12", "--seq", "256"]

from repro.launch.train import main  # noqa: E402

final_loss = main()
assert final_loss < 6.0, f"loss did not improve: {final_loss}"
print(f"OK: final loss {final_loss:.3f} (started ~ln(vocab)=6.2+)")
