"""Heterogeneous intra-stage allocation (Algorithm 1) on the real runtime.

A micro-batch is split unevenly — y=(3,1) — across the 2-wide data axis:
the strong shard carries 3 samples of every micro-batch, the weak one
carries 1, padded to B_max=3 with a static validity mask
(DESIGN.md §2.1).  The loss/gradient reductions are weighted by the true
per-shard counts, so the unbalanced run computes exactly the same
gradients as the uniform baseline on the same global batch.

    PYTHONPATH=src python examples/hetero_allocation.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.data import SyntheticLM  # noqa: E402
from repro.runtime.train import build_train_step, init_train_state  # noqa: E402

B, S, M, STEPS = 16, 64, 4, 4

cfg = get_smoke_config("phi3-mini-3.8b")
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))

# uniform baseline: each of the 2 data shards carries 2 samples/micro-batch
ts_u = build_train_step(cfg, mesh, global_batch=B, stage=2, n_micro=M)
# heterogeneous: shard 0 carries 3, shard 1 carries 1 (padded to B_max=3)
ts_h = build_train_step(cfg, mesh, global_batch=B, stage=2, n_micro=M,
                        shard_alloc=(3, 1))
print(f"uniform spec: shard_alloc={ts_u.spec.shard_alloc or 'uniform'}; "
      f"hetero spec: shard_alloc={ts_h.spec.shard_alloc}")

key = jax.random.PRNGKey(0)
ds = SyntheticLM(cfg.vocab_size, S)
batch_np = ds.batch(0, B)
params_u, _ = init_train_state(key, ts_u)
params_h, _ = init_train_state(key, ts_h)

(_, mu), gu = ts_u.grad_fn(params_u, ts_u.shard_batch(batch_np))
(_, mh), gh = ts_h.grad_fn(params_h, ts_h.shard_batch(batch_np))
worst = max(float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(gu), jax.tree.leaves(gh)))
print(f"ce uniform={float(mu['ce']):.6f} hetero={float(mh['ce']):.6f} "
      f"worst grad diff={worst:.2e}")
assert worst < 1e-4

# train a few steps through the padded pipeline
params, opt_state = init_train_state(key, ts_h)
for step in range(STEPS):
    batch = ts_h.shard_batch(ds.batch(step, B))
    params, opt_state, loss, metrics = ts_h.step_fn(params, opt_state, batch)
    print(f"step {step} loss {float(loss):.4f} ce {float(metrics['ce']):.4f}")
print("done")
