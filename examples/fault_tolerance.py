"""Fault-tolerant pipeline replay, live (§3.4 end-to-end).

Trains a small LM as an Asteroid HPP pipeline over a *simulated* edge
cluster (each "device" owns a stage partition of the params, executed
locally), with:

  1. heartbeat-guided failure detection (simulated clock),
  2. topology-driven stage replication (single-device stages checkpoint to a
     backup node in the next stage),
  3. layer-wise lightweight re-planning + concurrent layer migration,

then *continues training* after a device failure and shows the loss keeps
improving and the recovered weights are bit-identical where untouched.

    PYTHONPATH=src python examples/fault_tolerance.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import StageBackupStore
from repro.configs import get_smoke_config
from repro.core.hardware import env_d
from repro.core.planner import plan_hpp
from repro.core.profiler import LayerTable, Profile
from repro.core.replay import (assign_backups, detection_latency,
                               lightweight_replay)
from repro.data import SyntheticLM
from repro.models.model import init_model, loss_fn
from repro.models.module import tree_bytes
from repro.optim import AdamW

# ---------------------------------------------------------------------------
# Setup: plan a pipeline for the smoke model on Env D (1x TX2 + 3x Nano)
# ---------------------------------------------------------------------------

cfg = get_smoke_config("phi3-mini-3.8b").replace(n_layers=4)
table = LayerTable.from_model_config(cfg, seq_len=64)
profile = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=32)
plan = plan_hpp(profile, global_batch=32, micro_batch=8, arch=cfg.name)
print(f"plan: {[(s.layers, s.group) for s in plan.stages]}")

# the simulated cluster: params live as one tree; each stage's layer range
# maps to period indices (embed/head belong to first/last stage)
key = jax.random.PRNGKey(0)
params = init_model(key, cfg)
opt = AdamW(lr=1e-3)
opt_state = opt.init(params)
ds = SyntheticLM(cfg.vocab_size, 64)


@jax.jit
def train_step(params, opt_state, batch):
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
    new_params, new_opt = opt.update(grads, opt_state, params)
    return new_params, new_opt, loss


# ---------------------------------------------------------------------------
# Replication: single-device stages back up to the next stage's device
# ---------------------------------------------------------------------------

assign = assign_backups(plan, profile)
store = StageBackupStore()
print(f"backup topology: {assign.backup_of_stage} "
      f"(stage -> backup device rank)")


def stage_params_slice(params, stage):
    """The period slice owned by a pipeline stage (model layers only)."""
    i, j = stage.layers
    lo = max(i - 1, 0)                 # table layer 0 is the embedding
    hi = min(j - 1, cfg.n_periods)
    sl = jax.tree.map(lambda x: x[lo:hi], params["periods"])
    return sl, (lo, hi)


losses = []
CLOCK = 0.0
FAIL_AT = 12


def heartbeat_ok(step, failed):
    return not (failed and step >= FAIL_AT)


failed_rank = plan.stages[-1].group[0]
for step in range(25):
    batch = {k: jnp.asarray(v) for k, v in ds.batch(step, 32).items()}
    # periodic topology-driven replication (every 5 rounds)
    if step % 5 == 0:
        for p, st in enumerate(plan.stages):
            if p in assign.backup_of_stage:
                sl, _ = stage_params_slice(params, st)
                store.backup(p, sl)
    if step == FAIL_AT:
        # --- device failure: heartbeats stop ---------------------------
        det = detection_latency(fail_time=float(step))
        rep = lightweight_replay(plan, profile, failed_rank)
        print(f"step {step}: device {failed_rank} FAILED — detected in "
              f"{det:.2f}s, lightweight replay re-planned "
              f"{len(rep.new_plan.stages)} stages in {rep.total_s:.2f}s "
              f"(vs heavy rescheduling; see benchmarks/fig16)")
        # restore the failed stage's weights from its backup node
        for p, st in enumerate(plan.stages):
            if failed_rank in st.group and p in assign.backup_of_stage:
                restored = store.restore(p)
                sl, (lo, hi) = stage_params_slice(params, st)
                same = all(bool(jnp.allclose(a, b)) for a, b in zip(
                    jax.tree.leaves(restored), jax.tree.leaves(sl)))
                print(f"  stage {p} weights restored from backup rank "
                      f"{assign.backup_of_stage[p]} "
                      f"({tree_bytes(restored)/1e6:.1f} MB, "
                      f"{'stale-by-<=5-steps' if not same else 'exact'})")
        plan = rep.new_plan
    params, opt_state, loss = train_step(params, opt_state, batch)
    losses.append(float(loss))

print(f"loss: start {losses[0]:.3f} -> pre-failure {losses[FAIL_AT-1]:.3f} "
      f"-> final {losses[-1]:.3f}")
assert losses[-1] < losses[0], "training did not continue improving"
print("OK: training survived the device failure and kept converging")
