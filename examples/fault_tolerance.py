"""Fault-tolerant pipeline replay, live (§3.4 end-to-end) — on the REAL
distributed runtime.

A ``PipelineSession`` (repro.runtime.session) trains a small LM as an
Asteroid HPP pipeline under shard_map on 8 host devices, then survives a
mid-training device failure without re-initializing:

  1. heartbeat-guided failure detection (``ReplayCoordinator`` state
     machine: heartbeat -> probe -> confirm -> replan -> migrate -> resume),
  2. topology-driven stage replication (single-device stages push period
     rows to a backup node on a step cadence),
  3. layer-wise lightweight re-planning, then a *pure index migration* of
     the stacked period params + optimizer moments onto the re-lowered
     plan (``core.lowering.migrate_params``), restore of the failed stage
     from its backup, and a re-jitted train step.

Two scenarios:

  * **migration** — a device in a multi-device stage dies; the stage
    survives with its DP peer, boundary periods migrate toward the other
    stage, and ``reconcile_migration`` asserts the bytes moved equal the
    analytical ``RecoveryReport``'s migration inputs *exactly*.
  * **restore** — a single-device stage dies entirely; the pipeline
    collapses to one stage (tp widens to the full model axis), its periods
    are restored bit-identically from the backup replica.

In both, periods untouched by migration/restore stay bit-identical and the
loss keeps improving after recovery.

    PYTHONPATH=src python examples/fault_tolerance.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.core.hardware import Cluster, env_d  # noqa: E402
from repro.core.lowering import period_positions  # noqa: E402
from repro.core.planner import plan_hpp  # noqa: E402
from repro.core.profiler import LayerTable, Profile  # noqa: E402
from repro.data import SyntheticLM  # noqa: E402
from repro.runtime.session import PipelineSession  # noqa: E402

B, S = 8, 64
cfg = get_smoke_config("phi3-mini-3.8b").replace(n_layers=8)
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
table = LayerTable.from_model_config(cfg, S)
ds = SyntheticLM(cfg.vocab_size, S)


def run_scenario(name: str, cluster: Cluster, fail_pick, allowed_stages,
                 expect_mode: str) -> None:
    print(f"\n=== scenario: {name} ===")
    prof = Profile.analytic(table, cluster.sorted_by_memory(), max_batch=B)
    plan = plan_hpp(prof, B, micro_batch=2, arch=cfg.name,
                    allowed_stages=allowed_stages)
    session = PipelineSession(cfg, mesh, plan, prof, backup_every=2)
    session.init(jax.random.PRNGKey(0))
    print(f"plan: {[(st.layers, st.group) for st in session.plan.stages]} "
          f"periods={session.lowered.stage_periods} "
          f"M={session.lowered.n_micro}")

    losses = [session.step(ds.batch(s, B))[0] for s in range(6)]

    # snapshot the arranged period stack before the failure
    old_pos = period_positions(session.lowered)
    pre = [np.asarray(jax.device_get(x))
           for x in jax.tree.leaves(session.params["periods"])]

    failed_rank = fail_pick(session.plan)
    print(f"step {session.step_count}: device {failed_rank} FAILS "
          f"(heartbeats stop at t={session.clock:.1f}s)")
    session.fail(failed_rank)
    out = session.recover_now()

    assert out.mode == expect_mode, (out.mode, expect_mode)
    rep = out.report
    print(f"  coordinator: "
          f"{' -> '.join(s for s, _, _ in session.coordinator.events[-6:])}")
    print(f"  detected in {out.detection_observed_s:.2f}s (analytical "
          f"{rep.detection_s:.2f}s), {out.mode} replay: replan "
          f"{rep.replan_s * 1e3:.1f}ms + migrate {rep.migration_s:.2f}s + "
          f"restore {rep.restore_s:.2f}s")
    print(f"  new plan: {[(st.layers, st.group) for st in session.plan.stages]}"
          f" periods={session.lowered.stage_periods} "
          f"tp={session.ts.spec.plan.tp}")
    print(f"  migrated periods {out.migration.moved_periods} "
          f"({out.migration.total_bytes / 1e6:.1f} MB), restored "
          f"{out.restored_periods} from stage {out.restored_stage} backup")

    # 1) runtime migration bytes == analytical RecoveryReport inputs: the
    #    moved periods re-priced with the profiler's layer table must equal
    #    the analytical bytes exactly (actual array bytes shown alongside)
    if out.reconciliation is not None:
        for b, rec in out.reconciliation.items():
            assert rec["table_bytes"] == rec["analytic_bytes"], rec
            print(f"  boundary {b}: moved periods price to "
                  f"{rec['table_bytes'] / 1e6:.2f} MB == analytical "
                  f"{rec['analytic_bytes'] / 1e6:.2f} MB "
                  f"(array bytes {rec['runtime_bytes'] / 1e6:.2f} MB)  OK")

    # 2) periods untouched by migration/restore are bit-identical
    new_pos = period_positions(session.lowered)
    post = [np.asarray(jax.device_get(x))
            for x in jax.tree.leaves(session.params["periods"])]
    touched = set(out.migration.moved_periods) | set(out.restored_periods)
    untouched = [t for t in range(session.lowered.n_periods)
                 if t not in touched]
    for t in untouched:
        for a, b in zip(pre, post):
            assert np.array_equal(a[old_pos[t]], b[new_pos[t]]), \
                f"period {t} changed bits across the migration"
    print(f"  untouched periods {untouched} bit-identical  OK")

    # 3) training continues to improve on the replayed pipeline
    losses += [session.step(ds.batch(s, B))[0] for s in range(6, 18)]
    print(f"  loss: start {losses[0]:.3f} -> pre-failure {losses[5]:.3f} "
          f"-> final {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training did not continue improving"
    print(f"  OK: {name} recovery kept the pipeline converging")


def pick_multi_device_rank(plan):
    """A device whose stage has a DP peer -> pure-migration recovery."""
    st = max(plan.stages, key=lambda s: len(s.group))
    return st.group[-1]


def pick_single_device_rank(plan):
    """The device of a single-device stage -> backup-restore recovery."""
    st = next(s for s in plan.stages if len(s.group) == 1)
    return st.group[0]


# Env D (1x TX2 + 3x Nano), 2 stages: one stage gets multiple devices —
# failing one member keeps the stage alive and shifts the boundary, so the
# recovery is a pure lightweight migration (with byte reconciliation).
run_scenario("migration (DP peer survives)", env_d(),
             pick_multi_device_rank, allowed_stages={2},
             expect_mode="lightweight")

# Two devices, one per stage: failing one kills a whole stage — the
# pipeline collapses to a single stage (tp widens 2 -> 4) and the lost
# periods are restored from the backup node, stale by <= backup_every.
cl = env_d().sorted_by_memory()
run_scenario("restore (whole stage lost)",
             Cluster(cl.devices[:2], cl.bandwidth), pick_single_device_rank,
             allowed_stages={2}, expect_mode="lightweight")

# 4 single-device stages: a failure leaves 3 survivors, which does not
# divide the mesh model axis — the session falls back to heavy
# rescheduling (Algorithm 2 from scratch) restricted to lowerable stage
# counts, still migrating/restoring state instead of re-initializing.
run_scenario("heavy fallback (survivor count not lowerable)", env_d(),
             pick_single_device_rank, allowed_stages={4},
             expect_mode="heavy")

print("\nOK: training survived all three device failures without restarting")
