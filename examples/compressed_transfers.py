"""Compressed boundary transfers + bucketed gradient AllReduce (DESIGN.md
§10), end to end.

1. quantize -> ppermute -> dequantize round trip: the int8/fp8 wire format
   (per-tile f32 scales) crossing a real device permutation, with the
   error-feedback residual telescoping the quantization bias away,
2. the bucketed gradient stream: how gradient leaves pack into
   size-bounded buckets by their free mesh axes, and the compressed
   overlap timeline the planner prices,
3. a planner diff: the same model/cluster planned with and without the
   compression term — what the quantized wire buys on a 100 Mbps edge
   link.

    PYTHONPATH=src python examples/compressed_transfers.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.distributed.compat import shard_map  # noqa: E402
from repro.core.costmodel import CompressionConfig  # noqa: E402
from repro.core.hardware import MBPS_100, env_b  # noqa: E402
from repro.core.planner import plan_hpp  # noqa: E402
from repro.core.profiler import LayerTable, Profile  # noqa: E402
from repro.data import SyntheticLM  # noqa: E402
from repro.kernels.quant_transfer import (  # noqa: E402
    dequantize_op, quantize_op, roundtrip, roundtrip_ef, wire_bits)
from repro.models.frontend import frontend_dim  # noqa: E402
from repro.runtime.train import (  # noqa: E402
    build_train_step, init_train_state)

B, S, M, TILE = 8, 64, 4, 256

# ---------------------------------------------------------------------------
# 1. the wire format, round-tripped through a real ppermute
# ---------------------------------------------------------------------------
print("=== 1. quantize -> ppermute -> dequantize round trip ===")
x = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 128), jnp.float32)
for fmt in ("int8", "fp8"):
    packed = quantize_op(x, fmt=fmt, tile=TILE)
    x_hat = dequantize_op(packed, x.shape, x.dtype, tile=TILE)
    rel = float(jnp.max(jnp.abs(x - x_hat)) / jnp.max(jnp.abs(x)))
    bits = wire_bits(fmt, TILE)
    print(f"  {fmt}: {bits:.2f} bits/elem on the wire "
          f"({bits / 32:.3f}x of f32), round-trip rel err {rel:.4f}")

# the same payload crossing a device ring: what the pipeline's boundary
# hop does when TrainSpec.compress != "none"
devs = jax.devices()[:8]
ring = [(i, (i + 1) % 8) for i in range(8)]
packed = quantize_op(x, fmt="int8", tile=TILE)


_ring_mesh = Mesh(np.array(devs), ("r",))


@jax.jit
def _ring_hop(q, s):
    f = lambda t: jax.lax.ppermute(t, "r", ring)
    return shard_map(
        lambda a, b: (f(a), f(b)), mesh=_ring_mesh,
        in_specs=jax.sharding.PartitionSpec(None),
        out_specs=jax.sharding.PartitionSpec(None), check_vma=False)(q, s)


q2, s2 = _ring_hop(packed["q"], packed["scale"])
x_hop = dequantize_op({"q": q2, "scale": s2}, x.shape, x.dtype, tile=TILE)
x_ref = dequantize_op(packed, x.shape, x.dtype, tile=TILE)
print(f"  ppermute hop preserves the payload bit-exactly: "
      f"{bool(jnp.array_equal(x_hop, x_ref))}")

# error feedback: the residual carries what quantization dropped, so the
# *sum* of T compressed rounds converges on the sum of the raw tensors
err = jnp.zeros_like(x)
tot = jnp.zeros_like(x)
T = 8
for _ in range(T):
    x_hat, err = roundtrip_ef(x, err, fmt="int8", tile=TILE)
    tot = tot + x_hat
one_shot = float(jnp.max(jnp.abs(roundtrip(x, fmt="int8", tile=TILE) - x)))
bias = float(jnp.max(jnp.abs(tot / T - x)))
print(f"  error feedback over {T} rounds: per-round bias {bias:.2e} vs "
      f"one-shot {one_shot:.2e} ({one_shot / max(bias, 1e-12):.0f}x smaller)")

# ---------------------------------------------------------------------------
# 2. the bucketed gradient stream on the real runtime
# ---------------------------------------------------------------------------
print("\n=== 2. bucketed + compressed gradient AllReduce ===")
cfg = get_smoke_config("phi3-mini-3.8b")
mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
ts = build_train_step(cfg, mesh, global_batch=B, stage=2, n_micro=M,
                      compress="int8", bucket_mb=4.0)
print(f"  spec: compress={ts.spec.compress} bucket_mb={ts.spec.bucket_mb} "
      f"error_feedback={ts.spec.error_feedback}")
print(f"  {len(ts.buckets)} buckets (leaves grouped by the mesh axes their "
      f"psum reduces over, packed to the size cap):")
for bi, (free, idxs, sizes) in enumerate(ts.buckets):
    mb = sum(sizes) * 4 / 2**20
    print(f"    bucket {bi}: reduce over {free or '(none)'} — "
          f"{len(idxs)} leaves, {sum(sizes):,} elems "
          f"({mb:.2f} MiB raw, {mb * (8 + 32 / TILE) / 32:.2f} MiB wired)")

key = jax.random.PRNGKey(0)
params, opt_state = init_train_state(key, ts)
ds = SyntheticLM(cfg.vocab_size, S, n_codebooks=cfg.n_codebooks,
                 prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))
batch = ts.shard_batch(ds.batch(0, B))
ef = ts.init_ef()
(loss0, _), grads, ef = ts.grad_fn(params, batch, ef)
ef_mag = max(float(jnp.max(jnp.abs(v))) for v in jax.tree.leaves(ef))
print(f"  one compressed grad round: loss {float(loss0):.4f}, "
      f"largest carried residual {ef_mag:.2e}")
params, opt_state, ef, l0, _ = ts.step_fn(params, opt_state, ef, batch)
l1, _ = ts.loss_fn(params, batch)
print(f"  compressed step: loss {float(l0):.4f} -> {float(l1):.4f}")

# ---------------------------------------------------------------------------
# 3. the planner diff: what the quantized wire buys at 100 Mbps
# ---------------------------------------------------------------------------
print("\n=== 3. plan with vs without the compression term ===")
table = LayerTable.from_model_config(cfg, S)
cluster = env_b(MBPS_100).sorted_by_memory()
prof = Profile.analytic(table, cluster, max_batch=B)
raw = plan_hpp(prof, B, micro_batch=2, arch=cfg.name, staleness=1)
comp = plan_hpp(prof, B, micro_batch=2, arch=cfg.name, staleness=1,
                compress=CompressionConfig(fmt="int8", tile=TILE,
                                           bucket_mb=4.0))
auto = plan_hpp(prof, B, micro_batch=2, arch=cfg.name, staleness=1,
                compress="auto")
print(f"  raw wire:        {raw.latency * 1e3:8.1f} ms/round")
print(f"  int8 wire:       {comp.latency * 1e3:8.1f} ms/round "
      f"({raw.latency / comp.latency:.2f}x)")
print(f"  compress='auto': {auto.latency * 1e3:8.1f} ms/round — planner "
      f"chose {auto.compress.fmt if auto.compress else 'no compression'}")
for tag, plan in (("raw", raw), ("int8", comp)):
    comm = [s for s in plan.steps if s.kind == "comm"]
    if comm:
        print(f"    {tag}: boundary transfer {comm[0].ef * 1e3:.2f} ms fwd / "
              f"{comm[0].eb * 1e3:.2f} ms bwd per micro-batch")
assert comp.latency <= raw.latency * (1 + 1e-9)
print("\nOK: compressed plan is never priced slower than the raw plan")
