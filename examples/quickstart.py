"""Quickstart: plan an Asteroid HPP configuration for a heterogeneous edge
cluster and compare it against DP / PP — the paper's core result in 30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.configs.paper_models import PAPER_MODELS
from repro.core.hardware import env_c
from repro.core.planner import auto_microbatch, plan_dp, plan_gpipe
from repro.core.profiler import Profile
from repro.core.simulator import simulate

# 1. Profile the model on the cluster (1x NX + 2x TX2 + 3x Nano, 100 Mbps).
table = PAPER_MODELS["efficientnet-b1"]()
cluster = env_c().sorted_by_memory()
profile = Profile.analytic(table, cluster, max_batch=64)

# 2. Run the Asteroid planner (Algorithm 2 + Algorithm 1 inside).
plan = auto_microbatch(profile, global_batch=2048, arch="efficientnet-b1")
print(f"Asteroid plan: {len(plan.stages)} stages, micro-batch "
      f"{plan.micro_batch} x {plan.n_micro}")
for p, st in enumerate(plan.stages):
    devs = [cluster.devices[d].name for d in st.group]
    print(f"  stage {p}: layers {st.layers} on {devs}, samples {st.alloc}, "
          f"K_p={st.k_p}")

# 3. Validate the dominant-step estimate with the event-accurate simulator.
sim = simulate(plan, profile, policy="ours")
print(f"predicted round latency {plan.latency:.2f}s, simulated "
      f"{sim.makespan:.2f}s, peak device memory "
      f"{sim.max_peak_mem / 1e9:.2f} GB")

# 4. Compare with the conventional baselines.
dp = plan_dp(profile, 2048, plan.micro_batch)
pp = plan_gpipe(profile, 2048, plan.micro_batch)
print(f"throughput: Asteroid {plan.throughput:.0f} samples/s | "
      f"DP {dp.throughput:.0f} ({dp.latency / plan.latency:.1f}x slower) | "
      f"PP {pp.throughput:.0f} ({pp.latency / plan.latency:.1f}x slower)")
