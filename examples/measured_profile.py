"""Measured on-device profiling: profile THIS host, plan on the measured
times, and diff the plan against the analytic one (paper §3.3; DESIGN.md §3).

    PYTHONPATH=src python examples/measured_profile.py

1. runs the real jitted per-layer (tf, tb) sweep on the local device and
   replicates it into a 4-device virtual cluster,
2. round-trips the artifact through save_profile/load_profile (bit-exact),
3. plans the same workload on the measured profile and on the calibrated
   analytic model of the same devices,
4. prints the plan diff and the predicted-vs-measured latency gap of both
   — the quantity measured profiling exists to shrink.
"""

import os
import tempfile

from repro.configs import get_smoke_config
from repro.core.profiler import LayerTable, Profile, load_profile, save_profile
from repro.core.planner import plan_hpp
from repro.core.simulator import prediction_gap
from repro.launch.profile import measure_model

SEQ, GLOBAL_BATCH, MICRO_BATCH, MAX_BATCH = 64, 8, 2, 8

# 1. Measure the host (smoke-sized model keeps this a few seconds on CPU).
cfg = get_smoke_config("phi3-mini-3.8b")
print(f"measuring {cfg.name} seq={SEQ} on this host ...")
mp = measure_model(cfg, SEQ, batch_sizes=(1, 2, 4), repeats=2, replicate=4)
for li, name in enumerate(mp.layer_names):
    print(f"  {name:>8s}  fwd {mp.tf[0, -1, li] * 1e3:7.3f} ms   "
          f"bwd {mp.tb[0, -1, li] * 1e3:7.3f} ms   (batch {mp.batch_sizes[-1]})")

# 2. Serialize and reload — the artifact is what a real deployment ships
#    from each edge device to the planner host.
path = os.path.join(tempfile.gettempdir(), "asteroid_host_profile.json")
save_profile(path, mp)
mp = load_profile(path)
assert mp.compatibility_issues(cfg, SEQ) == [], "artifact went stale?!"
print(f"artifact round-tripped through {path}")

# 3. Plan on measured vs on the calibrated analytic model of the SAME
#    devices (effective FLOP rate, linear batch scaling).
table = LayerTable.from_model_config(cfg, SEQ)
measured = mp.to_profile(table, MAX_BATCH)
analytic = Profile.analytic(table, measured.cluster, MAX_BATCH)
plans = {src: plan_hpp(prof, GLOBAL_BATCH, MICRO_BATCH, arch=cfg.name)
         for src, prof in (("analytic", analytic), ("measured", measured))}

print("\nplan diff (same workload, same devices, different profile):")
for src, plan in plans.items():
    stages = [(st.layers, st.alloc) for st in plan.stages]
    print(f"  {src:>8s}: {len(plan.stages)} stages {stages} "
          f"M={plan.n_micro} predicted latency {plan.latency * 1e3:.2f} ms")

# 4. Both plans priced against reality (the measured tables).
print("\npredicted vs measured round latency:")
for src, plan in plans.items():
    gap = prediction_gap(plan, measured)
    print(f"  planned on {src:>8s}: predicted {gap['predicted_s'] * 1e3:7.2f} ms"
          f" | on measured times {gap['reference_s'] * 1e3:7.2f} ms"
          f" | gap {gap['gap_ratio']:.2f}x")
print("\nthe 'analytic' gap is what the paper's measured profiler removes; "
      "the 'measured' row is 1.00x by construction")
