"""Profile -> plan (Algorithm 2) -> lower -> train: the full Asteroid
workflow as one connected pipeline.

1. build an analytic per-layer profile of a transformer on a heterogeneous
   edge cluster (Env D: nano + tx2 + 2x nx),
2. run the DP planner restricted to mesh-feasible stage counts,
3. lower the plan into the shard_map runtime (heterogeneous period split,
   n_micro, K_p), cross-checking the schedule against the discrete-event
   simulator,
4. run a few distributed train steps on host devices.

    PYTHONPATH=src python examples/plan_to_run.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.core.hardware import env_d  # noqa: E402
from repro.core.lowering import plan_to_train_step  # noqa: E402
from repro.core.planner import plan_hpp  # noqa: E402
from repro.core.profiler import LayerTable, Profile  # noqa: E402
from repro.data import SyntheticLM  # noqa: E402
from repro.runtime.train import init_train_state  # noqa: E402

B, S, STEPS = 8, 64, 5

cfg = get_smoke_config("phi3-mini-3.8b")
cfg = cfg.replace(n_layers=4)                 # 4 periods: room to split unevenly

# 1. profile (analytic CPU path; measure_layer_times on a real board)
cluster = env_d().sorted_by_memory()
table = LayerTable.from_model_config(cfg, S)
prof = Profile.analytic(table, cluster, max_batch=B)
print(f"profiled {table.L} layers on {len(cluster.devices)} devices "
      f"({'/'.join(d.name for d in cluster.devices)})")

# 2. plan — stage counts restricted to divisors of the mesh model axis
devs = jax.devices()[:8]
mesh = Mesh(np.array(devs).reshape(2, 4), ("data", "model"))
plan = plan_hpp(prof, B, micro_batch=2, arch=cfg.name, allowed_stages={1, 2, 4})
print(f"plan: {len(plan.stages)} stages, predicted HPP-round "
      f"{plan.latency * 1e3:.1f} ms, throughput {plan.throughput:.0f} samples/s")
for p, st in enumerate(plan.stages):
    print(f"  stage {p}: layers [{st.layers[0]},{st.layers[1]}) on "
          f"{'+'.join(cluster.devices[d].name for d in st.group)} "
          f"alloc={st.alloc} K_p={st.k_p}")

# 3. lower (validates vs the simulator) and build the train step.  The
#    per-stage Algorithm 1 sample allocations collapse onto the data axis:
#    with an unbalanced collapse, batches are packed/padded to B_max per
#    shard and the loss is weighted by the true per-shard counts.
ts, lowered = plan_to_train_step(plan, prof, cfg, mesh)
print(f"lowered: period split {lowered.stage_periods}, M={lowered.n_micro}, "
      f"ticks fwd={lowered.forward_ticks} total={lowered.total_ticks}, "
      f"shard alloc {ts.spec.shard_alloc or 'uniform'}")

# 4. train (ts.shard_batch packs for the lowered allocation, if any)
key = jax.random.PRNGKey(0)
params, opt_state = init_train_state(key, ts)
ds = SyntheticLM(cfg.vocab_size, S)
for step in range(STEPS):
    batch = ts.shard_batch(ds.batch(step, B))
    params, opt_state, loss, metrics = ts.step_fn(params, opt_state, batch)
    print(f"step {step} loss {float(loss):.4f} ce {float(metrics['ce']):.4f}")
print("done")
