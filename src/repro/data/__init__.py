"""Data pipeline: deterministic synthetic token streams + sharded ingestion.

The paper trains on CIFAR/Mini-ImageNet/synthetic-BERT batches; the assigned
architectures are LMs, so the pipeline produces language-model token batches:

* ``SyntheticLM`` — a deterministic Zipf-ish Markov stream (seeded, resumable
  by step index, so data-parallel hosts and restarts agree),
* ``delay_pattern`` — MusicGen's codebook delay interleave,
* ``shard_batch`` — places a host batch onto the mesh with the train specs.

For the one-device examples it doubles as a real (tiny) corpus generator with
learnable structure so loss visibly decreases.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic structured token stream (learnable bigram structure)."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    n_codebooks: int = 1
    prefix_len: int = 0
    prefix_dim: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v = self.vocab_size
        # sparse bigram transition table: each token has 4 likely successors
        self._succ = rng.randint(0, v, size=(v, 4))

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.RandomState((self.seed * 9176 + step) % (2 ** 31))
        n_str = self.n_codebooks if self.n_codebooks > 1 else 1
        toks = np.zeros((batch_size, n_str, self.seq_len), np.int32)
        cur = rng.randint(0, self.vocab_size, size=(batch_size, n_str))
        toks[:, :, 0] = cur
        for t in range(1, self.seq_len):
            pick = rng.randint(0, 4, size=cur.shape)
            nxt = self._succ[cur, pick]
            noise = rng.rand(*cur.shape) < 0.1
            rand = rng.randint(0, self.vocab_size, size=cur.shape)
            cur = np.where(noise, rand, nxt)
            toks[:, :, t] = cur
        out = {"tokens": toks if self.n_codebooks > 1 else toks[:, 0]}
        if self.prefix_len:
            out["prefix"] = rng.randn(batch_size, self.prefix_len,
                                      self.prefix_dim).astype(np.float32) * 0.02
        return out


def delay_pattern(tokens: np.ndarray, pad_id: int = 0) -> np.ndarray:
    """MusicGen delay interleave: codebook k is shifted right by k steps.

    tokens: (B, CB, S) -> (B, CB, S) with per-codebook delay."""
    B, CB, S = tokens.shape
    out = np.full_like(tokens, pad_id)
    for k in range(CB):
        out[:, k, k:] = tokens[:, k, : S - k]
    return out


def shard_batch(batch: dict, mesh, specs: dict) -> dict:
    return {k: jax.device_put(jnp.asarray(v), NamedSharding(mesh, specs[k]))
            for k, v in batch.items()}
