"""Data pipeline: deterministic synthetic token streams + sharded ingestion.

The paper trains on CIFAR/Mini-ImageNet/synthetic-BERT batches; the assigned
architectures are LMs, so the pipeline produces language-model token batches:

* ``SyntheticLM`` — a deterministic Zipf-ish Markov stream (seeded, resumable
  by step index, so data-parallel hosts and restarts agree),
* ``delay_pattern`` — MusicGen's codebook delay interleave,
* ``pack_batch`` — realizes a heterogeneous per-data-shard sample allocation
  (Algorithm 1, lowered by ``core.lowering.lower_micro_alloc``) by splitting
  each micro-batch unevenly across shards and zero-padding every shard to
  ``B_max = max_d y_d``; the runtime masks the padding back out,
* ``shard_batch`` — places a host batch onto the mesh with the train specs.

For the one-device examples it doubles as a real (tiny) corpus generator with
learnable structure so loss visibly decreases.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class SyntheticLM:
    """Deterministic structured token stream (learnable bigram structure)."""

    vocab_size: int
    seq_len: int
    seed: int = 0
    n_codebooks: int = 1
    prefix_len: int = 0
    prefix_dim: int = 0

    def __post_init__(self):
        rng = np.random.RandomState(self.seed)
        v = self.vocab_size
        # sparse bigram transition table: each token has 4 likely successors
        self._succ = rng.randint(0, v, size=(v, 4))

    def batch(self, step: int, batch_size: int) -> dict:
        rng = np.random.RandomState((self.seed * 9176 + step) % (2 ** 31))
        n_str = self.n_codebooks if self.n_codebooks > 1 else 1
        toks = np.zeros((batch_size, n_str, self.seq_len), np.int32)
        cur = rng.randint(0, self.vocab_size, size=(batch_size, n_str))
        toks[:, :, 0] = cur
        for t in range(1, self.seq_len):
            pick = rng.randint(0, 4, size=cur.shape)
            nxt = self._succ[cur, pick]
            noise = rng.rand(*cur.shape) < 0.1
            rand = rng.randint(0, self.vocab_size, size=cur.shape)
            cur = np.where(noise, rand, nxt)
            toks[:, :, t] = cur
        out = {"tokens": toks if self.n_codebooks > 1 else toks[:, 0]}
        if self.prefix_len:
            out["prefix"] = rng.randn(batch_size, self.prefix_len,
                                      self.prefix_dim).astype(np.float32) * 0.02
        return out


def delay_pattern(tokens: np.ndarray, pad_id: int = 0) -> np.ndarray:
    """MusicGen delay interleave: codebook k is shifted right by k steps.

    tokens: (B, CB, S) -> (B, CB, S) with per-codebook delay."""
    B, CB, S = tokens.shape
    out = np.full_like(tokens, pad_id)
    for k in range(CB):
        out[:, k, k:] = tokens[:, k, : S - k]
    return out


def pack_indices(shard_alloc, n_micro: int):
    """Gather indices + validity realizing a heterogeneous batch packing.

    Returns ``(idx, valid)`` of shape ``(dp, n_micro, B_max)``: shard ``d``'s
    row ``m * B_max + b`` holds input row ``idx[d, m, b]`` when
    ``valid[d, m, b]`` (micro-batch ``m`` = input rows
    ``[m * micro_batch, (m+1) * micro_batch)``, split consecutively across
    shards per ``shard_alloc``), and zero padding otherwise.
    """
    alloc = [int(y) for y in shard_alloc]
    if any(y < 0 for y in alloc) or sum(alloc) <= 0:
        raise ValueError(f"invalid shard allocation {shard_alloc}")
    micro_batch, b_max = sum(alloc), max(alloc)
    offs = np.cumsum([0] + alloc[:-1])
    idx = np.zeros((len(alloc), n_micro, b_max), np.int64)
    valid = np.zeros((len(alloc), n_micro, b_max), bool)
    for d, (y, o) in enumerate(zip(alloc, offs)):
        for m in range(n_micro):
            idx[d, m, :y] = m * micro_batch + o + np.arange(y)
            valid[d, m, :y] = True
    return idx, valid


def pack_batch(batch: dict, shard_alloc, n_micro: int) -> dict:
    """Re-lay a host batch for a heterogeneous per-shard sample allocation.

    Input arrays are ``(n_micro * sum(shard_alloc), ...)``; the output is
    ``(dp * n_micro * B_max, ...)`` (shard-major, then micro-batch, then
    sample slot) with invalid slots zeroed — ready for the train specs'
    ``(pod, data)`` batch sharding.  Every input sample appears exactly once.
    """
    idx, valid = pack_indices(shard_alloc, n_micro)
    flat_idx, flat_valid = idx.reshape(-1), valid.reshape(-1)
    out = {}
    for k, v in batch.items():
        a = np.asarray(v)
        if a.shape[0] != n_micro * sum(int(y) for y in shard_alloc):
            raise ValueError(f"batch[{k!r}] has {a.shape[0]} rows; expected "
                             f"{n_micro} micro-batches of {sum(shard_alloc)}")
        g = a[flat_idx].copy()
        g[~flat_valid] = 0
        out[k] = g
    return out


def shard_batch(batch: dict, mesh, specs: dict) -> dict:
    return {k: jax.device_put(jnp.asarray(v), NamedSharding(mesh, specs[k]))
            for k, v in batch.items()}
