"""Loop-aware static cost analysis on jaxprs.

XLA's ``compiled.cost_analysis()`` counts a while-loop body **once** — a
scan-over-layers model looks ~L× cheaper than it is.  This walker traverses
the jaxpr instead, multiplying ``scan`` bodies by their trip count and
recursing through pjit/remat/shard_map, producing:

* ``flops``            — dot_general (2·M·N·K·batch) + ~1 flop/elt for
                         elementwise ops,
* ``bytes``            — HBM-traffic model: dot_general / gather / scatter /
                         collectives count operands+results (weights are
                         re-read from HBM per use — real on TPU); elementwise
                         ops count *outputs only* (each op materializes its
                         result once, reads fuse with producers).  Still a
                         conservative bound: a fully-fused flash attention
                         (the Pallas kernel) avoids materializing the score
                         chain at all,
* ``collective_bytes`` — per-device payload of psum / ppermute / all_to_all
                         / all_gather / reduce_scatter, trip-count-scaled
                         (ring all-reduce pays ~2× the buffer size).

Shapes inside shard_map bodies are per-shard, so all numbers are
**per-device**, matching the roofline convention.
"""

from __future__ import annotations

import dataclasses
import math
from collections import defaultdict

import jax
import numpy as np

ELEMENTWISE_FLOP = {
    "add", "sub", "mul", "div", "max", "min", "neg", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "erf", "abs", "sign",
    "floor", "ceil", "round", "cos", "sin", "select_n", "ge", "gt", "le",
    "lt", "eq", "ne", "and", "or", "not", "xor", "cumsum", "cumlogsumexp",
}

# collective kinds; payloads depend on the participating axis size n:
#   all-reduce: 2 (n-1)/n per byte (ring reduce-scatter + all-gather)
#   all-gather / reduce-scatter / all-to-all: (n-1)/n
#   ppermute: 1 (0 when the axis is trivial)
ALLREDUCE_PRIMS = {"psum", "psum2", "psum_invariant", "pmax", "pmin"}
SHUFFLE_PRIMS = {"all_to_all", "all_gather", "reduce_scatter", "pbroadcast"}
PERMUTE_PRIMS = {"ppermute"}
COLLECTIVE_PRIMS = ALLREDUCE_PRIMS | SHUFFLE_PRIMS | PERMUTE_PRIMS


def _collective_axes(eqn):
    params = eqn.params
    for key in ("axes", "axis_name", "axis_index_groups_axis", "axis"):
        if key in params and params[key] is not None:
            ax = params[key]
            return ax if isinstance(ax, (tuple, list)) else (ax,)
    return ()


def collective_payload(prim: str, out_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if prim in ALLREDUCE_PRIMS:
        return 2.0 * (n - 1) / n * out_bytes
    if prim in SHUFFLE_PRIMS:
        return (n - 1) / n * out_bytes
    return float(out_bytes)


def _nbytes(aval) -> int:
    try:
        return int(np.prod(aval.shape)) * aval.dtype.itemsize
    except Exception:
        return 0


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape))
    except Exception:
        return 0


def _axis_size(eqn, name_default: int = 1) -> int:
    return name_default


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.collective_bytes += other.collective_bytes * scale
        for k, v in other.by_collective.items():
            self.by_collective[k] += v * scale


def _dot_flops(eqn) -> float:
    a, b = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    m = math.prod(a.shape[i] for i in range(len(a.shape))
                  if i not in lc and i not in lb)
    n = math.prod(b.shape[i] for i in range(len(b.shape))
                  if i not in rc and i not in rb)
    k = math.prod(a.shape[i] for i in lc)
    batch = math.prod(a.shape[i] for i in lb)
    return 2.0 * m * n * k * batch


def _sub_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for higher-order primitives."""
    p = eqn.primitive.name
    params = eqn.params
    if p == "scan":
        return [(params["jaxpr"].jaxpr, params["length"])]
    if p == "while":
        # trip count unknown statically; count the body once (documented)
        out = []
        if "body_jaxpr" in params:
            out.append((params["body_jaxpr"].jaxpr, 1))
        if "cond_jaxpr" in params:
            out.append((params["cond_jaxpr"].jaxpr, 1))
        return out
    if p == "cond":
        # take the most expensive branch
        return [("MAX", [b.jaxpr for b in params["branches"]])]
    for key in ("jaxpr", "call_jaxpr"):
        if key in params:
            j = params[key]
            return [(j.jaxpr if hasattr(j, "jaxpr") else j, 1)]
    if "fun_jaxpr" in params:
        j = params["fun_jaxpr"]
        return [(j.jaxpr if hasattr(j, "jaxpr") else j, 1)]
    return []


def jaxpr_cost(jaxpr, axis_sizes: dict | None = None) -> Cost:
    axis_sizes = axis_sizes or {}
    cost = Cost()
    for eqn in jaxpr.eqns:
        p = eqn.primitive.name
        out_bytes = sum(_nbytes(v.aval) for v in eqn.outvars)
        in_bytes = sum(_nbytes(v.aval) for v in eqn.invars
                       if hasattr(v, "aval"))

        subs = _sub_jaxprs(eqn)
        if subs:
            for item in subs:
                if item[0] == "MAX":
                    branch_costs = [jaxpr_cost(b, axis_sizes) for b in item[1]]
                    best = max(branch_costs, key=lambda c: c.flops + c.bytes)
                    cost.add(best)
                else:
                    sub, mult = item
                    cost.add(jaxpr_cost(sub, axis_sizes), mult)
            continue

        if p == "dot_general":
            cost.flops += _dot_flops(eqn)
            cost.bytes += in_bytes + out_bytes
        elif p in COLLECTIVE_PRIMS:
            n = 1
            for ax in _collective_axes(eqn):
                n *= int(axis_sizes.get(ax, 1))
            payload = collective_payload(p, out_bytes, n)
            cost.collective_bytes += payload
            cost.by_collective[p] += payload
            cost.bytes += in_bytes + out_bytes
        elif p in ELEMENTWISE_FLOP:
            cost.flops += sum(_size(v.aval) for v in eqn.outvars)
            cost.bytes += out_bytes          # fused reads, one write
        elif p in ("reduce_sum", "reduce_max", "reduce_min", "argmax",
                   "argmin", "reduce_prod", "reduce_and", "reduce_or"):
            cost.flops += sum(_size(v.aval) for v in eqn.invars
                              if hasattr(v, "aval"))
            cost.bytes += in_bytes + out_bytes   # reductions read their input
        elif p in ("gather", "scatter", "scatter-add", "scatter_add",
                   "dynamic_slice", "dynamic_update_slice", "take",
                   "select_and_scatter_add"):
            cost.bytes += in_bytes + out_bytes
        elif p in ("reshape", "transpose", "rev", "broadcast_in_dim",
                   "convert_element_type", "slice", "concatenate", "pad",
                   "iota", "squeeze", "expand_dims", "bitcast_convert_type"):
            cost.bytes += out_bytes          # layout ops usually fuse away
        else:
            cost.bytes += out_bytes
    return cost


def cost_of_fn(fn, *abstract_args, axis_sizes: dict | None = None) -> Cost:
    """Trace ``fn`` with abstract args and analyze its jaxpr."""
    jaxpr = jax.make_jaxpr(fn)(*abstract_args)
    return jaxpr_cost(jaxpr.jaxpr, axis_sizes)
