"""HLO analysis: collective-communication byte accounting.

``cost_analysis()`` has no collective term, so we parse the compiled (or
lowered stablehlo) module text and sum operand bytes of every collective op:
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.

Bytes counted are the *per-device* payload of each op (operand size), which
is what crosses that device's links in a ring/bidirectional implementation
up to a small constant; the roofline divides by per-link bandwidth.
"""

from __future__ import annotations

import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[16,128]' -> bytes."""
    m = _SHAPE_RE.match(shape_str.strip())
    if not m:
        return 0
    dtype, dims = m.groups()
    size = DTYPE_BYTES.get(dtype, 4)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * size


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes per collective op kind (per device)."""
    out: dict[str, int] = defaultdict(int)
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # result-shape = opname(...): e.g.  %ag = bf16[4,128]{...} all-gather(
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[\w\[\],\s]+\)?)\{?[\d,]*\}?\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)", stripped)
        if not m:
            continue
        shapes_str, op = m.groups()
        total = 0
        if shapes_str.startswith("("):
            for part in shapes_str.strip("() ").split("),"):
                for sub in part.split(","):
                    if "[" in sub:
                        total += _shape_bytes(sub + ("]" if "]" not in sub else ""))
            # fall back to regex-all on the tuple
            total = sum(_shape_bytes(s.group(0))
                        for s in _SHAPE_RE.finditer(shapes_str))
        else:
            total = _shape_bytes(shapes_str)
        out[op] += total
        counts[op + "_count"] += 1
    out.update(counts)
    return dict(out)


def total_collective_bytes(hlo_text: str) -> int:
    d = collective_bytes(hlo_text)
    return sum(v for k, v in d.items() if not k.endswith("_count"))
