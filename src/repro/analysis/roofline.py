"""Roofline analysis from dry-run artifacts (TPU v5e targets).

Three terms per (arch, shape, mesh), all in seconds:

  compute   = HLO_FLOPs_per_device / peak_FLOP/s
  memory    = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

``cost_analysis()`` reports per-partition numbers (verified empirically), so
no division by chip count is needed.  MODEL_FLOPS uses 6·N·D with N =
active params (MoE) — the useful-work yardstick against compiled FLOPs.
"""

from __future__ import annotations

import dataclasses
import json

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


@dataclasses.dataclass(frozen=True)
class Roofline:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_per_device: float
    hlo_flops_per_device: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/padding/redundancy."""
        if self.hlo_flops_per_device <= 0:
            return 0.0
        return self.model_flops_per_device / self.hlo_flops_per_device

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization if execution hit the dominant roofline."""
        if self.bound_s <= 0:
            return 0.0
        return self.model_flops_per_device / PEAK_FLOPS / self.bound_s


def model_flops(active_params: float, tokens: float, training: bool) -> float:
    """6·N·D for training (fwd+bwd), 2·N·D for inference."""
    return (6.0 if training else 2.0) * active_params * tokens


def from_record(rec: dict) -> Roofline:
    """Build from a dry-run artifact record (see launch/dryrun.py).

    Prefers the loop-aware jaxpr cost (``jcost``) over XLA's
    ``cost_analysis`` — the latter counts scan bodies once, under-reporting
    layer-scanned models by ~depth×.  The jcost byte count is the *unfused*
    upper bound on HBM traffic (see analysis/jaxpr_cost.py)."""
    if "jcost" in rec:
        flops = rec["jcost"]["flops"]
        bytes_acc = rec["jcost"]["bytes"]
        coll = rec["jcost"]["collective_bytes"]
    else:
        flops = rec["cost"].get("flops", 0.0)
        bytes_acc = rec["cost"].get("bytes accessed", 0.0)
        coll = rec.get("collective_bytes_total", 0)
    chips = rec["n_devices"]
    tokens_global = rec["tokens_global"]
    mf = model_flops(rec["active_params"], tokens_global / chips,
                     rec["kind"] == "train")
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        compute_s=flops / PEAK_FLOPS,
        memory_s=bytes_acc / HBM_BW,
        collective_s=coll / ICI_BW,
        model_flops_per_device=mf,
        hlo_flops_per_device=flops,
    )


def load_records(path: str) -> list[dict]:
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    return recs


def table(rooflines: list[Roofline]) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':9s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'collect_s':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'MFUbound':>8s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rooflines:
        lines.append(
            f"{r.arch:26s} {r.shape:12s} {r.mesh:9s} {r.compute_s:10.4f} "
            f"{r.memory_s:10.4f} {r.collective_s:10.4f} {r.dominant:>10s} "
            f"{r.useful_ratio:7.2f} {r.mfu_bound:8.3f}")
    return "\n".join(lines)
