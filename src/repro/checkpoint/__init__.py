"""Checkpointing: npz-sharded pytree save/restore + stage-backup helpers.

Layout: <dir>/<name>.meta.json (treedef + shapes) and <name>.<i>.npz shards.
Also provides the in-memory stage replication used by the fault-tolerance
runtime (topology-driven backups, §3.4)."""

from __future__ import annotations

import json
import os

import ml_dtypes
import numpy as np

import jax
import jax.numpy as jnp

SHARD_BYTES = 1 << 30

# numpy cannot serialize ml_dtypes (bfloat16, fp8): store raw bits + dtype
_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_numpy(leaf):
    arr = np.asarray(jax.device_get(leaf))
    name = arr.dtype.name
    if name in _BITCAST:
        return arr.view(_BITCAST[name]), name
    return arr, name


def _from_numpy(arr, dtype_name):
    if dtype_name in _BITCAST:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, name: str, tree) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = _flatten(tree)
    meta = {"treedef": str(treedef), "n_leaves": len(leaves), "shards": [],
            "dtypes": []}
    shard, size, idx = {}, 0, 0
    for i, leaf in enumerate(leaves):
        arr, dtype_name = _to_numpy(leaf)
        meta["dtypes"].append(dtype_name)
        shard[f"leaf_{i}"] = arr
        size += arr.nbytes
        if size >= SHARD_BYTES:
            np.savez(os.path.join(path, f"{name}.{idx}.npz"), **shard)
            meta["shards"].append(idx)
            shard, size, idx = {}, 0, idx + 1
    if shard:
        np.savez(os.path.join(path, f"{name}.{idx}.npz"), **shard)
        meta["shards"].append(idx)
    with open(os.path.join(path, f"{name}.meta.json"), "w") as f:
        json.dump(meta, f)


def restore(path: str, name: str, like):
    """Restore into the structure (and shardings) of ``like``."""
    with open(os.path.join(path, f"{name}.meta.json")) as f:
        meta = json.load(f)
    arrays = {}
    for idx in meta["shards"]:
        with np.load(os.path.join(path, f"{name}.{idx}.npz")) as z:
            arrays.update({k: z[k] for k in z.files})
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(leaves_like) == meta["n_leaves"], "checkpoint/tree mismatch"
    new_leaves = []
    dtypes = meta.get("dtypes") or [None] * len(leaves_like)
    for i, ref in enumerate(leaves_like):
        arr = arrays[f"leaf_{i}"]
        if dtypes[i]:
            arr = _from_numpy(arr, dtypes[i])
        assert arr.shape == ref.shape, (i, arr.shape, ref.shape)
        if hasattr(ref, "sharding"):
            new_leaves.append(jax.device_put(jnp.asarray(arr, ref.dtype), ref.sharding))
        else:
            new_leaves.append(jnp.asarray(arr, ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


# ---------------------------------------------------------------------------
# Stage replication (fault tolerance)
# ---------------------------------------------------------------------------


class StageBackupStore:
    """In-memory topology-driven replica store: stage -> snapshot on the
    backup node (here: host memory standing in for the next-stage device).

    ``meta`` rides along with each snapshot (e.g. the canonical period range
    the rows cover and the training step they were captured at) so a replay
    session can scatter a restored stage back into a *re-arranged* period
    stack after a plan swap.
    """

    def __init__(self):
        self._store: dict[int, object] = {}
        self._meta: dict[int, dict] = {}
        self.bytes_transferred = 0

    def backup(self, stage: int, params, meta: dict | None = None) -> None:
        snap = jax.tree.map(lambda x: np.asarray(x).copy(), params)
        self._store[stage] = snap
        self._meta[stage] = dict(meta or {})
        self.bytes_transferred += sum(a.nbytes for a in jax.tree.leaves(snap))

    def restore(self, stage: int):
        if stage not in self._store:
            raise KeyError(f"no backup for stage {stage}")
        return jax.tree.map(jnp.asarray, self._store[stage])

    def meta(self, stage: int) -> dict:
        if stage not in self._store:
            raise KeyError(f"no backup for stage {stage}")
        return dict(self._meta.get(stage, {}))

    def has(self, stage: int) -> bool:
        return stage in self._store

    def drop(self, stage: int) -> None:
        self._store.pop(stage, None)
        self._meta.pop(stage, None)
