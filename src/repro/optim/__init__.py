"""Optimizers in pure JAX (pytree-wise): AdamW, SGD-momentum, schedules.

Optimizer states inherit the parameter shardings (elementwise updates), so
under pjit the update step is communication-free; optionally the first/second
moments can be kept in fp32 while params are bf16 (mixed precision).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float | None = 1.0
    moment_dtype: str = "float32"

    def init(self, params) -> AdamWState:
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree.map(zeros, params),
                          jax.tree.map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(m_.dtype),
                         state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(v_.dtype)),
                         state.v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(u.dtype)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v)


class SGDState(NamedTuple):
    step: jnp.ndarray
    mom: dict


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: float | Callable = 1e-2
    momentum: float = 0.9
    grad_clip: float | None = None

    def init(self, params) -> SGDState:
        return SGDState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(self, grads, state: SGDState, params):
        step = state.step + 1
        if self.grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mom = jax.tree.map(lambda m, g: self.momentum * m + g.astype(jnp.float32),
                           state.mom, grads)
        lr = self.lr(step) if callable(self.lr) else self.lr
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mom)
        return new_params, SGDState(step, mom)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def cosine_schedule(peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    def f(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return peak_lr * jnp.where(s < warmup, warm, cos)
    return f
