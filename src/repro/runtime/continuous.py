"""Continuous batching: slot-based decode with admit/retire (DESIGN.md §11).

The engine owns a fixed set of decode *slots* (rows of the padded per-shard
batch a ``build_slot_serve_step`` step decodes).  Requests queue on arrival,
are admitted into free slots (resetting that row's recurrent state), decode
one token per engine step at their own per-row position, and retire on
completion — no lockstep batch boundaries, so a long request never stalls
the batch behind it.

Determinism contract: a sampled token depends only on ``(request_id,
position)`` — the sampling key is ``fold_in(base, rid, pos)`` and decode is
row-independent — so the generated text is identical regardless of arrival
timing, admission order, or which slot a request lands in (the
``test_continuous`` property).  MoE capacity routing is the one documented
exception (rows couple through expert capacity).

The clock is injectable: the benchmark uses the real ``perf_counter`` to
measure step time, tests use a fake timer, and arrivals are replayed on the
same simulated clock either way (open-loop: the arrival process does not
slow down when the server falls behind).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    rid: int
    arrival: float             # seconds on the open-loop clock
    prompt_token: int          # synthetic single-token prompt (decode-only)
    n_tokens: int              # tokens to generate


@dataclasses.dataclass
class Completion:
    rid: int
    arrival: float
    finish: float
    tokens: list[int]
    token_latencies: list[float]   # completion clock - ready clock, per token

    @property
    def latency(self) -> float:
        return self.finish - self.arrival


def poisson_requests(rate: float, horizon: float, *, n_tokens: int,
                     seed: int = 0, vocab: int = 256) -> list[Request]:
    """Open-loop Poisson arrival process at ``rate`` requests/s for
    ``horizon`` seconds of simulated time."""
    rng = np.random.RandomState(seed)
    out, t, rid = [], 0.0, 0
    while True:
        t += float(rng.exponential(1.0 / rate))
        if t >= horizon:
            return out
        out.append(Request(rid=rid, arrival=t,
                           prompt_token=int(rng.randint(vocab)),
                           n_tokens=n_tokens))
        rid += 1


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    pos: int = 0
    remaining: int = 0
    next_token: int = 0
    ready: float = 0.0         # clock at which the next token became due
    fresh: bool = False        # admitted since the last engine step


class ContinuousBatcher:
    """Host-side admit/decode/retire loop over a per-slot decode step.

    ``step``: callable ``(tokens (B,), positions (B,), reset (B,)) ->
    logits (B, V)`` over the full padded batch (see
    ``engine_from_serve_step`` / ``engine_from_decode_step``).  ``slots``
    lists the live row indices — for a planner split this is
    ``slot_rows(shard_alloc)``; padded rows are never admitted into.
    """

    def __init__(self, step: Callable, *, slots: Sequence[int], batch: int,
                 cache_len: int, seed: int = 0,
                 timer: Callable[[], float] | None = None):
        self.step = step
        self.slot_rows = list(slots)
        self.batch = batch
        self.cache_len = cache_len
        self.key = jax.random.PRNGKey(seed)
        self.timer = timer or time.perf_counter
        self.free = list(self.slot_rows)
        self.active: dict[int, _Slot] = {}
        self.clock = 0.0
        self.steps = 0
        self.step_seconds: list[float] = []

    # -- scheduling --------------------------------------------------------

    def _admit(self, queue: list[Request]):
        while queue and self.free:
            req = queue.pop(0)
            row = self.free.pop(0)
            self.active[row] = _Slot(
                rid=req.rid, pos=0,
                remaining=min(req.n_tokens, self.cache_len),
                next_token=req.prompt_token, ready=max(req.arrival, self.clock),
                fresh=True)

    def _sample(self, logits_row: np.ndarray, rid: int, pos: int) -> int:
        key = jax.random.fold_in(jax.random.fold_in(self.key, rid), pos)
        return int(jax.random.categorical(key, jnp.asarray(logits_row)))

    # -- main loop ---------------------------------------------------------

    def run(self, requests: Sequence[Request],
            max_steps: int | None = None) -> list[Completion]:
        """Serve ``requests`` (sorted by arrival) to completion."""
        pending = sorted(requests, key=lambda r: (r.arrival, r.rid))
        queue: list[Request] = []
        done: dict[int, Completion] = {
            r.rid: Completion(r.rid, r.arrival, 0.0, [], []) for r in pending}
        tokens = np.zeros(self.batch, np.int32)
        positions = np.zeros(self.batch, np.int32)
        reset = np.zeros(self.batch, bool)

        while pending or queue or self.active:
            if max_steps is not None and self.steps >= max_steps:
                break
            # open-loop arrivals up to the current clock; if the server is
            # idle, fast-forward to the next arrival
            if not queue and not self.active and pending:
                self.clock = max(self.clock, pending[0].arrival)
            while pending and pending[0].arrival <= self.clock:
                queue.append(pending.pop(0))
            self._admit(queue)
            if not self.active:
                continue

            reset[:] = False
            for row, sl in self.active.items():
                tokens[row] = sl.next_token
                positions[row] = sl.pos
                reset[row] = sl.fresh
                sl.fresh = False
            t0 = self.timer()
            logits = self.step(jnp.asarray(tokens), jnp.asarray(positions),
                               jnp.asarray(reset))
            logits = np.asarray(jax.device_get(logits))
            dt = self.timer() - t0
            self.step_seconds.append(dt)
            self.clock += dt
            self.steps += 1

            for row in list(self.active):
                sl = self.active[row]
                tok = self._sample(logits[row], sl.rid, sl.pos)
                comp = done[sl.rid]
                comp.tokens.append(tok)
                comp.token_latencies.append(self.clock - sl.ready)
                sl.ready = self.clock
                sl.next_token = tok
                sl.pos += 1
                sl.remaining -= 1
                if sl.remaining <= 0 or sl.pos >= self.cache_len:
                    comp.finish = self.clock
                    del self.active[row]
                    self.free.append(row)
        return [done[r.rid] for r in sorted(requests, key=lambda r: r.rid)
                if done[r.rid].tokens]


def slot_rows(shard_alloc: Sequence[int]) -> list[int]:
    """Live row indices of the padded shard-major batch layout
    (``build_slot_serve_step``): rows ``[d*B_max, d*B_max + alloc[d])``."""
    b_max = max(shard_alloc)
    rows = []
    for d, y in enumerate(shard_alloc):
        rows.extend(range(d * b_max, d * b_max + y))
    return rows


def engine_from_serve_step(ss, params):
    """Adapt a ``build_slot_serve_step`` ServeStep into the batcher's step
    callable (owns the decode state tree across calls)."""
    from .serve import prepare_serve_states

    spec = ss.spec
    states = prepare_serve_states(spec.cfg, spec.plan, spec.batch_global,
                                  spec.cache_len)
    holder = {"states": states}

    def step(tokens, positions, reset):
        logits, holder["states"] = ss.step_fn(
            params, tokens, positions, reset, holder["states"])
        return logits

    return step


def engine_from_decode_step(params, cfg, *, batch: int, cache_len: int):
    """Single-device engine over ``models.model.decode_step`` — the
    mesh-free path the determinism test and quick benches use."""
    from repro.models.model import decode_step, init_decode_states

    holder = {"states": init_decode_states(batch, cache_len, cfg)}

    @jax.jit
    def _step(params, tokens, positions, reset, states):
        # zero recurrent state rows on admission; state leaves are
        # (n_periods, B, ...), batch on axis 1
        def clear_leaf(s):
            r = reset.reshape((1, -1) + (1,) * (s.ndim - 2))
            return jnp.where(r, jnp.zeros_like(s), s)

        states = jax.tree.map(clear_leaf, states)
        return decode_step(params, tokens, positions, states, cfg)

    def step(tokens, positions, reset):
        logits, holder["states"] = _step(params, tokens, positions, reset,
                                         holder["states"])
        return logits

    return step
