"""The HPP training runtime: circular pipeline under shard_map.

Asteroid's hybrid pipeline parallelism on the refined TPU mesh
``(pod, data, stage, tp)``:

* the decoder body (stacked periods) is sharded over ``stage``; each tick of
  a ``lax.scan`` executes one stage forward on one micro-batch and
  ``ppermute``s the activation to the next stage (M + P - 1 ticks for M
  micro-batches) — jax.grad of the scan yields the reverse pipeline;
* intra-stage parallelism = data parallelism over ``(pod, data)`` plus
  Megatron tensor parallelism over ``tp`` (explicit psums inside layers);
  Algorithm 1's *heterogeneous* sample allocation is realized by padding
  every data shard's micro-batch to ``B_max = max_d y_d``
  (``TrainSpec.shard_alloc``, packed host-side by ``data.pack_batch``) with
  a static validity mask weighting the loss reduction by true counts;
* MoE experts are expert-parallel over ``data`` (all_to_all dispatch);
* embedding and LM head are vocab-parallel over ``tp``; after the pipeline,
  last-stage outputs are *redistributed across stages* so the CE/head work
  is stage-sharded instead of wasted;
* the stage body is remat'ed (`jax.checkpoint`), bounding resident
  activations to the stage *input* per in-flight micro-batch — the SPMD
  realization of the paper's O(K_p) 1F1B memory bound (DESIGN.md §4).

The paper's planner picks the stage count; ``pad_periods`` pads the period
stack with zero (identity) layers when stages don't divide the period count.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.schedule import scan_ticks
from repro.distributed.compat import pcast_varying
from repro.kernels.quant_transfer import dequantize_op, quantize_op
from repro.distributed.mesh import MeshPlan
from repro.models.blocks import apply_period, shard_config
from repro.models.config import ModelConfig
from repro.models.model import MTP_WEIGHT
from repro.models.module import ParallelCtx, vary_all
from repro.models.norms import rmsnorm

from .vocab_parallel import vp_chunked_ce, vp_embed


def make_ctx(plan: MeshPlan, ep: bool = True, seq_shard: bool = False) -> ParallelCtx:
    # axes are always named (size-1 collectives are free) so vma typing stays
    # uniform across layouts
    return ParallelCtx(
        tp_axis="tp", tp_size=plan.tp,
        ep_axis="data" if ep else None, ep_size=plan.data,
        dp_axes=("pod", "data"),
        seq_axis="data" if seq_shard else None,
        seq_size=plan.data if seq_shard else 1,
    )


def pad_periods(periods, n_periods: int, n_stages: int):
    """Pad stacked period params with zero (identity) periods to a multiple
    of n_stages.  Returns (padded_params, valid_mask (padded,))."""
    padded = -(-n_periods // n_stages) * n_stages
    pad = padded - n_periods
    if pad == 0:
        return periods, jnp.ones((n_periods,), jnp.float32)
    padded_params = jax.tree.map(
        lambda x: jnp.concatenate(
            [x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], axis=0),
        periods)
    mask = jnp.concatenate([jnp.ones((n_periods,)), jnp.zeros((pad,))]).astype(jnp.float32)
    return padded_params, mask


def stage_period_mask(stage_periods) -> list[float]:
    """Static validity mask for heterogeneously-split periods: stage p's
    uniform slice holds (j_p - i_p) real periods then zero padding."""
    k = max(j - i for i, j in stage_periods)
    mask: list[float] = []
    for i, j in stage_periods:
        mask += [1.0] * (j - i) + [0.0] * (k - (j - i))
    return mask


def arrange_periods(periods, stage_periods):
    """Arrange stacked period params for a planner-chosen (possibly
    heterogeneous) stage split.

    ``stage_periods``: per-stage period ranges [i, j) partitioning
    [0, n_periods).  Stage p's uniform slice [p*k, (p+1)*k) of the result
    (k = max range length) holds its assigned periods followed by zero
    (identity) periods, so the runtime's static per-stage slicing realizes
    the heterogeneous split.  Returns (arranged_params, valid_mask (P*k,)).
    """
    mask_vals = stage_period_mask(stage_periods)
    take = []
    k = max(j - i for i, j in stage_periods)
    for i, j in stage_periods:
        take += list(range(i, j)) + [0] * (k - (j - i))
    idx = jnp.asarray(take)
    mask = jnp.asarray(mask_vals, jnp.float32)

    def f(x):
        g = x[idx]
        keep = (mask > 0).reshape(-1, *([1] * (g.ndim - 1)))
        return jnp.where(keep, g, jnp.zeros_like(g))

    return jax.tree.map(f, periods), mask


# ---------------------------------------------------------------------------
# Stage body
# ---------------------------------------------------------------------------


def _vary(x, axes=("stage",)):
    """Idempotent pcast-to-varying (vma typing helper; no-op on jax 0.4.x)."""
    return pcast_varying(x, axes)


def _stage_fn(periods_local, period_mask_local, x, positions, cfg_local,
              ctx: ParallelCtx, remat: bool):
    """Apply this stage's local periods (scan), masking padded periods' aux."""

    def body(carry, inputs):
        h, aux = carry
        pp, valid = inputs
        h, a = apply_period(pp, h, positions, cfg_local, ctx)
        return vary_all((h, aux + a * valid)), None

    fn = jax.checkpoint(body) if remat else body
    # params are stage-varying (and MoE aux data-varying), so the carry is
    # typed varying over all manual axes
    (x, aux) = vary_all((x, jnp.zeros((), jnp.float32)))
    (x, aux), _ = lax.scan(fn, (x, aux), (periods_local, period_mask_local))
    return x, aux


# ---------------------------------------------------------------------------
# Compressed boundary transfer (DESIGN.md §10)
# ---------------------------------------------------------------------------


@partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def compressed_ppermute(x, perm, fmt: str, tile: int):
    """quantize → ppermute → dequantize over the ``stage`` axis.

    The wire moves the packed int8/fp8 payload + per-tile scales instead of
    full-precision activations ((8 + 32/tile)/32 of the fp32 bytes).  The
    custom VJP quantizes the backward cotangent the same way and routes it
    through the *inverse* permutation — exactly the transpose of ppermute,
    so the reverse pipeline's boundary transfers are compressed too.  The
    carried value stays full precision (quantization error enters once per
    hop, not cumulatively), and all-zero tiles (pipeline warm-up bubbles)
    round-trip exactly.
    """
    packed = quantize_op(x, fmt=fmt, tile=tile)
    arrived = {k: lax.ppermute(v, "stage", perm) for k, v in packed.items()}
    return dequantize_op(arrived, x.shape, x.dtype, tile=tile)


def _cperm_fwd(x, perm, fmt, tile):
    # no residuals: the cotangent has the primal's shape/dtype already
    return compressed_ppermute(x, perm, fmt, tile), None


def _cperm_bwd(perm, fmt, tile, _res, g):
    inv = tuple((d, s) for s, d in perm)
    packed = quantize_op(g, fmt=fmt, tile=tile)
    arrived = {k: lax.ppermute(v, "stage", inv) for k, v in packed.items()}
    return (dequantize_op(arrived, g.shape, g.dtype, tile=tile),)


compressed_ppermute.defvjp(_cperm_fwd, _cperm_bwd)


# ---------------------------------------------------------------------------
# Circular pipeline
# ---------------------------------------------------------------------------


def pipeline_apply(periods_local, period_mask_local, x_micro, positions,
                   cfg_local: ModelConfig, ctx: ParallelCtx, n_stages: int,
                   remat: bool = True, double_buffer: bool = False,
                   compress: str = "none", quant_tile: int = 256):
    """Run M micro-batches through the stage pipeline.

    x_micro: (M, mb, S, D) — identical on every stage (batch-sharded over
    dp axes only); returns (outs (M, mb, S, D) valid on the last stage,
    aux_loss — sum over this stage's real ticks).

    ``double_buffer=False`` is the synchronous pipeline: each tick computes
    a stage forward and then ``ppermute``s the output, so the boundary
    transfer of micro-batch *m* serializes with the compute of *m+1* on the
    critical path (M + P - 1 ticks, 1-tick stage hop).

    ``double_buffer=True`` is the overlapped pipeline (DESIGN.md §8): the
    scan carries a (send, recv) buffer pair and each tick (a) launches the
    ppermute of the *previous* tick's output and (b) computes on the input
    received the tick before — the two are data-independent inside the scan
    body, so XLA's scheduler can run the transfer of micro-batch *m* on the
    comm stream while *m+1* computes.  The stage hop becomes 2 ticks
    (compute tick, then an in-flight tick), so the scan runs
    M + 2(P - 1) ticks; per-micro-batch values are bit-identical to the
    synchronous pipeline (same ops, same order — only the tick a transfer
    occupies moves).
    """
    M = x_micro.shape[0]
    P_st = n_stages
    # P_st == 1 runs the same tick scan (M ticks, identity ppermute): a
    # dedicated lax.map fast path trips jax 0.4.x's scan replication
    # checker (its carry-less scan infers mismatched reps), and a single
    # stage is exactly the degenerate case of the circular pipeline.
    # A single stage has no boundary transfers to hide, so double
    # buffering degenerates to the synchronous scan.
    if P_st == 1:
        double_buffer = False
    stage = lax.axis_index("stage")
    perm = tuple((i, (i + 1) % P_st) for i in range(P_st))
    hop = 2 if double_buffer else 1
    if compress != "none" and P_st > 1:
        def boundary(x):
            return compressed_ppermute(x, perm, compress, quant_tile)
    else:
        def boundary(x):
            return lax.ppermute(x, "stage", perm)

    state0, outs0, aux0 = vary_all(
        (jnp.zeros_like(x_micro[0]), jnp.zeros_like(x_micro),
         jnp.zeros((), jnp.float32)))

    def compute(recv, outs, aux, t):
        """One stage forward on this tick's input; masked aux/outs update.

        Shared by both pipeline variants: only *when* the boundary transfer
        runs differs, never the per-micro-batch math (the staleness-0
        bit-identity contract, ``dist_selftest --async``).
        """
        inp = jnp.where(stage == 0,
                        lax.dynamic_index_in_dim(x_micro, jnp.clip(t, 0, M - 1),
                                                 0, keepdims=False),
                        recv)
        out, a = _stage_fn(periods_local, period_mask_local, inp, positions,
                           cfg_local, ctx, remat)
        # only ticks carrying a real micro-batch contribute aux loss
        valid = (t >= hop * stage) & (t < hop * stage + M)
        aux = aux + jnp.where(valid, a, 0.0)
        oidx = t - hop * (P_st - 1)
        outs = jnp.where(
            (stage == P_st - 1) & (oidx >= 0),
            lax.dynamic_update_index_in_dim(outs, out, jnp.clip(oidx, 0, M - 1), 0),
            outs)
        return out, outs, aux

    if double_buffer:
        def tick(carry, t):
            send, recv, outs, aux = carry
            # transfer of the PREVIOUS tick's output: independent of this
            # tick's compute, so the two streams overlap
            arrived = boundary(send)
            out, outs, aux = compute(recv, outs, aux, t)
            return vary_all((out, arrived, outs, aux)), None

        carry0 = vary_all((state0, state0, outs0, aux0))
    else:
        def tick(carry, t):
            state, outs, aux = carry
            out, outs, aux = compute(state, outs, aux, t)
            nxt = boundary(out)
            return vary_all((nxt, outs, aux)), None

        carry0 = (state0, outs0, aux0)

    final, _ = lax.scan(tick, carry0,
                        jnp.arange(scan_ticks(P_st, M, double_buffer)))
    outs, aux = final[-2], final[-1]
    return outs, aux


# ---------------------------------------------------------------------------
# Full SPMD loss (runs inside shard_map)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainSpec:
    """Static configuration of the distributed train step."""

    cfg: ModelConfig                  # GLOBAL model config
    plan: MeshPlan
    n_micro: int
    remat: bool = True
    ce_chunk: int = 1024
    # Planner-lowered heterogeneous stage split: per-stage period ranges
    # [i, j) partitioning [0, n_periods) (core.lowering).  None = uniform.
    stage_periods: tuple[tuple[int, int], ...] | None = None
    # Planner-lowered heterogeneous intra-stage allocation (Algorithm 1 via
    # core.lowering.lower_micro_alloc): per-data-shard samples per
    # micro-batch, summing to the global micro-batch.  The batch arrives
    # packed (data.pack_batch): every shard padded to B_max = max_d y_d,
    # and a static validity mask keeps the padding out of the loss, so the
    # loss/gradient all-reduces are weighted by true per-shard counts.
    # None = uniform dp split (legacy layout, no padding).
    shard_alloc: tuple[int, ...] | None = None
    # Perf iteration 1 (EXPERIMENTS.md): hoist replicated->varying casts
    # (and hence the gradient all-reduces their transposes create) out of
    # the pipeline loops.  False reproduces the paper-faithful baseline.
    hoist_varying: bool = True
    # Async 1F1B runtime (DESIGN.md §8).  ``staleness`` bounds how many
    # rounds a gradient may lag its application: 0 = synchronous semantics
    # (round r's gradients are applied before round r+1 computes), 1 = the
    # optimizer update for round r's gradients happens at the r+1 boundary
    # while round r+1 computes on the pre-update params, so the gradient
    # AllReduce has a full round to hide in.  The knob changes only the
    # step *assembly* (runtime.train); the loss/grad functions are
    # staleness-free.
    staleness: int = 0
    # Double-buffer the stage-boundary sends: the P2P transfer of
    # micro-batch m overlaps the compute of m+1 on a second stream instead
    # of serializing inside the tick (2-tick stage hop, M + 2(P-1) ticks).
    # Per-micro-batch math is unchanged — gradients stay bit-identical to
    # the synchronous pipeline.
    double_buffer: bool = False
    # Compressed transfers (DESIGN.md §10): "none" | "int8" | "fp8".  When
    # set, stage-boundary ppermutes move quantized payloads (per-tile
    # scales, ``quant_tile`` elements per scale) in both directions, and
    # the gradient AllReduce switches to the bucketed/compressed path in
    # runtime.train (size-bounded buckets, per-bucket psum, quantized
    # local contributions with an error-feedback accumulator).
    compress: str = "none"
    quant_tile: int = 256
    # Gradient-bucket size bound in MiB; None = one bucket per free-axes
    # group.  Setting it (without compress) still enables DDP-style
    # bucketed psums so partial syncs overlap the backward.
    bucket_mb: float | None = None
    # Carry the per-bucket quantization residual across steps so the
    # transmitted gradient stream is unbiased (bias -> 0 as 1/T).
    error_feedback: bool = True

    @property
    def bucketed(self) -> bool:
        """True when the gradient path uses explicit per-bucket psums (and
        the step functions thread an error-feedback pytree)."""
        return self.compress != "none" or self.bucket_mb is not None

    @property
    def cfg_local(self) -> ModelConfig:
        return shard_config(self.cfg, tp=self.plan.tp, ep=self.plan.data)


def spmd_loss_fn(spec: TrainSpec):
    """Returns f(params, batch) -> (loss, metrics) for use inside shard_map.

    params: global-tree with locally-sharded leaves (periods already padded
    and leading-dim sliced by stage).  batch: {"tokens": (B_loc, S) int32,
    optional "prefix": (B_loc, pre, F)}.
    """
    cfg = spec.cfg
    cfg_local = spec.cfg_local
    plan = spec.plan
    M = spec.n_micro
    ctx = make_ctx(plan)

    def fn(params, batch):
        # PERF iteration 1: mark every param varying over all mesh axes
        # *before* the pipeline loops.  Otherwise jax inserts an implicit
        # replicated->varying cast at each use site inside the tick scan,
        # whose transpose is a per-tick gradient all-reduce — hoisting
        # yields exactly one all-reduce per parameter per step (measured
        # 27.7 GiB -> ~2 GiB per device per step, phi3-mini train_4k).
        if spec.hoist_varying:
            params = vary_all(params)
        tokens = batch["tokens"]
        B_loc = tokens.shape[0]
        S = tokens.shape[-1]
        if spec.shard_alloc is not None:
            # heterogeneous allocation: every shard is padded to B_max
            # samples per micro-batch; this shard's true count y_d selects
            # the static validity prefix (pack_batch's layout).
            mb = max(spec.shard_alloc)
            assert B_loc == M * mb, (B_loc, M, spec.shard_alloc)
            shard = (lax.axis_index("pod") * plan.data
                     + lax.axis_index("data"))
            y_here = jnp.asarray(spec.shard_alloc, jnp.int32)[shard]
            sample_valid = (jnp.arange(mb) < y_here).astype(jnp.float32)
        else:
            assert B_loc % M == 0, (B_loc, M)
            mb = B_loc // M
            sample_valid = None

        # ---- embed (vocab-parallel over tp) -----------------------------
        if cfg.n_codebooks > 1:
            x = sum(vp_embed(params["embed"][cb], tokens[:, cb], ctx)
                    for cb in range(cfg.n_codebooks))
        else:
            x = vp_embed(params["embed"], tokens, ctx)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = x.astype(cfg.cdtype)

        if cfg.prefix_len > 0:
            px = (batch["prefix"].astype(cfg.cdtype) @ params["prefix_proj"])
            x = jnp.concatenate([px.astype(cfg.cdtype), x], axis=1)
        S_tot = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S_tot, dtype=jnp.int32), (mb, S_tot))

        # ---- pipeline ----------------------------------------------------
        # validity mask for zero-padded periods (identity layers): static,
        # sliced to this stage's slice of the period stack.  With a lowered
        # heterogeneous split, each stage's uniform slice holds its assigned
        # periods then padding (arrange_periods).
        n_periods = cfg.n_periods
        if spec.stage_periods is not None:
            assert len(spec.stage_periods) == plan.stage, \
                (spec.stage_periods, plan.stage)
            mask_vals = stage_period_mask(spec.stage_periods)
            k_per_stage = len(mask_vals) // plan.stage
            mask_global = jnp.asarray(mask_vals, jnp.float32)
        else:
            padded = -(-n_periods // plan.stage) * plan.stage
            k_per_stage = padded // plan.stage
            mask_global = jnp.asarray(
                [1.0] * n_periods + [0.0] * (padded - n_periods), jnp.float32)
        if plan.stage > 1:
            mask_local = lax.dynamic_slice_in_dim(
                mask_global, lax.axis_index("stage") * k_per_stage, k_per_stage)
        else:
            mask_local = mask_global

        x_micro = x.reshape(M, mb, S_tot, cfg.d_model)
        if spec.hoist_varying:
            # same hoist for the micro-batch buffer: its cotangent (the
            # embedding-gradient path) is reduced once instead of per tick
            x_micro = vary_all(x_micro)
        outs, aux = pipeline_apply(params["periods"], mask_local,
                                   x_micro, positions, cfg_local, ctx,
                                   plan.stage, spec.remat,
                                   double_buffer=spec.double_buffer,
                                   compress=spec.compress,
                                   quant_tile=spec.quant_tile)

        # ---- redistribute last-stage outputs across stages ----------------
        # Every stage holds an `outs` buffer but only the last stage's is
        # real.  An all_to_all over 'stage' scatters each stage's rows so
        # device r receives row-chunk r *from every source*; taking the
        # segment that came from the last stage hands stage r exactly its
        # M/P micro-batches — the CE/head work is then stage-sharded.
        P_st = plan.stage
        stage = lax.axis_index("stage") if P_st > 1 else jnp.int32(0)
        chunk = -(-M // P_st)                      # micro-batches per stage
        start = stage * chunk
        if P_st > 1:
            pad_rows = chunk * P_st - M
            outs_p = jnp.pad(outs, ((0, pad_rows),) + ((0, 0),) * (outs.ndim - 1)) \
                if pad_rows else outs
            recv = lax.all_to_all(outs_p, "stage", split_axis=0, concat_axis=0,
                                  tiled=True)
            my = lax.slice_in_dim(recv, (P_st - 1) * chunk, P_st * chunk, axis=0)
        else:
            my = outs
        # ownership mask: rows past M (padding) contribute nothing
        own = (jnp.arange(chunk) + start) < M

        h = my.reshape(chunk * mb, S_tot, cfg.d_model)
        own_rows = jnp.repeat(own, mb)
        h = rmsnorm(params["final_norm"], h, cfg.norm_eps, cfg.zero_centered_norm)
        if cfg.prefix_len > 0:
            h_txt = h[:, cfg.prefix_len:]
        else:
            h_txt = h

        # ---- targets for this device's chunk -----------------------------
        tok_m = tokens.reshape(M, mb, *tokens.shape[1:])
        tok_my = lax.dynamic_slice_in_dim(tok_m, start, chunk, axis=0)
        tok_my = tok_my.reshape(chunk * mb, *tokens.shape[1:])

        def head_w(cb=None):
            if cfg.tie_embeddings:
                w = params["embed"]
                return (w[cb] if cb is not None else w).T
            w = params["head"]
            return w[cb] if cb is not None else w

        row_mask = own_rows.astype(jnp.float32)
        if sample_valid is not None:
            # rows are (micro-batch chunk, sample slot): slots past this
            # shard's y_d are padding and contribute nothing to loss, count,
            # or (through the masked CE's transpose) gradients
            row_mask = row_mask * jnp.tile(sample_valid, chunk)
        if cfg.n_codebooks > 1:
            loss_sum = jnp.zeros((), jnp.float32)
            cnt_sum = jnp.zeros((), jnp.float32)
            for cb in range(cfg.n_codebooks):
                tgt = tok_my[:, cb, 1:]
                msk = row_mask[:, None] * jnp.ones_like(tgt, jnp.float32)
                l, c = vp_chunked_ce(h_txt[:, :-1], head_w(cb), tgt, msk, ctx,
                                     cfg.logit_softcap, spec.ce_chunk,
                                     v_valid=cfg.vocab_size)
                loss_sum, cnt_sum = loss_sum + l, cnt_sum + c
        else:
            tgt = tok_my[:, 1:]
            msk = row_mask[:, None] * jnp.ones_like(tgt, jnp.float32)
            loss_sum, cnt_sum = vp_chunked_ce(h_txt[:, :-1], head_w(), tgt, msk,
                                              ctx, cfg.logit_softcap,
                                              spec.ce_chunk, v_valid=cfg.vocab_size)

        # ---- MTP (DeepSeek-V3) on the stage-sharded chunk ------------------
        # values are numerically tp-invariant (psum_tp'd inside) but may be
        # *marked* tp-varying by vscan; reduce over all axes and divide out
        # the tp replication so outputs are fully invariant (out_specs P()).
        red_axes = ("pod", "data", "stage", "tp")

        def allsum(x):
            return lax.psum(_vary(x, red_axes), red_axes) / plan.tp

        mtp_sum = jnp.zeros((), jnp.float32)
        if cfg.mtp_depth > 0 and cfg.n_codebooks == 1 and cfg.prefix_len == 0:
            m = params["mtp"]
            emb = vp_embed(params["embed"], tok_my, ctx).astype(cfg.cdtype)
            e = jnp.concatenate([emb[:, 1:], jnp.zeros_like(emb[:, :1])], axis=1)
            zc = cfg.zero_centered_norm
            hh = jnp.concatenate([
                rmsnorm(m["norm_e"], e, cfg.norm_eps, zc),
                rmsnorm(m["norm_h"], h_txt, cfg.norm_eps, zc)], axis=-1)
            hh = (hh @ m["combine"]).astype(cfg.cdtype)
            pos2 = jnp.broadcast_to(jnp.arange(S_tot, dtype=jnp.int32),
                                    (hh.shape[0], S_tot))
            hh, _ = apply_period(m["block"], hh, pos2, cfg_local, ctx)
            hh = rmsnorm(m["final_norm"], hh, cfg.norm_eps, zc)
            tgt2 = jnp.concatenate([tok_my[:, 2:], jnp.zeros_like(tok_my[:, :2])],
                                   axis=1)
            msk2 = row_mask[:, None] * (jnp.arange(S_tot) < S_tot - 2)[None, :]
            l2, c2 = vp_chunked_ce(hh, head_w(), tgt2, msk2.astype(jnp.float32),
                                   ctx, cfg.logit_softcap, spec.ce_chunk,
                                   v_valid=cfg.vocab_size)
            mtp_sum = l2 / jnp.maximum(allsum(c2), 1.0)

        # ---- global reduction ---------------------------------------------

        loss_sum = allsum(loss_sum)
        cnt_sum = allsum(cnt_sum)
        # aux: sum over stages (layers), mean over dp replicas AND over the
        # M micro-batches (each tick computes a mean-style aux estimate)
        aux = allsum(aux) / (plan.dp_shards * M)
        ce = loss_sum / jnp.maximum(cnt_sum, 1.0)
        loss = ce + aux
        if cfg.mtp_depth > 0 and cfg.n_codebooks == 1 and cfg.prefix_len == 0:
            mtp = allsum(mtp_sum)
            loss = loss + MTP_WEIGHT * mtp
        else:
            mtp = jnp.zeros(())
        metrics = {"ce": ce, "aux": aux, "mtp": mtp, "tokens": cnt_sum}
        return loss, metrics

    return fn


def batch_pspecs(cfg: ModelConfig) -> dict:
    """PartitionSpecs for the training batch (inside shard_map in_specs)."""
    if cfg.n_codebooks > 1:
        specs = {"tokens": P(("pod", "data"), None, None)}
    else:
        specs = {"tokens": P(("pod", "data"), None)}
    if cfg.prefix_len > 0:
        specs["prefix"] = P(("pod", "data"), None, None)
    return specs
