"""Distributed train-step builder.

``build_train_step`` wires everything: global param init (periods padded to
the stage count, vocab padded to tp divisibility), PartitionSpecs, the
shard_map SPMD loss, jax.grad (DP gradient psums fall out of the shard_map
transpose), and the optimizer update (sharding-preserving elementwise).

``abstract_train_state`` builds the same thing out of ShapeDtypeStructs for
the dry-run path (no allocation).
"""

from __future__ import annotations

import dataclasses
import warnings
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import shard_map, sharded_init
from repro.distributed.mesh import MeshPlan, mesh_plan, pick_stage_count, refine_mesh
from repro.distributed.sharding import (Layout, TRAIN_LAYOUT, named,
                                        param_pspecs)
from repro.kernels.quant_transfer import roundtrip, roundtrip_ef
from repro.models.config import ModelConfig
from repro.models.model import init_model
from repro.optim import AdamW

from .pipeline import (TrainSpec, arrange_periods, batch_pspecs, pad_periods,
                       spmd_loss_fn)


def vocab_axes(cfg: ModelConfig) -> dict:
    """Axis carrying the vocab dimension in each vocab-parallel leaf."""
    return {"embed": 0 if cfg.n_codebooks == 1 else 1,
            "head": 1 if cfg.n_codebooks == 1 else 2}


def pad_vocab_leaf(a, axis: int, cfg: ModelConfig, tp: int):
    """Zero-pad one leaf's vocab dim to a multiple of tp."""
    v = cfg.vocab_size
    v_pad = -(-v // tp) * tp - v
    if v_pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, v_pad)
    return jnp.pad(a, widths)


def strip_vocab_leaf(a, axis: int, cfg: ModelConfig):
    """Inverse of ``pad_vocab_leaf``: slice back to the true vocab size."""
    sl = [slice(None)] * a.ndim
    sl[axis] = slice(0, cfg.vocab_size)
    return a[tuple(sl)]


def pad_vocab_params(params, cfg: ModelConfig, tp: int):
    """Pad embed/head vocab dims to a multiple of tp (CE masks the pad)."""
    axes = vocab_axes(cfg)
    out = dict(params)
    out["embed"] = pad_vocab_leaf(params["embed"], axes["embed"], cfg, tp)
    if "head" in params:
        out["head"] = pad_vocab_leaf(params["head"], axes["head"], cfg, tp)
    return out


def prepare_params(key, cfg: ModelConfig, plan: MeshPlan,
                   stage_periods=None):
    """Global init + structural padding for the distributed layout.

    ``stage_periods``: planner-lowered per-stage period ranges; when given,
    the period stack is arranged so each stage's uniform slice holds its
    assigned (possibly heterogeneous) period range (core.lowering).
    """
    params = init_model(key, cfg)
    if stage_periods is not None:
        params["periods"], _ = arrange_periods(params["periods"],
                                               stage_periods)
    else:
        params["periods"], _ = pad_periods(params["periods"], cfg.n_periods,
                                           plan.stage)
    params = pad_vocab_params(params, cfg, plan.tp)
    return params


def default_n_micro(cfg: ModelConfig, plan: MeshPlan, global_batch: int) -> int:
    """Micro-batch count: enough to fill the pipeline (>= 2*stages when the
    local batch allows), dividing the per-shard batch."""
    b_loc = global_batch // plan.dp_shards
    target = min(2 * plan.stage, b_loc)
    m = 1
    for cand in range(target, 0, -1):
        if b_loc % cand == 0:
            m = cand
            break
    return max(m, 1)


@dataclasses.dataclass
class TrainStep:
    spec: TrainSpec
    mesh: Mesh                      # refined mesh
    param_specs: object
    batch_specs: dict
    step_fn: object                 # jitted (params, opt_state, batch) -> ...
    loss_fn: object                 # jitted (params, batch) -> (loss, metrics)
    grad_fn: object = None          # jitted (params, batch) ->
                                    #   ((loss, metrics), grads)
    # Bounded-staleness step (spec.staleness >= 1, DESIGN.md §8): computes
    # round r's gradients but applies the *buffered* round r-1 gradients,
    # so the gradient AllReduce of round r has the whole of round r+1 to
    # overlap with.  The FIRST round has no buffer yet — drive it with
    # ``grad_fn`` alone (no optimizer update), so the update/schedule step
    # count matches the sync run exactly (sync delayed by one boundary).
    # jitted (params, opt_state, grad_buf, batch) ->
    # (params', opt_state', grads, loss, metrics).
    async_step_fn: object = None
    # jitted (params, opt_state, grad_buf) -> (params', opt_state'): apply
    # the buffered gradients synchronously (end of training / before a
    # replay migration — a failure forces a staleness barrier).
    flush_fn: object = None
    # Bucketed/compressed gradient path (spec.bucketed, DESIGN.md §10): the
    # step functions gain an error-feedback pytree argument and return its
    # successor —
    #   grad_fn(params, batch, ef) -> ((loss, metrics), grads, ef')
    #   step_fn(params, opt_state, ef, batch)
    #       -> (params', opt_state', ef', loss, metrics)
    #   async_step_fn(params, opt_state, grad_buf, ef, batch)
    #       -> (params', opt_state', grads, ef', loss, metrics)
    # ``init_ef()`` materializes the zero residual state ({} when error
    # feedback is off — the arity stays uniform); reset it whenever the
    # step is re-lowered (membership changes re-bucket the tree).
    init_ef: object = None
    # Static bucket partition [(free_axes, leaf_indices, local_sizes), ...]
    # for introspection (benchmarks / examples timeline).
    buckets: tuple = ()

    def shard_batch(self, batch_np: dict) -> dict:
        """Place a host batch on the mesh, first packing it for the spec's
        heterogeneous per-shard allocation (padding to B_max) if one is
        lowered.  The single batch-ingestion entry point — a replayed
        session's re-lowered step re-packs for the survivors' allocation
        with no change at the call site."""
        from repro.data import pack_batch, shard_batch
        if self.spec.shard_alloc is not None:
            batch_np = pack_batch(batch_np, self.spec.shard_alloc,
                                  self.spec.n_micro)
        return shard_batch(batch_np, self.mesh, self.batch_specs)


def _check_shard_alloc(shard_alloc, plan: MeshPlan, n_micro: int,
                       global_batch: int, cfg: ModelConfig | None = None):
    shard_alloc = tuple(int(y) for y in shard_alloc)
    if len(shard_alloc) != plan.dp_shards:
        raise ValueError(f"shard_alloc {shard_alloc} has {len(shard_alloc)} "
                         f"entries for {plan.dp_shards} data shards")
    if min(shard_alloc) < 0 or max(shard_alloc) == 0:
        raise ValueError(f"shard_alloc {shard_alloc} must be non-negative "
                         f"with at least one positive entry")
    if n_micro * sum(shard_alloc) != global_batch:
        raise ValueError(
            f"shard_alloc {shard_alloc} allocates {sum(shard_alloc)} samples "
            f"per micro-batch; {n_micro} micro-batches do not cover the "
            f"global batch {global_batch}")
    if cfg is not None and cfg.moe is not None \
            and len(set(shard_alloc)) > 1:
        warnings.warn(
            f"heterogeneous shard_alloc {shard_alloc} with an MoE config: "
            "zero-padded sample slots still route through the experts, so "
            "they consume router capacity (displacing real tokens unless "
            "capacity_factor has headroom) and enter the aux load-balance "
            "statistics (DESIGN.md §2.1)")
    return shard_alloc


def _check_stage_periods(stage_periods, plan: MeshPlan, cfg: ModelConfig):
    stage_periods = tuple(tuple(r) for r in stage_periods)
    if len(stage_periods) != plan.stage:
        raise ValueError(f"stage_periods {stage_periods} has "
                         f"{len(stage_periods)} ranges for {plan.stage} stages")
    prev = 0
    for i, j in stage_periods:
        if i != prev or j <= i:
            raise ValueError(f"stage_periods {stage_periods} must be "
                             f"contiguous non-empty ranges from 0")
        prev = j
    if prev != cfg.n_periods:
        raise ValueError(f"stage_periods {stage_periods} covers "
                         f"[0, {prev}) but the model has "
                         f"{cfg.n_periods} periods")
    return stage_periods


def build_train_step(cfg: ModelConfig, production_mesh: Mesh,
                     global_batch: int, *, stage: int | None = None,
                     n_micro: int | None = None, optimizer: AdamW | None = None,
                     remat: bool = True, ce_chunk: int = 1024,
                     hoist_varying: bool = True, zero_opt: bool = False,
                     stage_periods=None, shard_alloc=None,
                     staleness: int = 0,
                     double_buffer: bool | None = None,
                     compress: str = "none", quant_tile: int = 256,
                     bucket_mb: float | None = None,
                     error_feedback: bool = True) -> TrainStep:
    n_heads = cfg.attn.n_heads if cfg.attn is not None else (
        cfg.d_model // cfg.rwkv.head_dim if cfg.rwkv is not None else cfg.d_model)
    model_axis = production_mesh.shape["model"]
    if stage is None:
        stage = pick_stage_count(cfg.n_layers, len(cfg.pattern), model_axis,
                                 n_heads)
    plan = mesh_plan(production_mesh, stage)
    if n_micro is None:
        if shard_alloc is not None:
            raise ValueError("shard_alloc requires an explicit n_micro")
        n_micro = default_n_micro(cfg, plan, global_batch)
    if stage_periods is not None:
        stage_periods = _check_stage_periods(stage_periods, plan, cfg)
    if shard_alloc is not None:
        shard_alloc = _check_shard_alloc(shard_alloc, plan, n_micro,
                                         global_batch, cfg)
    spec = TrainSpec(cfg=cfg, plan=plan, n_micro=n_micro, remat=remat,
                     ce_chunk=ce_chunk, hoist_varying=hoist_varying,
                     stage_periods=stage_periods, shard_alloc=shard_alloc,
                     staleness=_check_staleness(staleness),
                     double_buffer=_default_double_buffer(double_buffer,
                                                          staleness),
                     compress=_check_compress(compress),
                     quant_tile=int(quant_tile), bucket_mb=bucket_mb,
                     error_feedback=bool(error_feedback))
    return _assemble_train_step(cfg, production_mesh, spec, optimizer,
                                zero_opt)


def _check_staleness(staleness: int) -> int:
    if staleness not in (0, 1):
        raise ValueError(f"staleness must be 0 (sync) or 1 (bounded-stale "
                         f"async), got {staleness}")
    return staleness


def _check_compress(compress: str | None) -> str:
    compress = "none" if compress is None else str(compress)
    if compress not in ("none", "int8", "fp8"):
        raise ValueError(f"compress must be 'none', 'int8' or 'fp8', "
                         f"got {compress!r}")
    return compress


def _default_double_buffer(double_buffer: bool | None, staleness: int) -> bool:
    """The async runtime double-buffers by default; the sync runtime keeps
    the serialized sends (today's semantics) unless explicitly asked."""
    return staleness >= 1 if double_buffer is None else bool(double_buffer)


def train_spec_from_lowered(cfg: ModelConfig, production_mesh: Mesh, lowered,
                            *, remat: bool = True, ce_chunk: int = 1024,
                            hoist_varying: bool = True, staleness: int = 0,
                            double_buffer: bool | None = None,
                            compress: str = "none", quant_tile: int = 256,
                            bucket_mb: float | None = None,
                            error_feedback: bool = True) -> TrainSpec:
    """Derive the static step configuration from a ``core.lowering``
    ``LoweredPlan`` (duck-typed: ``stage``/``n_micro``/``stage_periods``/
    ``global_batch``/``micro_alloc`` attributes), validating mesh
    feasibility.  A heterogeneous ``micro_alloc`` is collapsed to the
    per-data-shard allocation the runtime executes
    (``core.lowering.lower_micro_alloc``); a uniform one keeps the legacy
    unpadded batch layout."""
    model_axis = production_mesh.shape["model"]
    if model_axis % lowered.stage:
        raise ValueError(f"stage count {lowered.stage} does not divide the "
                         f"mesh model axis {model_axis}")
    plan = mesh_plan(production_mesh, lowered.stage)
    dp = plan.dp_shards

    shard_alloc = None
    if getattr(lowered, "micro_alloc", None):
        from repro.core.lowering import lower_micro_alloc
        shard_alloc = lower_micro_alloc(lowered, dp)
        if len(set(shard_alloc)) == 1:
            shard_alloc = None           # uniform: no padding needed
        else:
            shard_alloc = _check_shard_alloc(shard_alloc, plan,
                                            lowered.n_micro,
                                            lowered.global_batch, cfg)
    if shard_alloc is None and (
            lowered.global_batch % dp
            or (lowered.global_batch // dp) % lowered.n_micro):
        raise ValueError(
            f"global batch {lowered.global_batch} not divisible into "
            f"{lowered.n_micro} micro-batches per {dp} data shards")
    stage_periods = _check_stage_periods(lowered.stage_periods, plan, cfg)
    return TrainSpec(cfg=cfg, plan=plan, n_micro=lowered.n_micro, remat=remat,
                     ce_chunk=ce_chunk, hoist_varying=hoist_varying,
                     stage_periods=stage_periods, shard_alloc=shard_alloc,
                     staleness=_check_staleness(staleness),
                     double_buffer=_default_double_buffer(double_buffer,
                                                          staleness),
                     compress=_check_compress(compress),
                     quant_tile=int(quant_tile), bucket_mb=bucket_mb,
                     error_feedback=bool(error_feedback))


def build_train_step_from_lowered(cfg: ModelConfig, production_mesh: Mesh,
                                  lowered, *, optimizer: AdamW | None = None,
                                  zero_opt: bool = False,
                                  **spec_kw) -> TrainStep:
    """Build (or, after a plan swap, re-build) the jitted step for a
    ``LoweredPlan`` — the session layer's entry point: params and optimizer
    state survive across calls, only the compiled step is replaced."""
    spec = train_spec_from_lowered(cfg, production_mesh, lowered, **spec_kw)
    return _assemble_train_step(cfg, production_mesh, spec, optimizer,
                                zero_opt)


# ---------------------------------------------------------------------------
# Bucketed / compressed gradient AllReduce (DESIGN.md §10)
# ---------------------------------------------------------------------------

MESH_AXES = ("pod", "data", "stage", "tp")


def _leaf_axes(spec) -> set:
    """Mesh axes appearing anywhere in a leaf's PartitionSpec."""
    used = set()
    for entry in spec:
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            if ax is not None:
                used.add(ax)
    return used


def _free_axes(spec) -> tuple:
    """Mesh axes a leaf's gradient must be psum'd over: every axis NOT
    already sharding the leaf.  A leaf sharded over an axis holds distinct
    shard values there (its gradient needs no reduction along it); a leaf
    replicated over an axis is used by every device along it (each holds a
    partial contribution).  This is exactly the reduction the shard_map
    transpose inserts for the un-bucketed path (psum is elementwise —
    reducing a concatenation equals concatenating the reductions), so
    uncompressed bucketed gradients match the legacy path to float
    reassociation (~1e-6 rel; XLA compiles a different reduction order)."""
    used = _leaf_axes(spec)
    return tuple(ax for ax in MESH_AXES if ax not in used)


def _local_size(shape, spec, mesh: Mesh) -> int:
    """Per-device element count of a leaf under its PartitionSpec."""
    n = 1
    for d, dim in enumerate(shape):
        div = 1
        if d < len(spec) and spec[d] is not None:
            entry = spec[d]
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                div *= mesh.shape[ax]
        n *= dim // div
    return n


def grad_buckets(abstract_params, pspecs, mesh: Mesh,
                 bucket_mb: float | None):
    """Static bucket partition of the gradient pytree.

    Leaves are grouped by free-axes set (one psum serves a whole bucket)
    and greedily packed into ``bucket_mb``-bounded buckets in tree-flatten
    order.  Each bucket's psum depends only on its own leaves' cotangents,
    so XLA's latency-hiding scheduler can launch early buckets' AllReduces
    while later layers are still in backward (DDP-style partial syncs —
    ``plan_dp(overlap=True)``'s pricing, now on the HPP gradient stream).

    Returns ``[(free_axes, leaf_indices, local_sizes), ...]``; leaf indices
    refer to ``jax.tree_util.tree_leaves`` order of the param tree.
    """
    leaves, _ = jax.tree_util.tree_flatten(abstract_params)
    spec_leaves = jax.tree_util.tree_leaves(
        jax.tree.map(lambda _, s: s, abstract_params, pspecs),
        is_leaf=lambda x: isinstance(x, P))
    assert len(leaves) == len(spec_leaves), (len(leaves), len(spec_leaves))
    cap = float("inf") if bucket_mb is None else float(bucket_mb) * (1 << 20)
    groups: dict = {}
    for i, (leaf, sp) in enumerate(zip(leaves, spec_leaves)):
        groups.setdefault(_free_axes(sp), []).append(
            (i, _local_size(leaf.shape, sp, mesh)))
    buckets = []
    for free, entries in sorted(groups.items()):
        cur: list = []
        cur_bytes = 0.0
        for i, n in entries:
            if cur and cur_bytes + n * 4 > cap:
                buckets.append((free, tuple(j for j, _ in cur),
                                tuple(m for _, m in cur)))
                cur, cur_bytes = [], 0.0
            cur.append((i, n))
            cur_bytes += n * 4
        if cur:
            buckets.append((free, tuple(j for j, _ in cur),
                            tuple(m for _, m in cur)))
    return buckets


def _ef_key(bi: int) -> str:
    return f"bucket{bi}"


def ef_specs_for(buckets):
    """PartitionSpecs for the error-feedback pytree: one per-device flat
    residual per bucket, stacked over every mesh axis on dim 0 (global
    shape ``(n_devices, L_b)``, local ``(1, L_b)``)."""
    return {_ef_key(bi): P(MESH_AXES, None) for bi in range(len(buckets))}


def ef_zeros(buckets, mesh: Mesh, shardings):
    """Materialize the zero error-feedback state on the mesh."""
    n_dev = 1
    for ax in MESH_AXES:
        n_dev *= mesh.shape[ax]
    out = {}
    for bi, (_, _, sizes) in enumerate(buckets):
        k = _ef_key(bi)
        out[k] = jax.device_put(jnp.zeros((n_dev, sum(sizes)), jnp.float32),
                                shardings[k])
    return out


def _bucketed_grad_fn(spec: TrainSpec, base_loss, buckets):
    """Inside-shard_map gradient with explicit per-bucket psums.

    ``jax.value_and_grad`` of the SPMD loss *inside* the shard_map body
    yields each device's unreduced local contribution (boundary casts are
    identity on this side of the shard_map boundary); every bucket is then
    flattened, optionally quantized (with the error-feedback residual
    carried across steps), and psum'd over its free axes.  The quantization
    compresses exactly the bytes each device contributes to the AllReduce.
    """
    fmt, tile, ef_on = spec.compress, spec.quant_tile, spec.error_feedback
    # Differentiating THROUGH the loss psum inside the body scales every
    # cotangent by the psum's transpose (another psum of the unit seed =
    # the device count over the reduced axes); undo it once here.  Device
    # counts are powers of two on every supported mesh, so the division
    # itself is exact.
    plan = spec.plan
    n_dev = plan.pod * plan.data * plan.stage * plan.tp

    def fn(params, batch, ef):
        (loss, metrics), grads = jax.value_and_grad(
            base_loss, has_aux=True)(params, batch)
        leaves, treedef = jax.tree_util.tree_flatten(grads)
        new_leaves = list(leaves)
        new_ef = dict(ef)
        for bi, (free, idxs, _sizes) in enumerate(buckets):
            flat = jnp.concatenate(
                [leaves[i].astype(jnp.float32).reshape(-1) for i in idxs])
            flat = flat * jnp.float32(1.0 / n_dev)
            if fmt != "none":
                if ef_on:
                    k = _ef_key(bi)
                    flat, res = roundtrip_ef(flat, ef[k][0], fmt=fmt,
                                             tile=tile)
                    new_ef[k] = res[None]
                else:
                    flat = roundtrip(flat, fmt=fmt, tile=tile)
            if free:
                flat = jax.lax.psum(flat, free)
            off = 0
            for i in idxs:
                n = new_leaves[i].size
                new_leaves[i] = flat[off:off + n].reshape(
                    leaves[i].shape).astype(leaves[i].dtype)
                off += n
        return loss, metrics, jax.tree_util.tree_unflatten(
            treedef, new_leaves), new_ef

    return fn


def _assemble_train_step(cfg: ModelConfig, production_mesh: Mesh,
                         spec: TrainSpec, optimizer: AdamW | None,
                         zero_opt: bool) -> TrainStep:
    plan = spec.plan
    stage_periods = spec.stage_periods
    mesh = refine_mesh(production_mesh, plan.stage)
    optimizer = optimizer or AdamW(lr=1e-3)

    # --- specs (built against an abstract param tree) ----------------------
    abstract = jax.eval_shape(
        lambda k: prepare_params(k, cfg, plan, stage_periods),
        jax.random.PRNGKey(0))
    kv_repl = cfg.attn is not None and cfg.attn.n_kv_heads % plan.tp != 0
    layout = dataclasses.replace(TRAIN_LAYOUT, kv_replicated=kv_repl)
    pspecs = param_pspecs(abstract, layout)
    bspecs = batch_pspecs(cfg)

    spmd = spmd_loss_fn(spec)
    metrics_sp = {"ce": P(), "aux": P(), "mtp": P(), "tokens": P()}
    sharded_loss = shard_map(spmd, mesh=mesh,
                             in_specs=(pspecs, bspecs),
                             out_specs=(P(), metrics_sp))

    def loss_fn(params, batch):
        return sharded_loss(params, batch)

    param_shardings = named(mesh, pspecs)
    batch_sh = named(mesh, bspecs)
    jit_loss = jax.jit(loss_fn, in_shardings=(param_shardings, batch_sh))
    opt_sh = _opt_shardings(optimizer, abstract, param_shardings,
                            zero_sharding=zero_opt)

    if spec.bucketed:
        return _assemble_bucketed(spec, mesh, optimizer, abstract, pspecs,
                                  bspecs, spmd, metrics_sp, param_shardings,
                                  batch_sh, opt_sh, jit_loss)

    def grad_fn(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def step_fn(params, opt_state, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, loss, metrics

    jit_grad = jax.jit(grad_fn, in_shardings=(param_shardings, batch_sh))
    jit_step = jax.jit(step_fn, in_shardings=(
        param_shardings, opt_sh, batch_sh),
        out_shardings=(param_shardings, opt_sh, None, None))

    jit_async = jit_flush = None
    if spec.staleness >= 1:
        # Bounded-staleness step: the update consumes the PREVIOUS round's
        # gradient buffer, so nothing downstream of this round's gradient
        # AllReduce is on this round's critical path — the AllReduce of
        # round r may complete any time before the r+1 boundary update
        # (staleness 1; DESIGN.md §8).  Gradients share the param tree
        # structure and shardings (the shard_map transpose psums them onto
        # the param specs).
        def async_step_fn(params, opt_state, grad_buf, batch):
            (loss, metrics), grads = grad_fn(params, batch)
            new_params, new_opt = optimizer.update(grad_buf, opt_state, params)
            return new_params, new_opt, grads, loss, metrics

        def flush_fn(params, opt_state, grad_buf):
            return optimizer.update(grad_buf, opt_state, params)

        jit_async = jax.jit(async_step_fn, in_shardings=(
            param_shardings, opt_sh, param_shardings, batch_sh),
            out_shardings=(param_shardings, opt_sh, param_shardings,
                           None, None))
        jit_flush = jax.jit(flush_fn, in_shardings=(
            param_shardings, opt_sh, param_shardings),
            out_shardings=(param_shardings, opt_sh))

    return TrainStep(spec=spec, mesh=mesh, param_specs=pspecs,
                     batch_specs=bspecs, step_fn=jit_step, loss_fn=jit_loss,
                     grad_fn=jit_grad, async_step_fn=jit_async,
                     flush_fn=jit_flush)


def _assemble_bucketed(spec: TrainSpec, mesh: Mesh, optimizer, abstract,
                       pspecs, bspecs, spmd, metrics_sp, param_shardings,
                       batch_sh, opt_sh, jit_loss) -> TrainStep:
    """Step assembly for the bucketed/compressed gradient path.

    The gradient is taken INSIDE the shard_map body and reduced by explicit
    per-bucket psums over each leaf's free axes — semantically the same
    reduction the legacy outside-grad transpose inserts, but addressable:
    each bucket is a separate, data-independent AllReduce that XLA can
    launch as soon as its leaves' backward completes, and the compressed
    variant quantizes exactly the per-device contribution that crosses the
    wire (error-feedback residual carried in the ``ef`` pytree).
    """
    buckets = tuple(grad_buckets(abstract, pspecs, mesh, spec.bucket_mb))
    use_ef = spec.compress != "none" and spec.error_feedback
    ef_sp = ef_specs_for(buckets) if use_ef else {}
    ef_sh = named(mesh, ef_sp)

    sharded_grad = shard_map(_bucketed_grad_fn(spec, spmd, buckets),
                             mesh=mesh,
                             in_specs=(pspecs, bspecs, ef_sp),
                             out_specs=(P(), metrics_sp, pspecs, ef_sp))

    def grad_fn(params, batch, ef):
        loss, metrics, grads, ef = sharded_grad(params, batch, ef)
        return (loss, metrics), grads, ef

    def step_fn(params, opt_state, ef, batch):
        (loss, metrics), grads, ef = grad_fn(params, batch, ef)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        return new_params, new_opt, ef, loss, metrics

    jit_grad = jax.jit(grad_fn, in_shardings=(param_shardings, batch_sh,
                                              ef_sh))
    jit_step = jax.jit(step_fn, in_shardings=(
        param_shardings, opt_sh, ef_sh, batch_sh),
        out_shardings=(param_shardings, opt_sh, ef_sh, None, None))

    jit_async = jit_flush = None
    if spec.staleness >= 1:
        def async_step_fn(params, opt_state, grad_buf, ef, batch):
            (loss, metrics), grads, ef = grad_fn(params, batch, ef)
            new_params, new_opt = optimizer.update(grad_buf, opt_state, params)
            return new_params, new_opt, grads, ef, loss, metrics

        def flush_fn(params, opt_state, grad_buf):
            return optimizer.update(grad_buf, opt_state, params)

        jit_async = jax.jit(async_step_fn, in_shardings=(
            param_shardings, opt_sh, param_shardings, ef_sh, batch_sh),
            out_shardings=(param_shardings, opt_sh, param_shardings, ef_sh,
                           None, None))
        jit_flush = jax.jit(flush_fn, in_shardings=(
            param_shardings, opt_sh, param_shardings),
            out_shardings=(param_shardings, opt_sh))

    def init_ef():
        return ef_zeros(buckets, mesh, ef_sh) if use_ef else {}

    return TrainStep(spec=spec, mesh=mesh, param_specs=pspecs,
                     batch_specs=bspecs, step_fn=jit_step, loss_fn=jit_loss,
                     grad_fn=jit_grad, async_step_fn=jit_async,
                     flush_fn=jit_flush, init_ef=init_ef, buckets=buckets)


def _zero_moment_shardings(abstract_params, param_shardings):
    """ZeRO-1-style: shard each moment over ('pod','data') on the first dim
    that is unsharded and divisible — fp32 Adam moments dominate the training
    footprint, and they are only touched in the (resharded) update step."""
    mesh = jax.tree.leaves(param_shardings)[0].mesh
    dp = mesh.shape["pod"] * mesh.shape["data"]

    def shard_one(leaf, named_sh):
        spec = list(named_sh.spec) + [None] * (leaf.ndim - len(named_sh.spec))
        used = set()
        for entry in spec:
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                used.add(ax)
        # only dp axes this tensor doesn't already use (e.g. EP'd experts
        # are already sharded over 'data' — they are "ZeRO'd" by EP)
        free = tuple(ax for ax in ("pod", "data") if ax not in used)
        n = 1
        for ax in free:
            n *= mesh.shape[ax]
        if n <= 1:
            return named_sh
        for i, dim in enumerate(leaf.shape):
            if spec[i] is None and dim % n == 0 and dim >= n:
                spec[i] = free if len(free) > 1 else free[0]
                return NamedSharding(mesh, P(*spec))
        return named_sh           # too small / indivisible: keep param layout

    return jax.tree.map(shard_one, abstract_params, param_shardings)


def _opt_shardings(optimizer, abstract_params, param_shardings,
                   zero_sharding: bool = False):
    """Moments share the param shardings (or a ZeRO-1 dp-sharded variant);
    the step counter is replicated."""
    from repro.optim import AdamWState, SGDState
    mesh = jax.tree.leaves(param_shardings)[0].mesh
    rep = NamedSharding(mesh, P())
    moments = (_zero_moment_shardings(abstract_params, param_shardings)
               if zero_sharding else param_shardings)
    st = jax.eval_shape(optimizer.init, abstract_params)
    if isinstance(st, AdamWState):
        return AdamWState(rep, moments, moments)
    if isinstance(st, SGDState):
        return SGDState(rep, moments)
    raise TypeError(type(st))


def init_train_state(key, ts: TrainStep, optimizer: AdamW | None = None):
    """Materialize sharded params + optimizer state on the mesh."""
    optimizer = optimizer or AdamW(lr=1e-3)
    cfg, plan = ts.spec.cfg, ts.spec.plan
    shardings = named(ts.mesh, ts.param_specs)
    params = sharded_init(lambda k: prepare_params(k, cfg, plan,
                                                   ts.spec.stage_periods),
                          shardings)(key)
    opt_state = sharded_init(optimizer.init,
                             _opt_shardings(optimizer,
                                            jax.eval_shape(lambda: params),
                                            shardings))(params)
    return params, opt_state
