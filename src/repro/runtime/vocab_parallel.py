"""Vocab-parallel embedding lookup and cross-entropy (Megatron-style).

The embedding table / LM head are sharded over the ``tp`` axis on the vocab
dim.  Lookup masks out-of-range ids and psums partial rows; cross-entropy
computes per-shard partial max / sum-exp / gold-logit and reduces — the full
(B, S, V) logits are never materialized, and the sequence dim is chunked.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.module import NO_PARALLEL, ParallelCtx, vscan


def vp_embed(table_local: jnp.ndarray, ids: jnp.ndarray,
             ctx: ParallelCtx = NO_PARALLEL) -> jnp.ndarray:
    """table_local: (V_local, D); ids: (...) global token ids -> (..., D)."""
    v_local = table_local.shape[0]
    off = ctx.tp_index() * v_local
    local_ids = ids - off
    ok = (local_ids >= 0) & (local_ids < v_local)
    rows = table_local[jnp.clip(local_ids, 0, v_local - 1)]
    rows = jnp.where(ok[..., None], rows, 0)
    return ctx.psum_tp(rows)


def vp_ce_chunk(h: jnp.ndarray, w_local: jnp.ndarray, targets: jnp.ndarray,
                mask: jnp.ndarray, ctx: ParallelCtx, softcap=None,
                v_valid: int | None = None):
    """CE over one chunk.  h: (..., D); w_local: (D, V_local);
    targets/mask: (...).  Returns (sum_loss, sum_count) fp32 — already
    psum-reduced over tp for the vocab dim (NOT over data/stage).

    ``v_valid``: true vocab size when the table was padded for tp
    divisibility — padded columns are masked out of the softmax."""
    v_local = w_local.shape[1]
    logits = (h @ w_local).astype(jnp.float32)              # (..., V_local)
    if softcap is not None:
        logits = softcap * jnp.tanh(logits / softcap)
    if v_valid is not None:
        col = ctx.tp_index() * v_local + jnp.arange(v_local)
        logits = jnp.where(col < v_valid, logits, -1e30)
    # the softmax max-shift is gradient-free (pmax has no vjp rule, so the
    # stop_gradient must sit *before* the collective)
    m = ctx.pmax_tp(lax.stop_gradient(logits.max(axis=-1)))
    se = ctx.psum_tp(jnp.exp(logits - m[..., None]).sum(axis=-1))
    lse = m + jnp.log(se)

    off = ctx.tp_index() * v_local
    local_t = targets - off
    ok = (local_t >= 0) & (local_t < v_local)
    gold_local = jnp.take_along_axis(
        logits, jnp.clip(local_t, 0, v_local - 1)[..., None], axis=-1)[..., 0]
    gold = ctx.psum_tp(jnp.where(ok, gold_local, 0.0))

    loss = (lse - gold) * mask
    return loss.sum(), mask.sum()


def vp_chunked_ce(h: jnp.ndarray, w_local: jnp.ndarray, targets: jnp.ndarray,
                  mask: jnp.ndarray, ctx: ParallelCtx = NO_PARALLEL,
                  softcap=None, chunk: int = 1024, v_valid: int | None = None):
    """Sequence-chunked vocab-parallel CE.

    h: (B, S, D); targets/mask: (B, S).  Returns (sum_loss, sum_count).
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = S // chunk
    rem = S - n * chunk

    def step(carry, args):
        s_loss, s_cnt = carry
        hi, ti, mi = args
        l, c = vp_ce_chunk(hi, w_local, ti, mi, ctx, softcap, v_valid)
        return (s_loss + l, s_cnt + c), None

    hc = h[:, : n * chunk].reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    tc = targets[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask[:, : n * chunk].reshape(B, n, chunk).transpose(1, 0, 2)
    zero = (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32))
    (s_loss, s_cnt), _ = vscan(step, zero, (hc, tc, mc))
    if rem:
        l, c = vp_ce_chunk(h[:, n * chunk:], w_local, targets[:, n * chunk:],
                           mask[:, n * chunk:], ctx, softcap, v_valid)
        s_loss, s_cnt = s_loss + l, s_cnt + c
    return s_loss, s_cnt
