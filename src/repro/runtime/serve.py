"""Distributed serving: one-token decode (serve_step) on the refined mesh.

Layout (per DESIGN.md): decode is latency-bound, so the ``model`` axis is
used mostly for tensor parallelism (stage=1 when the head count allows);
architectures whose head count caps tp keep a short pipeline and stream the
local batch through it in groups (same circular ppermute pattern as
training).  The KV/state cache is sharded:

* batch over ``(pod, data)`` for the throughput decode shapes,
* **sequence-sharded over ``data``** for ``long_500k`` (batch 1): each shard
  owns a slice of the KV cache and attention combines partial softmaxes with
  pmax/psum — flash-decoding mapped onto the mesh.

``check_vma=False``: decode caches are deliberately replicated across tp
when KV heads < tp, which the vma checker cannot express.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.planner import serve_stage_candidates
from repro.distributed.compat import shard_map
from repro.distributed.mesh import MeshPlan, mesh_plan, refine_mesh
from repro.distributed.sharding import (Layout, SERVE_LAYOUT, named,
                                        param_pspecs, state_pspecs)
from repro.models.blocks import decode_periods, init_period_states, shard_config
from repro.models.config import ModelConfig
from repro.models.norms import rmsnorm
from repro.models.module import vary_all

from .pipeline import make_ctx, pad_periods
from .train import pad_vocab_params, prepare_params
from .vocab_parallel import vp_embed


def serve_head_count(cfg: ModelConfig) -> int:
    """Head count that caps tensor parallelism for decode."""
    return cfg.attn.n_heads if cfg.attn is not None else (
        cfg.d_model // cfg.rwkv.head_dim if cfg.rwkv is not None else 1)


def pick_serve_stage(cfg: ModelConfig, model_axis: int) -> int:
    """Serve prefers TP: the smallest stage count whose tp divides the query
    head count (query heads must shard; KV may replicate).  Candidates are
    the divisors of ``model_axis`` — not a fixed power-of-two probe — so a
    6-device model axis yields stage 2 (tp 3) rather than a 6-deep
    pipeline.  ``core.planner.plan_serve`` makes the full latency-priced
    choice; this is the profile-free default."""
    return serve_stage_candidates(model_axis, serve_head_count(cfg))[0]


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    cfg: ModelConfig
    plan: MeshPlan
    cache_len: int
    batch_global: int
    seq_shard: bool            # long-context: shard cache seq over 'data'
    n_groups: int = 1          # decode pipelining groups (stage > 1)
    # Heterogeneous decode slots per dp shard (the ``TrainSpec.shard_alloc``
    # counterpart): shard d serves shard_alloc[d] live slots, every shard is
    # padded to max(shard_alloc) rows (SPMD needs equal local shapes) and the
    # padding rows are masked out of the sampling head.  Setting this also
    # switches the step to the per-slot signature
    # ``fn(params, token (B,), position (B,), reset (B,), states)``.
    shard_alloc: tuple[int, ...] | None = None

    @property
    def batch_sharded(self) -> bool:
        return not self.seq_shard

    @property
    def per_slot(self) -> bool:
        return self.shard_alloc is not None

    @property
    def slot_mask(self):
        """(dp_shards, B_max) validity of each padded slot row."""
        assert self.shard_alloc is not None
        b_max = self.batch_global // self.plan.dp_shards
        return jnp.asarray([[i < y for i in range(b_max)]
                            for y in self.shard_alloc])

    @property
    def cfg_local(self) -> ModelConfig:
        ep = self.plan.data if self.batch_sharded else 1
        return shard_config(self.cfg, tp=self.plan.tp, ep=ep)


def spmd_decode_fn(spec: ServeSpec):
    cfg = spec.cfg
    cfg_local = spec.cfg_local
    plan = spec.plan
    P_st = plan.stage
    ctx = make_ctx(plan, ep=spec.batch_sharded, seq_shard=spec.seq_shard)

    def head_w(params, cb=None):
        if cfg.tie_embeddings:
            w = params["embed"]
            return (w[cb] if cb is not None else w).T
        w = params["head"]
        return w[cb] if cb is not None else w

    slot_mask = spec.slot_mask if spec.per_slot else None

    def body(params, token, position, states):
        # token: (B_loc,) or (B_loc, CB); position: () or (B_loc,) int32
        if cfg.n_codebooks > 1:
            x = sum(vp_embed(params["embed"][cb], token[:, cb], ctx)
                    for cb in range(cfg.n_codebooks))
        else:
            x = vp_embed(params["embed"], token, ctx)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = x.astype(cfg.cdtype)

        if P_st == 1:
            h, new_states = decode_periods(params["periods"], x, position,
                                           states, cfg_local, ctx)
        else:
            h, new_states = _pipelined_decode(params["periods"], x, position,
                                              states, cfg_local, ctx, P_st,
                                              spec.n_groups)

        h = rmsnorm(params["final_norm"], h, cfg.norm_eps, cfg.zero_centered_norm)
        if cfg.n_codebooks > 1:
            logits = jnp.stack([(h @ head_w(params, cb)).astype(jnp.float32)
                                for cb in range(cfg.n_codebooks)], axis=1)
        else:
            logits = (h @ head_w(params)).astype(jnp.float32)
        if cfg.logit_softcap is not None:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        if P_st > 1:
            stage = lax.axis_index("stage")
            logits = lax.psum(
                jnp.where(stage == P_st - 1, logits, jnp.zeros_like(logits)),
                "stage")
        return logits, new_states

    if not spec.per_slot:
        return body

    def slot_fn(params, token, position, reset, states):
        # per-slot decode: position/reset are (B_loc,); padded slot rows
        # (beyond this shard's shard_alloc count) are masked out of the
        # sampling head, and reset rows get their recurrent state zeroed
        # before the step (attention caches need no reset — the per-row
        # cache_len mask hides stale entries).
        dp_idx = lax.axis_index("pod") * plan.data + lax.axis_index("data")
        valid = lax.dynamic_index_in_dim(slot_mask, dp_idx, 0, keepdims=False)

        def clear(s):
            r = reset.reshape((1, -1) + (1,) * (s.ndim - 2))
            return jnp.where(r, jnp.zeros_like(s), s)

        states = jax.tree.map(clear, states)
        logits, new_states = body(params, token, position, states)
        vmask = valid.reshape((-1,) + (1,) * (logits.ndim - 1))
        logits = jnp.where(vmask, logits, jnp.zeros_like(logits))
        return logits, new_states

    return slot_fn


def _pipelined_decode(periods_local, x, position, states, cfg_local, ctx,
                      P_st: int, n_groups: int):
    """Stream the local batch through the stage pipeline in groups."""
    B_loc, D = x.shape
    n_g = n_groups if (B_loc % n_groups == 0 and B_loc >= n_groups) else 1
    bg = B_loc // n_g
    xg = x.reshape(n_g, bg, D)
    stage = lax.axis_index("stage")
    perm = [(i, (i + 1) % P_st) for i in range(P_st)]

    def slice_b(s, g):
        return lax.dynamic_slice_in_dim(s, g * bg, bg, axis=1)

    def slice_pos(g):
        # per-row positions travel with their batch group
        if jnp.ndim(position) == 1:
            return lax.dynamic_slice_in_dim(position, g * bg, bg)
        return position

    def update_b(s, new, g, active):
        upd = lax.dynamic_update_slice_in_dim(s, new.astype(s.dtype), g * bg, axis=1)
        return jnp.where(active, upd, s)

    carry0 = vary_all((jnp.zeros((bg, D), x.dtype),
                       jnp.zeros((n_g, bg, D), x.dtype), states))

    def tick(carry, t):
        act, outs, st = carry
        g = jnp.clip(t - stage, 0, n_g - 1)
        inp = jnp.where(stage == 0,
                        lax.dynamic_index_in_dim(xg, jnp.clip(t, 0, n_g - 1), 0,
                                                 keepdims=False),
                        act)
        st_g = jax.tree.map(lambda s: slice_b(s, g), st)
        h, st_new = decode_periods(periods_local, inp, slice_pos(g), st_g,
                                   cfg_local, ctx)
        active = (t >= stage) & (t < stage + n_g)
        st = jax.tree.map(lambda s, n: update_b(s, n, g, active), st, st_new)
        nxt = lax.ppermute(h, "stage", perm)
        oidx = t - (P_st - 1)
        outs = jnp.where(
            (stage == P_st - 1) & (oidx >= 0),
            lax.dynamic_update_index_in_dim(outs, h, jnp.clip(oidx, 0, n_g - 1), 0),
            outs)
        return vary_all((nxt, outs, st)), None

    (_, outs, states), _ = lax.scan(tick, carry0, jnp.arange(n_g + P_st - 1))
    # outputs valid on the last stage; broadcast to all stages so the head
    # can run (masked psum keeps only the real values)
    return outs.reshape(B_loc, D), states


# ---------------------------------------------------------------------------
# Prefill (inference over a full prompt)
# ---------------------------------------------------------------------------


def build_prefill_step(cfg: ModelConfig, production_mesh: Mesh, *,
                       batch_global: int, seq_len: int,
                       stage: int | None = None, n_micro: int | None = None):
    """Prefill: forward over the prompt through the HPP pipeline, returning
    last-position logits.  (KV-cache export is an output-layout detail with
    no FLOPs — see DESIGN.md §Dry-run notes.)"""
    from repro.distributed.mesh import pick_stage_count
    from repro.runtime.pipeline import (TrainSpec, batch_pspecs, pipeline_apply,
                                        spmd_loss_fn)
    from repro.runtime.train import default_n_micro

    n_heads = cfg.attn.n_heads if cfg.attn is not None else (
        cfg.d_model // cfg.rwkv.head_dim if cfg.rwkv is not None else cfg.d_model)
    model_axis = production_mesh.shape["model"]
    if stage is None:
        stage = pick_stage_count(cfg.n_layers, len(cfg.pattern), model_axis,
                                 n_heads)
    mesh = refine_mesh(production_mesh, stage)
    plan = mesh_plan(production_mesh, stage)
    if n_micro is None:
        n_micro = default_n_micro(cfg, plan, batch_global)
    spec = TrainSpec(cfg=cfg, plan=plan, n_micro=n_micro, remat=False)
    cfg_local = spec.cfg_local
    ctx = make_ctx(plan)
    M = n_micro

    def head_w(params, cb=None):
        if cfg.tie_embeddings:
            w = params["embed"]
            return (w[cb] if cb is not None else w).T
        w = params["head"]
        return w[cb] if cb is not None else w

    def fn(params, batch):
        tokens = batch["tokens"]
        B_loc = tokens.shape[0]
        mb = B_loc // M
        if cfg.n_codebooks > 1:
            x = sum(vp_embed(params["embed"][cb], tokens[:, cb], ctx)
                    for cb in range(cfg.n_codebooks))
        else:
            x = vp_embed(params["embed"], tokens, ctx)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        x = x.astype(cfg.cdtype)
        if cfg.prefix_len > 0:
            px = batch["prefix"].astype(cfg.cdtype) @ params["prefix_proj"]
            x = jnp.concatenate([px.astype(cfg.cdtype), x], axis=1)
        S_tot = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S_tot, dtype=jnp.int32),
                                     (mb, S_tot))
        n_periods = cfg.n_periods
        padded = -(-n_periods // plan.stage) * plan.stage
        k_per = padded // plan.stage
        mask_global = jnp.asarray([1.0] * n_periods +
                                  [0.0] * (padded - n_periods), jnp.float32)
        if plan.stage > 1:
            mask_local = lax.dynamic_slice_in_dim(
                mask_global, lax.axis_index("stage") * k_per, k_per)
        else:
            mask_local = mask_global
        x_micro = x.reshape(M, mb, S_tot, cfg.d_model)
        from repro.runtime.pipeline import pipeline_apply as _pa
        outs, _ = _pa(params["periods"], mask_local, x_micro, positions,
                      cfg_local, ctx, plan.stage, remat=False)
        h_last = outs[:, :, -1, :].reshape(B_loc, cfg.d_model)
        if plan.stage > 1:
            st = lax.axis_index("stage")
            h_last = lax.psum(
                jnp.where(st == plan.stage - 1, h_last, jnp.zeros_like(h_last)),
                "stage")
        h_last = rmsnorm(params["final_norm"], h_last, cfg.norm_eps,
                         cfg.zero_centered_norm)
        if cfg.n_codebooks > 1:
            logits = jnp.stack([(h_last @ head_w(params, cb)).astype(jnp.float32)
                                for cb in range(cfg.n_codebooks)], axis=1)
        else:
            logits = (h_last @ head_w(params)).astype(jnp.float32)
        if cfg.logit_softcap is not None:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        return logits

    kv_repl = cfg.attn is not None and cfg.attn.n_kv_heads % plan.tp != 0
    layout = dataclasses.replace(SERVE_LAYOUT, kv_replicated=kv_repl,
                                 ep_axis="data")
    abstract_p = jax.eval_shape(lambda k: prepare_params(k, cfg, plan),
                                jax.random.PRNGKey(0))
    pspecs = param_pspecs(abstract_p, layout)
    bspecs = batch_pspecs(cfg)
    logits_spec = P(("pod", "data"), "tp") if cfg.n_codebooks == 1 \
        else P(("pod", "data"), None, "tp")
    sharded = shard_map(fn, mesh=mesh, in_specs=(pspecs, bspecs),
                        out_specs=logits_spec, check_vma=False)
    step = jax.jit(sharded, in_shardings=(named(mesh, pspecs),
                                          named(mesh, bspecs)))
    return ServeStep(spec=ServeSpec(cfg, plan, seq_len, batch_global, False,
                                    n_micro),
                     mesh=mesh, param_specs=pspecs, state_specs=bspecs,
                     step_fn=step)


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServeStep:
    spec: ServeSpec
    mesh: Mesh
    param_specs: object
    state_specs: object
    step_fn: object


def prepare_serve_states(cfg: ModelConfig, plan: MeshPlan, batch_global: int,
                         cache_len: int):
    """GLOBAL decode state tree (periods padded to the stage count)."""
    padded = -(-cfg.n_periods // plan.stage) * plan.stage
    cfg_pad = cfg.replace(n_layers=padded * len(cfg.pattern))
    return init_period_states(batch_global, cache_len, cfg_pad, cfg.cdtype)


def build_serve_step(cfg: ModelConfig, production_mesh: Mesh, *,
                     batch_global: int, cache_len: int,
                     stage: int | None = None, seq_shard: bool = False,
                     n_groups: int | None = None) -> ServeStep:
    model_axis = production_mesh.shape["model"]
    if stage is None:
        stage = pick_serve_stage(cfg, model_axis)
    mesh = refine_mesh(production_mesh, stage)
    plan = mesh_plan(production_mesh, stage)
    if n_groups is None:
        b_loc = batch_global // plan.dp_shards if not seq_shard else batch_global
        n_groups = stage if (b_loc % stage == 0 and b_loc >= stage) else 1
    spec = ServeSpec(cfg=cfg, plan=plan, cache_len=cache_len,
                     batch_global=batch_global, seq_shard=seq_shard,
                     n_groups=n_groups)

    kv_repl = cfg.attn is not None and cfg.attn.n_kv_heads % plan.tp != 0
    # batch-sharded decode keeps expert parallelism over 'data' (EP=DP);
    # seq-sharded long-context decode replicates experts (data carries the
    # KV sequence shards instead)
    layout = dataclasses.replace(SERVE_LAYOUT, kv_replicated=kv_repl,
                                 ep_axis=None if seq_shard else "data",
                                 seq_axis="data" if seq_shard else None)

    abstract_p = jax.eval_shape(lambda k: prepare_params(k, cfg, plan),
                                jax.random.PRNGKey(0))
    pspecs = param_pspecs(abstract_p, layout)
    abstract_s = jax.eval_shape(
        lambda: prepare_serve_states(cfg, plan, batch_global, cache_len))
    sspecs = state_pspecs(abstract_s, layout, batch_sharded=not seq_shard)

    tok_spec = (P(("pod", "data")) if not seq_shard else P(None)) \
        if cfg.n_codebooks == 1 else \
        (P(("pod", "data"), None) if not seq_shard else P(None, None))
    logits_spec = P(("pod", "data"), "tp") if not seq_shard else P(None, "tp")
    if cfg.n_codebooks > 1:
        logits_spec = P(("pod", "data"), None, "tp") if not seq_shard \
            else P(None, None, "tp")

    fn = spmd_decode_fn(spec)
    sharded = shard_map(fn, mesh=mesh,
                        in_specs=(pspecs, tok_spec, P(), sspecs),
                        out_specs=(logits_spec, sspecs),
                        check_vma=False)
    step = jax.jit(sharded,
                   in_shardings=(named(mesh, pspecs),
                                 named(mesh, tok_spec),
                                 named(mesh, P()),
                                 named(mesh, sspecs)))
    return ServeStep(spec=spec, mesh=mesh, param_specs=pspecs,
                     state_specs=sspecs, step_fn=step)


def build_slot_serve_step(cfg: ModelConfig, production_mesh: Mesh, *,
                          cache_len: int, shard_alloc,
                          stage: int | None = None,
                          n_groups: int | None = None) -> ServeStep:
    """Continuous-batching decode step with heterogeneous slot splits.

    ``shard_alloc[d]`` live decode slots run on dp shard ``d`` (a planner
    ``ServePlan.shard_alloc``, or any unbalanced split).  Every shard is
    padded to ``B_max = max(shard_alloc)`` rows; the returned step is

        ``step_fn(params, token (B,), position (B,), reset (B,), states)``

    with ``B = dp_shards * B_max`` global padded rows in shard-major order
    (rows ``[d*B_max, d*B_max + shard_alloc[d])`` are live).  ``position``
    is per-row — each slot decodes at its own sequence position — and rows
    with ``reset`` set have their recurrent state zeroed before the step
    (slot admission).  Padded rows return zero logits.
    """
    model_axis = production_mesh.shape["model"]
    if stage is None:
        stage = pick_serve_stage(cfg, model_axis)
    mesh = refine_mesh(production_mesh, stage)
    plan = mesh_plan(production_mesh, stage)
    shard_alloc = tuple(int(y) for y in shard_alloc)
    assert len(shard_alloc) == plan.dp_shards, (shard_alloc, plan.dp_shards)
    assert max(shard_alloc) >= 1, shard_alloc
    b_max = max(shard_alloc)
    batch_global = b_max * plan.dp_shards
    if n_groups is None:
        n_groups = stage if (b_max % stage == 0 and b_max >= stage) else 1
    spec = ServeSpec(cfg=cfg, plan=plan, cache_len=cache_len,
                     batch_global=batch_global, seq_shard=False,
                     n_groups=n_groups, shard_alloc=shard_alloc)

    kv_repl = cfg.attn is not None and cfg.attn.n_kv_heads % plan.tp != 0
    layout = dataclasses.replace(SERVE_LAYOUT, kv_replicated=kv_repl,
                                 ep_axis="data")

    abstract_p = jax.eval_shape(lambda k: prepare_params(k, cfg, plan),
                                jax.random.PRNGKey(0))
    pspecs = param_pspecs(abstract_p, layout)
    abstract_s = jax.eval_shape(
        lambda: prepare_serve_states(cfg, plan, batch_global, cache_len))
    sspecs = state_pspecs(abstract_s, layout, batch_sharded=True)

    row_spec = P(("pod", "data"))
    tok_spec = row_spec if cfg.n_codebooks == 1 else P(("pod", "data"), None)
    logits_spec = P(("pod", "data"), "tp") if cfg.n_codebooks == 1 \
        else P(("pod", "data"), None, "tp")

    fn = spmd_decode_fn(spec)
    sharded = shard_map(fn, mesh=mesh,
                        in_specs=(pspecs, tok_spec, row_spec, row_spec,
                                  sspecs),
                        out_specs=(logits_spec, sspecs),
                        check_vma=False)
    step = jax.jit(sharded,
                   in_shardings=(named(mesh, pspecs),
                                 named(mesh, tok_spec),
                                 named(mesh, row_spec),
                                 named(mesh, row_spec),
                                 named(mesh, sspecs)))
    return ServeStep(spec=spec, mesh=mesh, param_specs=pspecs,
                     state_specs=sspecs, step_fn=step)