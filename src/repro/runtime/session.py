"""Live pipeline replay + elastic membership: a session layer over the
lowered runtime (§3.4, DESIGN.md §9).

``PipelineSession`` makes a running pipeline a first-class, re-lowerable
object.  It owns the full chain

    Plan -> LoweredPlan -> TrainStep -> (params, opt_state)

and keeps training through any membership change without restarting:

1. every ``step()`` advances a simulated cluster clock and feeds heartbeats
   to a ``core.replay.MembershipController``;
2. on a failure (``fail(rank)``), the controller walks its state machine
   (missed heartbeat -> probe -> confirm) and then drives this session as
   its executor: ``replan`` (lightweight layer-wise replay, falling back to
   heavy rescheduling when the survivor stage count is not mesh-feasible),
   ``migrate`` (pure ``core.lowering.migrate_params`` index migration of
   the stacked period params *and* the optimizer moments, plus restore of
   the failed stage from its ``StageBackupStore`` replica), ``resume``
   (re-jitted step on the re-lowered plan);
3. planned transitions take the same barrier: ``admit(device)`` /
   ``admit(arrival=<measured sweep>)`` prices a hysteresis-gated join
   (rejected joins are pure no-ops — plan, jitted step and profile stay
   object-identical), ``drain(rank)`` lets a leaver keep serving while its
   layers stream directly to the survivors, ``evict(rank)`` removes it
   immediately; every transition is appended to ``memberships``;
4. single-device stages push period-row backups to their topology-assigned
   backup node on a step cadence, and every membership transition re-seeds
   the backup topology for the *new* arrangement, so a crash right after a
   churn event restores from replicas that match the deployed plan.

The ``Profile`` handed to the constructor — analytic, or a measured one
loaded from a ``repro.launch.profile`` artifact (``launch/train.py --plan
--profile``) — is held for the session's lifetime and reused by every
replay replan, so recovery predictions are priced on the same tables the
original plan was.

Across a swap the *weights are dynamic* (migrated / restored, bit-identical
where untouched) while the *step is static* (recompiled for the new stage
split); ``reconcile_migration`` asserts the bytes the migration moved match
the analytical ``RecoveryReport`` the planner-side replay predicted.  The
intra-stage sample allocation is re-lowered with the step: the new plan's
Algorithm 1 allocation over the survivors becomes a fresh
``TrainSpec.shard_alloc``, and ``ts.shard_batch`` re-packs (re-pads)
subsequent batches for it — batch-side only, never touching the migrated
params or moments.
"""

from __future__ import annotations

import dataclasses
import time as _time
import warnings

import jax

from repro.checkpoint import StageBackupStore
from repro.core.allocation import AllocationError
from repro.core.hardware import DeviceProfile
from repro.core.lowering import (DIRECT_SOURCE, LoweredPlan, LoweringError,
                                 MigrationReport, check_against_simulator,
                                 lower_plan, migrate_opt_state,
                                 migrate_params, period_owner,
                                 period_positions, reconcile_migration,
                                 relower, snap_plan)
from repro.core.planner import Plan
from repro.core.profiler import (Profile, ProfileError, extend_profile,
                                 subset_profile)
from repro.core.replay import (ADMISSION_HYSTERESIS, AdmissionDecision,
                               DeviceDraining, DeviceEvicted, DeviceFailed,
                               DeviceJoined, MembershipController,
                               MembershipEvent, RecoveryReport,
                               admission_replay, assign_backups,
                               departure_replay, heavy_rescheduling,
                               lightweight_replay)
from repro.distributed.sharding import named
from repro.models.config import ModelConfig
from repro.optim import AdamW, AdamWState, SGDState

from .train import (_assemble_train_step, _opt_shardings, init_train_state,
                    pad_vocab_leaf, pad_vocab_params, strip_vocab_leaf,
                    train_spec_from_lowered, vocab_axes)


@dataclasses.dataclass(frozen=True)
class RecoveryOutcome:
    """Everything one membership transition produced, for inspection and
    assertions.  A rejected admission records the pricing work alone:
    ``accepted=False`` with ``report``/``migration`` of ``None``."""

    report: RecoveryReport | None       # analytical timings + new plan
    migration: MigrationReport | None   # what migrate_params actually moved
    reconciliation: dict | None         # per-boundary byte agreement
    restored_stage: int | None          # old stage restored from backup
    restored_periods: tuple[int, ...]   # canonical periods it covered
    mode: str                           # "lightweight"|"heavy"|"admission"|"drain"|"evict"
    detection_observed_s: float         # coordinator wall vs report.detection_s
    missing_backup_stages: tuple[int, ...] = ()   # lost with no replica yet
    event: MembershipEvent | None = None   # the typed event driving it
    accepted: bool = True               # False = admission rejected
    stall_s: float = 0.0                # pipeline stall charged (report.stall_s)
    decision: AdmissionDecision | None = None   # join pricing detail


def _repad_vocab(tree: dict, cfg: ModelConfig, new_tp: int) -> dict:
    """Strip the old tp's vocab padding from embed/head and re-pad for
    ``new_tp`` (a stage-count change on a fixed model axis changes tp)."""
    axes = vocab_axes(cfg)
    out = dict(tree)
    out["embed"] = strip_vocab_leaf(out["embed"], axes["embed"], cfg)
    if "head" in out:
        out["head"] = strip_vocab_leaf(out["head"], axes["head"], cfg)
    return pad_vocab_params(out, cfg, new_tp)


def _repad_opt(opt_state, cfg: ModelConfig, new_tp: int):
    if isinstance(opt_state, AdamWState):
        return AdamWState(opt_state.step, _repad_vocab(opt_state.m, cfg, new_tp),
                          _repad_vocab(opt_state.v, cfg, new_tp))
    if isinstance(opt_state, SGDState):
        return SGDState(opt_state.step, _repad_vocab(opt_state.mom, cfg, new_tp))
    raise TypeError(type(opt_state))


class PipelineSession:
    """A re-lowerable training pipeline with live failure recovery."""

    def __init__(self, cfg: ModelConfig, production_mesh, plan: Plan,
                 profile: Profile, *, optimizer: AdamW | None = None,
                 backup_every: int = 5, check: bool = True,
                 portfolio_k: int = 0, probation_window: int = 2,
                 drift_watchdog=None, **spec_kw):
        self.cfg = cfg
        self.production_mesh = production_mesh
        self.profile = profile
        self.optimizer = optimizer or AdamW(lr=1e-3)
        self.backup_every = backup_every
        self.spec_kw = spec_kw
        self.model_axis = production_mesh.shape["model"]
        # -- portfolio auctions (DESIGN.md §12) --------------------------
        # portfolio_k > 0 arms the closed loop: a drift-watchdog trip or a
        # completed membership swap marks an auction pending, and the next
        # step() (which has a batch to probe with) runs it before training
        self.portfolio_k = portfolio_k
        self.probation_window = probation_window
        self.watchdog = drift_watchdog
        self.auctions: list = []           # ProbeReports, in order
        self._auction_pending = False
        self._auction_k = portfolio_k

        self.ts = None
        self.step_cache_hits = 0
        # error-feedback residuals for the compressed gradient stream
        # (spec.bucketed); zeroed by _install on every (re-)lowering
        self._ef = None
        lowered = lower_plan(plan, cfg, self.model_axis)
        if check:
            check_against_simulator(lowered, plan, profile)
        self._install(plan, lowered)

        self.store = StageBackupStore()
        self.params = None
        self.opt_state = None
        # bounded-staleness gradient buffer (spec.staleness >= 1): round
        # r's gradients, applied at the r+1 boundary by async_step_fn
        self._grad_buf = None
        self.step_count = 0
        self.clock = 0.0
        self._failed: set[int] = set()
        self._departed: set[int] = set()
        self._pending_failure: int | None = None
        self.coordinator = MembershipController(sorted(
            d for st in self.plan.stages for d in st.group))
        if self.portfolio_k:
            # post-churn replans re-arbitrate analytic-vs-runner-up with a
            # cheap 2-candidate probation at the next step
            self.coordinator.auction_hook = self._on_membership_swap
        if self.watchdog is not None:
            self.watchdog.install(self.plan, self.profile)
        self.recoveries: list[RecoveryOutcome] = []    # crash recoveries
        self.memberships: list[RecoveryOutcome] = []   # every transition
        # transition-in-flight scratch (set by *_replan, read by migrate)
        self._recovering_rank: int | None = None
        self._next_lowered: LoweredPlan | None = None
        self._next_mode = ""
        self._detect_wall = 0.0
        self._transition_event: MembershipEvent | None = None
        self._transition_lost = False      # crash: lost stages restore
        self._pending_profile: Profile | None = None   # extended, on join

    # -- installation ------------------------------------------------------

    def _install(self, plan: Plan, lowered: LoweredPlan) -> None:
        # a pending bounded-staleness gradient round was computed under the
        # OLD step's sharding and bucketing: apply it with the old step
        # BEFORE anything about the runtime changes.  The membership paths
        # flush at their own barrier, but rapid back-to-back re-lowerings
        # with no membership event in between — portfolio probation adopts
        # K plans in a row — reach _install directly, and a buffer carried
        # across the swap would be applied under the wrong spec.
        # (getattr: __init__ installs once before the buffer attr exists.)
        if getattr(self, "_grad_buf", None) is not None:
            self.flush_gradients()
        self.lowered = lowered
        # the deployed plan owns the *snapped* layer ranges — replaying from
        # it keeps the analytical old-ownership aligned with the runtime
        self.plan = snap_plan(plan, lowered, self.profile.table.L)
        spec = train_spec_from_lowered(self.cfg, self.production_mesh,
                                       lowered, **self.spec_kw)
        if self.ts is not None and spec == self.ts.spec:
            # the re-lowered plan has the same runtime shape (stages, tp,
            # n_micro, period split, collapsed allocation): the compiled
            # step is still valid — skip the re-jit, only the plan-side
            # bookkeeping above changes (device groups live in the Plan,
            # not in the TrainSpec)
            self.step_cache_hits += 1
            # same spec means the same bucketing, so carried EF residuals
            # still line up — but repair the invariant if a prior swap
            # dropped them (bucketed steps always need a residual tree)
            if self.ts.spec.bucketed and self._ef is None:
                self._ef = self.ts.init_ef()
            return
        self.ts = _assemble_train_step(self.cfg, self.production_mesh, spec,
                                       self.optimizer, zero_opt=False)
        # a re-lowered step re-buckets the gradient tree, so the carried
        # quantization residuals no longer line up — drop them (one round
        # of error feedback is lost, exactly like the staleness flush)
        self._ef = self.ts.init_ef() if self.ts.spec.bucketed else None

    def init(self, key):
        self.params, self.opt_state = init_train_state(key, self.ts,
                                                       self.optimizer)
        self._grad_buf = None
        return self.params

    # -- training loop -----------------------------------------------------

    @property
    def live_ranks(self) -> tuple[int, ...]:
        return tuple(sorted(d for st in self.plan.stages for d in st.group
                            if d not in self._failed))

    def step(self, batch_np: dict):
        """One training step (recovering first if a failure is pending).

        Advances the simulated cluster clock by at least one HPP-Round
        (the deployed plan's Eq. 4 latency) and feeds survivor heartbeats
        to the coordinator — the §3.4 detection timeline is therefore
        measured in the same units as the planner's latency predictions.
        """
        if self._pending_failure is not None:
            self.recover_now()
        if self._auction_pending and self.portfolio_k:
            # a watchdog trip or membership swap re-opened the auction;
            # this step's batch doubles as the probe batch
            self._auction_pending = False
            self.probe_portfolio(batch_np, k=self._auction_k,
                                 window=self.probation_window)
        t0 = _time.perf_counter() if self.watchdog is not None else 0.0
        # ts.shard_batch re-packs for the current plan's (possibly
        # heterogeneous, possibly just-replayed) per-shard allocation
        batch = self.ts.shard_batch(batch_np)
        bucketed = self.ts.spec.bucketed
        if self.ts.spec.staleness >= 1:
            # bounded-stale round: compute this round's gradients, apply
            # the previous round's (the buffer) — the gradient AllReduce
            # of round r overlaps round r+1 (DESIGN.md §8).  The first
            # round (no buffer yet) computes gradients only, keeping the
            # optimizer/schedule step count equal to the sync run.
            if self._grad_buf is None:
                if bucketed:
                    (loss, metrics), self._grad_buf, self._ef = \
                        self.ts.grad_fn(self.params, batch, self._ef)
                else:
                    (loss, metrics), self._grad_buf = self.ts.grad_fn(
                        self.params, batch)
            elif bucketed:
                (self.params, self.opt_state, self._grad_buf, self._ef,
                 loss, metrics) = self.ts.async_step_fn(
                    self.params, self.opt_state, self._grad_buf, self._ef,
                    batch)
            else:
                (self.params, self.opt_state, self._grad_buf, loss,
                 metrics) = self.ts.async_step_fn(
                    self.params, self.opt_state, self._grad_buf, batch)
        elif bucketed:
            (self.params, self.opt_state, self._ef, loss,
             metrics) = self.ts.step_fn(self.params, self.opt_state,
                                        self._ef, batch)
        else:
            self.params, self.opt_state, loss, metrics = self.ts.step_fn(
                self.params, self.opt_state, batch)
        if self.watchdog is not None:
            jax.block_until_ready(loss)
            if self.watchdog.observe(_time.perf_counter() - t0):
                self._auction_pending = True
                self._auction_k = self.portfolio_k or 2
        self.step_count += 1
        self.clock += max(self.plan.latency, self.coordinator.heartbeat_period)
        for r in self.live_ranks:
            self.coordinator.heartbeat(r, self.clock)
        if self.backup_every and self.step_count % self.backup_every == 0:
            self.backup_now()
        return float(loss), metrics

    def flush_gradients(self) -> bool:
        """Apply the in-flight bounded-staleness gradients synchronously.

        A recovery (and the end of training) is a staleness barrier: the
        buffered round's gradients are applied with the *current* step
        before anything migrates, so no gradient round is lost across a
        plan swap and the migrated optimizer moments already include it.
        Returns True when a buffer was pending.
        """
        if self._grad_buf is None:
            return False
        self.params, self.opt_state = self.ts.flush_fn(
            self.params, self.opt_state, self._grad_buf)
        self._grad_buf = None
        return True

    # -- portfolio auctions (DESIGN.md §12) --------------------------------

    def _on_membership_swap(self, kind: str, rank: int | None) -> None:
        """``MembershipController.auction_hook``: a completed churn swap
        installed an analytically-replanned pipeline — queue a cheap
        2-candidate auction so the measured mesh, not the cost model,
        confirms (or overturns) that choice at the next step."""
        self._auction_pending = True
        self._auction_k = 2

    def _plan_spec_kw(self, plan: Plan) -> dict:
        """Spec kwargs with ``plan``'s gradient-sync and wire semantics
        merged in.  The TrainSpec knobs (staleness, compression) normally
        come from the constructor's ``spec_kw`` — a portfolio candidate
        carries its own, which must win, or "installing" an async or
        compressed finalist would only swap the plan-side bookkeeping while
        the compiled step kept the old semantics."""
        kw = dict(self.spec_kw)
        kw["staleness"] = getattr(plan, "staleness", 0)
        comp = getattr(plan, "compress", None)
        if comp is not None:
            kw.update(compress=comp.fmt, quant_tile=comp.tile,
                      bucket_mb=comp.bucket_mb,
                      error_feedback=comp.error_feedback)
        else:
            # uncompressed candidate: raw wire, but keep any bucketed
            # AllReduce the caller configured (bucketing without
            # quantization is a valid standalone mode)
            kw["compress"] = "none"
        return kw

    def _adopt_plan(self, plan: Plan, *, reseed: bool = True) -> None:
        """Swap the session onto ``plan`` with no membership event: flush
        in-flight staleness-1 gradients, migrate period params and
        optimizer moments by the same pure gather a churn transition uses,
        re-pad vocab leaves when the stage count re-widths tp, merge the
        plan's sync/compression semantics into the spec, and re-install
        (jitted-step cache applies).  This is the probation primitive —
        called K times back-to-back by ``probe_portfolio``."""
        self.flush_gradients()
        old_lp = self.lowered
        new_lp = relower(old_lp, plan, self.cfg, self.model_axis)
        new_params, _ = migrate_params(self.params, old_lp, new_lp)
        new_opt = migrate_opt_state(self.opt_state, old_lp, new_lp)
        old_tp = self.ts.spec.plan.tp
        new_tp = self.model_axis // new_lp.stage
        if new_tp != old_tp:
            new_params = _repad_vocab(new_params, self.cfg, new_tp)
            new_opt = _repad_opt(new_opt, self.cfg, new_tp)
        self.spec_kw = self._plan_spec_kw(plan)
        self._install(plan, new_lp)
        shardings = named(self.ts.mesh, self.ts.param_specs)
        self.params = jax.device_put(new_params, shardings)
        opt_sh = _opt_shardings(self.optimizer,
                                jax.eval_shape(lambda: new_params), shardings)
        self.opt_state = jax.device_put(new_opt, opt_sh)
        if reseed:
            self._reseed_backups(old_lp)

    def _probe_rounds(self, batch_np: dict, window: int) -> list[float]:
        """Time ``window + 1`` executions of the installed plan's entry
        point WITHOUT committing any result — params, moments, EF residuals
        and the staleness buffer are all left untouched, so a probation
        sweep is invisible to training state (the bit-identity invariant).
        The extra first round absorbs compilation / a cold step cache;
        ``portfolio.robust_latency`` trims it."""
        batch = self.ts.shard_batch(batch_np)
        times = []
        for _ in range(window + 1):
            t0 = _time.perf_counter()
            if self.ts.spec.staleness >= 1:
                out = (self.ts.grad_fn(self.params, batch, self._ef)
                       if self.ts.spec.bucketed
                       else self.ts.grad_fn(self.params, batch))
            elif self.ts.spec.bucketed:
                out = self.ts.step_fn(self.params, self.opt_state, self._ef,
                                      batch)
            else:
                out = self.ts.step_fn(self.params, self.opt_state, batch)
            jax.block_until_ready(out)
            times.append(_time.perf_counter() - t0)
        return times

    def probe_portfolio(self, batch_np: dict | None = None, k: int = 3,
                        window: int = 2, *, hysteresis: float = 0.0,
                        measure=None):
        """Run one portfolio auction (DESIGN.md §12): enumerate every
        strategy family on the session profile, take the top-``k``
        mesh-lowerable finalists by predicted round latency, give each a
        live ``window``-round probation, and install the measured winner.

        Finalists probe in predicted order under ``portfolio.pick_winner``'s
        strict comparison, so ties keep the analytically-best plan and a
        measurement matching the predictions never churns.  ``measure``
        overrides the live probe with a callable ``measure(candidate) ->
        seconds | [rounds]`` (tests inject synthetic measurements; the full
        adopt/migrate cycle still runs).  After churn the enumeration is
        restricted to the surviving ranks via ``profiler.subset_profile``.
        Returns the ``portfolio.ProbeReport`` (also kept in
        ``self.auctions``)."""
        from repro.core.portfolio import (PlanPortfolio, ProbeReport,
                                          ProbeResult, pick_winner, plan_key,
                                          robust_latency)
        if batch_np is None and measure is None:
            raise ValueError("probe_portfolio needs a probe batch "
                             "(or a measure= override)")
        if self._pending_failure is not None:
            self.recover_now()
        self.flush_gradients()
        # the auction's device pool is membership-derived (profile cluster
        # minus crashed/departed ranks), NOT the installed plan's groups: a
        # winner that idles a device (e.g. a 1-stage gpipe candidate) must
        # not shrink every later auction's planning universe
        pool = tuple(sorted(set(range(len(self.profile.cluster.devices)))
                            - self._failed - self._departed))
        prof, ranks = self.profile, None
        if len(pool) < len(self.profile.cluster.devices):
            ranks = pool
            prof = subset_profile(self.profile, pool)
        portfolio = PlanPortfolio.enumerate(
            prof, self.lowered.global_batch, self.lowered.micro_batch,
            arch=self.plan.arch or self.cfg.name,
            allowed_stages=self._lowerable_stages, ranks=ranks)

        def _lowerable(c) -> bool:
            try:
                relower(self.lowered, c.plan, self.cfg, self.model_axis)
                return True
            except (LoweringError, AllocationError):
                return False

        finalists = portfolio.finalists(k, runnable=_lowerable)
        if not finalists:
            raise RuntimeError("portfolio produced no mesh-lowerable "
                               "finalist for this session")
        incumbent_key = plan_key(self.plan)
        pre_lp = self.lowered       # backups in the store are keyed by this
        results: list[ProbeResult] = []
        keys = []
        for c in finalists:
            self._adopt_plan(c.plan, reseed=False)
            keys.append(plan_key(self.plan))       # snapped, like incumbent
            if measure is not None:
                m = measure(c)
                rounds = (tuple(float(x) for x in m)
                          if isinstance(m, (list, tuple)) else (float(m),))
                measured = robust_latency(list(rounds),
                                          warmup=1 if len(rounds) > 1 else 0)
            else:
                rounds = tuple(self._probe_rounds(batch_np, window))
                measured = robust_latency(list(rounds))
            results.append(ProbeResult(c.family, c.predicted_s, measured,
                                       rounds))
        best = pick_winner([r.measured_s for r in results], hysteresis)
        if keys[best] != plan_key(self.plan):
            # we finished probation on a non-winning finalist — swap back
            self._adopt_plan(finalists[best].plan, reseed=False)
        self._reseed_backups(pre_lp)
        results[best] = dataclasses.replace(results[best], installed=True)
        if self.watchdog is not None:
            self.watchdog.install(self.plan, self.profile)
        report = ProbeReport(tuple(results), best, len(portfolio.candidates),
                             portfolio.n_enumerated, window,
                             churned=keys[best] != incumbent_key)
        self.auctions.append(report)
        return report

    def canonical_leaves(self) -> dict:
        """Training state in plan-independent canonical form, as numpy:
        period rows re-ordered to canonical period order and vocab padding
        stripped from the embed/head leaves (both are arrangement artifacts
        of the installed plan's stage split / tp width).  Two sessions hold
        bit-identical training state iff these trees are equal — the
        comparison a probation cycle is pinned against."""
        import numpy as np

        pos = period_positions(self.lowered)
        order = np.asarray([pos[t] for t in range(len(pos))])
        axes = vocab_axes(self.cfg)

        def canon(tree: dict) -> dict:
            out = {}
            for key, leaf in tree.items():
                if key == "periods":
                    out[key] = jax.tree.map(
                        lambda x: np.asarray(x)[order], leaf)
                elif key in axes:
                    out[key] = jax.tree.map(
                        np.asarray,
                        strip_vocab_leaf(leaf, axes[key], self.cfg))
                else:
                    out[key] = jax.tree.map(np.asarray, leaf)
            return out

        trees = {"params": canon(self.params)}
        if isinstance(self.opt_state, AdamWState):
            trees["m"] = canon(self.opt_state.m)
            trees["v"] = canon(self.opt_state.v)
        elif isinstance(self.opt_state, SGDState):
            trees["mom"] = canon(self.opt_state.mom)
        return trees

    # -- replication -------------------------------------------------------

    def backup_now(self) -> None:
        """Push single-device stages' canonical period rows — plus the
        embed/head-side leaves the first/last stage own — to their
        topology-assigned backup nodes (DP peers replicate the rest)."""
        assign = assign_backups(self.plan, self.profile)
        k = self.lowered.k_per_stage
        for p, backup_rank in assign.backup_of_stage.items():
            i, j = self.lowered.stage_periods[p]
            rows = jax.tree.map(lambda x: x[p * k:p * k + (j - i)],
                                self.params["periods"])
            self.store.backup(p, {"rows": rows, "extras": self._edge_extras(p)},
                              meta={"periods": (i, j),
                                    "step": self.step_count,
                                    "backup_rank": backup_rank})

    def _edge_extras(self, p: int) -> dict:
        """Non-period leaves owned by an edge stage: the embedding side for
        stage 0, the head side for the last stage — the analytic layer
        table charges their bytes to those stages' checkpoint/restore
        traffic.  Vocab padding is stripped so a restore can re-pad for
        whatever tp the post-replay mesh uses."""
        cfg = self.cfg
        axes = vocab_axes(cfg)
        out: dict = {}
        if p == 0:
            out["embed"] = strip_vocab_leaf(self.params["embed"],
                                            axes["embed"], cfg)
            if "prefix_proj" in self.params:
                out["prefix_proj"] = self.params["prefix_proj"]
        if p == len(self.plan.stages) - 1:
            if "head" in self.params:
                out["head"] = strip_vocab_leaf(self.params["head"],
                                               axes["head"], cfg)
            out["final_norm"] = self.params["final_norm"]
            if "mtp" in self.params:
                out["mtp"] = self.params["mtp"]
        return out

    # -- failure injection + recovery --------------------------------------

    def fail(self, rank: int) -> None:
        """Simulate ``rank`` dying (the paper's pulled-power experiment,
        Fig. 16/17): its heartbeats stop; the next ``step()`` (or
        ``recover_now()``) detects and recovers through the replay."""
        if rank not in self.live_ranks:
            raise ValueError(f"rank {rank} is not a live device "
                             f"({self.live_ranks})")
        self._failed.add(rank)
        self._pending_failure = rank

    def recover_now(self) -> RecoveryOutcome:
        """Drive the full §3.4 recovery timeline for the pending failure:
        detect (missed heartbeats -> probe -> confirm, on the simulated
        clock) then replan -> migrate -> resume via the coordinator, with
        this session as executor.  Returns the recorded outcome (also
        appended to ``self.recoveries``)."""
        failed = self._pending_failure
        if failed is None:
            raise RuntimeError("no pending failure")
        self._pending_failure = None
        self.flush_gradients()
        self._fail_time = self.clock
        # advance the simulated clock: survivors keep heartbeating, the
        # failed rank is silent, the coordinator probes and confirms
        t = self.clock
        confirmed = None
        while confirmed is None:
            t += self.coordinator.heartbeat_period
            for r in self.live_ranks:
                self.coordinator.heartbeat(r, t)
            confirmed = self.coordinator.poll(t)
        assert confirmed == failed, (confirmed, failed)
        self._detect_wall = t - self._fail_time
        self._recovering_rank = failed
        self._transition_event = DeviceFailed(failed)
        self._transition_lost = True
        _, outcome = self.coordinator.run_recovery(failed, self, now=t)
        self.clock = self.coordinator.events[-1][1]
        self._recovering_rank = None
        self._transition_event = None
        self._transition_lost = False
        self.recoveries.append(outcome)
        self.memberships.append(outcome)
        return outcome

    # -- elastic membership entry points ------------------------------------

    def admit(self, device: DeviceProfile | None = None, *,
              arrival=None,
              hysteresis: float = ADMISSION_HYSTERESIS) -> RecoveryOutcome:
        """Offer a newcomer to the pipeline (hysteresis-gated admission).

        ``arrival`` is the newcomer's on-arrival measured sweep (a
        ``core.profiler.MeasuredProfile``, e.g. from ``launch/profile.py``
        run on the joining device); when given, its measured rows price the
        admission and ``device`` may be omitted (taken from the sweep's
        cluster).  Without it the analytic FLOP model of ``device`` is
        used.  Returns the recorded outcome — ``accepted=False`` means the
        pipeline keeps its incumbent plan untouched."""
        if device is None:
            if arrival is None:
                raise ValueError("admit() needs a DeviceProfile, an "
                                 "on-arrival measured sweep, or both")
            device = arrival.cluster().devices[0]
        event = DeviceJoined(device, arrival, hysteresis)
        return self._membership_transition(event)

    def drain(self, rank: int) -> RecoveryOutcome:
        """Gracefully remove ``rank``: it keeps serving while its layers
        stream off, so the pipeline stalls only for the re-plan."""
        return self._membership_transition(DeviceDraining(self._live(rank)))

    def evict(self, rank: int) -> RecoveryOutcome:
        """Immediately remove ``rank`` (planned, so no detection latency or
        backup restore, but the pipeline pauses for the migration)."""
        return self._membership_transition(DeviceEvicted(self._live(rank)))

    def _live(self, rank: int) -> int:
        if rank not in self.live_ranks:
            raise ValueError(f"rank {rank} is not a live device "
                             f"({self.live_ranks})")
        return rank

    def _membership_transition(self, event: MembershipEvent) -> RecoveryOutcome:
        """Drive one planned membership event through the controller with
        this session as executor.  Any pending crash recovers first, and
        in-flight staleness-1 gradients are flushed before the plan swap."""
        if self._pending_failure is not None:
            self.recover_now()
        self.flush_gradients()
        self._detect_wall = 0.0
        self._transition_event = event
        self._transition_lost = False
        self._recovering_rank = getattr(event, "rank", None)
        result, outcome = self.coordinator.handle(event, self, now=self.clock)
        self.clock = self.coordinator.events[-1][1]
        self._recovering_rank = None
        self._transition_event = None
        if isinstance(result, AdmissionDecision):
            if not result.accepted:
                outcome = RecoveryOutcome(
                    None, None, None, None, (), "admission", 0.0,
                    event=event, accepted=False, stall_s=result.replan_s,
                    decision=result)
            else:
                outcome = dataclasses.replace(outcome, decision=result)
        else:
            self._departed.add(event.rank)
        self.memberships.append(outcome)
        return outcome

    # -- MembershipController executor protocol ----------------------------

    @property
    def _lowerable_stages(self) -> set[int]:
        """Stage counts the production mesh can lower (divisors of the
        model axis, with at least one period per stage)."""
        return {d for d in range(1, self.model_axis + 1)
                if self.model_axis % d == 0
                and d <= self.lowered.n_periods}

    def replan(self, failed_rank: int) -> RecoveryReport:
        """Executor step 1 (crash): plan the survivors' pipeline (§3.4).

        Lightweight layer-wise replay first — period-quantized cut moves
        priced on ``self.profile`` (the SAME profile object the session
        was built with, analytic or measured, so recovery predictions stay
        consistent with the original planning source) — falling back to
        heavy rescheduling (a fresh Algorithm 2 run restricted to
        mesh-lowerable stage counts) when the survivor count is not
        mesh-feasible or the allocation is infeasible."""
        quantum = len(self.cfg.pattern)
        try:
            rep = lightweight_replay(self.plan, self.profile, failed_rank,
                                     fail_time=self._fail_time,
                                     layer_quantum=quantum)
            self._next_lowered = relower(self.lowered, rep.new_plan, self.cfg,
                                         self.model_axis)
            self._next_mode = "lightweight"
            return rep
        except (LoweringError, AllocationError):
            # survivor stage count not mesh-feasible (or infeasible alloc):
            # heavy rescheduling restricted to lowerable stage counts
            rep = heavy_rescheduling(self.plan, self.profile, failed_rank,
                                     fail_time=self._fail_time,
                                     allowed_stages=self._lowerable_stages)
            self._next_lowered = relower(self.lowered, rep.new_plan, self.cfg,
                                         self.model_axis)
            self._next_mode = "heavy"
            return rep

    def admit_replan(self, event: DeviceJoined) -> AdmissionDecision:
        """Executor step 1 (join): price the newcomer into the pipeline.

        The newcomer's measured on-arrival sweep extends the session
        profile when usable (analytic FLOP-model fallback otherwise), and
        incremental candidates are priced by ``replay.admission_replay``
        restricted to mesh-lowerable stage counts.  The extended profile
        is installed only if the join is accepted and survives lowering."""
        quantum = len(self.cfg.pattern)
        new_rank = len(self.profile.cluster.devices)
        tf = tb = None
        if event.arrival is not None:
            try:
                tf, tb = event.arrival.device_rows(self.profile.table,
                                                   self.profile.max_batch)
            except ProfileError as e:
                warnings.warn(f"on-arrival sweep unusable ({e}); pricing "
                              f"{event.device.name} with the analytic "
                              "FLOP model instead")
                tf = tb = None
        ext = extend_profile(self.profile, event.device, tf, tb)
        decision = admission_replay(self.plan, ext, new_rank,
                                    hysteresis=event.hysteresis,
                                    layer_quantum=quantum,
                                    allowed_stages=self._lowerable_stages)
        if not decision.accepted:
            return decision
        try:
            self._next_lowered = relower(self.lowered,
                                         decision.report.new_plan,
                                         self.cfg, self.model_axis)
        except LoweringError as e:
            return dataclasses.replace(
                decision, accepted=False, report=None,
                reason=f"accepted candidate is not mesh-lowerable: {e}")
        self._next_mode = "admission"
        self._pending_profile = ext
        return decision

    def drain_replan(self, rank: int) -> RecoveryReport:
        """Executor step 1 (graceful drain)."""
        return self._departure_replan(rank, graceful=True)

    def evict_replan(self, rank: int) -> RecoveryReport:
        """Executor step 1 (planned evict)."""
        return self._departure_replan(rank, graceful=False)

    def _departure_replan(self, rank: int, graceful: bool) -> RecoveryReport:
        """Plan a departure: layer-wise ``departure_replay`` first (leaver
        streams its layers off directly), heavy rescheduling fallback when
        the survivor stage count is not mesh-feasible — with detection
        zeroed (the leaver announced itself) and the drain's overlap kept."""
        quantum = len(self.cfg.pattern)
        try:
            rep = departure_replay(self.plan, self.profile, rank,
                                   graceful=graceful, layer_quantum=quantum)
            self._next_lowered = relower(self.lowered, rep.new_plan, self.cfg,
                                         self.model_axis)
            self._next_mode = rep.mode
            return rep
        except (LoweringError, AllocationError):
            rep = heavy_rescheduling(self.plan, self.profile, rank,
                                     fail_time=self.clock,
                                     allowed_stages=self._lowerable_stages)
            rep = dataclasses.replace(rep, detection_s=0.0,
                                      overlapped=graceful)
            self._next_lowered = relower(self.lowered, rep.new_plan, self.cfg,
                                         self.model_axis)
            self._next_mode = "heavy"
            return rep

    def migrate(self, report: RecoveryReport) -> RecoveryOutcome:
        """Executor step 2: move training state onto the new plan.

        Pure index migration of the stacked period params and both Adam
        moments (``core.lowering.migrate_params`` — bit-identical for
        untouched periods, direction-agnostic, so a join's scale-out moves
        use the same gather as a crash's scale-in), vocab re-padding when
        the stage-count change re-widths tp, backup restore for a fully
        *lost* single-device stage (crashes only — a draining or evicted
        leaver streams its layers off directly), and exact byte
        reconciliation of the runtime's moved periods against the
        analytical RecoveryReport for every layer-wise mode (DESIGN.md §7;
        the heavy fallback redistributes everything, so has no per-move
        prediction to reconcile)."""
        old_lp, new_lp = self.lowered, self._next_lowered
        departing = self._recovering_rank
        lost = self._transition_lost
        old_owner = self._device_owner(departing, report.new_plan, new_lp,
                                       lost=lost)
        new_params, mig = migrate_params(self.params, old_lp, new_lp,
                                         old_owner=old_owner)
        new_opt = migrate_opt_state(self.opt_state, old_lp, new_lp)

        old_tp = self.ts.spec.plan.tp
        new_tp = self.model_axis // new_lp.stage
        if new_tp != old_tp:
            new_params = _repad_vocab(new_params, self.cfg, new_tp)
            new_opt = _repad_opt(new_opt, self.cfg, new_tp)

        # a fully-failed single-device stage: overwrite its (physically
        # lost) period rows with the backup replica, stale by < backup_every
        restored_stage = None
        restored_periods: tuple[int, ...] = ()
        missing: list[int] = []
        if lost:
            for q, st in enumerate(self.plan.stages):
                if departing in st.group and len(st.group) == 1:
                    if self.store.has(q):
                        new_params, restored_periods = self._restore_stage(
                            new_params, q, new_lp)
                        restored_stage = q
                    else:
                        missing.append(q)
        if missing:
            warnings.warn(
                f"stage(s) {missing} failed before any backup was pushed: "
                "no replica to restore from — continuing with the "
                "in-process values (on real hardware this state would be "
                "lost; lower backup_every or call backup_now() earlier)")

        reconciliation = None
        if self._next_mode in ("lightweight", "admission", "drain", "evict"):
            reconciliation = reconcile_migration(
                mig, report, new_lp, self.profile.table, len(self.cfg.pattern))

        # swap in the re-lowered runtime, re-sharding the migrated state
        self._install(report.new_plan, new_lp)
        if self._pending_profile is not None:
            # an accepted join extends the cluster the session plans over
            self.profile = self._pending_profile
            self._pending_profile = None
        shardings = named(self.ts.mesh, self.ts.param_specs)
        self.params = jax.device_put(new_params, shardings)
        opt_sh = _opt_shardings(self.optimizer,
                                jax.eval_shape(lambda: new_params), shardings)
        self.opt_state = jax.device_put(new_opt, opt_sh)
        self._reseed_backups(old_lp)
        return RecoveryOutcome(report, mig, reconciliation, restored_stage,
                               restored_periods, self._next_mode,
                               self._detect_wall, tuple(missing),
                               event=self._transition_event,
                               accepted=True, stall_s=report.stall_s)

    def _reseed_backups(self, old_lp: LoweredPlan) -> None:
        """Backups are keyed by the stage split, which every membership
        transition changes: drop the old arrangement's replicas and re-seed
        the NEW single-device stages immediately, so a follow-up failure
        never restores rows scattered for a split that no longer exists.
        Sessions that replicate manually (``backup_every=0`` with explicit
        ``backup_now()`` calls) are re-seeded too — going from "replicated"
        to "stale metadata" across a transition was the regression."""
        had_replicas = any(self.store.has(q)
                           for q in range(len(old_lp.stage_periods)))
        for q in range(len(old_lp.stage_periods)):
            self.store.drop(q)
        if self.backup_every or had_replicas:
            self.backup_now()

    def resume(self, report: RecoveryReport, outcome: RecoveryOutcome) -> None:
        """Executor step 3: nothing left to do — ``migrate`` installed the
        re-jitted step and re-seeded the stage backups for the new
        arrangement before handing control back, so the pipeline is
        restartable even if resumption itself is interrupted."""

    # -- helpers -----------------------------------------------------------

    def _device_owner(self, departing_rank: int | None, new_plan: Plan,
                      new_lp: LoweredPlan, lost: bool = True):
        """Per-canonical-period owner in NEW-plan stage coordinates, by
        *device identity*: a period is already resident on its new owner
        stage when some surviving device of its old stage belongs to that
        stage's new group; otherwise its owner is the new stage holding a
        surviving old holder.  A stage departing whole leaves no holder:
        ``None`` when it is *lost* (crashed — restored from backup) and
        ``DIRECT_SOURCE`` when the leaver is alive (drain/evict — its rows
        stream straight to their new owners).  For a lightweight replay
        (survivors keep their order) this reduces to the survivor index
        map that the analytical boundary accounting uses; for joins and
        the heavy fallback it keeps moved/resident reporting truthful
        across a stage-count change.  ``departing_rank=None`` (a join)
        keeps every incumbent a holder."""
        new_of_rank = {d: p for p, st in enumerate(new_plan.stages)
                       for d in st.group}
        new_own = period_owner(new_lp)
        owner: list[int | None] = []
        for q, (i, j) in enumerate(self.lowered.stage_periods):
            holders = [d for d in self.plan.stages[q].group
                       if d != departing_rank]
            for t in range(i, j):
                if any(d in new_plan.stages[new_own[t]].group
                       for d in holders):
                    owner.append(new_own[t])     # already resident
                elif holders:
                    owner.append(new_of_rank.get(holders[0]))
                else:
                    # whole stage departed: lost -> backup restore;
                    # alive -> direct stream off the leaver
                    owner.append(None if lost else DIRECT_SOURCE)
        return owner

    def _restore_stage(self, tree: dict, q: int, new_lp: LoweredPlan):
        snap = self.store.restore(q)
        rows, extras = snap["rows"], snap["extras"]
        i, j = self.store.meta(q)["periods"]
        pos = period_positions(new_lp)

        def scatter(dest, src):
            for t in range(i, j):
                dest = dest.at[pos[t]].set(src[t - i].astype(dest.dtype))
            return dest

        out = dict(tree)
        out["periods"] = jax.tree.map(scatter, tree["periods"], rows)
        new_tp = self.model_axis // new_lp.stage
        axes = vocab_axes(self.cfg)
        for key, leaf in extras.items():
            if key in axes:
                out[key] = pad_vocab_leaf(leaf, axes[key], self.cfg, new_tp)
            else:
                out[key] = leaf
        return out, tuple(range(i, j))
