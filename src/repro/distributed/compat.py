"""JAX version compatibility for the distributed runtime.

The runtime targets two API generations:

* **jax >= 0.5/0.6**: ``jax.shard_map`` is a public top-level API with
  varying-manual-axes (vma) typing — replication is part of the avals,
  adjusted explicitly with ``lax.pcast`` and queried via ``jax.typeof``.
  The strictness knob is ``check_vma``.
* **jax 0.4.x** (the floor this repo supports): shard_map lives in
  ``jax.experimental.shard_map``, takes ``check_rep`` instead of
  ``check_vma``, and has no vma typing at all — ``lax.pcast`` /
  ``jax.typeof`` do not exist and replication is tracked internally by
  rewrite rules.

Everything version-dependent is centralized here so the rest of the code
has exactly one spelling:

* ``shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
* ``pcast_varying(x, axes)`` — mark ``x`` varying over ``axes`` (pure type
  operation on new jax, identity on 0.4.x),
* ``varying_axes(x)`` — the axes ``x`` is already varying over,
* ``manual_axes()`` — the manual mesh axes of the enclosing shard_map
  (empty outside shard_map, and always empty on 0.4.x).

On 0.4.x ``check_vma`` maps directly to ``check_rep``: True additionally
enables the replication-*rewrite* machinery, which auto-inserts the
pbroadcasts that explicit pcasts provide on new jax — load-bearing for
correct psum transposes under ``jax.grad``, so the train path must keep
it on.  ``check_vma=False`` (the serve paths' deliberately-replicated KV
caches, inexpressible to either checker) maps to ``check_rep=False``.
"""

from __future__ import annotations

import jax
from jax import lax

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
HAS_VMA = hasattr(lax, "pcast") and hasattr(jax, "typeof")

# On 0.4.x the RNG lowering is NOT sharding-invariant: jitting an
# initializer with out_shardings that split a dimension across the mesh
# (e.g. the vocab-parallel embedding, or period stacks over ``stage``)
# silently produces different bits than the same program run eager or
# unsharded — with the legacy threefry for some layouts, and even with
# ``jax_threefry_partitionable`` for others (stage-sharded stacks on a
# multi-axis mesh).  ``SHARDED_INIT_SAFE`` gates whether out_shardings may
# be trusted for random initialization; ``sharded_init`` falls back to
# unsharded init + device_put when it cannot.
SHARDED_INIT_SAFE = HAS_NATIVE_SHARD_MAP


def sharded_init(fn, shardings):
    """``jax.jit(fn, out_shardings=shardings)``, or a numerically-safe
    equivalent (init unsharded, then place) on jax 0.4.x."""
    if SHARDED_INIT_SAFE:
        return jax.jit(fn, out_shardings=shardings)
    jitted = jax.jit(fn)

    def wrapped(*args):
        return jax.device_put(jitted(*args), shardings)

    return wrapped


def _patch_04x_transpose() -> None:
    """Fix jax 0.4.x's ``_shard_map_transpose`` emitting cotangents for
    *defined* primals (residuals / closed-over constants).

    Constants that enter the body linearly — e.g. the pipeline scan's zero
    initial carry — are partial-eval'ed into residual inputs of the
    backward shard_map with in_names ``{0: all_axes}``, and 0.4.x's
    ``ad.backward_pass`` hands back real (non-Zero) cotangents for them.
    Nothing upstream consumes d/d(constant), but a scalar one crashes
    ``_check_names`` (a rank-0 aval cannot carry a dim-0 sharding).  The
    fix — also the behavior of the rewritten >= 0.5 implementation — is to
    zero cotangents for every input that is not an ``UndefinedPrimal``.
    """
    from math import prod

    import jax.experimental.shard_map as _sm
    from jax._src import core, dtypes
    from jax._src import linear_util as lu
    from jax._src.api_util import flatten_fun_nokwargs
    from jax._src.interpreters import ad, partial_eval as pe
    from jax._src.tree_util import tree_flatten, tree_unflatten

    def fixed_transpose(out_cts, *args, jaxpr, mesh, in_names, out_names,
                        check_rep, rewrite, auto):
        mb_div = lambda x, y: x / y if y != 1 else x
        out_cts = [
            ad.Zero(_sm._shard_aval(mesh, ns, x.aval)) if type(x) is ad.Zero
            else x if rewrite or dtypes.dtype(x) == dtypes.float0
            else mb_div(x, prod(map(mesh.shape.get,
                                    _sm._unmentioned2(mesh, ns, auto))))
            for ns, x in zip(out_names, out_cts)]
        args = [x if type(x) is not ad.UndefinedPrimal else
                ad.UndefinedPrimal(_sm._shard_aval(mesh, ns, x.aval))
                for ns, x in zip(in_names, args)]
        all_args, in_tree = tree_flatten((out_cts, args))

        @lu.wrap_init
        def fun_trans(out_cts, args):
            undef = list(map(ad.is_undefined_primal, args))
            res, undefs = _sm.partition_list(undef, list(args))
            jaxpr_known, jaxpr_unknown, _, _ = pe.partial_eval_jaxpr_nounits(
                pe.close_jaxpr(jaxpr), undef, False)
            res_reshaped = core.jaxpr_as_fun(jaxpr_known)(*res)
            out = ad.backward_pass(
                jaxpr_unknown.jaxpr, False, (), (*res_reshaped, *undefs),
                out_cts)
            # the fix: defined primals carry no cotangent
            out = [ad.Zero(x.aval) if not u and type(x) is not ad.Zero else x
                   for u, x in zip(undef, out)]
            out = [
                ad.Zero(_sm._unshard_aval(mesh, ns, x.aval))
                if type(x) is ad.Zero else x if rewrite
                else jax.lax.psum(x, tuple(_sm._unmentioned2(mesh, ns, auto)))
                for ns, x in zip(in_names, out)]
            return out

        fun_trans, nz_arg_cts = ad.nonzero_outputs(fun_trans)
        fun_trans_flat, out_tree = flatten_fun_nokwargs(fun_trans, in_tree)

        new_in_names = \
            [n for n, x in zip(out_names, out_cts) if type(x) is not ad.Zero] + \
            [n for n, x in zip(in_names, args)
             if type(x) is not ad.UndefinedPrimal]

        def new_out_names_thunk():
            return tuple(names for names, nz in zip(in_names, nz_arg_cts())
                         if nz)

        out_flat = _sm.shard_map_p.bind(
            fun_trans_flat, *all_args, mesh=mesh,
            in_names=tuple(new_in_names),
            out_names_thunk=new_out_names_thunk, check_rep=check_rep,
            rewrite=rewrite, auto=auto)
        return tree_unflatten(out_tree(), out_flat)

    ad.primitive_transposes[_sm.shard_map_p] = fixed_transpose


def _patch_04x_scan_check() -> None:
    """Fix jax 0.4.x's ``_scan_check`` rejecting literal scan-carry inits.

    In the 0.4.x replication *checker* a trace-time constant reads as rep
    ``None``, and ``_scan_check`` compares carry reps with strict equality —
    so a literal carry init (e.g. the pipeline's ``0.0`` aux accumulator)
    mismatches the body's computed rep even though the *rewrite* machinery
    (which decides where pbroadcasts are actually needed) already treats
    ``None`` as fully replicated.  Normalize exactly as the rewrite does —
    the behavior of the >= 0.5 vma implementation, where constants are
    replicated by construction.  Surfaced by single-stage (P=1) pipelines,
    whose carries stay constant up to the scan.
    """
    import jax.experimental.shard_map as _sm
    from jax._src.lax.control_flow import loops
    from jax._src.util import split_list

    def fixed_scan_check(mesh, *in_rep, jaxpr, num_consts, num_carry, **_):
        full = set(mesh.axis_names)
        in_rep = [full if r is None else r for r in in_rep]
        _, carry_rep_in, _ = split_list(in_rep, [num_consts, num_carry])
        out_rep = _sm._check_rep(mesh, jaxpr.jaxpr, in_rep)
        carry_rep_out, _ = split_list(
            [full if r is None else r for r in out_rep], [num_carry])
        if carry_rep_in != carry_rep_out:
            raise Exception(
                "Scan carry input and output got mismatched replication "
                f"types {carry_rep_in} and {carry_rep_out}.")
        return out_rep

    _sm._check_rules[loops.scan_p] = fixed_scan_check


if not HAS_NATIVE_SHARD_MAP:
    _patch_04x_transpose()
    _patch_04x_scan_check()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Version-adaptive ``shard_map`` entrypoint (keyword-only specs)."""
    if HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    # check_rep=True additionally turns on 0.4.x's replication-rewrite
    # machinery, which auto-inserts the pbroadcasts that the explicit
    # pcasts provide on new jax — required for correct psum transposes
    # under jax.grad.  check_vma=False maps to check_rep=False (the serve
    # paths' deliberately-unexpressible replicated KV caches).
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def manual_axes() -> tuple:
    """Manual axes of the enclosing shard_map ('' outside / on 0.4.x)."""
    if not HAS_VMA:
        return ()
    try:
        am = jax.sharding.get_abstract_mesh()
        return tuple(getattr(am, "manual_axes", ()) or ())
    except Exception:
        return ()


def varying_axes(x) -> frozenset:
    """Axes ``x`` is typed varying over (always empty without vma typing)."""
    if not HAS_VMA:
        return frozenset()
    return frozenset(jax.typeof(x).vma)


def pcast_varying(x, axes):
    """Idempotently mark ``x`` varying over ``axes`` (no-op on 0.4.x —
    there is no vma type to adjust, and pcast is purely a type operation)."""
    if not HAS_VMA or not axes:
        return x
    need = tuple(a for a in axes if a not in jax.typeof(x).vma)
    return lax.pcast(x, need, to="varying") if need else x
