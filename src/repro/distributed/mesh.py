"""Mesh refinement: production mesh -> logical (pod, data, stage, tp) mesh.

``make_production_mesh`` (launch/mesh.py) returns the pinned
``(data=16, model=16)`` or ``(pod=2, data=16, model=16)`` mesh.  Asteroid's
HPP maps onto it by *refining* the ``model`` axis into ``stage × tp``:
pipeline stages across ``stage`` (inter-group pipeline parallelism) with
Megatron tensor parallelism inside each stage (the TPU analogue of
intra-group parallelism), and data parallelism over ``(pod, data)``.

Refinement is a pure reshape of the device array — no new jax device state.
"""

from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

AXES = ("pod", "data", "stage", "tp")


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """Logical parallelism layout on top of a production mesh."""

    pod: int
    data: int
    stage: int
    tp: int

    @property
    def dp_shards(self) -> int:
        return self.pod * self.data

    @property
    def model(self) -> int:
        return self.stage * self.tp


def refine_mesh(mesh: Mesh, stage: int) -> Mesh:
    """Split the trailing 'model' axis of a production mesh into stage×tp."""
    names = mesh.axis_names
    assert names[-1] == "model", names
    model = mesh.shape["model"]
    assert model % stage == 0, (model, stage)
    tp = model // stage
    devs = np.asarray(mesh.devices)
    if "pod" in names:
        pod, data = mesh.shape["pod"], mesh.shape["data"]
    else:
        pod, data = 1, mesh.shape["data"]
    new = devs.reshape(pod, data, stage, tp)
    return Mesh(new, AXES)


def mesh_plan(mesh: Mesh, stage: int) -> MeshPlan:
    model = mesh.shape["model"]
    pod = mesh.shape.get("pod", 1)
    return MeshPlan(pod=pod, data=mesh.shape["data"], stage=stage,
                    tp=model // stage)


def pick_stage_count(n_layers: int, pattern_len: int, model_axis: int,
                     n_heads: int, max_stage: int | None = None) -> int:
    """Choose the pipeline-stage count for an architecture.

    Constraints: stage divides the model axis; tp = model/stage must divide
    n_heads (query heads are tp-sharded); prefer the largest stage count
    whose period padding waste is <= 12.5%.  The Asteroid planner can
    override this (it optimizes the same trade-off with its DP), but this
    gives a deterministic default for dry-runs.
    """
    n_periods = n_layers // pattern_len
    best = 1
    divisors = [d for d in (16, 8, 4, 2, 1) if model_axis % d == 0]
    for s in divisors:
        if max_stage and s > max_stage:
            continue
        tp = model_axis // s
        if n_heads % tp != 0 and tp % max(n_heads, 1) != 0:
            continue
        padded = -(-n_periods // s) * s
        waste = (padded - n_periods) / padded
        if waste <= 0.125:
            best = s
            break
    return best
