"""Parameter/state PartitionSpecs for the refined (pod, data, stage, tp) mesh.

Specs are derived from tree paths.  Layout summary (Megatron-style):

* ``periods`` subtree: leading axis = stacked periods -> ``stage``.
* attention: wq/wk/wv column-parallel over heads (``tp`` on the output dim),
  wo row-parallel (``tp`` on the input dim).  KV projections stay replicated
  when tp > n_kv_heads (the runtime slices heads dynamically).
* MLA: the up-projections (wq_b, wk_b, wv_b) are head-sharded; latent
  down-projections replicated.
* MLP: gate/up column-parallel, down row-parallel.
* MoE: experts sharded over the expert-parallel axis (``data`` in training —
  the EP=DP layout) on dim 0 and over ``tp`` on d_ff; router replicated.
* Mamba: d_inner sharded over ``tp`` (in_proj/conv/dt_proj column-parallel;
  x_proj/out_proj/A/D row-parallel on the d_inner dim).
* RWKV: head projections column-parallel, out row-parallel; gate lora for
  the decay sharded on its output.
* embed / head: vocab-parallel over ``tp``.
"""

from __future__ import annotations

import dataclasses

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Layout:
    """Which mesh axes play which role for a given step type."""

    ep_axis: str | None = "data"    # expert-parallel axis (None = replicate)
    stage_axis: str | None = "stage"
    tp_axis: str | None = "tp"
    dp_axes: tuple[str, ...] = ("pod", "data")
    seq_axis: str | None = None     # decode KV cache sequence sharding
    kv_replicated: bool = False     # tp > n_kv_heads: KV projections replicated

TRAIN_LAYOUT = Layout()
SERVE_LAYOUT = Layout(ep_axis=None)
SERVE_SEQSHARD_LAYOUT = Layout(ep_axis=None, seq_axis="data")


def _spec_for(path: tuple[str, ...], ndim: int, lo: Layout,
              stacked: bool) -> P:
    """PartitionSpec for one param leaf.  ``stacked`` => leading period dim."""
    tp = lo.tp_axis
    name = path[-1]
    parent = path[-2] if len(path) >= 2 else ""

    def wrap(*dims):
        dims = list(dims)
        # pad to ndim (account for the stacked leading axis)
        body = ndim - (1 if stacked else 0)
        while len(dims) < body:
            dims.append(None)
        dims = dims[:body]
        if stacked:
            dims = [lo.stage_axis] + dims
        return P(*dims)

    # ---- embedding / head (never stacked) -------------------------------
    if name == "embed":
        return P(tp, None) if ndim == 2 else P(None, tp, None)
    if name == "head":
        return P(None, tp) if ndim == 2 else P(None, None, tp)
    if name == "prefix_proj":
        return P(None, None)

    # ---- MoE -------------------------------------------------------------
    if parent == "experts":
        if name in ("gate", "up"):
            return wrap(lo.ep_axis, None, tp)
        if name == "down":
            return wrap(lo.ep_axis, tp, None)
    if name == "router":
        return wrap(None, None)

    # ---- attention --------------------------------------------------------
    if name in ("wq", "wq_b", "wk_b", "wv_b"):
        return wrap(None, tp)
    if name in ("wk", "wv"):
        # replicated when tp does not divide the KV head count (MQA/GQA);
        # each shard then slices its head at compute time
        return wrap(None, None if lo.kv_replicated else tp)
    if name in ("wo", "out", "out_proj", "down", "wv_cm"):
        return wrap(tp, None)
    if name in ("wq_a", "wkv_a", "combine"):
        return wrap(None, None)

    # ---- dense MLP ---------------------------------------------------------
    if parent == "mlp" or parent == "shared":
        if name in ("gate", "up"):
            return wrap(None, tp)
        if name == "down":
            return wrap(tp, None)

    # ---- mamba --------------------------------------------------------------
    if name in ("in_x", "in_z", "conv_w", "dt_proj"):
        return wrap(None, tp)
    if name in ("conv_b", "dt_bias", "D"):
        return wrap(tp)
    if name in ("x_proj", "A_log"):
        return wrap(tp, None)

    # ---- rwkv -----------------------------------------------------------------
    if name in ("wr", "wk_tm", "wv_tm", "wg"):
        return wrap(None, tp)
    if name in ("w0", "u"):
        return wrap(tp)
    if name == "w_lora_b":
        return wrap(None, tp)
    if name in ("w_lora_a", "mix_lora_a", "mix_lora_b", "mix_base",
                "mix_k", "mix_r"):
        return wrap(*([None] * 8))

    # norms, biases, everything else: replicated (stacked over stage only)
    return wrap(*([None] * 8))


# RWKV name disambiguation: time-mix wk/wv/wr collide with channel-mix and
# attention names; resolve by parent.
def _resolve(path: tuple[str, ...]) -> tuple[str, ...]:
    if len(path) >= 2:
        parent, name = path[-2], path[-1]
        if parent == "rwkv_tm" and name in ("wk", "wv"):
            return path[:-1] + (name + "_tm",)
        if parent == "rwkv_cm":
            if name == "wv":
                return path[:-1] + ("wv_cm",)
            if name == "wr":
                return path[:-1] + ("wr_cm",)
            if name == "wk":
                return path[:-1] + ("wk_cm",)
    return path


def _cm_spec(name: str, ndim: int, lo: Layout, stacked: bool) -> P | None:
    tp = lo.tp_axis
    table = {"wk_cm": (None, tp), "wv_cm": (tp, None), "wr_cm": (None, None)}
    if name in table:
        dims = list(table[name])
        if stacked:
            dims = [lo.stage_axis] + dims
        return P(*dims)
    return None


def _path_names(path) -> tuple[str, ...]:
    out = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            out.append(str(k.key))
        elif isinstance(k, jax.tree_util.SequenceKey):
            out.append(f"[{k.idx}]")
        else:
            out.append(str(k))
    return tuple(out)


def param_pspecs(params, layout: Layout = TRAIN_LAYOUT):
    """PartitionSpec tree matching ``params`` (global model params)."""

    def leaf_spec(path, leaf):
        names = _resolve(tuple(n for n in _path_names(path) if not n.startswith("[")))
        stacked = "periods" in names
        cm = _cm_spec(names[-1], leaf.ndim, layout, stacked)
        if cm is not None:
            return cm
        spec = _spec_for(names, leaf.ndim, layout, stacked)
        # sanity: never more dims than the array has
        assert len(spec) <= leaf.ndim or leaf.ndim == 0, (names, spec, leaf.shape)
        return spec if leaf.ndim else P()

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def kv_replicated_overrides(params, cfg, layout: Layout):
    """When tp > n_kv_heads, wk/wv (and their caches) stay replicated."""
    def fix(path, spec, leaf):
        names = _path_names(path)
        if names and names[-1] in ("wk", "wv") and "attn" in names:
            dims = list(spec)
            dims[-1] = None
            return P(*dims)
        return spec
    return jax.tree_util.tree_map_with_path(
        lambda p, s, l: fix(p, s, l), param_pspecs(params, layout), params)


def state_pspecs(states, layout: Layout, batch_sharded: bool = True):
    """PartitionSpecs for decode states (leading dim = stacked periods).

    Leaf layouts (batch axis is always axis 1):
      attn k/v      (P, B, S, H, D)   heads over tp (None if kv replicated)
      mla c_kv/rope (P, B, S, R)
      mamba conv    (P, B, K-1, d_in) d_in over tp
      mamba ssm     (P, B, d_in, N)   d_in over tp
      rwkv wkv      (P, B, H, d, d)   heads over tp
      shift         (P, B, 1, D)
    The cache sequence dim is sharded over ``layout.seq_axis`` when set
    (flash-decoding style); batch over (pod, data) when ``batch_sharded``.
    """
    tp = layout.tp_axis
    b = ("pod", "data") if batch_sharded else None
    seq = layout.seq_axis

    def leaf_spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        if name in ("k", "v"):
            return P(layout.stage_axis, b, seq,
                     None if layout.kv_replicated else tp, None)
        if name in ("c_kv", "k_rope"):
            return P(layout.stage_axis, b, seq, None)
        if name == "conv":
            return P(layout.stage_axis, b, None, tp)
        if name == "ssm":
            return P(layout.stage_axis, b, tp, None)
        if name == "wkv":
            return P(layout.stage_axis, b, tp, None, None)
        if name == "shift":
            return P(layout.stage_axis, b, None, None)
        raise KeyError(f"unknown state leaf {names}")

    return jax.tree_util.tree_map_with_path(leaf_spec, states)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
