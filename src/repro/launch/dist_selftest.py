"""Distributed-runtime self-test: HPP train parity vs single-device reference.

Runs every architecture family's smoke config through the full shard_map
pipeline (data=2, stage=2, tp=2 on 8 host devices) and compares the loss to
the single-device ``repro.models.model.loss_fn`` with identical params.

Invoked by tests/test_distributed.py in a subprocess (so the host-device
flag does not leak into other tests) and usable directly:

    PYTHONPATH=src python -m repro.launch.dist_selftest [arch ...]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses  # noqa: E402
import sys  # noqa: E402

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402

DEFAULT_ARCHS = [
    "phi3-mini-3.8b",          # dense MHA
    "gemma-2b",                # MQA kv=1 (replicated-KV slice path), GeGLU, tied
    "gemma2-2b",               # sliding window + softcaps + post norms
    "phi3.5-moe-42b-a6.6b",    # MoE with EP all_to_all
    "jamba-1.5-large-398b",    # hybrid mamba + attn + MoE
    "rwkv6-7b",                # attention-free
    "musicgen-large",          # multi-codebook + prefix
    "internvl2-2b",            # VLM prefix
    "deepseek-v3-671b",        # MLA + sigmoid router + MTP
]

TOL = 2e-3


def run_arch(arch: str, devices) -> float:
    from repro.configs import get_smoke_config
    from repro.data import SyntheticLM
    from repro.models.frontend import frontend_dim
    from repro.models.model import init_model, loss_fn as local_loss_fn
    from repro.runtime.train import build_train_step, init_train_state

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        # capacity drops are the one legitimate local/global divergence —
        # disable them for the parity check
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    B, S = 8, 64
    mesh_prod = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))
    ts = build_train_step(cfg, mesh_prod, global_batch=B, stage=2, n_micro=4)

    key = jax.random.PRNGKey(0)
    ds = SyntheticLM(cfg.vocab_size, S, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))
    batch_np = ds.batch(0, B)
    batch = ts.shard_batch(batch_np)
    params, opt_state = init_train_state(key, ts)

    loss_d, metrics = ts.loss_fn(params, batch)

    ref_params = init_model(key, cfg)
    loss_r, metrics_r = jax.jit(lambda p, b: local_loss_fn(p, b, cfg, ce_chunk=1024))(
        ref_params, {k: jnp.asarray(v) for k, v in batch_np.items()})

    # CE must match exactly; the MoE aux loss is a per-shard/per-microbatch
    # estimate (as in production systems), so the total gets a looser bound
    diff = abs(float(metrics["ce"]) - float(metrics_r["ce"]))
    diff_total = abs(float(loss_d) - float(loss_r))
    assert diff_total < 0.05, (arch, diff_total)

    # and one optimizer step must reduce the loss
    new_params, new_opt, l0, _ = ts.step_fn(params, opt_state, batch)
    l1, _ = ts.loss_fn(new_params, batch)
    improved = float(l1) < float(l0)
    print(f"{arch:26s} dist={float(loss_d):.5f} ref={float(loss_r):.5f} "
          f"diff={diff:.2e} step {float(l0):.4f}->{float(l1):.4f} "
          f"{'OK' if diff < TOL and improved else 'FAIL'}", flush=True)
    if diff >= TOL or not improved:
        raise SystemExit(f"{arch}: parity diff {diff} (tol {TOL}) improved={improved}")
    return diff


def run_arch_hetero(arch: str, devices) -> float:
    """Heterogeneous intra-stage allocation (Algorithm 1) on the real
    runtime: a y=(3,1) sample split across the 2-wide data axis, padded to
    B_max=3 with static validity masks.  Asserts loss parity vs the
    single-device reference, *gradient* parity vs the uniform-allocation
    baseline on the same global batch (dense models; MoE aux statistics are
    per-shard estimates, so only CE is compared there), bit-identical param
    shapes, and a loss-reducing optimizer step through the padded pipeline."""
    from repro.configs import get_smoke_config
    from repro.data import SyntheticLM
    from repro.models.frontend import frontend_dim
    from repro.models.model import init_model, loss_fn as local_loss_fn
    from repro.runtime.train import build_train_step, init_train_state

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    cfg = cfg.replace(n_layers=4 * len(cfg.pattern))       # 4 periods
    B, S, M = 16, 64, 4
    mesh_prod = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))
    ts_u = build_train_step(cfg, mesh_prod, global_batch=B, stage=2, n_micro=M)
    ts_h = build_train_step(cfg, mesh_prod, global_batch=B, stage=2, n_micro=M,
                            shard_alloc=(3, 1))
    assert ts_h.spec.shard_alloc == (3, 1)

    key = jax.random.PRNGKey(0)
    ds = SyntheticLM(cfg.vocab_size, S, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))
    batch_np = ds.batch(0, B)
    batch_u = ts_u.shard_batch(batch_np)
    batch_h = ts_h.shard_batch(batch_np)
    params_u, opt_u = init_train_state(key, ts_u)
    params_h, opt_h = init_train_state(key, ts_h)

    ref_params = init_model(key, cfg)
    _, metrics_r = jax.jit(lambda p, b: local_loss_fn(p, b, cfg, ce_chunk=1024))(
        ref_params, {k: jnp.asarray(v) for k, v in batch_np.items()})
    (_, metrics_u), grads_u = ts_u.grad_fn(params_u, batch_u)
    (_, metrics_h), grads_h = ts_h.grad_fn(params_h, batch_h)
    diff_ref = abs(float(metrics_h["ce"]) - float(metrics_r["ce"]))
    diff_u = abs(float(metrics_h["ce"]) - float(metrics_u["ce"]))
    assert float(metrics_h["tokens"]) == float(metrics_u["tokens"])

    # gradient parity: same global batch, unbalanced vs uniform allocation
    worst_grad = 0.0
    for gu, gh in zip(jax.tree.leaves(grads_u), jax.tree.leaves(grads_h)):
        assert gu.shape == gh.shape and gu.dtype == gh.dtype
        if cfg.moe is None:
            d = float(jnp.max(jnp.abs(gu - gh)))
            scale = max(float(jnp.max(jnp.abs(gu))), 1e-12)
            worst_grad = max(worst_grad, d / scale)

    new_h, _, l0, _ = ts_h.step_fn(params_h, opt_h, batch_h)
    l1, _ = ts_h.loss_fn(new_h, batch_h)
    improved = float(l1) < float(l0)

    # the same unbalanced allocation as a full planner Plan, lowered through
    # plan_to_train_step (check_against_simulator validates the Eq. 8
    # allocation-scaled per-device times before anything compiles)
    ts_p = _hetero_plan_step(cfg, mesh_prod, micro_batch=B // M, n_micro=M)
    assert ts_p.spec.shard_alloc == (3, 1), ts_p.spec.shard_alloc
    params_p, _ = init_train_state(key, ts_p)
    _, metrics_p = ts_p.loss_fn(params_p, ts_p.shard_batch(batch_np))
    diff_p = abs(float(metrics_p["ce"]) - float(metrics_r["ce"]))

    ok = (diff_ref < TOL and diff_u < TOL and diff_p < TOL
          and worst_grad < 1e-4 and improved)
    print(f"{arch:26s} [hetero] y={ts_h.spec.shard_alloc} ref diff="
          f"{diff_ref:.2e} uniform diff={diff_u:.2e} plan diff={diff_p:.2e} "
          f"grad rel={worst_grad:.2e} step {float(l0):.4f}->{float(l1):.4f} "
          f"{'OK' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit(f"{arch}: hetero allocation parity ref={diff_ref} "
                         f"uniform={diff_u} plan={diff_p} grad={worst_grad} "
                         f"improved={improved}")
    return max(diff_ref, diff_u)


def _hetero_plan_step(cfg, mesh_prod, micro_batch: int, n_micro: int):
    """A 2-stage Plan whose every stage allocates y=(3,1) across its
    two-device group (a TX2 paired with a nano), lowered end-to-end."""
    from repro.core.costmodel import Step, allreduce_time, kp_policy, \
        round_latency
    from repro.core.hardware import JETSON_NANO, JETSON_TX2, Cluster
    from repro.core.lowering import plan_to_train_step
    from repro.core.planner import Plan, StagePlan, _comm_step
    from repro.core.profiler import LayerTable, Profile

    table = LayerTable.from_model_config(cfg, 64)
    cluster = Cluster((JETSON_TX2, JETSON_NANO, JETSON_TX2, JETSON_NANO))
    prof = Profile.analytic(table, cluster, max_batch=micro_batch * n_micro)
    cut = 1 + (table.L - 2) // 2                           # period boundary
    y = (3, 1)
    assert sum(y) == micro_batch, (y, micro_batch)
    stages, steps = [], []
    for p, (i, j, group) in enumerate([(0, cut, (0, 1)), (cut, table.L, (2, 3))]):
        ef = max(prof.t_fwd(d, yy, i, j) for d, yy in zip(group, y))
        eb = max(prof.t_bwd(d, yy, i, j) for d, yy in zip(group, y))
        ta = allreduce_time(table.param_bytes(i, j), group, prof.cluster)
        steps.append(Step("exec", ef, eb, ta, group, (i, j), y))
        stages.append(StagePlan((i, j), group, y, kp_policy(2, p)))
        if p == 0:
            steps.append(_comm_step(prof, micro_batch, cut, (0, 1), (2, 3)))
    plan = Plan(cfg.name, tuple(stages), tuple(steps), micro_batch, n_micro,
                round_latency(tuple(steps), n_micro), "hand")
    ts, _ = plan_to_train_step(plan, prof, cfg, mesh_prod)
    return ts


def run_async(arch: str, devices) -> float:
    """Async 1F1B runtime equivalence (DESIGN.md §8).

    1. staleness 0 + double-buffered sends is gradient-BIT-IDENTICAL to the
       synchronous runtime on the same batch (the overlap only moves the
       tick a transfer occupies, never the per-micro-batch math);
    2. a staleness-1 run applies round r's gradients at the r+1 boundary:
       after N steps + a final flush its loss lands within tolerance of the
       sync run on the same batch stream (bounded-staleness convergence),
       and both arms applied exactly the same number of optimizer updates
       (the first async round computes gradients only — no update, no
       schedule-step skew)."""
    from repro.configs import get_smoke_config
    from repro.data import SyntheticLM
    from repro.models.frontend import frontend_dim
    from repro.runtime.train import build_train_step, init_train_state

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    B, S, M, N = 8, 64, 4, 8
    mesh_prod = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))
    ts_sync = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                               n_micro=M)
    ts_db = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                             n_micro=M, staleness=0, double_buffer=True)
    ts_async = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                                n_micro=M, staleness=1)
    assert ts_async.spec.double_buffer, "staleness 1 defaults to overlap"

    key = jax.random.PRNGKey(0)
    ds = SyntheticLM(cfg.vocab_size, S, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))
    batch_np = ds.batch(0, B)

    # 1) bit-identical gradients: sync vs double-buffered staleness-0
    params, opt0 = init_train_state(key, ts_sync)
    (_, m_sync), g_sync = ts_sync.grad_fn(params, ts_sync.shard_batch(batch_np))
    (_, m_db), g_db = ts_db.grad_fn(params, ts_db.shard_batch(batch_np))
    n_diff = sum(0 if bool(jnp.array_equal(a, b)) else 1
                 for a, b in zip(jax.tree.leaves(g_sync),
                                 jax.tree.leaves(g_db)))
    bit_identical = n_diff == 0 and float(m_sync["ce"]) == float(m_db["ce"])

    # 2) staleness-1 convergence smoke vs sync on the SAME batch stream:
    # the first async round computes gradients only (no optimizer update),
    # every later round applies the previous round's buffer, the flush
    # applies the final round — so both arms apply exactly N+1 updates
    p_a, o_a = init_train_state(key, ts_async)
    (l0a, _), buf = ts_async.grad_fn(p_a, ts_async.shard_batch(batch_np))
    grads_live = any(float(jnp.max(jnp.abs(x))) > 0
                     for x in jax.tree.leaves(buf))
    p_s, o_s, _, _ = ts_sync.step_fn(params, opt0,
                                     ts_sync.shard_batch(batch_np))
    for step in range(N):
        b_np = ds.batch(step + 1, B)
        p_s, o_s, _, _ = ts_sync.step_fn(p_s, o_s,
                                         ts_sync.shard_batch(b_np))
        p_a, o_a, buf, _, _ = ts_async.async_step_fn(
            p_a, o_a, buf, ts_async.shard_batch(b_np))
    p_a, o_a = ts_async.flush_fn(p_a, o_a, buf)
    steps_match = int(o_a.step) == int(o_s.step)
    l_s, _ = ts_sync.loss_fn(p_s, ts_sync.shard_batch(batch_np))
    l_a, _ = ts_async.loss_fn(p_a, ts_async.shard_batch(batch_np))
    gap = abs(float(l_s) - float(l_a))
    converged = gap < 0.15 and float(l_a) < float(l0a)

    ok = bit_identical and grads_live and steps_match and converged
    print(f"{arch:26s} [async] grad-bit-identical={bit_identical} "
          f"(diff leaves {n_diff}) updates-match={steps_match} "
          f"stale-vs-sync loss gap={gap:.4f} "
          f"({float(l_s):.4f} vs {float(l_a):.4f}) "
          f"{'OK' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit(f"{arch}: async equivalence bit={bit_identical} "
                         f"updates={steps_match} live={grads_live} "
                         f"gap={gap}")
    return gap


INT8_TOL = 5e-2           # pinned compressed-vs-raw gradient rel-err bound


def run_compress(arch: str, devices) -> float:
    """Compressed boundary transfers + bucketed gradient AllReduce
    (DESIGN.md §10) on the real runtime, staleness 0:

    1. the *bucketed but uncompressed* gradient path matches the legacy
       per-leaf psum path to float reassociation (~1e-5 rel — same math,
       different reduction order);
    2. int8-compressed gradients (quantized boundary activations AND the
       quantized bucketed AllReduce) land within the pinned ``INT8_TOL``
       of the uncompressed gradients on the same params/batch, with live
       error-feedback residuals;
    3. error feedback is unbiased in the telescoping-sum sense: the mean
       of T compressed gradient rounds on a frozen params/batch drifts
       toward the raw gradient, beating the no-feedback quantizer;
    4. one compressed optimizer step reduces the loss.
    """
    from repro.configs import get_smoke_config
    from repro.data import SyntheticLM
    from repro.models.frontend import frontend_dim
    from repro.runtime.train import build_train_step, init_train_state

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    B, S, M, T = 8, 64, 4, 6
    mesh_prod = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))
    ts_base = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                               n_micro=M)
    ts_bkt = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                              n_micro=M, bucket_mb=4.0)
    ts_q = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                            n_micro=M, compress="int8")
    ts_qnef = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                               n_micro=M, compress="int8",
                               error_feedback=False)
    assert ts_bkt.spec.bucketed and ts_q.spec.bucketed

    key = jax.random.PRNGKey(0)
    ds = SyntheticLM(cfg.vocab_size, S, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))
    batch_np = ds.batch(0, B)
    params, opt0 = init_train_state(key, ts_base)

    def rel(ga, gb):
        worst = 0.0
        for a, b in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
            assert a.shape == b.shape and a.dtype == b.dtype
            d = float(jnp.max(jnp.abs(a - b)))
            scale = max(float(jnp.max(jnp.abs(b))), 1e-12)
            worst = max(worst, d / scale)
        return worst

    (_, m0), g0 = ts_base.grad_fn(params, ts_base.shard_batch(batch_np))

    # 1) bucketed-uncompressed == legacy up to reduction-order reassociation
    (_, mb), gb, _ = ts_bkt.grad_fn(params, ts_bkt.shard_batch(batch_np),
                                    ts_bkt.init_ef())
    worst_bkt = rel(gb, g0)

    # 2) int8 end-to-end (compressed ppermute + compressed bucketed psum)
    batch_q = ts_q.shard_batch(batch_np)
    (_, mq), gq, ef = ts_q.grad_fn(params, batch_q, ts_q.init_ef())
    worst_q = rel(gq, g0)
    ef_live = any(float(jnp.max(jnp.abs(x))) > 0 for x in jax.tree.leaves(ef))
    ce_gap = abs(float(mq["ce"]) - float(m0["ce"]))

    # 3) telescoping error feedback: mean of T rounds on frozen params/batch
    #    approaches the raw gradient; without feedback the quantizer bias is
    #    whatever round 1 produced, every round
    acc = jax.tree.map(jnp.zeros_like, gq)
    ef_t = ts_q.init_ef()
    for _ in range(T):
        (_, _), g_t, ef_t = ts_q.grad_fn(params, batch_q, ef_t)
        acc = jax.tree.map(jnp.add, acc, g_t)
    mean_ef = jax.tree.map(lambda x: x / T, acc)
    bias_ef = rel(mean_ef, g0)
    (_, _), g_nef, _ = ts_qnef.grad_fn(params, ts_qnef.shard_batch(batch_np),
                                       ts_qnef.init_ef())
    bias_nef = rel(g_nef, g0)
    ef_wins = bias_ef < bias_nef

    # 4) one compressed step reduces the loss (step_fn's bucketed arity)
    p1, _, ef1, l0, _ = ts_q.step_fn(params, opt0, ts_q.init_ef(), batch_q)
    l1, _ = ts_q.loss_fn(p1, batch_q)
    improved = float(l1) < float(l0)

    ok = (worst_bkt < 1e-4 and worst_q < INT8_TOL and ef_live and ce_gap < 0.02
          and ef_wins and improved)
    print(f"{arch:26s} [compress] bucketed rel={worst_bkt:.2e} int8 "
          f"rel={worst_q:.2e} ce gap={ce_gap:.2e} ef-bias {bias_nef:.2e}->"
          f"{bias_ef:.2e} step {float(l0):.4f}->{float(l1):.4f} "
          f"{'OK' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit(f"{arch}: compressed parity bucketed={worst_bkt} "
                         f"int8={worst_q} ce={ce_gap} ef_live={ef_live} "
                         f"ef {bias_nef}->{bias_ef} improved={improved}")
    return worst_q


def run_arch_planned(arch: str, devices) -> float:
    """Full planner->lowering->runtime path: profile an edge cluster, run
    Algorithm 2 restricted to mesh-feasible stage counts, lower the plan
    (heterogeneous period split + n_micro + K_p cross-check against the
    simulator), and verify train-loss parity vs the single-device model."""
    from repro.configs import get_smoke_config
    from repro.core.hardware import env_d
    from repro.core.lowering import plan_to_train_step
    from repro.core.planner import plan_hpp
    from repro.core.profiler import LayerTable, Profile
    from repro.data import SyntheticLM
    from repro.models.frontend import frontend_dim
    from repro.models.model import init_model, loss_fn as local_loss_fn
    from repro.runtime.train import build_train_step, init_train_state

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    # 4 periods so a 2-stage split can be heterogeneous
    cfg = cfg.replace(n_layers=4 * len(cfg.pattern))
    B, S = 8, 64
    mesh_prod = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))

    table = LayerTable.from_model_config(cfg, S)
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=B)
    plan = plan_hpp(prof, B, micro_batch=2, arch=arch, allowed_stages={2})
    ts, lowered = plan_to_train_step(plan, prof, cfg, mesh_prod)

    key = jax.random.PRNGKey(0)
    ds = SyntheticLM(cfg.vocab_size, S, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))
    batch_np = ds.batch(0, B)
    ref_params = init_model(key, cfg)
    loss_r, metrics_r = jax.jit(lambda p, b: local_loss_fn(p, b, cfg, ce_chunk=1024))(
        ref_params, {k: jnp.asarray(v) for k, v in batch_np.items()})

    batch = ts.shard_batch(batch_np)
    params, opt_state = init_train_state(key, ts)
    loss_d, metrics = ts.loss_fn(params, batch)
    diff = abs(float(metrics["ce"]) - float(metrics_r["ce"]))

    new_params, new_opt, l0, _ = ts.step_fn(params, opt_state, batch)
    l1, _ = ts.loss_fn(new_params, batch)
    improved = float(l1) < float(l0)

    # the planner may have chosen a uniform split — exercise a maximally
    # skewed heterogeneous one (3 periods | 1 period) explicitly
    ts2 = build_train_step(cfg, mesh_prod, global_batch=B, stage=2,
                           n_micro=4, stage_periods=((0, 3), (3, 4)))
    batch2 = ts2.shard_batch(batch_np)
    params2, _ = init_train_state(key, ts2)
    _, metrics2 = ts2.loss_fn(params2, batch2)
    diff2 = abs(float(metrics2["ce"]) - float(metrics_r["ce"]))

    ok = diff < TOL and diff2 < TOL and improved
    print(f"{arch:26s} [plan] periods={lowered.stage_periods} "
          f"M={lowered.n_micro} K_p={lowered.warmup} "
          f"y={ts.spec.shard_alloc or 'uniform'} diff={diff:.2e} "
          f"het(3|1) diff={diff2:.2e} step {float(l0):.4f}->{float(l1):.4f} "
          f"{'OK' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit(f"{arch}: planned-lowering parity {diff}/{diff2} "
                         f"improved={improved}")
    return diff


def run_replay(arch: str, devices) -> float:
    """Live pipeline replay (§3.4) end-to-end on the real runtime.

    Plan -> session -> train -> kill a rank mid-training -> lightweight
    replay -> keep training.  Asserts: untouched periods bit-identical
    across the migration, runtime boundary bytes reconcile exactly with the
    analytical RecoveryReport, the re-lowered step matches a
    fresh-from-scratch lowering of the new plan on identical params, and
    the loss keeps improving after recovery."""
    import numpy as _np

    from repro.configs import get_smoke_config
    from repro.core.hardware import env_d
    from repro.core.lowering import period_positions as positions
    from repro.core.planner import plan_hpp
    from repro.core.profiler import LayerTable, Profile
    from repro.data import SyntheticLM
    from repro.runtime.session import PipelineSession
    from repro.runtime.train import build_train_step_from_lowered

    cfg = get_smoke_config(arch)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    cfg = cfg.replace(n_layers=8 * len(cfg.pattern))   # 8 periods
    B, S = 8, 64
    mesh = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))
    table = LayerTable.from_model_config(cfg, S)
    prof = Profile.analytic(table, env_d().sorted_by_memory(), max_batch=B)
    plan = plan_hpp(prof, B, micro_batch=2, arch=arch, allowed_stages={2})

    session = PipelineSession(cfg, mesh, plan, prof, backup_every=2)
    session.init(jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg.vocab_size, S, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len)
    losses = [session.step(ds.batch(s, B))[0] for s in range(4)]

    old_pos = positions(session.lowered)
    pre = [_np.asarray(jax.device_get(x))
           for x in jax.tree.leaves(session.params["periods"])]

    # fail a member of the multi-device stage: the stage survives with its
    # DP peers, so the recovery is a pure lightweight migration
    st = max(session.plan.stages, key=lambda s: len(s.group))
    assert len(st.group) > 1, session.plan.stages
    session.fail(st.group[-1])
    out = session.recover_now()
    assert out.mode == "lightweight", out.mode

    # 1) runtime boundary bytes == analytical migration inputs (exact)
    assert out.reconciliation is not None
    for rec in out.reconciliation.values():
        assert rec["table_bytes"] == rec["analytic_bytes"], rec

    # 2) untouched periods bit-identical across the arrangement swap
    new_pos = positions(session.lowered)
    post = [_np.asarray(jax.device_get(x))
            for x in jax.tree.leaves(session.params["periods"])]
    touched = set(out.migration.moved_periods) | set(out.restored_periods)
    for t in range(session.lowered.n_periods):
        if t in touched:
            continue
        for a, b in zip(pre, post):
            assert _np.array_equal(a[old_pos[t]], b[new_pos[t]]), t

    # 3) the session's re-lowered step == a fresh lowering of the new plan
    #    on identical params
    fresh = build_train_step_from_lowered(cfg, mesh, session.lowered)
    assert fresh.spec.shard_alloc == session.ts.spec.shard_alloc
    batch_np = ds.batch(100, B)
    batch = session.ts.shard_batch(batch_np)
    l_sess, m_sess = session.ts.loss_fn(session.params, batch)
    l_fresh, m_fresh = fresh.loss_fn(session.params, batch)
    d_fresh = abs(float(l_sess) - float(l_fresh))
    assert d_fresh < 1e-6, (float(l_sess), float(l_fresh))

    # 4) training keeps improving on the replayed pipeline
    losses += [session.step(ds.batch(s, B))[0] for s in range(4, 12)]
    ok = losses[-1] < losses[0]
    print(f"{arch:26s} [replay] moved={out.migration.moved_periods} "
          f"stages {len(plan.stages)}->{session.lowered.stage} "
          f"fresh-lowering diff={d_fresh:.1e} loss {losses[0]:.4f}->"
          f"{losses[-1]:.4f} {'OK' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit(f"{arch}: loss did not improve after replay "
                         f"({losses})")
    return d_fresh


def run_serve(arch: str, devices, seq_shard: bool = False, stage=None) -> float:
    """Distributed serve_step vs single-device decode logits parity."""
    from repro.configs import get_smoke_config
    from repro.models.model import decode_step, init_decode_states, init_model
    from repro.runtime.serve import build_serve_step, prepare_serve_states
    from repro.runtime.train import prepare_params
    from repro.distributed.compat import sharded_init
    from repro.distributed.sharding import named

    cfg = get_smoke_config(arch).replace(prefix_len=0, mtp_depth=0)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    B, cache_len, steps = (1, 64, 6) if seq_shard else (8, 64, 6)
    mesh_prod = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))
    ss = build_serve_step(cfg, mesh_prod, batch_global=B, cache_len=cache_len,
                          seq_shard=seq_shard, stage=stage)

    key = jax.random.PRNGKey(0)
    params = sharded_init(lambda k: prepare_params(k, cfg, ss.spec.plan),
                          named(ss.mesh, ss.param_specs))(key)
    states = sharded_init(lambda: prepare_serve_states(cfg, ss.spec.plan, B, cache_len),
                          named(ss.mesh, ss.state_specs))()

    ref_params = init_model(key, cfg)
    ref_states = init_decode_states(B, cache_len, cfg)
    ref_step = jax.jit(lambda p, t, pos, st: decode_step(p, t, pos, st, cfg))

    shape = (steps, B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (steps, B)
    tokens = np.random.RandomState(0).randint(0, cfg.vocab_size, size=shape)
    worst = 0.0
    for t in range(steps):
        tok = jnp.asarray(tokens[t], jnp.int32)
        logits_d, states = ss.step_fn(params, tok, jnp.int32(t), states)
        logits_r, ref_states = ref_step(ref_params, tok, jnp.int32(t), ref_states)
        d = float(jnp.max(jnp.abs(jnp.asarray(logits_d) - logits_r)))
        worst = max(worst, d)
    tag = "serve-seqshard" if seq_shard else "serve"
    ok = worst < 2e-3
    print(f"{arch:26s} [{tag}] stage={ss.spec.plan.stage} tp={ss.spec.plan.tp} "
          f"max_logit_diff={worst:.2e} {'OK' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit(f"{arch} serve parity {worst}")
    return worst


def run_serve_hetero(arch: str, devices, stage=None) -> float:
    """Heterogeneous slot-split decode (build_slot_serve_step) parity.

    An unbalanced shard_alloc=(3, 1) with staggered slot admission must
    reproduce the uniform lockstep single-device decode logits row-for-row:
    slot s admitted at wall step ``delay[s]`` decodes position p at wall
    step ``delay[s] + p`` with identical logits.  Also asserts padded slot
    rows return exactly-zero logits (the sampling-head mask) and that the
    per-row reset wipes recurrent state on admission (the staggered rows
    would diverge without it on RWKV/Mamba archs)."""
    from repro.configs import get_smoke_config
    from repro.models.model import decode_step, init_decode_states, init_model
    from repro.runtime.continuous import slot_rows
    from repro.runtime.serve import build_slot_serve_step, prepare_serve_states
    from repro.runtime.train import prepare_params
    from repro.distributed.compat import sharded_init
    from repro.distributed.sharding import named

    cfg = get_smoke_config(arch).replace(prefix_len=0, mtp_depth=0)
    if cfg.n_codebooks > 1:
        print(f"{arch:26s} [serve-hetero] skipped (multi-codebook)", flush=True)
        return 0.0
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    alloc, cache_len, steps = (3, 1), 64, 6
    delay = (0, 1, 2, 1)                     # admission wall-step per slot
    mesh_prod = Mesh(np.array(devices).reshape(2, 4), ("data", "model"))
    ss = build_slot_serve_step(cfg, mesh_prod, cache_len=cache_len,
                               shard_alloc=alloc, stage=stage)
    rows = slot_rows(alloc)
    B_live, B_pad = len(rows), ss.spec.batch_global

    key = jax.random.PRNGKey(0)
    params = sharded_init(lambda k: prepare_params(k, cfg, ss.spec.plan),
                          named(ss.mesh, ss.param_specs))(key)
    states = sharded_init(
        lambda: prepare_serve_states(cfg, ss.spec.plan, B_pad, cache_len),
        named(ss.mesh, ss.state_specs))()

    ref_params = init_model(key, cfg)
    ref_states = init_decode_states(B_live, cache_len, cfg)
    ref_step = jax.jit(lambda p, t, pos, st: decode_step(p, t, pos, st, cfg))

    tokens = np.random.RandomState(0).randint(0, cfg.vocab_size,
                                              size=(B_live, steps))
    ref_logits = []
    for t in range(steps):
        lg, ref_states = ref_step(ref_params, jnp.asarray(tokens[:, t]),
                                  jnp.int32(t), ref_states)
        ref_logits.append(np.asarray(lg))

    worst, pad_max = 0.0, 0.0
    for w in range(steps + max(delay)):
        tok = np.zeros(B_pad, np.int64)
        pos = np.zeros(B_pad, np.int64)
        reset = np.zeros(B_pad, bool)
        live = {}
        for s, row in enumerate(rows):
            p = w - delay[s]
            if p < 0 or p >= steps:
                reset[row] = True            # idle slots stay wiped
                continue
            tok[row], pos[row], reset[row] = tokens[s, p], p, p == 0
            live[s] = (row, p)
        logits, states = ss.step_fn(params, jnp.asarray(tok, jnp.int32),
                                    jnp.asarray(pos, jnp.int32),
                                    jnp.asarray(reset), states)
        logits = np.asarray(jax.device_get(logits))
        for s, (row, p) in live.items():
            worst = max(worst, float(np.max(np.abs(logits[row] -
                                                   ref_logits[p][s]))))
        for row in range(B_pad):
            if row not in rows:
                pad_max = max(pad_max, float(np.max(np.abs(logits[row]))))
    ok = worst < TOL and pad_max == 0.0
    print(f"{arch:26s} [serve-hetero] y={alloc} stage={ss.spec.plan.stage} "
          f"tp={ss.spec.plan.tp} max_logit_diff={worst:.2e} "
          f"pad_logits={pad_max:.1e} {'OK' if ok else 'FAIL'}", flush=True)
    if not ok:
        raise SystemExit(f"{arch} serve-hetero parity {worst} pad={pad_max}")
    return worst


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    serve = "--serve" in sys.argv
    serve_hetero = "--serve-hetero" in sys.argv
    seq_shard = "--seq-shard" in sys.argv
    planned = "--plan" in sys.argv
    replay = "--replay" in sys.argv
    hetero = "--hetero" in sys.argv
    async_mode = "--async" in sys.argv
    compress = "--compress" in sys.argv
    stage = 2 if "--stage2" in sys.argv else None
    archs = args or DEFAULT_ARCHS
    devices = jax.devices()
    assert len(devices) >= 8, "needs 8 host devices"
    for arch in archs:
        if serve_hetero:
            run_serve_hetero(arch, devices[:8], stage=stage)
        elif serve:
            run_serve(arch, devices[:8], seq_shard=seq_shard)
        elif planned:
            run_arch_planned(arch, devices[:8])
        elif replay:
            run_replay(arch, devices[:8])
        elif hetero:
            run_arch_hetero(arch, devices[:8])
        elif async_mode:
            run_async(arch, devices[:8])
        elif compress:
            run_compress(arch, devices[:8])
        else:
            run_arch(arch, devices[:8])
    print("ALL OK")


if __name__ == "__main__":
    main()
