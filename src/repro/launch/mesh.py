"""Production mesh definition (as a function — importing this module never
touches jax device state)."""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The pinned production mesh: 16x16 = 256 chips per pod (v5e), and
    2 pods = 512 chips for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
