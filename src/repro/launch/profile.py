"""Measured on-device profiling CLI (paper §3.3).

Executes the *real* per-layer ``(tf, tb)`` sweeps
(``core.profiler.measure_layer_times``) for a model config on the local
host across a batch-size sweep, and serializes the result — raw time
tables plus device/cluster metadata and staleness fingerprints — to a
versioned JSON artifact the planner can consume instead of the analytic
FLOP model:

    PYTHONPATH=src python -m repro.launch.profile --quick -o prof.json
    PYTHONPATH=src python -m repro.launch.train --plan --profile prof.json

Under a multi-process JAX mesh every rank measures its own accelerator and
the sweeps are gathered to rank 0, which writes one device row per rank
(single-process runs just profile the host).  ``--replicate N`` tiles the
host's row into N virtual devices, emulating a homogeneous edge cluster
from one measurement so the planner can produce multi-stage plans on a
laptop — the paper's setting would run this CLI once per Jetson instead.

On a CPU host the measured numbers are CPU numbers; the point is the
pipeline (measure -> serialize -> plan -> lower -> execute), which is
hardware-agnostic.  See DESIGN.md §3 for the artifact schema and the
staleness rules ``launch.train`` applies before trusting an artifact.
"""

from __future__ import annotations

import argparse
import itertools
import os
import sys
import time


def _host_mem_bytes(default: float = 8e9) -> float:
    """Physical memory of this host (the planner's budget u_d)."""
    try:
        return float(os.sysconf("SC_PHYS_PAGES") * os.sysconf("SC_PAGE_SIZE"))
    except (ValueError, OSError, AttributeError):
        return default


def build_layer_fns(cfg, seq_len: int, key=None):
    """Per-layer callables matching ``LayerTable.from_model_config(cfg)``.

    Returns ``(layer_fns, make_input)`` for ``measure_layer_times``: one
    jittable ``x -> y`` per table entry (embed, each of the ``n_layers``
    block layers, head), bound to freshly-initialized params.  Block layers
    reuse one period's params per pattern slot — timing is weight-value
    independent, so one init covers all periods.
    """
    import jax
    import jax.numpy as jnp

    from repro.models.blocks import apply_layer
    from repro.models.model import _head_weight, embed_tokens, init_model

    if key is None:
        key = jax.random.PRNGKey(0)
    params = init_model(key, cfg)
    period0 = jax.tree.map(lambda x: x[0], params["periods"])

    def embed_fn(tokens):
        return embed_tokens(params, tokens, cfg)

    fns = [embed_fn]
    for li in range(cfg.n_layers):
        spec = cfg.pattern[li % len(cfg.pattern)]
        lp = period0["layers"][li % len(cfg.pattern)]

        def block_fn(x, lp=lp, spec=spec):
            B, S = x.shape[0], x.shape[1]
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                         (B, S))
            return apply_layer(lp, x, positions, cfg, spec)[0]

        fns.append(block_fn)

    head_w = _head_weight(params, cfg,
                          codebook=0 if cfg.n_codebooks > 1 else None)

    def head_fn(x):
        return x @ head_w

    fns.append(head_fn)

    def make_input(beta: int, li: int):
        if li == 0:            # embed consumes token ids
            shape = (beta, cfg.n_codebooks, seq_len) if cfg.n_codebooks > 1 \
                else (beta, seq_len)
            return jnp.zeros(shape, jnp.int32)
        return jnp.ones((beta, seq_len, cfg.d_model), cfg.cdtype) * 0.01

    return fns, make_input


_KV_GATHER_CALLS = itertools.count()


def gather_process_rows(tf, tb):
    """Gather every process's ``(n_batches, L)`` sweep into ``(D, ...)``.

    Primary path: ``multihost_utils.process_allgather`` (one jitted
    all-gather over the global mesh — what a real TPU/GPU edge mesh runs).
    The CPU backend hosts a multi-process *coordination* service but not
    multi-process XLA computations, so there the rows travel through the
    distributed KV store instead — same contract, control-plane transport
    (bit-exact: float64 lists survive the JSON round trip).  Exercised by
    ``repro.launch.profile_selftest`` (2 processes) in CI.
    """
    import jax
    import numpy as np

    n = jax.process_count()
    if n == 1:
        return np.asarray(tf)[None], np.asarray(tb)[None]
    from jax.experimental import multihost_utils
    try:
        return (np.asarray(multihost_utils.process_allgather(tf)),
                np.asarray(multihost_utils.process_allgather(tb)))
    except Exception as e:                      # noqa: BLE001
        if "Multiprocess computations" not in str(e):
            raise
    import json

    from jax._src import distributed

    client = distributed.global_state.client
    rank = jax.process_index()
    # keys and barrier ids are single-use in the coordination service —
    # suffix with a per-call counter so repeated measure_model calls in
    # one distributed run (several configs / seq_lens) keep working
    call = next(_KV_GATHER_CALLS)
    payload = json.dumps({"tf": np.asarray(tf).tolist(),
                          "tb": np.asarray(tb).tolist()})
    client.key_value_set(f"asteroid/profile_row/{call}/{rank}", payload)
    client.wait_at_barrier(f"asteroid_profile_gather/{call}", 120_000)
    rows = [json.loads(client.blocking_key_value_get(
        f"asteroid/profile_row/{call}/{r}", 120_000)) for r in range(n)]
    return (np.stack([np.asarray(r["tf"]) for r in rows]),
            np.stack([np.asarray(r["tb"]) for r in rows]))


def measure_model(cfg, seq_len: int, batch_sizes=(1, 2, 4), repeats: int = 3,
                  *, replicate: int = 1, mem_bytes: float | None = None,
                  bandwidth: float | None = None, seed: int = 0):
    """Profile ``cfg`` on the local host into a ``MeasuredProfile``.

    Runs the jitted per-layer sweep, gathers one device row per JAX process
    (``gather_process_rows`` — every rank receives every row), then
    tiles rows ``replicate`` times into virtual devices.  The effective
    FLOP rate at the largest measured batch is recorded per device so
    ``MeasuredProfile.cluster()`` yields the best analytic model of the
    same hardware.
    """
    import jax
    import numpy as np

    from repro.core.hardware import MBPS_1000
    from repro.core.profiler import (LayerTable, MeasuredProfile,
                                     config_fingerprint, device_fingerprint,
                                     measure_layer_times)

    table = LayerTable.from_model_config(cfg, seq_len)
    fns, make_input = build_layer_fns(cfg, seq_len, jax.random.PRNGKey(seed))
    assert len(fns) == table.L, (len(fns), table.L)
    batch_sizes = tuple(sorted(set(int(b) for b in batch_sizes)))
    t0 = time.perf_counter()
    tf, tb = measure_layer_times(fns, make_input, batch_sizes, repeats)
    elapsed = time.perf_counter() - t0

    plat = jax.local_devices()[0].platform
    tf, tb = gather_process_rows(tf, tb)             # (D, n_batches, L)
    if jax.process_count() > 1:
        names = [f"{plat}:{r}" for r in range(jax.process_count())]
    else:
        names = [f"{plat}:0"]

    if replicate > 1:
        tf = np.tile(tf, (replicate, 1, 1))
        tb = np.tile(tb, (replicate, 1, 1))
        names = [f"{n}/v{k}" for n in names for k in range(replicate)]
    # (D, n_batches, L)
    beta_max = batch_sizes[-1]
    est = tuple(float(table.flops(0, table.L) * beta_max /
                      max(tf[d, -1].sum(), 1e-12)) for d in range(len(names)))
    mem = mem_bytes if mem_bytes is not None else _host_mem_bytes()
    return MeasuredProfile(
        arch=cfg.name, seq_len=seq_len, batch_sizes=batch_sizes,
        layer_names=tuple(l.name for l in table.layers),
        tf=tf, tb=tb, device_names=tuple(names),
        config_hash=config_fingerprint(cfg, seq_len),
        device_hash=device_fingerprint(),
        mem_bytes=(float(mem),) * len(names), est_flops=est,
        bandwidth=float(bandwidth if bandwidth is not None else MBPS_1000),
        repeats=repeats,
        meta={"jax": jax.__version__,
              "python": sys.version.split()[0],
              "platform": plat,
              "measure_seconds": round(elapsed, 3),
              "created": time.strftime("%Y-%m-%dT%H:%M:%S%z")})


def main(argv=None) -> str:
    ap = argparse.ArgumentParser(
        description="measure per-layer (tf, tb) sweeps on the local host "
                    "and write a planner-consumable profile artifact")
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true",
                    help="profile the reduced same-family config")
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run: --smoke, seq 64, batches 1,2,4, "
                         "1 repeat, 4 virtual devices")
    ap.add_argument("--seq", type=int, default=None,
                    help="sequence length (default 128; 64 under --quick)")
    ap.add_argument("--batches", default=None,
                    help="comma-separated batch sizes to sweep "
                         "(default 1,2,4,8; 1,2,4 under --quick)")
    ap.add_argument("--repeats", type=int, default=None,
                    help="timed repetitions per (layer, batch) after the "
                         "compile warm-up (default 3; 1 under --quick)")
    ap.add_argument("--replicate", type=int, default=None,
                    help="tile the host row into N virtual devices "
                         "(default 1; 4 under --quick)")
    ap.add_argument("--mem-gb", type=float, default=None,
                    help="override the per-device memory budget "
                         "(default: host physical memory)")
    ap.add_argument("--bw-mbps", type=float, default=None,
                    help="assumed D2D bandwidth between profiled devices "
                         "(default 1000)")
    ap.add_argument("-o", "--out", default="prof.json")
    args = ap.parse_args(argv)

    seq = args.seq if args.seq is not None else (64 if args.quick else 128)
    batches = tuple(int(b) for b in args.batches.split(",")) if args.batches \
        else ((1, 2, 4) if args.quick else (1, 2, 4, 8))
    repeats = args.repeats if args.repeats is not None else \
        (1 if args.quick else 3)
    replicate = args.replicate if args.replicate is not None else \
        (4 if args.quick else 1)

    from repro.configs import get_config, get_smoke_config
    from repro.core.profiler import save_profile

    smoke = args.smoke or args.quick
    cfg = get_smoke_config(args.arch) if smoke else get_config(args.arch)
    print(f"profiling {cfg.name} (smoke={smoke}) seq={seq} "
          f"batches={batches} repeats={repeats} replicate={replicate}")
    mp = measure_model(cfg, seq, batches, repeats, replicate=replicate,
                       mem_bytes=None if args.mem_gb is None
                       else args.mem_gb * 1e9,
                       bandwidth=None if args.bw_mbps is None
                       else args.bw_mbps * 1e6 / 8)
    import dataclasses
    mp = dataclasses.replace(mp, meta={**mp.meta, "arch_id": args.arch,
                                       "smoke": smoke})
    import jax
    if jax.process_index() != 0:
        return args.out          # rank 0 gathered every row and writes
    for li, name in enumerate(mp.layer_names):
        fwd = " ".join(f"{mp.tf[0, bi, li] * 1e3:8.3f}"
                       for bi in range(len(mp.batch_sizes)))
        bwd = " ".join(f"{mp.tb[0, bi, li] * 1e3:8.3f}"
                       for bi in range(len(mp.batch_sizes)))
        print(f"  {name:>10s}  fwd[ms] {fwd}   bwd[ms] {bwd}")
    save_profile(args.out, mp)
    print(f"profile ({mp.D} device rows x {len(mp.batch_sizes)} batches x "
          f"{mp.L} layers) -> {args.out}")
    return args.out


if __name__ == "__main__":
    main()
