"""Distributed training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch phi3-mini-3.8b \
        --smoke --devices 8 --steps 50 --global-batch 16 --seq 128

On a real TPU slice the production mesh comes from ``make_production_mesh``;
on CPU ``--devices N`` forces N host devices (must be set before jax init,
which this module does first).
"""

import argparse
import os
import sys


def _preparse_devices():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    args, _ = ap.parse_known_args()
    if args.devices:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")


_preparse_devices()

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402


def _steady_tok_s(args, n_compile: int, t0: float, t_warm, t_end: float):
    """FINAL steady-state rate, shared by the plain and session paths:
    tokens over the steps after the compile step(s) (n_compile jitted
    entry points: 1 sync, 2 bounded-staleness), or over the whole run
    when there were no post-compile steps to time."""
    tokens = args.global_batch * args.seq
    if t_warm is not None:
        return tokens * (args.steps - n_compile) / max(t_end - t_warm, 1e-9)
    return tokens * args.steps / max(t_end - t0, 1e-9)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default phi3-mini-3.8b, or the "
                         "--profile artifact's recorded arch)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--data-axis", type=int, default=None)
    ap.add_argument("--stage", type=int, default=None)
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=None,
                    help="sequence length (default 128, or the --profile "
                         "artifact's recorded seq_len)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override width (e.g. scale to ~100M params)")
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--plan", action="store_true",
                    help="derive stage split / n_micro / K_p from the "
                         "Asteroid planner (Algorithm 2) and lower it")
    ap.add_argument("--no-offload", action="store_true",
                    help="disable Algorithm 1 Phase 2 (straggler workload "
                         "offloading) when planning — the Fig. 15a ablation")
    ap.add_argument("--force-offload", action="store_true",
                    help="always keep the Phase 2 allocation (default: "
                         "'auto' — keep it only when the planner predicts "
                         "a strict latency gain, since a heterogeneous "
                         "allocation pads every data shard to B_max)")
    ap.add_argument("--staleness", type=int, default=0, choices=(0, 1),
                    help="async 1F1B gradient staleness bound: 0 = "
                         "synchronous rounds, 1 = round r's gradients are "
                         "applied at the r+1 boundary so their AllReduce "
                         "overlaps round r+1 (DESIGN.md §8)")
    ap.add_argument("--double-buffer", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="double-buffer stage-boundary sends (2-tick hop, "
                         "transfer of micro-batch m overlaps compute of "
                         "m+1); default: on when --staleness 1")
    ap.add_argument("--compress", default="none",
                    choices=("none", "int8", "fp8", "auto"),
                    help="quantize boundary activation/gradient transfers "
                         "and the gradient AllReduce (DESIGN.md §10); "
                         "'auto' (requires --plan) lets the planner keep "
                         "compression only when it prices strictly faster")
    ap.add_argument("--quant-tile", type=int, default=256,
                    help="elements per quantization tile (one f32 scale "
                         "per tile on the wire)")
    ap.add_argument("--bucket-mb", type=float, default=None,
                    help="bucket the gradient AllReduce into size-bounded "
                         "chunks (MiB of compressed wire bytes); implies "
                         "the bucketed gradient path even without "
                         "--compress")
    ap.add_argument("--error-feedback", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="carry the per-bucket quantization residual into "
                         "the next round's gradients (unbiased in the "
                         "telescoping-sum sense); only active with "
                         "--compress")
    ap.add_argument("--env", default="D", choices=list("ABCD"),
                    help="edge environment (analytic profile) for --plan; "
                         "ignored when a valid --profile artifact is given")
    ap.add_argument("--bandwidth", type=float, default=None, metavar="MBPS",
                    help="override the analytic environment's D2D link "
                         "bandwidth (megabits/s; default: the env preset's)")
    ap.add_argument("--profile", default=None, metavar="PATH",
                    help="measured profile artifact from "
                         "repro.launch.profile; the planner/lowering/"
                         "simulator run on its measured (tf, tb) tables, "
                         "falling back to the analytic model with a warning "
                         "if the artifact is stale or incompatible")
    ap.add_argument("--events", default=None, metavar="SCHEDULE",
                    help="membership event schedule, comma-separated "
                         "'kind@step[:arg]' entries, e.g. "
                         "'join@40:dev.json,drain@80:2'.  Kinds: fail/"
                         "drain/evict take a cluster rank (default: last "
                         "stage's lead device); join takes a device preset "
                         "(nano/tx2/nx/a100/v5e, default nx), a device-spec "
                         "JSON file ({name, mem_bytes, flops, ...}), or a "
                         "repro.launch.profile artifact measured on the "
                         "joining device (its sweep prices the admission). "
                         "Requires --plan")
    ap.add_argument("--hysteresis", type=float, default=None,
                    help="admission hysteresis margin for join events "
                         "(default: replay.ADMISSION_HYSTERESIS)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="sugar for --events 'fail@STEP[:--fail-rank]': "
                         "kill a rank before this step and recover through "
                         "the live replay session (requires --plan)")
    ap.add_argument("--fail-rank", type=int, default=None,
                    help="edge-cluster rank to kill (default: last stage's "
                         "lead device)")
    ap.add_argument("--backup-every", type=int, default=5,
                    help="stage-replication cadence in steps (with --events)")
    ap.add_argument("--portfolio", type=int, default=0, metavar="K",
                    help="closed-loop portfolio planning (DESIGN.md §12): "
                         "enumerate every strategy family, give the top-K "
                         "finalists a live probation window each, and "
                         "install the measured winner before training. "
                         "Requires --plan")
    ap.add_argument("--probation-rounds", type=int, default=2, metavar="N",
                    help="timed rounds per finalist in a portfolio "
                         "probation (plus one warmup round that the robust "
                         "stat trims)")
    ap.add_argument("--drift-threshold", type=float, default=None,
                    help="arm the portfolio drift watchdog: re-open the "
                         "auction when the EWMA of observed/predicted round "
                         "latency drifts more than this fraction from its "
                         "baseline (default: off — probe once, keep the "
                         "winner)")
    args = ap.parse_args()
    events = _parse_events(args.events)
    if args.fail_at is not None:     # old flags kept as sugar
        arg = "" if args.fail_rank is None else str(args.fail_rank)
        events.append((args.fail_at, "fail", arg))
    events.sort(key=lambda e: e[0])
    if events and not args.plan:
        raise SystemExit("--events/--fail-at require --plan (the membership "
                         "session recovers by re-lowering a planner Plan)")
    if args.profile and not args.plan:
        raise SystemExit("--profile requires --plan (a measured profile "
                         "only feeds the planner)")
    if args.compress == "auto" and not args.plan:
        raise SystemExit("--compress auto requires --plan (the planner "
                         "prices the compressed vs raw wire)")
    if args.portfolio and not args.plan:
        raise SystemExit("--portfolio requires --plan (the auction probes "
                         "re-lowered planner Plans)")

    from repro import checkpoint
    from repro.configs import get_config, get_smoke_config
    from repro.data import SyntheticLM
    from repro.models.frontend import frontend_dim
    from repro.optim import AdamW, cosine_schedule
    from repro.runtime.train import build_train_step, init_train_state

    # a --profile artifact supplies the model/seq it was measured for;
    # explicit flags still win (a mismatch then falls back to analytic)
    measured = None
    if args.profile:
        from repro.core.profiler import load_profile
        measured = load_profile(args.profile)
        if args.arch is None and "arch_id" in measured.meta:
            args.arch = measured.meta["arch_id"]
        if args.seq is None:
            args.seq = measured.seq_len
        if not args.smoke and measured.meta.get("smoke"):
            print(f"adopting --smoke from profile artifact {args.profile}")
            args.smoke = True
    args.arch = args.arch or "phi3-mini-3.8b"
    args.seq = args.seq or 128

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    overrides = {}
    if args.d_model:
        overrides["d_model"] = args.d_model
    if args.n_layers:
        overrides["n_layers"] = args.n_layers
    if overrides:
        cfg = cfg.replace(**overrides)

    devs = jax.devices()
    n = len(devs)
    data_axis = args.data_axis or max(1, n // 4)
    model_axis = n // data_axis
    mesh = Mesh(np.array(devs).reshape(data_axis, model_axis),
                ("data", "model"))
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh=(data={data_axis}, model={model_axis})")

    opt = AdamW(lr=cosine_schedule(args.lr, warmup=min(20, args.steps // 5),
                                   total=args.steps))
    if args.plan:
        from repro.core.hardware import ENVS
        from repro.core.lowering import plan_to_train_step
        from repro.core.planner import plan_hpp
        from repro.core.profiler import (LayerTable, Profile,
                                         resolve_profile)

        table = LayerTable.from_model_config(cfg, args.seq)
        max_batch = max(args.global_batch, 1)
        prof = resolve_profile(measured, cfg, args.seq, table, max_batch,
                               label=f"measured profile {args.profile}",
                               fallback_note=f" (env {args.env})")
        if prof is not None:
            print(f"profile=measured({args.profile}, "
                  f"{len(prof.cluster.devices)} devices, "
                  f"batches<={max(measured.batch_sizes)} measured)")
        else:
            cluster = ENVS[args.env]()
            if args.bandwidth:
                from repro.core.hardware import Cluster
                cluster = Cluster(cluster.devices, args.bandwidth * 1e6 / 8)
            prof = Profile.analytic(table, cluster.sorted_by_memory(),
                                    max_batch=max_batch)
            print(f"profile=analytic(env {args.env}"
                  + (f", {args.bandwidth:g} Mbps" if args.bandwidth else "")
                  + ")")
        n_periods = cfg.n_layers // len(cfg.pattern)
        divisors = {d for d in range(1, model_axis + 1)
                    if model_axis % d == 0 and d <= n_periods}
        if args.n_micro:
            if args.global_batch % args.n_micro:
                raise SystemExit(f"--n-micro {args.n_micro} must divide "
                                 f"--global-batch {args.global_batch}")
            mb = args.global_batch // args.n_micro
        else:
            m = next(m for m in (4, 2, 1) if args.global_batch % m == 0)
            mb = args.global_batch // m
        if args.no_offload:
            intra_opt = False
        elif args.force_offload:
            intra_opt = True
        else:
            intra_opt = "auto"
        from repro.core.costmodel import CompressionConfig
        if args.compress == "auto":
            plan_compress = "auto"
        elif args.compress != "none":
            plan_compress = CompressionConfig(
                fmt=args.compress, tile=args.quant_tile,
                bucket_mb=args.bucket_mb,
                error_feedback=args.error_feedback)
        else:
            plan_compress = None
        plan = plan_hpp(prof, args.global_batch, mb, arch=cfg.name,
                        allowed_stages=divisors, intra_opt=intra_opt,
                        staleness=args.staleness, compress=plan_compress)
        # the runtime executes whatever the (possibly 'auto') plan chose
        run_compress = plan.compress.fmt if plan.compress else "none"
        compress_kw = dict(compress=run_compress,
                           quant_tile=args.quant_tile,
                           bucket_mb=args.bucket_mb,
                           error_feedback=args.error_feedback)
        if events or args.portfolio:
            from repro.runtime.session import PipelineSession
            watchdog = None
            if args.portfolio and args.drift_threshold is not None:
                from repro.core.portfolio import DriftWatchdog
                watchdog = DriftWatchdog(threshold=args.drift_threshold)
            session = PipelineSession(cfg, mesh, plan, prof, optimizer=opt,
                                      backup_every=args.backup_every,
                                      portfolio_k=args.portfolio,
                                      probation_window=args.probation_rounds,
                                      drift_watchdog=watchdog,
                                      staleness=args.staleness,
                                      double_buffer=args.double_buffer,
                                      **compress_kw)
            lowered = session.lowered
            print(f"asteroid plan: {lowered.stage} stages periods="
                  f"{lowered.stage_periods} M={lowered.n_micro} "
                  f"K_p={lowered.warmup} predicted latency {plan.latency:.3f}s")
            return _run_session(session, cfg, args, events)
        ts, lowered = plan_to_train_step(plan, prof, cfg, mesh, optimizer=opt,
                                         staleness=args.staleness,
                                         double_buffer=args.double_buffer,
                                         **compress_kw)
        print(f"asteroid plan: {lowered.stage} stages periods="
              f"{lowered.stage_periods} M={lowered.n_micro} "
              f"K_p={lowered.warmup} alloc={lowered.micro_alloc} "
              f"predicted latency {plan.latency:.3f}s")
    else:
        ts = build_train_step(cfg, mesh, global_batch=args.global_batch,
                              stage=args.stage, n_micro=args.n_micro,
                              optimizer=opt, staleness=args.staleness,
                              double_buffer=args.double_buffer,
                              compress=args.compress,
                              quant_tile=args.quant_tile,
                              bucket_mb=args.bucket_mb,
                              error_feedback=args.error_feedback)
    print(f"plan: stage={ts.spec.plan.stage} tp={ts.spec.plan.tp} "
          f"M={ts.spec.n_micro} shard_alloc="
          f"{ts.spec.shard_alloc or 'uniform'} "
          f"staleness={ts.spec.staleness} "
          f"double_buffer={ts.spec.double_buffer} "
          f"compress={ts.spec.compress}"
          + (f" bucket_mb={ts.spec.bucket_mb:g}" if ts.spec.bucket_mb else "")
          + (" ef" if ts.spec.bucketed and ts.spec.compress != "none"
             and ts.spec.error_feedback else ""))

    key = jax.random.PRNGKey(0)
    params, opt_state = init_train_state(key, ts, opt)
    ds = SyntheticLM(cfg.vocab_size, args.seq, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))

    import time
    t0 = time.perf_counter()
    t_warm = None
    loss = float("nan")
    grad_buf = None
    bucketed = ts.spec.bucketed
    ef = ts.init_ef() if bucketed else None
    # steady state starts once every jitted entry point has compiled: the
    # sync path compiles step_fn at step 0; the bounded-staleness path
    # compiles grad_fn (first round) at step 0 and async_step_fn at step 1
    n_compile = 2 if ts.spec.staleness >= 1 else 1
    for step in range(args.steps):
        batch = ts.shard_batch(ds.batch(step, args.global_batch))
        if ts.spec.staleness >= 1:
            if grad_buf is None:
                # first bounded-staleness round: gradients only, no update
                # (keeps the optimizer/schedule step count equal to sync)
                if bucketed:
                    (loss, metrics), grad_buf, ef = \
                        ts.grad_fn(params, batch, ef)
                else:
                    (loss, metrics), grad_buf = ts.grad_fn(params, batch)
            elif bucketed:
                params, opt_state, grad_buf, ef, loss, metrics = \
                    ts.async_step_fn(params, opt_state, grad_buf, ef, batch)
            else:
                params, opt_state, grad_buf, loss, metrics = \
                    ts.async_step_fn(params, opt_state, grad_buf, batch)
        elif bucketed:
            params, opt_state, ef, loss, metrics = \
                ts.step_fn(params, opt_state, ef, batch)
        else:
            params, opt_state, loss, metrics = ts.step_fn(params, opt_state,
                                                          batch)
        if step == n_compile - 1 and args.steps > n_compile:
            jax.block_until_ready(params)
            t_warm = time.perf_counter()      # exclude compile from FINAL
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tput = args.global_batch * args.seq * (step + 1) / dt
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"ce {float(metrics['ce']):.4f} tok/s {tput:,.0f}")
    jax.block_until_ready(params)
    t_end = time.perf_counter()          # before the flush: its one-off jit
    if grad_buf is not None:             # compile must not bias FINAL
        # staleness barrier: apply the final in-flight gradient round
        params, opt_state = ts.flush_fn(params, opt_state, grad_buf)
        jax.block_until_ready(params)
    steady = _steady_tok_s(args, n_compile, t0, t_warm, t_end)
    if args.checkpoint_dir:
        checkpoint.save(args.checkpoint_dir, "final", params)
        print(f"checkpoint saved to {args.checkpoint_dir}")
    print(f"FINAL tok_s={steady:.1f} loss={float(loss):.4f}")
    print("done")
    return float(loss)


def _parse_events(spec: str | None) -> list:
    """Parse a ``--events`` schedule into ``(step, kind, arg)`` triples."""
    events = []
    if not spec:
        return events
    for entry in spec.split(","):
        entry = entry.strip()
        if not entry:
            continue
        head, _, arg = entry.partition(":")
        kind, at, step = head.partition("@")
        kind = kind.strip().lower()
        if kind not in ("fail", "join", "drain", "evict") or not at:
            raise SystemExit(f"--events entry {entry!r} is not "
                             "'kind@step[:arg]' with kind in "
                             "fail/join/drain/evict")
        try:
            events.append((int(step), kind, arg.strip()))
        except ValueError:
            raise SystemExit(f"--events entry {entry!r}: step {step!r} is "
                             "not an integer")
    return events


def _resolve_join(arg: str):
    """Resolve a join event's argument to ``(device, arrival_sweep)``.

    A preset name or device-spec JSON prices the newcomer analytically;
    a ``repro.launch.profile`` artifact supplies its measured on-arrival
    sweep (the device identity then comes from the sweep itself)."""
    import json

    from repro.core.hardware import (A100, JETSON_NANO, JETSON_NX,
                                     JETSON_TX2, TPU_V5E, DeviceProfile)
    presets = {"nano": JETSON_NANO, "tx2": JETSON_TX2, "nx": JETSON_NX,
               "a100": A100, "v5e": TPU_V5E}
    if not arg:
        return JETSON_NX, None
    if arg.lower() in presets:
        return presets[arg.lower()], None
    with open(arg) as f:
        doc = json.load(f)
    if "batch_sizes" in doc and "tf" in doc:     # a measured sweep artifact
        from repro.core.profiler import load_profile
        return None, load_profile(arg)
    try:
        dev = DeviceProfile(
            name=doc.get("name", "custom"),
            mem_bytes=float(doc["mem_bytes"]), flops=float(doc["flops"]),
            **{k: doc[k] for k in ("sat_batch", "sat_flops", "overhead")
               if k in doc})
    except KeyError as e:
        raise SystemExit(f"join device spec {arg} is missing {e} (need at "
                         "least name/mem_bytes/flops, or pass a "
                         "repro.launch.profile artifact)")
    return dev, None


def _apply_event(session, kind: str, arg: str, args) -> None:
    """Fire one membership event on the live session and report it."""
    from repro.core.replay import ADMISSION_HYSTERESIS

    if kind == "join":
        device, arrival = _resolve_join(arg)
        out = session.admit(device, arrival=arrival,
                            hysteresis=(args.hysteresis
                                        if args.hysteresis is not None
                                        else ADMISSION_HYSTERESIS))
        dec = out.decision
        if not out.accepted:
            print(f"  join rejected ({dec.reason})")
            return
        rep = out.report
        print(f"  joined ({dec.reason}): replan {rep.replan_s * 1e3:.1f}ms "
              f"migrate {rep.migration_s:.2f}s replicate "
              f"{rep.replicate_s:.2f}s | {dec.incumbent_latency:.3f}s -> "
              f"{dec.candidate_latency:.3f}s/round | new stages "
              f"{[(st.layers, st.group) for st in session.plan.stages]}")
        return
    rank = int(arg) if arg else session.plan.stages[-1].group[0]
    if kind == "fail":
        print(f"  killing rank {rank}")
        session.fail(rank)      # detected + recovered inside the next step
        return
    out = session.drain(rank) if kind == "drain" else session.evict(rank)
    rep = out.report
    print(f"  {kind} rank {rank} ({out.mode}"
          f"{', overlapped' if rep.overlapped else ''}): replan "
          f"{rep.replan_s * 1e3:.1f}ms migrate {rep.migration_s:.2f}s "
          f"stall {out.stall_s:.3f}s | new stages "
          f"{[(st.layers, st.group) for st in session.plan.stages]}")


def _run_session(session, cfg, args, events) -> float:
    """Drive a live membership session: train through the scheduled
    join/drain/evict/fail events without restarting."""
    import time

    from repro.data import SyntheticLM
    from repro.models.frontend import frontend_dim

    key = jax.random.PRNGKey(0)
    session.init(key)
    ds = SyntheticLM(cfg.vocab_size, args.seq, n_codebooks=cfg.n_codebooks,
                     prefix_len=cfg.prefix_len, prefix_dim=frontend_dim(cfg))
    if getattr(args, "portfolio", 0):
        # opening auction (DESIGN.md §12): probe the top-K finalists on the
        # live mesh before the first training step; the probation is
        # invisible to training state — pinned by the bit-identity line the
        # portfolio-smoke CI job greps for
        import json

        before = session.canonical_leaves()
        report = session.probe_portfolio(ds.batch(0, args.global_batch),
                                         k=args.portfolio,
                                         window=args.probation_rounds)
        after = session.canonical_leaves()
        identical = all(
            np.array_equal(a, b)
            for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)))
        w, f = report.winner, report.first_choice
        print(f"portfolio: winner installed {w.family} measured "
              f"{w.measured_s * 1e3:.2f}ms/round (analytic first choice "
              f"{f.family} measured {f.measured_s * 1e3:.2f}ms; "
              f"{len(report.results)} finalists of {report.n_candidates} "
              f"candidates, {report.window}-round probation)")
        print(f"portfolio: probation state bit-identical: {identical}")
        rec = dict(report.to_record(), bit_identical=identical)
        print("PORTFOLIO " + json.dumps(rec))
    loss = float("nan")
    seen_recoveries = 0
    pending = sorted(events, key=lambda e: e[0])
    sim_busy = 0.0          # edge-cluster round time under the deployed plan
    t0 = time.perf_counter()
    t_warm = None
    # same compile accounting as the main path: the staleness path has two
    # jitted entry points (first-round grad_fn, then async_step_fn); the
    # spec is read AFTER any opening auction — the installed winner's
    # semantics decide which entry points exist
    n_compile = 2 if session.ts.spec.staleness >= 1 else 1
    for step in range(args.steps):
        while pending and pending[0][0] <= step:
            _, kind, arg = pending.pop(0)
            print(f"step {step}: {kind} event")
            _apply_event(session, kind, arg, args)
        loss, metrics = session.step(ds.batch(step, args.global_batch))
        sim_busy += session.plan.latency
        if step == n_compile - 1 and args.steps > n_compile:
            jax.block_until_ready(session.params)
            t_warm = time.perf_counter()      # exclude compile from FINAL
        if len(session.recoveries) > seen_recoveries:
            seen_recoveries = len(session.recoveries)
            out = session.recoveries[-1]
            rep = out.report
            print(f"  recovered ({out.mode}): detect {rep.detection_s:.2f}s "
                  f"replan {rep.replan_s * 1e3:.1f}ms migrate "
                  f"{rep.migration_s:.2f}s restore {rep.restore_s:.2f}s | "
                  f"moved periods {out.migration.moved_periods} restored "
                  f"{out.restored_periods} | new stages "
                  f"{[(st.layers, st.group) for st in session.plan.stages]}")
        if step % args.log_every == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tput = args.global_batch * args.seq * (step + 1) / dt
            print(f"step {step:5d} loss {loss:.4f} "
                  f"ce {float(metrics['ce']):.4f} tok/s {tput:,.0f}")
    jax.block_until_ready(session.params)
    t_end = time.perf_counter()     # flush compile must not bias FINAL
    session.flush_gradients()       # staleness barrier at end of training
    jax.block_until_ready(session.params)
    if args.checkpoint_dir:
        from repro import checkpoint
        checkpoint.save(args.checkpoint_dir, "final", session.params)
        print(f"checkpoint saved to {args.checkpoint_dir}")
    # same steady-state definition as the main path (shared helper), so
    # FINAL lines stay comparable across the two paths
    tput = _steady_tok_s(args, n_compile, t0, t_warm, t_end)
    # throughput on the simulated edge-cluster clock: per-round latency of
    # whichever plan was deployed at each step, plus the stall every
    # membership transition charged — the metric the churn benchmark tracks
    stalls = sum(o.stall_s for o in session.memberships)
    sim_tput = args.global_batch * args.seq * args.steps / max(
        sim_busy + stalls, 1e-9)
    print(f"FINAL sim_tok_s={sim_tput:.1f} (rounds {sim_busy:.2f}s + "
          f"membership stalls {stalls:.3f}s)")
    print(f"FINAL tok_s={tput:.1f} loss={loss:.4f}")
    print("done")
    return loss


if __name__ == "__main__":
    main()
