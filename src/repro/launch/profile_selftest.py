"""2-process measured-profiling selftest: CI coverage for the
``process_allgather`` multi-process gather path in ``launch.profile``
(ROADMAP: "the multi-process gather has no CI coverage").

The parent picks a free TCP port and spawns two worker subprocesses; each
worker joins a 2-process JAX distributed runtime
(``jax.distributed.initialize``), measures its own (CPU) device with the
real jitted per-layer sweeps, and the rank-0 worker gathers both device
rows via ``multihost_utils.process_allgather`` and writes the artifact —
exactly the code path a real multi-device edge mesh uses, minus the
heterogeneous hardware.  The parent then validates the artifact: two
device rows, loadable bit-exactly, and plannable (Algorithm 2 produces a
multi-stage plan from the gathered tables).

    PYTHONPATH=src python -m repro.launch.profile_selftest

Invoked by tests/test_measured_profile.py (slow marker) and the CI
profile-smoke job.
"""

from __future__ import annotations

import argparse
import os
import socket
import subprocess
import sys
import tempfile


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _worker(rank: int, port: int, out: str, seq: int) -> None:
    # one CPU device per process; must be set before jax initializes
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
    import jax

    jax.distributed.initialize(coordinator_address=f"127.0.0.1:{port}",
                               num_processes=2, process_id=rank)
    assert jax.process_count() == 2, jax.process_count()

    from repro.configs import get_smoke_config
    from repro.core.profiler import save_profile
    from repro.launch.profile import measure_model

    cfg = get_smoke_config("phi3-mini-3.8b")
    mp = measure_model(cfg, seq, batch_sizes=(1, 2), repeats=1)
    assert mp.tf.shape[0] == 2, ("rank rows not gathered", mp.tf.shape)
    if jax.process_index() == 0:
        save_profile(out, mp)
        print(f"rank 0 gathered {mp.D} device rows -> {out}", flush=True)
    print(f"worker {rank} done", flush=True)


def run_selftest(seq: int = 32, timeout: int = 420) -> str:
    """Spawn the 2-process run and validate the gathered artifact.

    Returns the artifact path (in a temp dir).  Raises on any failure —
    including the distributed runtime being unavailable, which IS a
    failure: this selftest exists to keep the gather path working.
    """
    port = _free_port()
    out = os.path.join(tempfile.mkdtemp(prefix="asteroid-prof2p-"),
                       "prof2p.json")
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(root, "src")
    env.pop("XLA_FLAGS", None)
    procs = [subprocess.Popen(
        [sys.executable, "-m", "repro.launch.profile_selftest",
         "--worker", str(r), "--port", str(port), "--seq", str(seq),
         "-o", out],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True,
        env=env, cwd=root) for r in range(2)]
    outs = []
    for r, p in enumerate(procs):
        try:
            stdout, _ = p.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            for q in procs:
                q.kill()
            raise
        outs.append(stdout)
        if p.returncode != 0:
            raise RuntimeError(f"worker {r} failed:\n{stdout[-3000:]}")

    from repro.configs import get_smoke_config
    from repro.core.planner import plan_hpp
    from repro.core.profiler import LayerTable, load_profile

    mp = load_profile(out)
    assert mp.D == 2, f"expected 2 gathered device rows, got {mp.D}"
    assert len(set(mp.device_names)) == 2, mp.device_names
    assert (mp.tf > 0).all() and (mp.tb > 0).all(), "non-positive timings"

    cfg = get_smoke_config("phi3-mini-3.8b")
    table = LayerTable.from_model_config(cfg, seq)
    prof = mp.to_profile(table, max_batch=4)
    plan = plan_hpp(prof, 4, 2, arch=cfg.name, allowed_stages={1, 2})
    print(f"2-process gather OK: rows={mp.device_names} -> "
          f"{len(plan.stages)}-stage plan, predicted {plan.latency:.4f}s")
    return out


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="2-process process_allgather profiling selftest")
    ap.add_argument("--worker", type=int, default=None)
    ap.add_argument("--port", type=int, default=None)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("-o", "--out", default="prof2p.json")
    args = ap.parse_args(argv)
    if args.worker is not None:
        _worker(args.worker, args.port, args.out, args.seq)
        return
    run_selftest(args.seq)
    print("ALL OK")


if __name__ == "__main__":
    main()
