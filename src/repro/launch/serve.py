"""Distributed serving launcher: batched autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --smoke --devices 8 --batch 8 --prompt-len 16 --gen 32

Continuous-batching mode (DESIGN.md §11): plan_serve picks the stage
split and the heterogeneous per-shard slot counts against a modeled
edge cluster, build_slot_serve_step lowers them onto the local mesh,
and an open-loop Poisson request stream is served through
ContinuousBatcher with slot-level admission control:

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --smoke --devices 8 --continuous --requests 12 --gen 16
"""

import argparse
import os


def _preparse_devices():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    args, _ = ap.parse_known_args()
    if args.devices:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")


_preparse_devices()

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402


def run_continuous(args, cfg, mesh) -> None:
    """Planner-driven continuous batching on the local mesh."""
    import time

    from repro.core.hardware import Cluster, JETSON_NX, JETSON_TX2, MBPS_100
    from repro.core.planner import plan_serve
    from repro.core.profiler import LayerTable, Profile
    from repro.distributed.compat import sharded_init
    from repro.distributed.sharding import named
    from repro.runtime.continuous import (ContinuousBatcher,
                                          engine_from_serve_step,
                                          poisson_requests, slot_rows)
    from repro.runtime.serve import build_slot_serve_step, serve_head_count
    from repro.runtime.train import prepare_params

    if cfg.n_codebooks > 1:
        raise SystemExit("--continuous drives scalar token streams; "
                         "multi-codebook archs are not supported")
    dp, model_axis = mesh.shape["data"], mesh.shape["model"]
    cache_len = args.prompt_len + args.gen

    # Plan against a modeled heterogeneous edge cluster (alternating fast
    # NX / slow TX2 shard blocks) so the slot split is visibly unbalanced;
    # max_batch caps the per-shard slot count to what the host can pad.
    devs = tuple((JETSON_NX if d % 2 == 0 else JETSON_TX2,) * model_axis
                 for d in range(dp))
    cluster = Cluster(sum(devs, ()), bandwidth=MBPS_100)
    table = LayerTable.from_model_config(cfg, seq_len=cache_len)
    prof = Profile.analytic(table, cluster, max_batch=args.max_slots)

    # modeled offered load: --util of the equal-split capacity, so the
    # greedy split has queueing pressure to plan against
    from repro.core.planner import (_price_serve_alloc, _serve_cuts,
                                    serve_stage_candidates)
    stage0 = serve_stage_candidates(model_axis, serve_head_count(cfg))[0]
    cuts0 = _serve_cuts(table.L, stage0)
    cap = 0.0
    for y in range(1, args.max_slots + 1):
        st, _, _ = _price_serve_alloc(prof, [y] * dp, stage=stage0,
                                      tp=model_axis // stage0, cuts=cuts0,
                                      seq_len=cache_len, arrival_rate=0.0,
                                      compress=None)
        cap = max(cap, dp * y / st if st > 0 else 0.0)
    plan = plan_serve(prof, args.util * cap, dp_shards=dp,
                      model_axis=model_axis, n_heads=serve_head_count(cfg),
                      cache_len=cache_len, seq_len=cache_len, arch=cfg.name)
    print(f"serve plan: stage={plan.stage} tp={plan.tp} "
          f"alloc={plan.shard_alloc} caps={plan.max_slots} "
          f"modeled p99={plan.predicted_p99 * 1e3:.2f}ms")

    ss = build_slot_serve_step(cfg, mesh, cache_len=cache_len,
                               shard_alloc=plan.shard_alloc,
                               stage=plan.stage)
    key = jax.random.PRNGKey(0)
    params = sharded_init(lambda k: prepare_params(k, cfg, ss.spec.plan),
                          named(ss.mesh, ss.param_specs))(key)
    engine = engine_from_serve_step(ss, params)

    B = ss.spec.batch_global
    zeros = jnp.zeros(B, jnp.int32)
    jax.device_get(engine(zeros, zeros, jnp.ones(B, bool)))   # compile
    t0 = time.perf_counter()
    jax.device_get(engine(zeros, zeros, jnp.zeros(B, bool)))
    step_s = time.perf_counter() - t0
    rate = args.rate or args.util * plan.slots / step_s
    print(f"engine step {step_s * 1e3:.1f}ms on this host -> offered load "
          f"{rate:.1f} tok/s ({args.util:.0%} of capacity)")

    reqs = poisson_requests(rate / args.gen,
                            horizon=args.requests * args.gen / rate,
                            n_tokens=args.gen, seed=0,
                            vocab=cfg.vocab_size)
    bat = ContinuousBatcher(engine, slots=slot_rows(plan.shard_alloc),
                            batch=B, cache_len=cache_len, seed=0)
    done = bat.run(reqs)
    lats = np.array([l for c in done for l in c.token_latencies])
    total = sum(len(c.tokens) for c in done)
    span = max(c.finish for c in done) - min(c.arrival for c in done)
    p50, p95, p99 = np.percentile(lats, [50, 95, 99])
    from repro.core.costmodel import serve_latency_quantile
    pred = [serve_latency_quantile(step_s, plan.slots, rate, p)
            for p in (0.5, 0.95, 0.99)]
    print(f"served {len(done)} requests / {total} tokens in {bat.steps} "
          f"steps: {total / span:.1f} tok/s")
    print(f"token latency p50/p95/p99 = {p50 * 1e3:.1f}/{p95 * 1e3:.1f}/"
          f"{p99 * 1e3:.1f} ms (predicted from measured step: "
          f"{pred[0] * 1e3:.1f}/{pred[1] * 1e3:.1f}/{pred[2] * 1e3:.1f} ms)")
    print("done")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--continuous", action="store_true",
                    help="planner-driven continuous batching "
                         "(plan_serve -> slot step -> Poisson stream)")
    ap.add_argument("--requests", type=int, default=12,
                    help="--continuous: requests in the Poisson trace")
    ap.add_argument("--rate", type=float, default=0.0,
                    help="--continuous: offered load (tokens/s); default "
                         "derives from the measured step time and --util")
    ap.add_argument("--util", type=float, default=0.6,
                    help="--continuous: target utilization for the "
                         "derived offered load")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="--continuous: per-shard slot cap handed to the "
                         "planner as profile.max_batch")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.distributed.compat import sharded_init
    from repro.distributed.sharding import named
    from repro.runtime.serve import build_serve_step, prepare_serve_states
    from repro.runtime.train import prepare_params

    cfg = (get_smoke_config(args.arch) if args.smoke else get_config(args.arch))
    cfg = cfg.replace(prefix_len=0, mtp_depth=0)
    devs = jax.devices()
    n = len(devs)
    data_axis = max(1, n // 4)
    mesh = Mesh(np.array(devs).reshape(data_axis, n // data_axis),
                ("data", "model"))
    if args.continuous:
        run_continuous(args, cfg, mesh)
        return
    cache_len = args.prompt_len + args.gen
    ss = build_serve_step(cfg, mesh, batch_global=args.batch,
                          cache_len=cache_len, seq_shard=args.seq_shard)
    print(f"arch={cfg.name} serve plan: stage={ss.spec.plan.stage} "
          f"tp={ss.spec.plan.tp} cache={cache_len}")

    key = jax.random.PRNGKey(0)
    params = sharded_init(lambda k: prepare_params(k, cfg, ss.spec.plan),
                          named(ss.mesh, ss.param_specs))(key)
    states = sharded_init(lambda: prepare_serve_states(cfg, ss.spec.plan,
                                                       args.batch, cache_len),
                          named(ss.mesh, ss.state_specs))()

    rng = np.random.RandomState(0)
    shape = (args.batch, cfg.n_codebooks) if cfg.n_codebooks > 1 else (args.batch,)
    prompt = rng.randint(0, cfg.vocab_size,
                         size=(args.prompt_len, *shape)).astype(np.int32)

    import time
    seqs = [prompt[t] for t in range(args.prompt_len)]
    tok = jnp.asarray(prompt[0])
    t0 = time.perf_counter()
    skey = key
    for pos in range(cache_len - 1):
        logits, states = ss.step_fn(params, tok, jnp.int32(pos), states)
        if pos + 1 < args.prompt_len:
            tok = jnp.asarray(prompt[pos + 1])
        else:
            skey = jax.random.fold_in(skey, pos)
            nxt = jax.random.categorical(
                skey, jnp.asarray(logits) / args.temperature, axis=-1)
            tok = nxt.astype(jnp.int32)
            seqs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    gen_tokens = args.gen * args.batch
    print(f"decoded {args.gen} steps x batch {args.batch} in {dt:.1f}s "
          f"({gen_tokens / dt:.1f} tok/s on CPU-interpret hardware)")
    out = np.stack(seqs)  # (T, B) or (T, B, CB)
    print("sample sequence 0:", out[:, 0].reshape(out.shape[0], -1)[:, 0][:24], "...")
    print("done")


if __name__ == "__main__":
    main()
