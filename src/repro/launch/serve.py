"""Distributed serving launcher: batched autoregressive decode.

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --smoke --devices 8 --batch 8 --prompt-len 16 --gen 32
"""

import argparse
import os


def _preparse_devices():
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--devices", type=int, default=0)
    args, _ = ap.parse_known_args()
    if args.devices:
        os.environ.setdefault(
            "XLA_FLAGS", f"--xla_force_host_platform_device_count={args.devices}")


_preparse_devices()

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import Mesh  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="phi3-mini-3.8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--seq-shard", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config, get_smoke_config
    from repro.distributed.compat import sharded_init
    from repro.distributed.sharding import named
    from repro.runtime.serve import build_serve_step, prepare_serve_states
    from repro.runtime.train import prepare_params

    cfg = (get_smoke_config(args.arch) if args.smoke else get_config(args.arch))
    cfg = cfg.replace(prefix_len=0, mtp_depth=0)
    devs = jax.devices()
    n = len(devs)
    data_axis = max(1, n // 4)
    mesh = Mesh(np.array(devs).reshape(data_axis, n // data_axis),
                ("data", "model"))
    cache_len = args.prompt_len + args.gen
    ss = build_serve_step(cfg, mesh, batch_global=args.batch,
                          cache_len=cache_len, seq_shard=args.seq_shard)
    print(f"arch={cfg.name} serve plan: stage={ss.spec.plan.stage} "
          f"tp={ss.spec.plan.tp} cache={cache_len}")

    key = jax.random.PRNGKey(0)
    params = sharded_init(lambda k: prepare_params(k, cfg, ss.spec.plan),
                          named(ss.mesh, ss.param_specs))(key)
    states = sharded_init(lambda: prepare_serve_states(cfg, ss.spec.plan,
                                                       args.batch, cache_len),
                          named(ss.mesh, ss.state_specs))()

    rng = np.random.RandomState(0)
    shape = (args.batch, cfg.n_codebooks) if cfg.n_codebooks > 1 else (args.batch,)
    prompt = rng.randint(0, cfg.vocab_size,
                         size=(args.prompt_len, *shape)).astype(np.int32)

    import time
    seqs = [prompt[t] for t in range(args.prompt_len)]
    tok = jnp.asarray(prompt[0])
    t0 = time.perf_counter()
    skey = key
    for pos in range(cache_len - 1):
        logits, states = ss.step_fn(params, tok, jnp.int32(pos), states)
        if pos + 1 < args.prompt_len:
            tok = jnp.asarray(prompt[pos + 1])
        else:
            skey = jax.random.fold_in(skey, pos)
            nxt = jax.random.categorical(
                skey, jnp.asarray(logits) / args.temperature, axis=-1)
            tok = nxt.astype(jnp.int32)
            seqs.append(np.asarray(tok))
    dt = time.perf_counter() - t0
    gen_tokens = args.gen * args.batch
    print(f"decoded {args.gen} steps x batch {args.batch} in {dt:.1f}s "
          f"({gen_tokens / dt:.1f} tok/s on CPU-interpret hardware)")
    out = np.stack(seqs)  # (T, B) or (T, B, CB)
    print("sample sequence 0:", out[:, 0].reshape(out.shape[0], -1)[:, 0][:24], "...")
    print("done")


if __name__ == "__main__":
    main()
