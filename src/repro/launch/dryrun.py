import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and record memory/cost/collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --arch phi3-mini-3.8b \
        --shape train_4k [--multi-pod] [--all] [--out artifacts/dryrun]

For each combination this builds the distributed step (HPP pipeline train
step, prefill step, or TP/seq-sharded serve step), lowers it with
ShapeDtypeStruct inputs (no allocation), compiles for the full mesh, and
writes a JSON record with:

  * compiled.memory_analysis()  — per-device bytes (proves it fits),
  * compiled.cost_analysis()    — per-device FLOPs / bytes for the roofline,
  * collective bytes parsed from the compiled HLO (per op kind),
  * the parallelism layout (stage/tp/M) chosen for the arch.

Shapes (from the assignment):
  train_4k     seq=4096    global_batch=256   train_step
  prefill_32k  seq=32768   global_batch=32    prefill (forward)
  decode_32k   seq=32768   global_batch=128   serve_step (1 token, KV cache)
  long_500k    seq=524288  global_batch=1     serve_step, seq-sharded cache
               (sub-quadratic archs only — see configs.LONG_CONTEXT_OK)
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode_long", seq=524288, batch=1),
}

DRYRUN_DTYPES = dict(param_dtype="bfloat16", compute_dtype="bfloat16")


def input_specs(cfg, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input (weak-type-correct,
    shardable, no device allocation)."""
    from repro.models.frontend import frontend_dim

    info = SHAPES[shape_name]
    B, S = info["batch"], info["seq"]
    if info["kind"] in ("train", "prefill"):
        if cfg.n_codebooks > 1:
            toks = jax.ShapeDtypeStruct((B, cfg.n_codebooks, S), jnp.int32)
        else:
            toks = jax.ShapeDtypeStruct((B, S), jnp.int32)
        batch = {"tokens": toks}
        if cfg.prefix_len > 0:
            batch["prefix"] = jax.ShapeDtypeStruct(
                (B, cfg.prefix_len, frontend_dim(cfg)), jnp.bfloat16)
        return batch
    # decode: one token per sequence + scalar position
    if cfg.n_codebooks > 1:
        tok = jax.ShapeDtypeStruct((B, cfg.n_codebooks), jnp.int32)
    else:
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    return {"token": tok, "position": jax.ShapeDtypeStruct((), jnp.int32)}


def _abstract(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def jaxpr_cost_record(arch: str, shape_name: str, multi_pod: bool,
                      stage: int | None = None,
                      n_micro: int | None = None,
                      hoist: bool = True) -> dict | None:
    """Loop-aware static cost (repro.analysis.jaxpr_cost) for one combo.

    XLA's cost_analysis counts scan bodies once; this traces the jaxpr and
    multiplies trip counts — the roofline uses these numbers when present.
    """
    from repro.analysis.jaxpr_cost import cost_of_fn
    from repro.configs import LONG_CONTEXT_OK, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.optim import AdamW
    from repro.runtime.serve import (build_prefill_step, build_serve_step,
                                     prepare_serve_states)
    from repro.runtime.train import build_train_step, prepare_params

    info = SHAPES[shape_name]
    cfg = get_config(arch).replace(**DRYRUN_DTYPES)
    if info["kind"] == "decode_long" and arch not in LONG_CONTEXT_OK:
        return None
    mesh = make_production_mesh(multi_pod=multi_pod)

    def axsz(plan):
        return {"pod": plan.pod, "data": plan.data, "stage": plan.stage,
                "tp": plan.tp}

    if info["kind"] == "train":
        ts = build_train_step(cfg, mesh, global_batch=info["batch"],
                              stage=stage, n_micro=n_micro,
                              hoist_varying=hoist)
        ap = jax.eval_shape(lambda k: prepare_params(k, cfg, ts.spec.plan),
                            jax.random.PRNGKey(0))
        ao = jax.eval_shape(AdamW(lr=1e-3).init, ap)
        c = cost_of_fn(ts.step_fn, ap, ao, input_specs(cfg, shape_name),
                       axis_sizes=axsz(ts.spec.plan))
    elif info["kind"] == "prefill":
        ss = build_prefill_step(cfg, mesh, batch_global=info["batch"],
                                seq_len=info["seq"], stage=stage,
                                n_micro=n_micro)
        ap = jax.eval_shape(lambda k: prepare_params(k, cfg, ss.spec.plan),
                            jax.random.PRNGKey(0))
        c = cost_of_fn(ss.step_fn, ap, input_specs(cfg, shape_name),
                       axis_sizes=axsz(ss.spec.plan))
    else:
        seq_shard = info["kind"] == "decode_long"
        ss = build_serve_step(cfg, mesh, batch_global=info["batch"],
                              cache_len=info["seq"], seq_shard=seq_shard,
                              stage=stage)
        ap = jax.eval_shape(lambda k: prepare_params(k, cfg, ss.spec.plan),
                            jax.random.PRNGKey(0))
        as_ = jax.eval_shape(lambda: prepare_serve_states(
            cfg, ss.spec.plan, info["batch"], info["seq"]))
        sp = input_specs(cfg, shape_name)
        c = cost_of_fn(ss.step_fn, ap, sp["token"], sp["position"], as_,
                       axis_sizes=axsz(ss.spec.plan))
    return {"jcost": {"flops": c.flops, "bytes": c.bytes,
                      "collective_bytes": c.collective_bytes,
                      "by_collective": dict(c.by_collective)}}


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            stage: int | None = None, n_micro: int | None = None,
            tag: str = "", hoist: bool = True, zero_opt: bool = False) -> dict:
    from repro.analysis.hlo import collective_bytes, total_collective_bytes
    from repro.configs import LONG_CONTEXT_OK, get_config
    from repro.launch.mesh import make_production_mesh
    from repro.optim import AdamW
    from repro.runtime.serve import (build_prefill_step, build_serve_step,
                                     prepare_serve_states)
    from repro.runtime.train import build_train_step, prepare_params

    info = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = get_config(arch).replace(**DRYRUN_DTYPES)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "kind": info["kind"], "status": "skip"}

    if info["kind"] == "decode_long" and arch not in LONG_CONTEXT_OK:
        rec["reason"] = "full-attention arch: long_500k skipped per assignment"
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()

    if info["kind"] == "train":
        ts = build_train_step(cfg, mesh, global_batch=info["batch"],
                              stage=stage, n_micro=n_micro,
                              hoist_varying=hoist, zero_opt=zero_opt)
        plan = ts.spec.plan
        abstract_params = jax.eval_shape(
            lambda k: prepare_params(k, cfg, plan), jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        abstract_opt = jax.eval_shape(opt.init, abstract_params)
        lowered = ts.step_fn.lower(abstract_params, abstract_opt,
                                   input_specs(cfg, shape_name))
        tokens_global = info["batch"] * info["seq"]
        rec.update(stage=plan.stage, tp=plan.tp, n_micro=ts.spec.n_micro)
    elif info["kind"] == "prefill":
        ss = build_prefill_step(cfg, mesh, batch_global=info["batch"],
                                seq_len=info["seq"], stage=stage,
                                n_micro=n_micro)
        plan = ss.spec.plan
        abstract_params = jax.eval_shape(
            lambda k: prepare_params(k, cfg, plan), jax.random.PRNGKey(0))
        lowered = ss.step_fn.lower(abstract_params,
                                   input_specs(cfg, shape_name))
        tokens_global = info["batch"] * info["seq"]
        rec.update(stage=plan.stage, tp=plan.tp, n_micro=ss.spec.n_groups)
    else:
        seq_shard = info["kind"] == "decode_long"
        ss = build_serve_step(cfg, mesh, batch_global=info["batch"],
                              cache_len=info["seq"], seq_shard=seq_shard,
                              stage=stage)
        plan = ss.spec.plan
        abstract_params = jax.eval_shape(
            lambda k: prepare_params(k, cfg, plan), jax.random.PRNGKey(0))
        abstract_states = jax.eval_shape(
            lambda: prepare_serve_states(cfg, plan, info["batch"], info["seq"]))
        spec_in = input_specs(cfg, shape_name)
        lowered = ss.step_fn.lower(abstract_params, spec_in["token"],
                                   spec_in["position"], abstract_states)
        tokens_global = info["batch"]          # one token per sequence
        rec.update(stage=plan.stage, tp=plan.tp, seq_shard=seq_shard)

    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    cost = dict(compiled.cost_analysis() or {})
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    rec.update(
        status="ok",
        n_devices=mesh.devices.size,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        tokens_global=tokens_global,
        active_params=cfg.active_param_count(),
        total_params=cfg.param_count(),
        memory=dict(
            argument_bytes=ma.argument_size_in_bytes,
            output_bytes=ma.output_size_in_bytes,
            temp_bytes=ma.temp_size_in_bytes,
            alias_bytes=ma.alias_size_in_bytes,
            total_bytes=(ma.argument_size_in_bytes + ma.output_size_in_bytes +
                         ma.temp_size_in_bytes - ma.alias_size_in_bytes),
        ),
        cost={k: cost.get(k, 0.0) for k in ("flops", "bytes accessed")},
        collectives=coll,
        collective_bytes_total=total_collective_bytes(hlo),
        hlo_bytes=len(hlo),
    )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None,
                    choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--stage", type=int, default=None)
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--zero-opt", action="store_true",
                    help="ZeRO-1: shard Adam moments over (pod,data)")
    ap.add_argument("--no-hoist", action="store_true",
                    help="paper-faithful baseline (no varying-cast hoist)")
    ap.add_argument("--jcost", action="store_true",
                    help="backfill loop-aware jaxpr costs into existing "
                         "artifacts (no compile)")
    args = ap.parse_args()

    from repro.configs import ARCH_IDS

    archs = args.arch or (list(ARCH_IDS) if args.all else ["phi3-mini-3.8b"])
    shapes = args.shape or (list(SHAPES) if args.all else ["train_4k"])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                name = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                if args.tag:
                    name += f"__{args.tag}"
                path = os.path.join(args.out, name + ".json")
                if args.jcost:
                    if not os.path.exists(path):
                        continue
                    rec = json.load(open(path))
                    if rec.get("status") != "ok" or "jcost" in rec:
                        continue
                    try:
                        extra = jaxpr_cost_record(arch, shape, mp,
                                                  stage=args.stage,
                                                  n_micro=args.n_micro,
                                                  hoist=not args.no_hoist)
                        if extra:
                            rec.update(extra)
                            json.dump(rec, open(path, "w"), indent=1)
                            print(f"[jcost] {name} flops={extra['jcost']['flops']:.3e} "
                                  f"coll={extra['jcost']['collective_bytes']/2**20:.0f}MiB",
                                  flush=True)
                    except Exception as e:
                        print(f"[jcost-error] {name}: {e}", flush=True)
                    continue
                if os.path.exists(path):
                    print(f"[cached] {name}")
                    results.append(json.load(open(path)))
                    continue
                print(f"[dryrun] {name} ...", flush=True)
                try:
                    rec = run_one(arch, shape, mp, args.out, stage=args.stage,
                                  n_micro=args.n_micro, tag=args.tag,
                                  hoist=not args.no_hoist,
                                  zero_opt=args.zero_opt)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                ok = rec["status"]
                extra = ""
                if ok == "ok":
                    extra = (f" flops/dev={rec['cost']['flops']:.3e}"
                             f" mem/dev={rec['memory']['total_bytes']/2**30:.2f}GiB"
                             f" coll/dev={rec['collective_bytes_total']/2**20:.1f}MiB"
                             f" compile={rec['compile_s']}s")
                elif ok == "error":
                    extra = " " + rec["error"][:200]
                print(f"[{ok}] {name}{extra}", flush=True)
                results.append(rec)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
