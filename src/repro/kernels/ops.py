"""Jitted dispatch wrappers for the Pallas kernels.

On CPU (this container) kernels run in ``interpret=True`` mode (the kernel
body executes in Python for correctness); on TPU they compile natively.
``use_pallas()`` is the switch the model layer consults — the distributed
runtime uses the XLA-native paths by default and swaps kernels in with
``--use-pallas`` on real hardware.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import flash_decode
from .flash_attention import flash_attention
from .fused_swiglu import fused_swiglu
from .rwkv6_wkv import rwkv6_wkv

__all__ = ["flash_attention_op", "flash_decode_op", "rwkv6_wkv_op",
           "fused_swiglu_op", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp() -> bool:
    return not on_tpu()


def flash_attention_op(q, k, v, **kw):
    """(B, S, H, D) layout wrapper -> flattens heads into the grid dim."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    # GQA contiguity: q head i maps to kv head i // (H // Hkv) within a batch
    out = flash_attention(qf, kf, vf, interpret=_interp(), **kw)
    return out.reshape(B, H, S, D).transpose(0, 2, 1, 3)


def flash_decode_op(q, k_cache, v_cache, cache_len, **kw):
    """q: (B, H, D); caches: (B, S, Hkv, D); cache_len: () or (B,)."""
    B, H, D = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    qf = q.reshape(B * H, D)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(B * Hkv, S, D)
    if jnp.ndim(cache_len) == 1:     # per-slot lengths -> per-kv-row
        cache_len = jnp.repeat(cache_len, Hkv)
    out = flash_decode(qf, kf, vf, cache_len, interpret=_interp(), **kw)
    return out.reshape(B, H, D)


def rwkv6_wkv_op(r, k, v, w, u, **kw):
    """(B, H, S, d) layout wrapper."""
    B, H, S, d = r.shape
    flat = lambda t: t.reshape(B * H, S, d)
    u2 = u[None].repeat(B, axis=0).reshape(B * H, d) if u.ndim == 2 else u
    out = rwkv6_wkv(flat(r), flat(k), flat(v), flat(w), u2,
                    interpret=_interp(), **kw)
    return out.reshape(B, H, S, d)


def fused_swiglu_op(x, wg, wu, wd, **kw):
    """(..., D) layout wrapper."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = fused_swiglu(x2, wg, wu, wd, interpret=_interp(), **kw)
    return out.reshape(shape)
