"""Pallas TPU fused SwiGLU MLP: silu(x·Wg) ⊙ (x·Wu) · Wd in one kernel.

Grid = (n_row_blocks, n_ff_blocks): each step computes one (bm × bf) tile of
the hidden activation and immediately contracts it with the matching Wd row
block, accumulating the (bm × D) output in VMEM scratch — the (T, d_ff)
hidden tensor never exists in HBM.  bm/bf default to MXU-aligned 128/512.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                   n_ff: int, act: str):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)            # (bm, D)
    wg = wg_ref[...].astype(jnp.float32)          # (D, bf)
    wu = wu_ref[...].astype(jnp.float32)
    wd = wd_ref[...].astype(jnp.float32)          # (bf, D)

    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if act == "silu":
        h = g * jax.nn.sigmoid(g) * u
    else:  # gelu_tanh
        h = jax.nn.gelu(g, approximate=True) * u
    acc_ref[...] += jax.lax.dot_general(h, wd, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(j == n_ff - 1)
    def _finish():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_f", "act",
                                             "interpret"))
def fused_swiglu(x, wg, wu, wd, *, block_m: int = 128, block_f: int = 512,
                 act: str = "silu", interpret: bool = False):
    """x: (T, D); wg/wu: (D, F); wd: (F, D) -> (T, D)."""
    T, D = x.shape
    F = wg.shape[1]
    block_m = min(block_m, T)
    block_f = min(block_f, F)
    assert T % block_m == 0 and F % block_f == 0, (T, F, block_m, block_f)
    n_m = T // block_m
    n_f = F // block_f

    kernel = functools.partial(_swiglu_kernel, n_ff=n_f, act=act)
    return pl.pallas_call(
        kernel,
        grid=(n_m, n_f),
        in_specs=[
            pl.BlockSpec((block_m, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((D, block_f), lambda i, j: (0, j)),
            pl.BlockSpec((block_f, D), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, D), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((T, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, D), jnp.float32)],
        interpret=interpret,
    )(x, wg, wu, wd)
