"""Pallas TPU flash attention (forward): causal / sliding-window / softcap /
GQA, with explicit BlockSpec VMEM tiling.

TPU mapping: grid = (batch·q_heads, n_q_blocks, n_kv_blocks); the innermost
grid dim streams KV blocks through VMEM while an (m, l, acc) online-softmax
accumulator lives in VMEM scratch (TPU grids execute sequentially, so
scratch persists across the kv dimension).  GQA is expressed in the KV
BlockSpec index map (q-head h reads kv-head h // group) — no KV replication
in HBM.  Block shapes default to 128 (MXU-aligned).

Validated against ``ref.naive_attention`` in interpret mode on CPU; compiled
path targets TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window, softcap,
                 block_q: int, block_k: int, n_kv: int, seq_len: int):
    j = pl.program_id(1)          # q block index
    t = pl.program_id(2)          # kv block index

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (bq, d)
    k = k_ref[0].astype(jnp.float32)              # (bk, d)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = j * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = t * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(t == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, scale: float | None = None, causal: bool = True,
                    window: int | None = None, softcap: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (BH, S, D); k/v: (BHkv, S, D) with BH % BHkv == 0 (GQA grouping
    is contiguous: q row i reads kv row i // (BH // BHkv)).  Returns (BH, S, D).
    """
    BH, S, D = q.shape
    BHkv = k.shape[0]
    group = BH // BHkv
    if scale is None:
        scale = D ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    n_q = -(-S // block_q)
    n_kv = -(-S // block_k)

    # pad the sequence so every BlockSpec tile is in-bounds (pallas clamps
    # out-of-range block starts, which would alias tiles); padded keys are
    # masked via k_pos < seq_len, padded q rows are sliced off below.
    S_pad = max(n_q * block_q, n_kv * block_k)
    if S_pad != S:
        q = jnp.pad(q, ((0, 0), (0, n_q * block_q - S), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, n_kv * block_k - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, n_kv * block_k - S), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_k=block_k, n_kv=n_kv,
        seq_len=S)

    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda i, j, t: (i, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j, t: (i // group, t, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, j, t: (i // group, t, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda i, j, t: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, n_q * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)[:, :S]
