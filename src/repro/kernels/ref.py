"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def naive_attention(q, k, v, *, scale=None, causal=True, window=None,
                    softcap=None):
    """q: (BH, S, D); k/v: (BHkv, S, D).  Full-softmax reference."""
    BH, S, D = q.shape
    G = BH // k.shape[0]
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def naive_decode(q, k_cache, v_cache, cache_len, *, scale=None, window=None):
    """q: (BH, D); caches (BHkv, S, D); reference one-token attention."""
    BH, D = q.shape
    BHkv, S, _ = k_cache.shape
    G = BH // BHkv
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k_cache, G, axis=0)
    vv = jnp.repeat(v_cache, G, axis=0)
    s = jnp.einsum("bd,bkd->bk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    pos = jnp.arange(S)[None, :]
    mask = pos < cache_len
    if window is not None:
        mask &= pos >= cache_len - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p, vv.astype(jnp.float32)).astype(q.dtype)


def naive_wkv6(r, k, v, w, u):
    """Step-by-step WKV-6 recurrence.  r/k/v/w: (BH, S, d); u: (BH, d)."""
    BH, S, d = r.shape

    def per_head(r_h, k_h, v_h, w_h, u_h):
        def step(s, inputs):
            r_t, k_t, v_t, w_t = inputs
            kv = jnp.outer(k_t, v_t)
            out = r_t @ (s + u_h[:, None] * kv)
            s = s * w_t[:, None] + kv
            return s, out

        s0 = jnp.zeros((d, d), jnp.float32)
        _, outs = jax.lax.scan(step, s0, (r_h, k_h, v_h, w_h))
        return outs

    return jax.vmap(per_head)(r.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), w.astype(jnp.float32),
                              u.astype(jnp.float32))


def naive_swiglu(x, wg, wu, wd, act: str = "silu"):
    xf = x.astype(jnp.float32)
    g = xf @ wg.astype(jnp.float32)
    uu = xf @ wu.astype(jnp.float32)
    if act == "silu":
        h = jax.nn.silu(g) * uu
    else:
        h = jax.nn.gelu(g, approximate=True) * uu
    return (h @ wd.astype(jnp.float32)).astype(x.dtype)


def naive_mamba_scan(dt, b, c, x, a):
    """Step-by-step selective-scan reference.  dt/x: (B,S,d); b/c: (B,S,N);
    a: (d,N)."""
    import jax
    import jax.numpy as jnp

    def per_batch(dt_b, b_b, c_b, x_b):
        def step(h, inp):
            dt_t, b_t, c_t, x_t = inp
            h = h * jnp.exp(dt_t[:, None] * a) + (dt_t * x_t)[:, None] * b_t[None, :]
            return h, jnp.sum(h * c_t[None, :], axis=1)

        h0 = jnp.zeros(a.shape, jnp.float32)
        _, ys = jax.lax.scan(step, h0, (dt_b, b_b, c_b, x_b))
        return ys

    return jax.vmap(per_batch)(dt, b, c, x)
