"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def naive_attention(q, k, v, *, scale=None, causal=True, window=None,
                    softcap=None):
    """q: (BH, S, D); k/v: (BHkv, S, D).  Full-softmax reference."""
    BH, S, D = q.shape
    G = BH // k.shape[0]
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def naive_decode(q, k_cache, v_cache, cache_len, *, scale=None, window=None):
    """q: (BH, D); caches (BHkv, S, D); reference one-token attention."""
    BH, D = q.shape
    BHkv, S, _ = k_cache.shape
    G = BH // BHkv
    if scale is None:
        scale = D ** -0.5
    kk = jnp.repeat(k_cache, G, axis=0)
    vv = jnp.repeat(v_cache, G, axis=0)
    s = jnp.einsum("bd,bkd->bk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    pos = jnp.arange(S)[None, :]
    mask = pos < cache_len
    if window is not None:
        mask &= pos >= cache_len - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bk,bkd->bd", p, vv.astype(jnp.float32)).astype(q.dtype)


def naive_wkv6(r, k, v, w, u):
    """Step-by-step WKV-6 recurrence.  r/k/v/w: (BH, S, d); u: (BH, d)."""
    BH, S, d = r.shape

    def per_head(r_h, k_h, v_h, w_h, u_h):
        def step(s, inputs):
            r_t, k_t, v_t, w_t = inputs
            kv = jnp.outer(k_t, v_t)
            out = r_t @ (s + u_h[:, None] * kv)
            s = s * w_t[:, None] + kv
            return s, out

        s0 = jnp.zeros((d, d), jnp.float32)
        _, outs = jax.lax.scan(step, s0, (r_h, k_h, v_h, w_h))
        return outs

    return jax.vmap(per_head)(r.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), w.astype(jnp.float32),
                              u.astype(jnp.float32))


def naive_swiglu(x, wg, wu, wd, act: str = "silu"):
    xf = x.astype(jnp.float32)
    g = xf @ wg.astype(jnp.float32)
    uu = xf @ wu.astype(jnp.float32)
    if act == "silu":
        h = jax.nn.silu(g) * uu
    else:
        h = jax.nn.gelu(g, approximate=True) * uu
    return (h @ wd.astype(jnp.float32)).astype(x.dtype)


#: power-of-two scale divisor per wire format (mirrors quant_transfer.QDIV
#: without a circular import — quant_transfer imports these oracles).
#: Dividing the tile abs-max by a power of two is EXACT in binary floating
#: point, so the scale is bitwise identical whether computed eagerly, under
#: jit (XLA rewrites constant divisions to reciprocal multiplies — 1 ULP
#: off for non-power-of-two divisors), or inside the Pallas kernel.
#: int8: amax maps to +-128, clipped to the symmetric [-127, 127] payload.
#: fp8 (e4m3, max finite 448): amax maps to +-256 — float formats are
#: scale-invariant in relative error, so the headroom costs no precision.
_QDIV = {"int8": 128.0, "fp8": 256.0}


def quant_scale(amax, fmt: str):
    """Per-tile scale from the row abs-max; 1.0 for all-zero tiles (their
    payload quantizes to zeros regardless, and 0/0 must not appear)."""
    if fmt not in _QDIV:
        raise ValueError(f"unknown quantization format {fmt!r}")
    return jnp.where(amax > 0, amax / _QDIV[fmt], 1.0)


def naive_quantize_tiles(x, *, fmt: str = "int8"):
    """x: (R, tile) float -> (q (R, tile) int8/fp8, scales (R, 1) f32).

    The arithmetic ground truth for ``quant_transfer.quantize_tiles`` —
    same ops in the same order, so parity with the Pallas kernel is
    bitwise, not approximate."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=1, keepdims=True)
    scale = quant_scale(amax, fmt)
    y = xf / scale
    if fmt == "int8":
        q = jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    elif fmt == "fp8":
        q = y.astype(jnp.float8_e4m3fn)
    else:
        raise ValueError(f"unknown quantization format {fmt!r}")
    return q, scale


def naive_dequantize_tiles(q, scales, *, out_dtype=jnp.float32):
    """(q (R, tile), scales (R, 1)) -> (R, tile) ``out_dtype``."""
    return (q.astype(jnp.float32) * scales).astype(out_dtype)


def naive_mamba_scan(dt, b, c, x, a):
    """Step-by-step selective-scan reference.  dt/x: (B,S,d); b/c: (B,S,N);
    a: (d,N)."""
    import jax
    import jax.numpy as jnp

    def per_batch(dt_b, b_b, c_b, x_b):
        def step(h, inp):
            dt_t, b_t, c_t, x_t = inp
            h = h * jnp.exp(dt_t[:, None] * a) + (dt_t * x_t)[:, None] * b_t[None, :]
            return h, jnp.sum(h * c_t[None, :], axis=1)

        h0 = jnp.zeros(a.shape, jnp.float32)
        _, ys = jax.lax.scan(step, h0, (dt_b, b_b, c_b, x_b))
        return ys

    return jax.vmap(per_batch)(dt, b, c, x)
