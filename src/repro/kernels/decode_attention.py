"""Pallas TPU flash-decoding kernel: one query token vs a long KV cache.

Grid = (B·Hq, n_kv_blocks): KV blocks stream through VMEM; the partial
softmax (m, l, acc) lives in scratch and the final renormalized output is
written on the last block — the kernel analogue of the sequence-sharded
``decode_attention`` collective path (which splits the same computation
*across chips* and combines partials with pmax/psum).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, window, block_k: int, n_kv: int,
                   group: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)            # (1, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    # per-kv-row cache length (continuous batching: each slot decodes at its
    # own position); lockstep callers broadcast a scalar to all rows
    cache_len = len_ref[pl.program_id(0) // group]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (1, bk)
    pos = t * block_k + jax.lax.broadcasted_iota(jnp.int32, (1, block_k), 1)
    mask = pos < cache_len
    if window is not None:
        mask &= pos >= cache_len - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(t == n_kv - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "scale", "block_k",
                                             "interpret"))
def flash_decode(q, k_cache, v_cache, cache_len, *, scale: float | None = None,
                 window: int | None = None, block_k: int = 512,
                 interpret: bool = False):
    """q: (BH, D); k/v_cache: (BHkv, S, D); cache_len: () or (BHkv,) int32.

    Returns (BH, D).  GQA via the KV index map (q row i -> kv row i//G).
    A per-row ``cache_len`` masks each KV row at its own valid length."""
    BH, D = q.shape
    BHkv, S, _ = k_cache.shape
    group = BH // BHkv
    if scale is None:
        scale = D ** -0.5
    block_k = min(block_k, S)
    n_kv = -(-S // block_k)
    if n_kv * block_k != S:   # pad: pallas clamps OOB block starts
        pad = n_kv * block_k - S
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0)))
    q3 = q[:, None, :]
    clen = jnp.broadcast_to(jnp.asarray(cache_len), (BHkv,)).astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, scale=scale, window=window,
                               block_k=block_k, n_kv=n_kv, group=group)
    out = pl.pallas_call(
        kernel,
        grid=(BH, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda i, t: (i, 0, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, t: (i // group, t, 0)),
            pl.BlockSpec((1, block_k, D), lambda i, t: (i // group, t, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda i, t: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, 1, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k_cache, v_cache, clen)
    return out[:, 0]
