"""Pallas TPU kernel for the RWKV-6 WKV recurrence (chunked).

    out_t = r_t · (S_{t-1} + diag(u) k_tᵀ v_t)
    S_t   = diag(w_t) S_{t-1} + k_tᵀ v_t        (w_t data-dependent, per channel)

TPU mapping: grid = (B·H, n_chunks); the (d, d) state matrix lives in VMEM
scratch and carries across the sequential chunk dimension.  Within a chunk
the recurrence is expanded to matmul form with decay-weighted triangular
attention (same scheme as the XLA-native ``repro.models.rwkv``): with
per-step log-decay clamped to [-20, 0] the factored ``exp(±cumsum)`` terms
stay in fp32 range for chunk sizes <= 128.

Block shapes: r/k/v/w tiles (1, C, d) stream through VMEM; d = head_dim
(64 for RWKV-6) keeps the state tile MXU-aligned at fp32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, s_ref, *,
                chunk: int, d: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0].astype(jnp.float32)      # (C, d)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    w = w_ref[0].astype(jnp.float32)      # per-step decay in (0, 1)
    u = u_ref[0].astype(jnp.float32)      # (1, d) bonus

    logw = jnp.log(jnp.maximum(w, 1e-20))
    cum = jnp.cumsum(logw, axis=0)                       # (C, d)
    c = jnp.concatenate([jnp.zeros((1, d), jnp.float32), cum[:-1]], axis=0)

    rq = r * jnp.exp(c)
    kq = k * jnp.exp(-cum)
    att = jax.lax.dot_general(rq, kq, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    ti = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(tj < ti, att, 0.0)
    out = jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    # bonus diagonal
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)    # (C, 1)
    out = out + bonus * v
    # incoming state
    out = out + jax.lax.dot_general(rq, s_ref[...], (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)

    # state update: S <- diag(exp(cum_C)) S + sum_j diag(exp(cum_C - cum_j)) k_j^T v_j
    decay_all = jnp.exp(cum[-1])                         # (d,)
    kw = k * jnp.exp(cum[-1][None, :] - cum)             # (C, d)
    s_ref[...] = (s_ref[...] * decay_all[:, None] +
                  jax.lax.dot_general(kw, v, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_wkv(r, k, v, w, u, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/w: (BH, S, d) with w the per-step decay in (0,1); u: (BH, d).

    Returns (BH, S, d) fp32 WKV outputs (pre group-norm)."""
    BH, S, d = r.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    u3 = u[:, None, :]                                    # (BH, 1, d)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, d=d)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, 1, d), lambda i, t: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, d), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u3)
