"""Pallas quantize/dequantize kernels for compressed boundary transfers.

The wire format (DESIGN.md §10): a float tensor is flattened, zero-padded
to a multiple of ``tile`` elements and viewed as ``(R, tile)`` — one
*scale tile* per row.  ``quantize_tiles`` emits the packed payload
``q (R, tile)`` in int8 (symmetric round-to-nearest, clipped to ±127) or
fp8 (e4m3) plus per-tile fp32 scales ``(R, 1)``; ``dequantize_tiles``
reconstructs ``q * scale``.  Both payloads travel through the runtime's
``ppermute`` / ``psum`` collectives, so compressed int8 moves
``(1 + 4/tile) / 4`` of the fp32 bytes (``costmodel.CompressionConfig``
prices exactly this ratio).

Kernels grid over row blocks; each step reduces its block's row-wise
abs-max in registers and writes payload + scales in one pass.  On CPU
(no TPU backend) the dispatch wrappers fall back to the pure-jnp oracles
in ``kernels.ref`` — the SAME arithmetic ops in the same order, so
kernel-vs-reference parity is bitwise (``tests/test_kernels.py``) and the
distributed runtime's numerics do not depend on the backend.

``roundtrip_ef`` is the error-feedback form used for the gradient stream:
the residual ``e_t`` of round t is added to round t+1's tensor before
quantization, so the *running sum* of transmitted gradients telescopes to
the true sum up to one residual (bias → 0 as 1/T over steps).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import naive_dequantize_tiles, naive_quantize_tiles, quant_scale

QUANT_FORMATS = ("int8", "fp8")
#: power-of-two scale divisor per format (exact fp division — see
#: ``ref.quant_scale``); int8 payloads clip to the symmetric [-127, 127]
QDIV = {"int8": 128.0, "fp8": 256.0}


def quant_dtype(fmt: str):
    if fmt == "int8":
        return jnp.int8
    if fmt == "fp8":
        return jnp.float8_e4m3fn
    raise ValueError(f"unknown quantization format {fmt!r} "
                     f"(expected one of {QUANT_FORMATS})")


def wire_bits(fmt: str, tile: int) -> float:
    """Payload bits per element including the amortized per-tile scale."""
    return 8.0 + 32.0 / tile


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------


def _block_rows(R: int, want: int) -> int:
    """Largest divisor of R that is <= want (rows are independent, so any
    row-block size is valid — divisibility just keeps the grid exact)."""
    b = min(want, R)
    while R % b:
        b -= 1
    return b


def _quantize_kernel(x_ref, q_ref, s_ref, *, fmt: str):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)
    scale = quant_scale(amax, fmt)
    y = x / scale
    if fmt == "int8":
        y = jnp.clip(jnp.round(y), -127.0, 127.0)
    q_ref[...] = y.astype(q_ref.dtype)
    s_ref[...] = scale


def _dequantize_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fmt", "block_rows", "interpret"))
def quantize_tiles(x, *, fmt: str = "int8", block_rows: int = 8,
                   interpret: bool = False):
    """x: (R, tile) float -> (q (R, tile) int8/fp8, scales (R, 1) f32)."""
    R, T = x.shape
    block_rows = _block_rows(R, block_rows)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, fmt=fmt),
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, T), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((block_rows, T), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((R, T), quant_dtype(fmt)),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("out_dtype", "block_rows",
                                             "interpret"))
def dequantize_tiles(q, scales, *, out_dtype=jnp.float32, block_rows: int = 8,
                     interpret: bool = False):
    """(q (R, tile), scales (R, 1)) -> (R, tile) ``out_dtype``."""
    R, T = q.shape
    block_rows = _block_rows(R, block_rows)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, T), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, T), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, T), out_dtype),
        interpret=interpret,
    )(q, scales)


# ---------------------------------------------------------------------------
# Dispatch + packing (the runtime entry points)
# ---------------------------------------------------------------------------


def _use_kernel() -> bool:
    return jax.default_backend() == "tpu"


def pack_tiles(x, tile: int):
    """Flatten and zero-pad ``x`` to the (R, tile) wire layout."""
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    R = -(-n // tile)
    pad = R * tile - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat.reshape(R, tile)


def unpack_tiles(x2d, shape, dtype):
    """Inverse of ``pack_tiles``: strip padding, restore shape/dtype."""
    n = 1
    for d in shape:
        n *= d
    return x2d.reshape(-1)[:n].reshape(shape).astype(dtype)


def quantize_op(x, *, fmt: str = "int8", tile: int = 256):
    """Quantize an arbitrary-shape tensor into the wire pytree
    ``{"q": (R, tile) int8/fp8, "scale": (R, 1) f32}`` — the payload the
    pipeline's ``ppermute`` (and any other collective) actually moves."""
    x2d = pack_tiles(x, tile)
    if _use_kernel():
        q, s = quantize_tiles(x2d, fmt=fmt)
    else:
        q, s = naive_quantize_tiles(x2d, fmt=fmt)
    return {"q": q, "scale": s}


def dequantize_op(packed, shape, dtype, *, tile: int = 256):
    """Reconstruct the tensor from the wire pytree on the receiver."""
    if _use_kernel():
        x2d = dequantize_tiles(packed["q"], packed["scale"])
    else:
        x2d = naive_dequantize_tiles(packed["q"], packed["scale"])
    return unpack_tiles(x2d, shape, dtype)


def roundtrip(x, *, fmt: str = "int8", tile: int = 256):
    """quantize -> dequantize (what the receiver sees of ``x``)."""
    return dequantize_op(quantize_op(x, fmt=fmt, tile=tile), x.shape, x.dtype,
                         tile=tile)


def roundtrip_ef(x, err, *, fmt: str = "int8", tile: int = 256):
    """Error-feedback round trip: returns ``(x_hat, new_err)``.

    The accumulated residual ``err`` (same shape as ``x``) is folded into
    the tensor before quantization and the fresh quantization error becomes
    the next residual: ``sum_t x_hat_t = sum_t x_t + e_0 - e_T``, so the
    transmitted stream is unbiased up to one trailing residual.
    """
    comp = x.astype(jnp.float32) + err.astype(jnp.float32)
    x_hat = roundtrip(comp, fmt=fmt, tile=tile)
    return x_hat.astype(x.dtype), (comp - x_hat).astype(err.dtype)
