"""Pallas TPU kernels with pure-jnp oracles (``ref.py``) and jitted
dispatch wrappers (``ops.py``).

The quantized-transfer ops are exported at package level because the
distributed runtime (``runtime.pipeline`` / ``runtime.train``) calls them
on every boundary transfer and gradient bucket: they dispatch to the
Pallas kernels on TPU and to the bit-identical jnp oracles everywhere
else, so CPU CI (no GPU/TPU) exercises the exact wire numerics without a
hardware backend — ``quantize_tiles(..., interpret=True)`` remains
available for running the kernel bodies themselves off-TPU.
"""

from .quant_transfer import (QDIV, QUANT_FORMATS, dequantize_op,
                             dequantize_tiles, pack_tiles, quant_dtype,
                             quantize_op, quantize_tiles, roundtrip,
                             roundtrip_ef, unpack_tiles, wire_bits)

__all__ = ["QDIV", "QUANT_FORMATS", "dequantize_op", "dequantize_tiles",
           "pack_tiles", "quant_dtype", "quantize_op", "quantize_tiles",
           "roundtrip", "roundtrip_ef", "unpack_tiles", "wire_bits"]
