"""Pallas TPU kernel for the Mamba selective scan (chunked serial).

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t x_t) ⊗ B_t ;   y_t = h_t · C_t

The XLA-native path (`repro.models.ssm`) uses an associative scan whose
log-depth combine levels materialize (B, C, d_inner, N) intermediates —
the dominant HBM-traffic term for Jamba in the roofline analysis
(EXPERIMENTS.md §Perf iteration 3).  This kernel runs the recurrence
serially inside VMEM: grid = (batch, n_chunks), the (d, N) state lives in
scratch across the sequential chunk dimension, and each step's intermediates
never leave registers/VMEM — HBM traffic drops from O(S·d·N·log C) to
O(S·(d + N)) reads + O(S·d) writes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, o_ref, h_ref, *,
                 chunk: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...]                               # (d, N) = -exp(A_log)

    def step(i, h):
        dt_i = dt_ref[0, i]                      # (d,)
        x_i = x_ref[0, i]                        # (d,)
        b_i = b_ref[0, i]                        # (N,)
        c_i = c_ref[0, i]                        # (N,)
        decay = jnp.exp(dt_i[:, None] * a)       # (d, N)
        h = h * decay + (dt_i * x_i)[:, None] * b_i[None, :]
        o_ref[0, i] = jnp.sum(h * c_i[None, :], axis=1).astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(dt, b, c, x, a, *, chunk: int = 128, interpret: bool = False):
    """dt/x: (B, S, d) fp32; b/c: (B, S, N) fp32; a: (d, N) = -exp(A_log).

    Returns y: (B, S, d) fp32 (the C·h readout; the D·x skip and gating stay
    outside, as in the model layer)."""
    B, S, d = dt.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda i, t: (i, t, 0)),
            pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
            pl.BlockSpec((d, N), lambda i, t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d), lambda i, t: (i, t, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d, N), jnp.float32)],
        interpret=interpret,
    )(dt, b, c, x, a)
