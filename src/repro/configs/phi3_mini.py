"""Phi-3-mini 3.8B [arXiv:2404.14219].

32L, d_model=3072, 32 heads (kv=32, i.e. full MHA), SwiGLU d_ff=8192,
vocab=32064, RoPE.
"""

from repro.models import AttentionConfig, LayerSpec, ModelConfig

ARCH_ID = "phi3-mini-3.8b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=3072,
        vocab_size=32064,
        d_ff=8192,
        attn=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=96,
                             rope_theta=10000.0),
        pattern=(LayerSpec(kind="attn", mlp="mlp"),),
        act="silu",
        source="arXiv:2404.14219",
    )
