"""Per-layer cost tables for the paper's evaluation models.

The planner/simulator benchmarks reproduce the paper's tables with the same
four models: EfficientNet-B1, MobileNetV2, ResNet-50 (vision) and BERT-small
(language).  The CNNs are *cost tables* (exact per-block FLOPs/params/
activation sizes derived from the architecture definitions) — the JAX-
executable model zoo covers the ten assigned transformer architectures;
DESIGN.md records this split.

Inputs match the paper: CIFAR-10 3x32x32 for EfficientNet-B1/MobileNetV2,
Mini-ImageNet 3x224x224 for ResNet-50, and 512-token sequences for
BERT-small.
"""

from __future__ import annotations

from repro.core.profiler import ACT_BYTES, PARAM_BYTES, LayerCost, LayerTable


def _conv_cost(name, h, w, cin, cout, k, stride=1, groups=1):
    """Output activation is (cout, h/stride, w/stride)."""
    ho, wo = -(-h // stride), -(-w // stride)
    flops = 2.0 * ho * wo * cout * cin // groups * k * k
    params = cout * cin // groups * k * k + 2 * cout   # + BN
    act = cout * ho * wo * ACT_BYTES
    return LayerCost(name, flops, params * PARAM_BYTES, act), ho, wo


def _inverted_residual(name, h, w, cin, cout, expand, k, stride):
    """MobileNet/EfficientNet MBConv block as one planner layer."""
    mid = cin * expand
    flops = 0.0
    params = 0.0
    if expand != 1:
        flops += 2.0 * h * w * cin * mid            # 1x1 expand
        params += cin * mid + 2 * mid
    ho, wo = -(-h // stride), -(-w // stride)
    flops += 2.0 * ho * wo * mid * k * k            # depthwise
    params += mid * k * k + 2 * mid
    flops += 2.0 * ho * wo * mid * cout             # 1x1 project
    params += mid * cout + 2 * cout
    act = cout * ho * wo * ACT_BYTES
    return LayerCost(name, flops, params * PARAM_BYTES, act), ho, wo


def mobilenet_v2(input_hw: int = 32) -> LayerTable:
    """MobileNetV2 (width 1.0).  [Sandler et al., CVPR'18]"""
    cfg = [  # (expand, cout, n, stride)
        (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
        (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1),
    ]
    layers = []
    h = w = input_hw
    stem, h, w = _conv_cost("stem", h, w, 3, 32, 3, stride=2 if input_hw > 64 else 1)
    layers.append(stem)
    cin = 32
    for bi, (t, c, n, s) in enumerate(cfg):
        for i in range(n):
            blk, h, w = _inverted_residual(f"mb{bi}_{i}", h, w, cin, c, t, 3,
                                           s if i == 0 else 1)
            layers.append(blk)
            cin = c
    head, h, w = _conv_cost("head_conv", h, w, cin, 1280, 1)
    layers.append(head)
    fc = LayerCost("classifier", 2.0 * 1280 * 1000, 1280 * 1000 * PARAM_BYTES,
                   1000 * ACT_BYTES)
    layers.append(fc)
    return LayerTable("mobilenetv2", tuple(layers))


def efficientnet_b1(input_hw: int = 32) -> LayerTable:
    """EfficientNet-B1 (width 1.0, depth 1.1 on the B0 skeleton)."""
    b0 = [  # (expand, cout, n, stride, k)
        (1, 16, 1, 1, 3), (6, 24, 2, 2, 3), (6, 40, 2, 2, 5), (6, 80, 3, 2, 3),
        (6, 112, 3, 1, 5), (6, 192, 4, 2, 5), (6, 320, 1, 1, 3),
    ]
    import math
    depth = lambda n: int(math.ceil(n * 1.1))
    layers = []
    h = w = input_hw
    stem, h, w = _conv_cost("stem", h, w, 3, 32, 3, stride=2 if input_hw > 64 else 1)
    layers.append(stem)
    cin = 32
    for bi, (t, c, n, s, k) in enumerate(b0):
        for i in range(depth(n)):
            blk, h, w = _inverted_residual(f"mb{bi}_{i}", h, w, cin, c, t, k,
                                           s if i == 0 else 1)
            layers.append(blk)
            cin = c
    head, h, w = _conv_cost("head_conv", h, w, cin, 1280, 1)
    layers.append(head)
    layers.append(LayerCost("classifier", 2.0 * 1280 * 1000,
                            1280 * 1000 * PARAM_BYTES, 1000 * ACT_BYTES))
    return LayerTable("efficientnet-b1", tuple(layers))


def resnet50(input_hw: int = 224) -> LayerTable:
    """ResNet-50 bottleneck stacks [He et al., CVPR'16]."""
    stages = [(64, 256, 3, 1), (128, 512, 4, 2), (256, 1024, 6, 2),
              (512, 2048, 3, 2)]
    layers = []
    h = w = input_hw
    stem, h, w = _conv_cost("stem7x7", h, w, 3, 64, 7, stride=2)
    layers.append(stem)
    h, w = h // 2, w // 2     # maxpool
    cin = 64
    for si, (mid, cout, n, stride) in enumerate(stages):
        for i in range(n):
            s = stride if i == 0 else 1
            ho, wo = -(-h // s), -(-w // s)
            flops = (2.0 * h * w * cin * mid +
                     2.0 * ho * wo * mid * mid * 9 +
                     2.0 * ho * wo * mid * cout)
            params = cin * mid + mid * mid * 9 + mid * cout + 2 * (2 * mid + cout)
            if i == 0:
                flops += 2.0 * ho * wo * cin * cout     # projection shortcut
                params += cin * cout + 2 * cout
            act = cout * ho * wo * ACT_BYTES
            layers.append(LayerCost(f"res{si}_{i}", flops,
                                    params * PARAM_BYTES, act))
            h, w, cin = ho, wo, cout
    layers.append(LayerCost("classifier", 2.0 * 2048 * 1000,
                            2048 * 1000 * PARAM_BYTES, 1000 * ACT_BYTES))
    return LayerTable("resnet50", tuple(layers))


def bert_small(seq_len: int = 32) -> LayerTable:
    """BERT-small: 4 layers, d=512, 8 heads [Devlin et al.].

    The paper's synthetic input is 32x512 = (seq 32, hidden 512): short
    sequences make activations tiny relative to the 110 MB of parameters —
    exactly why its planner picks a straight pipeline for BERT."""
    from repro.models import AttentionConfig, LayerSpec, ModelConfig
    cfg = ModelConfig(name="bert-small", n_layers=4, d_model=512,
                      vocab_size=30522, d_ff=2048,
                      attn=AttentionConfig(n_heads=8, n_kv_heads=8, head_dim=64),
                      pattern=(LayerSpec(),))
    table = LayerTable.from_model_config(cfg, seq_len=seq_len)
    # the paper trains on synthetic data with a small task head (not a full
    # vocab LM head): swap the final layer for a CLS classifier
    cls = LayerCost("cls_head", 2.0 * 512 * 2, 512 * 2 * PARAM_BYTES,
                    2 * ACT_BYTES)
    return LayerTable("bert-small", table.layers[:-1] + (cls,))


def efficientnet_b1_fine(input_hw: int = 32) -> LayerTable:
    """EfficientNet-B1 at sub-block granularity (~80 planner layers),
    approximating the paper's 213-layer planning granularity (Table 7)."""
    coarse = efficientnet_b1(input_hw)
    layers = []
    for lc in coarse.layers:
        if lc.name.startswith("mb"):
            # split expand / depthwise / project thirds
            for i, frac in enumerate((0.45, 0.2, 0.35)):
                layers.append(LayerCost(f"{lc.name}.{i}", lc.flops_fwd * frac,
                                        lc.param_bytes * frac,
                                        lc.act_bytes))
        else:
            layers.append(lc)
    return LayerTable("efficientnet-b1-fine", tuple(layers))


PAPER_MODELS = {
    "efficientnet-b1": lambda: efficientnet_b1(32),
    "mobilenetv2": lambda: mobilenet_v2(32),
    "resnet50": lambda: resnet50(224),
    "bert-small": lambda: bert_small(32),
}

# global mini-batch sizes used in the paper's Table 4
PAPER_BATCH = {"efficientnet-b1": 2048, "mobilenetv2": 2048,
               "resnet50": 256, "bert-small": 2048}
