"""RWKV-6 "Finch" 7B [arXiv:2404.05892].

32L, d_model=4096 (attention-free), channel-mix d_ff=14336 (3.5x),
vocab=65536; data-dependent decay WKV6 time-mix, head_dim=64.
"""

from repro.models import LayerSpec, ModelConfig, RWKVConfig

ARCH_ID = "rwkv6-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        vocab_size=65536,
        d_ff=14336,
        rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32),
        pattern=(LayerSpec(kind="rwkv", mlp="rwkv_cm"),),
        source="arXiv:2404.05892",
    )
