"""Phi-3.5-MoE 42B (6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct].

32L, d_model=4096, 32 heads (GQA kv=8), expert d_ff=6400, vocab=32064,
16 experts top-2, every layer MoE.
"""

from repro.models import AttentionConfig, LayerSpec, ModelConfig, MoEConfig

ARCH_ID = "phi3.5-moe-42b-a6.6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=32,
        d_model=4096,
        vocab_size=32064,
        d_ff=6400,
        attn=AttentionConfig(n_heads=32, n_kv_heads=8, head_dim=128,
                             rope_theta=10000.0),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=6400, score_fn="softmax"),
        pattern=(LayerSpec(kind="attn", mlp="moe"),),
        act="silu",
        source="hf:microsoft/Phi-3.5-MoE-instruct",
    )
