"""InternVL2-2B [arXiv:2404.16821] — InternLM2-1.8B language backbone.

24L, d_model=2048, 16 heads (GQA kv=8), d_ff=8192, vocab=92553.
The InternViT-300M vision encoder + MLP projector are a stub: 256 patch
embeddings (1024-d) arrive precomputed and are projected into the prefix.
"""

from repro.models import AttentionConfig, LayerSpec, ModelConfig

ARCH_ID = "internvl2-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=24,
        d_model=2048,
        vocab_size=92553,
        d_ff=8192,
        attn=AttentionConfig(n_heads=16, n_kv_heads=8, head_dim=128,
                             rope_theta=10000.0),
        pattern=(LayerSpec(kind="attn", mlp="mlp"),),
        act="silu",
        prefix_len=256,              # stub ViT patch tokens
        source="arXiv:2404.16821",
    )
