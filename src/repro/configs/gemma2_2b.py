"""Gemma-2 2B [arXiv:2408.00118].

26L, d_model=2304, 8 heads (GQA kv=4), head_dim=256, GeGLU d_ff=9216,
vocab=256000.  Alternating local (sliding window 4096) and global attention,
attention logit softcap 50, final logit softcap 30, sandwich (post) norms,
tied embeddings.
"""

from repro.models import AttentionConfig, LayerSpec, ModelConfig

ARCH_ID = "gemma2-2b"
LOCAL_WINDOW = 4096


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=26,
        d_model=2304,
        vocab_size=256000,
        d_ff=9216,
        attn=AttentionConfig(n_heads=8, n_kv_heads=4, head_dim=256,
                             rope_theta=10000.0, softcap=50.0),
        pattern=(
            LayerSpec(kind="attn", mlp="mlp", window=LOCAL_WINDOW, full_attention=False),
            LayerSpec(kind="attn", mlp="mlp"),   # global
        ),
        act="gelu_tanh",
        logit_softcap=30.0,
        post_norms=True,
        zero_centered_norm=True,
        embed_scale=True,
        tie_embeddings=True,
        source="arXiv:2408.00118",
    )
