"""Jamba-1.5-Large 398B [arXiv:2403.19887, arXiv:2408.12570].

72L, d_model=8192, 64 heads (GQA kv=8), d_ff=24576, vocab=65536.
Hybrid Mamba+attention at 1:7 (one attention layer per 8-layer period) and
MoE (16 experts, top-2) on every other layer.
"""

from repro.models import (AttentionConfig, LayerSpec, MambaConfig, ModelConfig,
                          MoEConfig)

ARCH_ID = "jamba-1.5-large-398b"


def config() -> ModelConfig:
    # 8-layer period: attention at index 2 (interior placement, as in Jamba's
    # published block layout); MoE replaces the MLP on every other layer.
    pattern = tuple(
        LayerSpec(kind="attn" if i == 2 else "mamba",
                  mlp="moe" if i % 2 == 1 else "mlp")
        for i in range(8)
    )
    return ModelConfig(
        name=ARCH_ID,
        n_layers=72,
        d_model=8192,
        vocab_size=65536,
        d_ff=24576,
        attn=AttentionConfig(n_heads=64, n_kv_heads=8, head_dim=128,
                             rope_theta=10000.0),
        moe=MoEConfig(n_experts=16, top_k=2, d_ff=24576),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        pattern=pattern,
        source="arXiv:2403.19887",
    )
