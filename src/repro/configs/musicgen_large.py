"""MusicGen-large [arXiv:2306.05284] — decoder backbone over EnCodec tokens.

48L, d_model=2048, 32 heads (kv=32), d_ff=8192, vocab=2048 (EnCodec codebook
size), 4 codebooks with the delay interleaving pattern.  The EnCodec codec
and T5 text encoder are stubs: conditioning arrives as precomputed prefix
embeddings (see repro.models.frontend).
"""

from repro.models import AttentionConfig, LayerSpec, ModelConfig

ARCH_ID = "musicgen-large"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=48,
        d_model=2048,
        vocab_size=2048,
        d_ff=8192,
        attn=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=64,
                             rope_theta=10000.0),
        pattern=(LayerSpec(kind="attn", mlp="mlp"),),
        act="gelu",
        n_codebooks=4,
        prefix_len=64,               # stub text-conditioning prefix
        source="arXiv:2306.05284",
    )
