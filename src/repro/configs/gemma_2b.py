"""Gemma 2B [arXiv:2403.08295].

18L, d_model=2048, 8 heads with MQA (kv=1), head_dim=256, GeGLU d_ff=16384,
vocab=256000, tied embeddings, Gemma-style (1+w) RMSNorm, sqrt(d) embed scale.
"""

from repro.models import AttentionConfig, LayerSpec, ModelConfig

ARCH_ID = "gemma-2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=18,
        d_model=2048,
        vocab_size=256000,
        d_ff=16384,
        attn=AttentionConfig(n_heads=8, n_kv_heads=1, head_dim=256,
                             rope_theta=10000.0),
        pattern=(LayerSpec(kind="attn", mlp="mlp"),),
        act="gelu_tanh",            # GeGLU
        tie_embeddings=True,
        zero_centered_norm=True,
        embed_scale=True,
        source="arXiv:2403.08295",
    )
