"""DeepSeek-V3 671B [arXiv:2412.19437].

61L, d_model=7168, 128 heads with MLA (q_lora=1536, kv_lora=512,
qk_nope=128, qk_rope=64, v=128), MoE: 1 shared + 256 routed experts top-8
(sigmoid scores), expert d_ff=2048, vocab=129280, MTP depth 1.

Simplification noted in DESIGN.md: the real model's first 3 layers are
dense; here every layer is MoE so the body stays a uniform scan.
"""

from repro.models import (AttentionConfig, LayerSpec, MLAConfig, ModelConfig,
                          MoEConfig)

ARCH_ID = "deepseek-v3-671b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=61,
        d_model=7168,
        vocab_size=129280,
        d_ff=2048,
        attn=AttentionConfig(
            n_heads=128, n_kv_heads=128, head_dim=128, rope_theta=10000.0,
            mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                          qk_rope_dim=64, v_head_dim=128)),
        moe=MoEConfig(n_experts=256, top_k=8, d_ff=2048, n_shared_experts=1,
                      score_fn="sigmoid"),
        pattern=(LayerSpec(kind="attn", mlp="moe"),),
        mtp_depth=1,
        source="arXiv:2412.19437",
    )
