"""Shared helpers for architecture configs, incl. the smoke-test reducer."""

from __future__ import annotations

import dataclasses

from repro.models import (AttentionConfig, LayerSpec, MambaConfig, MLAConfig,
                          ModelConfig, MoEConfig, RWKVConfig)


def smoke_reduce(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: <=2 periods, d_model<=512, <=4 experts.

    Keeps the pattern (so hybrid/alternating structure is exercised) while
    shrinking every dimension for a CPU-speed forward/train step.
    """
    d_model = 256
    n_layers = len(cfg.pattern) * max(1, 2 // len(cfg.pattern))
    kw: dict = {
        "n_layers": n_layers,
        "d_model": d_model,
        "d_ff": 512,
        "vocab_size": min(cfg.vocab_size, 512),
        "param_dtype": "float32",
        "compute_dtype": "float32",
    }
    if cfg.attn is not None:
        a = cfg.attn
        n_heads = 4
        n_kv = max(1, min(a.n_kv_heads, n_heads * a.n_kv_heads // a.n_heads)) or 1
        mla = None
        if a.mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                            qk_rope_dim=16, v_head_dim=32)
        kw["attn"] = dataclasses.replace(
            a, n_heads=n_heads, n_kv_heads=max(n_kv, 1), head_dim=64,
            window=None if a.window is None else 64,
            mla=mla, q_chunk=64, kv_chunk=64)
        # shrink per-layer window overrides in the pattern
        kw["pattern"] = tuple(
            dataclasses.replace(s, window=None if s.window is None else 64)
            for s in cfg.pattern)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff=256)
    if cfg.mamba is not None:
        kw["mamba"] = dataclasses.replace(cfg.mamba, d_state=8, chunk=32)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(cfg.rwkv, head_dim=32, decay_lora=16,
                                         mix_lora=8, chunk=16)
    if cfg.prefix_len > 0:
        kw["prefix_len"] = 8
    return cfg.replace(**kw)
