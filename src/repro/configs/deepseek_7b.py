"""DeepSeek-LLM 7B [arXiv:2401.02954] — Llama architecture.

30L, d_model=4096, 32 heads (kv=32), SwiGLU d_ff=11008, vocab=102400.
"""

from repro.models import AttentionConfig, LayerSpec, ModelConfig

ARCH_ID = "deepseek-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        n_layers=30,
        d_model=4096,
        vocab_size=102400,
        d_ff=11008,
        attn=AttentionConfig(n_heads=32, n_kv_heads=32, head_dim=128,
                             rope_theta=10000.0),
        pattern=(LayerSpec(kind="attn", mlp="mlp"),),
        act="silu",
        source="arXiv:2401.02954",
    )
