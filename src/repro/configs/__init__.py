"""Architecture config registry: ``--arch <id>`` resolution.

Each module defines the exact published config (with source citation) for
one assigned architecture; ``smoke_reduce`` produces the reduced same-family
variant used by CPU smoke tests.
"""

from __future__ import annotations

from repro.models import ModelConfig

from . import (deepseek_7b, deepseek_v3, gemma2_2b, gemma_2b, internvl2_2b,
               jamba_1_5_large, musicgen_large, phi3_5_moe_42b, phi3_mini,
               rwkv6_7b)
from .common import smoke_reduce

_MODULES = (
    phi3_5_moe_42b, gemma_2b, rwkv6_7b, jamba_1_5_large, phi3_mini,
    musicgen_large, deepseek_v3, internvl2_2b, deepseek_7b, gemma2_2b,
)

ARCH_IDS: tuple[str, ...] = tuple(m.ARCH_ID for m in _MODULES)
_BY_ID = {m.ARCH_ID: m for m in _MODULES}

# architecture family tags (from the assignment)
FAMILY = {
    "phi3.5-moe-42b-a6.6b": "moe",
    "gemma-2b": "dense",
    "rwkv6-7b": "ssm",
    "jamba-1.5-large-398b": "hybrid",
    "phi3-mini-3.8b": "dense",
    "musicgen-large": "audio",
    "deepseek-v3-671b": "moe",
    "internvl2-2b": "vlm",
    "deepseek-7b": "dense",
    "gemma2-2b": "dense",
}

# archs allowed to run the long_500k decode shape (sub-quadratic path);
# see DESIGN.md §Arch-applicability for the skip rationale.
LONG_CONTEXT_OK = ("rwkv6-7b", "jamba-1.5-large-398b", "gemma2-2b")


def get_config(arch: str) -> ModelConfig:
    if arch not in _BY_ID:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_BY_ID)}")
    return _BY_ID[arch].config()


def get_smoke_config(arch: str) -> ModelConfig:
    return smoke_reduce(get_config(arch))
