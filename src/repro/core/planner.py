"""Algorithm 2: dynamic-programming HPP planning (+ baseline planners).

``Q(l, n, p)`` = HPP-Round latency of the optimal plan slicing the *last* l
layers into p stages across the *last* n devices (devices pre-sorted by
descending memory — earlier stages hold more activations, §3.3).  The
transition (Eq. 10) extends an optimal sub-pipeline with one new head stage
replicated over the remaining devices, re-evaluating the dominant step
(Eq. 11) and the full HPP-Round latency (Eqs. 4–6).

Baselines implemented for the paper's comparisons: pure DP (EDDL-style with
heterogeneous batch allocation), GPipe-style PP (compute-balanced, ignores
boundary activations), PipeDream / Dapple planners (homogeneous-cluster
assumptions, no memory budget), and a HetPipe-style HDP arrangement.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from functools import lru_cache

from .allocation import Allocation, AllocationError, allocate_microbatch
from .costmodel import (CompressionConfig, Step, allreduce_time,
                        bucketed_allreduce_residual,
                        compressed_allreduce_time, compressed_comm_time,
                        decode_boundary_time, decode_step_time,
                        dominant_index, hpp_round_latency, hpp_volume,
                        kp_policy, parse_compress, queue_wait_quantile,
                        round_latency, serve_stage_slots, stage_memory)
from .profiler import Profile


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One pipeline stage of a ``Plan``: the unit Algorithms 1+2 decide.

    ``alloc`` is Algorithm 1's heterogeneous intra-stage micro-batch split
    (Eq. 9 capacity-proportional, Eq. 3 memory-capped); ``k_p`` is the
    1F1B warm-up depth ``2(P-p)-1`` that bounds resident activations
    (Eq. 3, DESIGN.md §4).
    """

    layers: tuple[int, int]        # [i, j)
    group: tuple[int, ...]         # device ranks (into profile.cluster order)
    alloc: tuple[int, ...]         # micro-batch samples per device
    k_p: int                       # warm-up depth (2*(P-p)-1)


@dataclasses.dataclass(frozen=True)
class Plan:
    """A complete HPP training configuration (Algorithm 2 output).

    ``steps`` interleave exec and comm ``costmodel.Step``s in pipeline
    order; ``latency`` is the HPP-Round estimate from Eqs. (4)–(6) on the
    profile the plan was made with (``core.simulator.prediction_gap``
    re-prices it under another profile, e.g. measured).  Consumed by
    ``core.lowering.lower_plan`` (execution) and ``core.replay`` (failure
    recovery).
    """

    arch: str
    stages: tuple[StagePlan, ...]
    steps: tuple[Step, ...]
    micro_batch: int
    n_micro: int
    latency: float                 # predicted HPP-Round latency (s)
    planner: str = "asteroid"
    plan_time: float = 0.0
    # Gradient-sync semantics the plan was priced under: 0 = synchronous
    # rounds (Eq. 4 charges every AllReduce), 1 = bounded-stale overlap
    # (``costmodel.round_latency_async`` charges only un-hidden comm); the
    # runtime knob ``TrainSpec.staleness`` should match.
    staleness: int = 0
    # Compressed-transfer configuration the plan was priced under
    # (``costmodel.CompressionConfig`` or None = full-precision wire); the
    # runtime knobs ``TrainSpec.compress``/``quant_tile``/``bucket_mb``
    # should match.  ``dataclasses.replace``-based replay replans carry it
    # automatically; ``simulator.reprice_plan`` re-applies it.
    compress: CompressionConfig | None = None

    @property
    def global_batch(self) -> int:
        return self.micro_batch * self.n_micro

    @property
    def throughput(self) -> float:
        """Training throughput estimate (samples/s): B / T_round (Eq. 4)."""
        return self.global_batch / self.latency if self.latency > 0 else 0.0

    def memory_per_device(self, profile: Profile) -> dict[int, float]:
        """Eq. (3) peak bytes per device rank under this plan's K_p."""
        out = {}
        for st in self.stages:
            for d, y in zip(st.group, st.alloc):
                out[d] = stage_memory(profile.table, *st.layers, y, st.k_p,
                                      self.n_micro)
        return out

    def comm_volume(self, profile: Profile) -> float:
        """Eq. (2) for this plan."""
        sp = [profile.table.param_bytes(*st.layers) for st in self.stages]
        gs = [len(st.group) for st in self.stages]
        ba = [profile.table.boundary_act(st.layers[1])
              for st in self.stages[:-1]]
        return hpp_volume(sp, gs, ba, self.global_batch)


# ---------------------------------------------------------------------------
# Asteroid DP planner
# ---------------------------------------------------------------------------


def _group_flops(profile: Profile, group) -> float:
    return min(profile.cluster.devices[d].flops for d in group)


def _comm_step(profile: Profile, micro_batch: int, boundary_layer: int,
               g_left, g_right, compress=None) -> Step:
    """Inter-stage activation transfer: one micro-batch's boundary tensor
    over the slowest link between the two device groups.  Under
    compression the wire moves the quantized payload and each endpoint is
    charged its (de)quantization time (DESIGN.md §10) — both directions,
    since the custom VJP compresses the backward cotangent identically."""
    nbytes = micro_batch * profile.table.boundary_act(boundary_layer)
    bw = min(profile.cluster.bw(a, b) for a in g_left for b in g_right)
    t = compressed_comm_time(nbytes, bw, compress,
                             _group_flops(profile, g_left),
                             _group_flops(profile, g_right))
    return Step("comm", ef=t, eb=t)


def _stage_ta(profile: Profile, i: int, j: int, group, compress,
              backward_s: float) -> float:
    """Gradient-sync seconds charged to one stage: Eq. (5) over the
    (possibly compressed) gradient bytes, minus what DDP-style bucketed
    overlap hides behind the stage's own backward."""
    pb = profile.table.param_bytes(i, j)
    ta = compressed_allreduce_time(pb, group, profile.cluster, compress,
                                   _group_flops(profile, group))
    return bucketed_allreduce_residual(ta, backward_s, pb, compress)


def plan_hpp(profile: Profile, global_batch: int, micro_batch: int,
             max_stages: int | None = None, arch: str = "",
             check_memory: bool = True, intra_opt=True,
             allowed_stages=None, staleness: int = 0,
             compress=None) -> Plan:
    """Run Algorithm 2: DP over ``Q(l, n, p)`` with the Eq. 10 transition.

    Each candidate head stage is priced by Algorithm 1
    (``allocate_microbatch``: Eq. 8 lockstep stage time at the Eq. 9
    allocation, Eq. 3 memory-feasible given warm-up depth ``kp_policy``)
    and the extended pipeline re-evaluated with the full HPP-Round latency
    (Eqs. 4–6) rather than only the Eq. 11 dominant step.  ``profile`` may
    be analytic or measured — the DP only ever reads the prefix-sum time
    tables.

    ``allowed_stages``: optional collection restricting the final stage
    count (e.g. divisors of a runtime mesh's model axis, so the plan can be
    lowered — see ``core.lowering``).  ``intra_opt=False`` disables
    Algorithm 1 Phase 2 (straggler offloading) — the Fig. 15a ablation;
    ``intra_opt="auto"`` keeps Phase 2's heterogeneous allocation only when
    it strictly improves the predicted latency (a hetero allocation pads
    every data shard to B_max at runtime, so offloading with no predicted
    gain costs real throughput — the fig15a_runtime regression).

    ``staleness=1`` prices candidates with the two-stream overlapped round
    model (``costmodel.round_latency_async``): the gradient AllReduce
    leaves the critical path, which shifts stage cuts toward splits that
    balance the Execution Phase instead of amortizing T_a.

    ``compress``: None, 'int8'/'fp8', a ``costmodel.CompressionConfig``,
    or 'auto'.  A set format prices every boundary transfer and gradient
    AllReduce over the quantized wire (ratio + (de)quant endpoint cost —
    Algorithm 2's cuts then chase the cheaper links harder), and the
    resulting plan records the choice for the runtime and replay.
    'auto' is the error/time trade made explicit: price both, keep the
    compressed plan only when it is strictly faster — otherwise the
    quantization error buys nothing and full precision wins."""
    if compress == "auto":
        kw = dict(max_stages=max_stages, arch=arch, check_memory=check_memory,
                  intra_opt=intra_opt, allowed_stages=allowed_stages,
                  staleness=staleness)
        comp = plan_hpp(profile, global_batch, micro_batch,
                        compress="int8", **kw)
        base = plan_hpp(profile, global_batch, micro_batch,
                        compress=None, **kw)
        return comp if comp.latency < base.latency * (1.0 - 1e-9) else base
    compress = parse_compress(compress)
    if intra_opt == "auto":
        kw = dict(max_stages=max_stages, arch=arch, check_memory=check_memory,
                  allowed_stages=allowed_stages, staleness=staleness,
                  compress=compress)
        full = plan_hpp(profile, global_batch, micro_batch,
                        intra_opt=True, **kw)
        if all(len(set(st.alloc)) <= 1 for st in full.stages):
            return full                  # Phase 2 changed nothing
        base = plan_hpp(profile, global_batch, micro_batch,
                        intra_opt=False, **kw)
        return full if full.latency < base.latency * (1.0 - 1e-9) else base
    t_start = time.perf_counter()
    table = profile.table
    L, N = table.L, len(profile.cluster.devices)
    M = global_batch // micro_batch
    assert M >= 1, (global_batch, micro_batch)
    P_max = min(max_stages or N, N, L)

    @lru_cache(maxsize=None)
    def stage_eval(i: int, j: int, a: int, b: int, k_p: int) -> Allocation | None:
        """T(i->j, G) for device ranks [a, b) with warm-up depth k_p."""
        group = tuple(range(a, b))
        try:
            return allocate_microbatch(
                profile, group, micro_batch, i, j,
                k_p if check_memory else 0,
                block=max(1, micro_batch // 16), offload=intra_opt)
        except AllocationError:
            return None

    # Q[(l, n, p)] = (steps tuple, latency) ; l = layers from the end,
    # n = devices from the end.
    Q: dict[tuple[int, int, int], tuple[tuple[Step, ...], float]] = {}

    for p in range(1, P_max + 1):
        for n in range(p, N + 1):
            for l in range(p, L + 1):
                i = L - l                     # head stage starts at layer i
                best = None
                if p == 1:
                    alloc = stage_eval(i, L, N - n, N, kp_policy(1, 0))
                    if alloc is None:
                        continue
                    ta = _stage_ta(profile, i, L, tuple(range(N - n, N)),
                                   compress, alloc.eb * M)
                    steps = (Step("exec", alloc.ef, alloc.eb, ta,
                                  tuple(range(N - n, N)), (i, L), alloc.y),)
                    best = (steps, hpp_round_latency(steps, M, staleness))
                else:
                    for l2 in range(p - 1, l):        # sub-pipeline layer count
                        for n2 in range(p - 1, n):    # sub-pipeline device count
                            sub = Q.get((l2, n2, p - 1))
                            if sub is None:
                                continue
                            j = L - l2                # head stage covers [i, j)
                            a, b = N - n, N - n2      # head stage device ranks
                            alloc = stage_eval(i, j, a, b, kp_policy(p, 0))
                            if alloc is None:
                                continue
                            ta = _stage_ta(profile, i, j, tuple(range(a, b)),
                                           compress, alloc.eb * M)
                            head = Step("exec", alloc.ef, alloc.eb, ta,
                                        tuple(range(a, b)), (i, j), alloc.y)
                            comm = _comm_step(profile, micro_batch, j,
                                              tuple(range(a, b)), sub[0][0].group,
                                              compress)
                            steps = (head, comm) + sub[0]
                            lat = hpp_round_latency(steps, M, staleness)
                            if best is None or lat < best[1]:
                                best = (steps, lat)
                if best is not None:
                    Q[(l, n, p)] = best

    feasible = [p for p in range(1, P_max + 1) if (L, N, p) in Q]
    candidates = [(Q[(L, N, p)][1], p) for p in feasible
                  if allowed_stages is None or p in allowed_stages]
    if not candidates:
        if feasible:
            raise AllocationError(
                f"no feasible plan with allowed_stages={sorted(allowed_stages)} "
                f"(feasible stage counts: {feasible})")
        raise AllocationError("no feasible HPP plan (memory budgets too tight)")
    lat, p_best = min(candidates)
    steps = Q[(L, N, p_best)][0]
    stages = _stages_from_steps(steps, p_best)
    return Plan(arch, stages, steps, micro_batch, M, lat, "asteroid",
                time.perf_counter() - t_start, staleness=staleness,
                compress=compress)


def _stages_from_steps(steps, P: int) -> tuple[StagePlan, ...]:
    stages = []
    p = 0
    for st in steps:
        if st.kind == "exec":
            stages.append(StagePlan(st.layers, st.group, st.alloc,
                                    kp_policy(P, p)))
            p += 1
    return tuple(stages)


def replan_for_membership(profile: Profile, incumbent: Plan,
                          allowed_stages=None) -> Plan:
    """Full Algorithm-2 re-plan after a membership change, keeping the
    incumbent's batch geometry and gradient-sync semantics.

    This is the FTPipeHD-style fallback the membership controller reaches
    for when incremental candidates (``replay.admission_replay`` /
    ``replay.departure_replay``) are infeasible: ``profile`` is the
    cluster *after* the change (see ``profiler.extend_profile`` for
    joins), and every weight placement is up for grabs."""
    return plan_hpp(profile, incumbent.global_batch, incumbent.micro_batch,
                    arch=incumbent.arch, allowed_stages=allowed_stages,
                    staleness=getattr(incumbent, "staleness", 0),
                    compress=getattr(incumbent, "compress", None))


def auto_microbatch(profile: Profile, global_batch: int,
                    candidates=(1, 2, 4, 8, 16, 32, 64), arch: str = "",
                    **kw) -> Plan:
    """Sweep micro-batch sizes; return the fastest feasible plan.

    The paper fixes the micro-batch per experiment; this outer sweep makes
    the trade explicit — smaller micro-batches shrink bubbles (Eq. 6) but
    pay more per-layer launch overhead and lower batch efficiency
    (Fig. 6), and Eq. 3 memory feasibility can cut either way."""
    best = None
    for mb in candidates:
        if global_batch % mb:
            continue
        try:
            plan = plan_hpp(profile, global_batch, mb, arch=arch, **kw)
        except AllocationError:
            continue
        if best is None or plan.latency < best.latency:
            best = plan
    if best is None:
        raise AllocationError("no feasible plan for any micro-batch size")
    return best


# ---------------------------------------------------------------------------
# Baseline planners (paper's comparison systems)
# ---------------------------------------------------------------------------


def plan_dp(profile: Profile, global_batch: int, micro_batch: int,
            arch: str = "", heterogeneous: bool = True,
            overlap: bool = True) -> Plan:
    """Pure data parallelism (EDDL-style when heterogeneous=True) — the
    paper's DP baseline in Table 4 / Fig. 13.

    One stage spanning all layers on every device; latency is Eq. 4 with a
    single exec step and the Eq. 5 full-model AllReduce.  ``overlap``:
    DDP-style bucketed gradient AllReduce overlapped with the backward
    pass (the AllReduce only charges the part the backward can't hide) —
    without this the DP baseline would be unrealistically weak."""
    t0 = time.perf_counter()
    table = profile.table
    N = len(profile.cluster.devices)
    group = tuple(range(N))
    M = global_batch // micro_batch
    if heterogeneous:
        alloc = allocate_microbatch(profile, group, micro_batch, 0, table.L,
                                    k_p=1, block=max(1, micro_batch // 16))
    else:
        share = micro_batch // N
        y = [share] * N
        for r in range(micro_batch - share * N):
            y[r] += 1
        ef = max(profile.t_fwd(d, y[d], 0, table.L) for d in group)
        eb = max(profile.t_bwd(d, y[d], 0, table.L) for d in group)
        alloc = Allocation(tuple(y), ef, eb)
    ta = allreduce_time(table.param_bytes(0, table.L), group, profile.cluster)
    if overlap:
        ta = max(ta - alloc.eb * M, 0.1 * ta)
    steps = (Step("exec", alloc.ef, alloc.eb, ta, group, (0, table.L), alloc.y),)
    lat = round_latency(steps, M)
    stages = (StagePlan((0, table.L), group, alloc.y, 1),)
    return Plan(arch, stages, steps, micro_batch, M, lat,
                "eddl" if heterogeneous else "dp", time.perf_counter() - t0)


def plan_gpipe(profile: Profile, global_batch: int, micro_batch: int,
               arch: str = "", n_stages: int | None = None) -> Plan:
    """GPipe-style PP: equal-FLOPs contiguous split, one device per stage,
    ignores boundary activation sizes and device heterogeneity (the
    paper's PP baseline in Table 4) — its Eq. 11 dominant step is whatever
    stage happens to land on the slowest device."""
    t0 = time.perf_counter()
    table = profile.table
    N = len(profile.cluster.devices)
    P = n_stages or N
    M = global_batch // micro_batch
    total = table.flops(0, table.L)
    cuts, acc, target = [0], 0.0, total / P
    for li in range(table.L):
        acc += table.layers[li].flops_fwd
        if acc >= target * len(cuts) and len(cuts) < P:
            cuts.append(li + 1)
    while len(cuts) < P + 1:
        cuts.append(table.L)
    cuts[-1] = table.L

    steps = []
    stages = []
    for p in range(P):
        i, j = cuts[p], cuts[p + 1]
        d = p  # device rank p
        ef = profile.t_fwd(d, micro_batch, i, j)
        eb = profile.t_bwd(d, micro_batch, i, j)
        steps.append(Step("exec", ef, eb, 0.0, (d,), (i, j), (micro_batch,)))
        stages.append(StagePlan((i, j), (d,), (micro_batch,), kp_policy(P, p)))
        if p < P - 1:
            steps.append(_comm_step(profile, micro_batch, j, (d,), (d + 1,)))
    lat = round_latency(tuple(steps), M)
    return Plan(arch, tuple(stages), tuple(steps), micro_batch, M, lat,
                "gpipe", time.perf_counter() - t0)


def plan_homogeneous_hpp(profile: Profile, global_batch: int, micro_batch: int,
                         arch: str = "", include_allreduce: bool = False,
                         name: str = "pipedream") -> Plan:
    """PipeDream / Dapple-style planning: treats the cluster as homogeneous
    (mean capacity), ignores per-device memory budgets; Dapple additionally
    models the synchronous AllReduce cost (include_allreduce=True).

    The chosen configuration is then re-priced on the REAL heterogeneous
    profile (Eq. 8 at the actual device times) — the gap between the two
    is what deploying a homogeneity-assuming plan costs (Fig. 13)."""
    import numpy as np

    from .hardware import Cluster, DeviceProfile

    t0 = time.perf_counter()
    devs = profile.cluster.devices
    mean_flops = float(np.mean([d.flops for d in devs]))
    mean_mem = float(np.mean([d.mem_bytes for d in devs]))
    homog = Cluster(tuple(
        DeviceProfile(f"homog{i}", mem_bytes=mean_mem, flops=mean_flops,
                      sat_batch=devs[i].sat_batch, overhead=devs[i].overhead)
        for i in range(len(devs))), profile.cluster.bandwidth,
        profile.cluster.bw_matrix)
    homog_profile = Profile.analytic(profile.table, homog, profile.max_batch)

    plan = plan_hpp(homog_profile, global_batch, micro_batch, arch=arch,
                    check_memory=False)
    # Re-evaluate the chosen configuration on the REAL cluster (this is what
    # deploying a homogeneity-assuming plan on heterogeneous devices costs).
    steps = []
    for st in plan.steps:
        if st.kind == "comm":
            steps.append(st)
            continue
        i, j = st.layers
        ef = max(profile.t_fwd(d, y, i, j) for d, y in zip(st.group, st.alloc))
        eb = max(profile.t_bwd(d, y, i, j) for d, y in zip(st.group, st.alloc))
        # Dapple charges the synchronous AllReduce re-priced on the real
        # devices; PipeDream's async weight updates keep it off the round's
        # critical path entirely.
        ta = (_stage_ta(profile, i, j, st.group, None, eb * plan.n_micro)
              if include_allreduce else 0.0)
        steps.append(Step("exec", ef, eb, ta, st.group, st.layers, st.alloc))
    lat = round_latency(tuple(steps), plan.n_micro)
    return Plan(arch, plan.stages, tuple(steps), micro_batch, plan.n_micro,
                lat, name, time.perf_counter() - t0)


def plan_hetpipe_hdp(profile: Profile, global_batch: int, micro_batch: int,
                     arch: str = "", n_groups: int = 2):
    """HetPipe-style HDP: devices split into virtual workers (intra-group PP,
    inter-group DP through a parameter server).  Returns (per-round latency,
    comm volume per Eq. 1) for the Table 2 comm-volume comparison — the
    bidirectional full-model PS sync is the term Eq. 2's HPP avoids."""
    from .costmodel import hdp_volume

    table = profile.table
    N = len(profile.cluster.devices)
    n_groups = min(n_groups, N)
    ranks = list(range(N))
    groups = [tuple(ranks[i::n_groups]) for i in range(n_groups)]
    batches = [global_batch // n_groups] * n_groups
    batches[0] += global_batch - sum(batches)

    # per-group pipeline: equal-FLOPs split over group devices
    lat = 0.0
    vol_groups = []
    for g, beta in zip(groups, batches):
        sub = plan_gpipe_sub(profile, g, beta, micro_batch)
        lat = max(lat, sub)
        bounds = [table.boundary_act(table.L * (k + 1) // len(g))
                  for k in range(len(g) - 1)]
        vol_groups.append({"batch": beta, "act_bytes": bounds})
    # PS bidirectional full-model sync through the slowest link
    p_bytes = table.param_bytes(0, table.L)
    ps_time = 2.0 * p_bytes / profile.cluster.bandwidth if n_groups > 1 else 0.0
    lat += ps_time
    vol = hdp_volume(p_bytes, vol_groups)
    return lat, vol


def plan_gpipe_sub(profile: Profile, group, global_batch: int,
                   micro_batch: int) -> float:
    """Round latency of an equal-FLOPs pipeline over a device subset."""
    table = profile.table
    P = len(group)
    M = max(1, global_batch // micro_batch)
    total = table.flops(0, table.L)
    cuts, acc, target = [0], 0.0, total / P
    for li in range(table.L):
        acc += table.layers[li].flops_fwd
        if acc >= target * len(cuts) and len(cuts) < P:
            cuts.append(li + 1)
    while len(cuts) < P + 1:
        cuts.append(table.L)
    cuts[-1] = table.L
    steps = []
    for p in range(P):
        i, j = cuts[p], cuts[p + 1]
        d = group[p]
        steps.append(Step("exec", profile.t_fwd(d, micro_batch, i, j),
                          profile.t_bwd(d, micro_batch, i, j), 0.0, (d,),
                          (i, j), (micro_batch,)))
        if p < P - 1:
            steps.append(_comm_step(profile, micro_batch, j, (d,), (group[p + 1],)))
    return round_latency(tuple(steps), M)


# ---------------------------------------------------------------------------
# Serve-mode planning (DESIGN.md §11): stage/tp/split candidates priced by
# predicted per-token latency percentiles under a target offered load
# ---------------------------------------------------------------------------


def serve_stage_candidates(model_axis: int, n_heads: int) -> list[int]:
    """Lowerable stage counts for decode: every divisor of ``model_axis``
    whose tensor-parallel width divides the query head count.

    Replaces the old hard-coded {1, 2, 4, 8, 16} probe — a 6-device model
    axis now yields (1, 2, 3, 6) instead of falling through to the
    worst case.  Smallest-first: serve prefers TP (stage=1) when feasible.
    """
    out = [s for s in range(1, model_axis + 1)
           if model_axis % s == 0 and n_heads % (model_axis // s) == 0]
    return out or [model_axis]


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """A planner-driven decode configuration (the serving analogue of
    ``Plan``): mesh refinement (stage × tp), the heterogeneous slot split
    across data shards, and the latency percentiles it was priced at.

    Consumed by ``runtime.serve.build_slot_serve_step`` (``stage`` +
    ``shard_alloc``) and ``runtime.continuous.ContinuousBatcher``
    (``shard_alloc`` + ``cache_len`` as the admission-control cap).
    """

    arch: str
    stage: int
    tp: int
    cuts: tuple[int, ...]           # layer cut points, len == stage + 1
    shard_alloc: tuple[int, ...]    # decode slots per dp shard (unbalanced)
    max_slots: tuple[int, ...]      # per-shard admission cap (memory model)
    cache_len: int
    seq_len: int                    # profile row the per-token times divide
    arrival_rate: float             # offered load priced against (tokens/s)
    step_time: float                # engine-step service period (s)
    token_latency: float            # one token's pipeline traversal (s)
    predicted_p50: float
    predicted_p95: float
    predicted_p99: float
    planner: str = "asteroid-serve"
    plan_time: float = 0.0
    compress: CompressionConfig | None = None

    @property
    def slots(self) -> int:
        return sum(self.shard_alloc)

    @property
    def throughput(self) -> float:
        """Decode capacity (tokens/s): every engine step retires one token
        from each live slot."""
        return self.slots / self.step_time if self.step_time > 0 else 0.0

    @property
    def utilization(self) -> float:
        return self.arrival_rate / self.throughput if self.throughput else float("inf")


def _serve_cuts(L: int, stage: int) -> tuple[int, ...]:
    """Equal contiguous layer split — what the serve runtime lowers (periods
    padded to the stage count and divided evenly)."""
    return tuple(round(p * L / stage) for p in range(stage + 1))


def _shard_stage_groups(shard: int, model_axis: int, stage: int,
                        tp: int) -> list[tuple[int, ...]]:
    """Device ranks of each pipeline stage of one dp shard: shards occupy
    consecutive ``model_axis``-sized blocks of the cluster order, stages
    consecutive ``tp``-sized sub-blocks."""
    base = shard * model_axis
    return [tuple(range(base + p * tp, base + (p + 1) * tp))
            for p in range(stage)]


def _price_serve_shard(profile: Profile, shard: int, y: int, *, stage: int,
                       tp: int, cuts, seq_len: int, compress,
                       pipelined: bool) -> tuple[float, float]:
    """(service period, token traversal latency) of one dp shard running
    ``y`` decode slots through its stage × tp device block.

    Stage compute is the measured per-token forward slice divided by the
    tensor-parallel width (TP collectives are not charged — decode moments
    are bandwidth-bound on the boundary hops, not the intra-stage psum);
    boundary hops move one token's activation under the §10 link model.
    When the runtime group-streams the local batch (``pipelined``), stages
    overlap across groups and the service period is the slowest step; the
    traversal latency always sums the full path.
    """
    groups = _shard_stage_groups(shard, model_axis=stage * tp, stage=stage,
                                 tp=tp)
    table = profile.table
    comp, hops = [], []
    for p in range(stage):
        i, j = cuts[p], cuts[p + 1]
        t = max(decode_step_time(profile, d, y, i, j, seq_len)
                for d in groups[p]) / tp
        comp.append(t)
        if p < stage - 1:
            bw = min(profile.cluster.bw(a, b)
                     for a in groups[p] for b in groups[p + 1])
            hops.append(decode_boundary_time(
                table, j, y, seq_len, bw, compress,
                _group_flops(profile, groups[p]),
                _group_flops(profile, groups[p + 1])))
    token_latency = sum(comp) + sum(hops)
    period = max(comp + hops) if (pipelined and stage > 1) else token_latency
    return period, token_latency


def _serve_percentiles(step_time: float, token_latency: float, slots: int,
                       arrival_rate: float, levels=(0.5, 0.95, 0.99)):
    """M/M/1 tail on the aggregate service rate: a token waits for a free
    slot, then traverses the pipeline once."""
    if step_time <= 0 or slots <= 0:
        return tuple(float("inf") for _ in levels)
    mu = slots / step_time
    return tuple(token_latency + queue_wait_quantile(arrival_rate, mu, p)
                 for p in levels)


def _shard_slot_cap(profile: Profile, shard: int, *, stage: int, tp: int,
                    cuts, cache_len: int, seq_len: int,
                    mem_fraction: float) -> int:
    """Admission-control cap for one dp shard: every stage must fit its
    params plus the per-slot cache slice (both 1/tp per device)."""
    groups = _shard_stage_groups(shard, model_axis=stage * tp, stage=stage,
                                 tp=tp)
    cap = profile.max_batch
    for p in range(stage):
        i, j = cuts[p], cuts[p + 1]
        mem = min(profile.cluster.devices[d].mem_bytes for d in groups[p])
        cap = min(cap, serve_stage_slots(profile.table, i, j, mem * tp,
                                         cache_len, seq_len,
                                         mem_fraction=mem_fraction))
    return max(cap, 0)


def _price_serve_alloc(profile, alloc, *, stage, tp, cuts, seq_len,
                       arrival_rate, compress, pipelined=True):
    """(step_time, token_latency, (p50, p95, p99)) for a full slot split."""
    periods, lats = [], []
    for g, y in enumerate(alloc):
        if y <= 0:
            continue
        per, lat = _price_serve_shard(profile, g, y, stage=stage, tp=tp,
                                      cuts=cuts, seq_len=seq_len,
                                      compress=compress, pipelined=pipelined)
        periods.append(per)
        lats.append(lat)
    if not periods:
        inf = float("inf")
        return inf, inf, (inf, inf, inf)
    # SPMD lockstep: one jitted step advances every shard concurrently, so
    # the engine period is the slowest shard's; a token's traversal is its
    # own shard's path but the planner reports the worst case.
    step_time = max(periods)
    token_latency = max(lats)
    pct = _serve_percentiles(step_time, token_latency, sum(alloc),
                             arrival_rate)
    return step_time, token_latency, pct


def plan_serve(profile: Profile, arrival_rate: float, *, dp_shards: int,
               model_axis: int, n_heads: int, cache_len: int, seq_len: int,
               arch: str = "", compress=None, mem_fraction: float = 0.9,
               allowed_stages=None, uniform: bool = False,
               legacy_stage_probe: bool = False) -> ServePlan:
    """Serve-mode Algorithm 2: enumerate (stage, tp, slot split) candidates
    and keep the one minimizing predicted per-token p99 latency under the
    offered load.

    For each lowerable stage count (divisors of ``model_axis`` whose tp
    divides the head count) the slot split across dp shards is grown
    greedily — each new slot goes to the shard that minimizes the resulting
    p99 — under the Eq.-3-style admission cap (params + slots × per-token
    cache per device).  Faster shards absorb more slots: the serving
    analogue of Algorithm 1's capacity-proportional micro-batch split.

    ``uniform=True`` restricts the split to equal per-shard counts (the
    pre-planner baseline the bench compares against);
    ``legacy_stage_probe=True`` additionally restores the old
    {1, 2, 4, 8, 16} stage sweep.
    """
    t0 = time.perf_counter()
    compress = parse_compress(compress)
    if legacy_stage_probe:
        cands = [s for s in (1, 2, 4, 8, 16)
                 if model_axis % s == 0 and n_heads % (model_axis // s) == 0]
        cands = cands[:1] or [model_axis]
    else:
        cands = serve_stage_candidates(model_axis, n_heads)
    if allowed_stages is not None:
        cands = [s for s in cands if s in allowed_stages] or cands
    n_dev = len(profile.cluster.devices)
    if dp_shards * model_axis > n_dev:
        raise AllocationError(
            f"serve mesh needs {dp_shards * model_axis} devices, cluster "
            f"has {n_dev}")

    best = None
    for stage in cands:
        tp = model_axis // stage
        cuts = _serve_cuts(profile.table.L, stage)
        caps = [_shard_slot_cap(profile, g, stage=stage, tp=tp, cuts=cuts,
                                cache_len=cache_len, seq_len=seq_len,
                                mem_fraction=mem_fraction)
                for g in range(dp_shards)]
        if sum(caps) == 0:
            continue
        price = lambda a: _price_serve_alloc(
            profile, a, stage=stage, tp=tp, cuts=cuts, seq_len=seq_len,
            arrival_rate=arrival_rate, compress=compress)
        if uniform:
            cap = min(c for c in caps)
            cand_alloc, cand_cost = None, None
            for y in range(1, cap + 1):
                alloc = [y] * dp_shards
                st, lat, pct = price(alloc)
                if cand_cost is None or pct[2] < cand_cost[2][2]:
                    cand_alloc, cand_cost = alloc, (st, lat, pct)
            if cand_alloc is None:
                continue
            alloc, (st, lat, pct) = cand_alloc, cand_cost
        else:
            alloc = [0] * dp_shards
            st, lat, pct = price(alloc)
            while True:
                step = None
                for g in range(dp_shards):
                    if alloc[g] >= caps[g]:
                        continue
                    trial = list(alloc)
                    trial[g] += 1
                    cost = price(trial)
                    if step is None or cost[2][2] < step[1][2][2]:
                        step = (trial, cost)
                if step is None:
                    break
                trial, cost = step
                if cost[2][2] >= pct[2] and pct[2] < float("inf"):
                    break                     # adding slots no longer helps
                alloc, (st, lat, pct) = trial, cost
            if sum(alloc) == 0:
                continue
        plan = ServePlan(
            arch=arch, stage=stage, tp=tp, cuts=cuts,
            shard_alloc=tuple(alloc), max_slots=tuple(caps),
            cache_len=cache_len, seq_len=seq_len,
            arrival_rate=arrival_rate, step_time=st,
            token_latency=lat, predicted_p50=pct[0], predicted_p95=pct[1],
            predicted_p99=pct[2],
            planner="uniform-serve" if uniform else "asteroid-serve",
            compress=compress)
        if best is None or plan.predicted_p99 < best.predicted_p99:
            best = plan
    if best is None:
        raise AllocationError("no feasible serve plan (memory caps exhaust "
                              "every stage candidate)")
    return dataclasses.replace(best, plan_time=time.perf_counter() - t0)


def plan_serve_uniform(profile: Profile, arrival_rate: float,
                       **kw) -> ServePlan:
    """The pre-planner baseline: legacy power-of-two stage probe and an
    equal slot count on every dp shard."""
    return plan_serve(profile, arrival_rate, uniform=True,
                      legacy_stage_probe=True, **kw)
