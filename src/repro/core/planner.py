"""Algorithm 2: dynamic-programming HPP planning (+ baseline planners).

``Q(l, n, p)`` = HPP-Round latency of the optimal plan slicing the *last* l
layers into p stages across the *last* n devices (devices pre-sorted by
descending memory — earlier stages hold more activations, §3.3).  The
transition (Eq. 10) extends an optimal sub-pipeline with one new head stage
replicated over the remaining devices, re-evaluating the dominant step
(Eq. 11) and the full HPP-Round latency (Eqs. 4–6).

Baselines implemented for the paper's comparisons: pure DP (EDDL-style with
heterogeneous batch allocation), GPipe-style PP (compute-balanced, ignores
boundary activations), PipeDream / Dapple planners (homogeneous-cluster
assumptions, no memory budget), and a HetPipe-style HDP arrangement.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from functools import lru_cache

from .allocation import Allocation, AllocationError, allocate_microbatch
from .costmodel import (CompressionConfig, Step, allreduce_time,
                        bucketed_allreduce_residual,
                        compressed_allreduce_time, compressed_comm_time,
                        dominant_index, hpp_round_latency, hpp_volume,
                        kp_policy, parse_compress, round_latency,
                        stage_memory)
from .profiler import Profile


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One pipeline stage of a ``Plan``: the unit Algorithms 1+2 decide.

    ``alloc`` is Algorithm 1's heterogeneous intra-stage micro-batch split
    (Eq. 9 capacity-proportional, Eq. 3 memory-capped); ``k_p`` is the
    1F1B warm-up depth ``2(P-p)-1`` that bounds resident activations
    (Eq. 3, DESIGN.md §4).
    """

    layers: tuple[int, int]        # [i, j)
    group: tuple[int, ...]         # device ranks (into profile.cluster order)
    alloc: tuple[int, ...]         # micro-batch samples per device
    k_p: int                       # warm-up depth (2*(P-p)-1)


@dataclasses.dataclass(frozen=True)
class Plan:
    """A complete HPP training configuration (Algorithm 2 output).

    ``steps`` interleave exec and comm ``costmodel.Step``s in pipeline
    order; ``latency`` is the HPP-Round estimate from Eqs. (4)–(6) on the
    profile the plan was made with (``core.simulator.prediction_gap``
    re-prices it under another profile, e.g. measured).  Consumed by
    ``core.lowering.lower_plan`` (execution) and ``core.replay`` (failure
    recovery).
    """

    arch: str
    stages: tuple[StagePlan, ...]
    steps: tuple[Step, ...]
    micro_batch: int
    n_micro: int
    latency: float                 # predicted HPP-Round latency (s)
    planner: str = "asteroid"
    plan_time: float = 0.0
    # Gradient-sync semantics the plan was priced under: 0 = synchronous
    # rounds (Eq. 4 charges every AllReduce), 1 = bounded-stale overlap
    # (``costmodel.round_latency_async`` charges only un-hidden comm); the
    # runtime knob ``TrainSpec.staleness`` should match.
    staleness: int = 0
    # Compressed-transfer configuration the plan was priced under
    # (``costmodel.CompressionConfig`` or None = full-precision wire); the
    # runtime knobs ``TrainSpec.compress``/``quant_tile``/``bucket_mb``
    # should match.  ``dataclasses.replace``-based replay replans carry it
    # automatically; ``simulator.reprice_plan`` re-applies it.
    compress: CompressionConfig | None = None

    @property
    def global_batch(self) -> int:
        return self.micro_batch * self.n_micro

    @property
    def throughput(self) -> float:
        """Training throughput estimate (samples/s): B / T_round (Eq. 4)."""
        return self.global_batch / self.latency if self.latency > 0 else 0.0

    def memory_per_device(self, profile: Profile) -> dict[int, float]:
        """Eq. (3) peak bytes per device rank under this plan's K_p."""
        out = {}
        for st in self.stages:
            for d, y in zip(st.group, st.alloc):
                out[d] = stage_memory(profile.table, *st.layers, y, st.k_p,
                                      self.n_micro)
        return out

    def comm_volume(self, profile: Profile) -> float:
        """Eq. (2) for this plan."""
        sp = [profile.table.param_bytes(*st.layers) for st in self.stages]
        gs = [len(st.group) for st in self.stages]
        ba = [profile.table.boundary_act(st.layers[1])
              for st in self.stages[:-1]]
        return hpp_volume(sp, gs, ba, self.global_batch)


# ---------------------------------------------------------------------------
# Asteroid DP planner
# ---------------------------------------------------------------------------


def _group_flops(profile: Profile, group) -> float:
    return min(profile.cluster.devices[d].flops for d in group)


def _comm_step(profile: Profile, micro_batch: int, boundary_layer: int,
               g_left, g_right, compress=None) -> Step:
    """Inter-stage activation transfer: one micro-batch's boundary tensor
    over the slowest link between the two device groups.  Under
    compression the wire moves the quantized payload and each endpoint is
    charged its (de)quantization time (DESIGN.md §10) — both directions,
    since the custom VJP compresses the backward cotangent identically."""
    nbytes = micro_batch * profile.table.boundary_act(boundary_layer)
    bw = min(profile.cluster.bw(a, b) for a in g_left for b in g_right)
    t = compressed_comm_time(nbytes, bw, compress,
                             _group_flops(profile, g_left),
                             _group_flops(profile, g_right))
    return Step("comm", ef=t, eb=t)


def _stage_ta(profile: Profile, i: int, j: int, group, compress,
              backward_s: float) -> float:
    """Gradient-sync seconds charged to one stage: Eq. (5) over the
    (possibly compressed) gradient bytes, minus what DDP-style bucketed
    overlap hides behind the stage's own backward."""
    pb = profile.table.param_bytes(i, j)
    ta = compressed_allreduce_time(pb, group, profile.cluster, compress,
                                   _group_flops(profile, group))
    return bucketed_allreduce_residual(ta, backward_s, pb, compress)


def plan_hpp(profile: Profile, global_batch: int, micro_batch: int,
             max_stages: int | None = None, arch: str = "",
             check_memory: bool = True, intra_opt=True,
             allowed_stages=None, staleness: int = 0,
             compress=None) -> Plan:
    """Run Algorithm 2: DP over ``Q(l, n, p)`` with the Eq. 10 transition.

    Each candidate head stage is priced by Algorithm 1
    (``allocate_microbatch``: Eq. 8 lockstep stage time at the Eq. 9
    allocation, Eq. 3 memory-feasible given warm-up depth ``kp_policy``)
    and the extended pipeline re-evaluated with the full HPP-Round latency
    (Eqs. 4–6) rather than only the Eq. 11 dominant step.  ``profile`` may
    be analytic or measured — the DP only ever reads the prefix-sum time
    tables.

    ``allowed_stages``: optional collection restricting the final stage
    count (e.g. divisors of a runtime mesh's model axis, so the plan can be
    lowered — see ``core.lowering``).  ``intra_opt=False`` disables
    Algorithm 1 Phase 2 (straggler offloading) — the Fig. 15a ablation;
    ``intra_opt="auto"`` keeps Phase 2's heterogeneous allocation only when
    it strictly improves the predicted latency (a hetero allocation pads
    every data shard to B_max at runtime, so offloading with no predicted
    gain costs real throughput — the fig15a_runtime regression).

    ``staleness=1`` prices candidates with the two-stream overlapped round
    model (``costmodel.round_latency_async``): the gradient AllReduce
    leaves the critical path, which shifts stage cuts toward splits that
    balance the Execution Phase instead of amortizing T_a.

    ``compress``: None, 'int8'/'fp8', a ``costmodel.CompressionConfig``,
    or 'auto'.  A set format prices every boundary transfer and gradient
    AllReduce over the quantized wire (ratio + (de)quant endpoint cost —
    Algorithm 2's cuts then chase the cheaper links harder), and the
    resulting plan records the choice for the runtime and replay.
    'auto' is the error/time trade made explicit: price both, keep the
    compressed plan only when it is strictly faster — otherwise the
    quantization error buys nothing and full precision wins."""
    if compress == "auto":
        kw = dict(max_stages=max_stages, arch=arch, check_memory=check_memory,
                  intra_opt=intra_opt, allowed_stages=allowed_stages,
                  staleness=staleness)
        comp = plan_hpp(profile, global_batch, micro_batch,
                        compress="int8", **kw)
        base = plan_hpp(profile, global_batch, micro_batch,
                        compress=None, **kw)
        return comp if comp.latency < base.latency * (1.0 - 1e-9) else base
    compress = parse_compress(compress)
    if intra_opt == "auto":
        kw = dict(max_stages=max_stages, arch=arch, check_memory=check_memory,
                  allowed_stages=allowed_stages, staleness=staleness,
                  compress=compress)
        full = plan_hpp(profile, global_batch, micro_batch,
                        intra_opt=True, **kw)
        if all(len(set(st.alloc)) <= 1 for st in full.stages):
            return full                  # Phase 2 changed nothing
        base = plan_hpp(profile, global_batch, micro_batch,
                        intra_opt=False, **kw)
        return full if full.latency < base.latency * (1.0 - 1e-9) else base
    t_start = time.perf_counter()
    table = profile.table
    L, N = table.L, len(profile.cluster.devices)
    M = global_batch // micro_batch
    assert M >= 1, (global_batch, micro_batch)
    P_max = min(max_stages or N, N, L)

    @lru_cache(maxsize=None)
    def stage_eval(i: int, j: int, a: int, b: int, k_p: int) -> Allocation | None:
        """T(i->j, G) for device ranks [a, b) with warm-up depth k_p."""
        group = tuple(range(a, b))
        try:
            return allocate_microbatch(
                profile, group, micro_batch, i, j,
                k_p if check_memory else 0,
                block=max(1, micro_batch // 16), offload=intra_opt)
        except AllocationError:
            return None

    # Q[(l, n, p)] = (steps tuple, latency) ; l = layers from the end,
    # n = devices from the end.
    Q: dict[tuple[int, int, int], tuple[tuple[Step, ...], float]] = {}

    for p in range(1, P_max + 1):
        for n in range(p, N + 1):
            for l in range(p, L + 1):
                i = L - l                     # head stage starts at layer i
                best = None
                if p == 1:
                    alloc = stage_eval(i, L, N - n, N, kp_policy(1, 0))
                    if alloc is None:
                        continue
                    ta = _stage_ta(profile, i, L, tuple(range(N - n, N)),
                                   compress, alloc.eb * M)
                    steps = (Step("exec", alloc.ef, alloc.eb, ta,
                                  tuple(range(N - n, N)), (i, L), alloc.y),)
                    best = (steps, hpp_round_latency(steps, M, staleness))
                else:
                    for l2 in range(p - 1, l):        # sub-pipeline layer count
                        for n2 in range(p - 1, n):    # sub-pipeline device count
                            sub = Q.get((l2, n2, p - 1))
                            if sub is None:
                                continue
                            j = L - l2                # head stage covers [i, j)
                            a, b = N - n, N - n2      # head stage device ranks
                            alloc = stage_eval(i, j, a, b, kp_policy(p, 0))
                            if alloc is None:
                                continue
                            ta = _stage_ta(profile, i, j, tuple(range(a, b)),
                                           compress, alloc.eb * M)
                            head = Step("exec", alloc.ef, alloc.eb, ta,
                                        tuple(range(a, b)), (i, j), alloc.y)
                            comm = _comm_step(profile, micro_batch, j,
                                              tuple(range(a, b)), sub[0][0].group,
                                              compress)
                            steps = (head, comm) + sub[0]
                            lat = hpp_round_latency(steps, M, staleness)
                            if best is None or lat < best[1]:
                                best = (steps, lat)
                if best is not None:
                    Q[(l, n, p)] = best

    feasible = [p for p in range(1, P_max + 1) if (L, N, p) in Q]
    candidates = [(Q[(L, N, p)][1], p) for p in feasible
                  if allowed_stages is None or p in allowed_stages]
    if not candidates:
        if feasible:
            raise AllocationError(
                f"no feasible plan with allowed_stages={sorted(allowed_stages)} "
                f"(feasible stage counts: {feasible})")
        raise AllocationError("no feasible HPP plan (memory budgets too tight)")
    lat, p_best = min(candidates)
    steps = Q[(L, N, p_best)][0]
    stages = _stages_from_steps(steps, p_best)
    return Plan(arch, stages, steps, micro_batch, M, lat, "asteroid",
                time.perf_counter() - t_start, staleness=staleness,
                compress=compress)


def _stages_from_steps(steps, P: int) -> tuple[StagePlan, ...]:
    stages = []
    p = 0
    for st in steps:
        if st.kind == "exec":
            stages.append(StagePlan(st.layers, st.group, st.alloc,
                                    kp_policy(P, p)))
            p += 1
    return tuple(stages)


def replan_for_membership(profile: Profile, incumbent: Plan,
                          allowed_stages=None) -> Plan:
    """Full Algorithm-2 re-plan after a membership change, keeping the
    incumbent's batch geometry and gradient-sync semantics.

    This is the FTPipeHD-style fallback the membership controller reaches
    for when incremental candidates (``replay.admission_replay`` /
    ``replay.departure_replay``) are infeasible: ``profile`` is the
    cluster *after* the change (see ``profiler.extend_profile`` for
    joins), and every weight placement is up for grabs."""
    return plan_hpp(profile, incumbent.global_batch, incumbent.micro_batch,
                    arch=incumbent.arch, allowed_stages=allowed_stages,
                    staleness=getattr(incumbent, "staleness", 0),
                    compress=getattr(incumbent, "compress", None))


def auto_microbatch(profile: Profile, global_batch: int,
                    candidates=(1, 2, 4, 8, 16, 32, 64), arch: str = "",
                    **kw) -> Plan:
    """Sweep micro-batch sizes; return the fastest feasible plan.

    The paper fixes the micro-batch per experiment; this outer sweep makes
    the trade explicit — smaller micro-batches shrink bubbles (Eq. 6) but
    pay more per-layer launch overhead and lower batch efficiency
    (Fig. 6), and Eq. 3 memory feasibility can cut either way."""
    best = None
    for mb in candidates:
        if global_batch % mb:
            continue
        try:
            plan = plan_hpp(profile, global_batch, mb, arch=arch, **kw)
        except AllocationError:
            continue
        if best is None or plan.latency < best.latency:
            best = plan
    if best is None:
        raise AllocationError("no feasible plan for any micro-batch size")
    return best


# ---------------------------------------------------------------------------
# Baseline planners (paper's comparison systems)
# ---------------------------------------------------------------------------


def plan_dp(profile: Profile, global_batch: int, micro_batch: int,
            arch: str = "", heterogeneous: bool = True,
            overlap: bool = True) -> Plan:
    """Pure data parallelism (EDDL-style when heterogeneous=True) — the
    paper's DP baseline in Table 4 / Fig. 13.

    One stage spanning all layers on every device; latency is Eq. 4 with a
    single exec step and the Eq. 5 full-model AllReduce.  ``overlap``:
    DDP-style bucketed gradient AllReduce overlapped with the backward
    pass (the AllReduce only charges the part the backward can't hide) —
    without this the DP baseline would be unrealistically weak."""
    t0 = time.perf_counter()
    table = profile.table
    N = len(profile.cluster.devices)
    group = tuple(range(N))
    M = global_batch // micro_batch
    if heterogeneous:
        alloc = allocate_microbatch(profile, group, micro_batch, 0, table.L,
                                    k_p=1, block=max(1, micro_batch // 16))
    else:
        share = micro_batch // N
        y = [share] * N
        for r in range(micro_batch - share * N):
            y[r] += 1
        ef = max(profile.t_fwd(d, y[d], 0, table.L) for d in group)
        eb = max(profile.t_bwd(d, y[d], 0, table.L) for d in group)
        alloc = Allocation(tuple(y), ef, eb)
    ta = allreduce_time(table.param_bytes(0, table.L), group, profile.cluster)
    if overlap:
        ta = max(ta - alloc.eb * M, 0.1 * ta)
    steps = (Step("exec", alloc.ef, alloc.eb, ta, group, (0, table.L), alloc.y),)
    lat = round_latency(steps, M)
    stages = (StagePlan((0, table.L), group, alloc.y, 1),)
    return Plan(arch, stages, steps, micro_batch, M, lat,
                "eddl" if heterogeneous else "dp", time.perf_counter() - t0)


def plan_gpipe(profile: Profile, global_batch: int, micro_batch: int,
               arch: str = "", n_stages: int | None = None) -> Plan:
    """GPipe-style PP: equal-FLOPs contiguous split, one device per stage,
    ignores boundary activation sizes and device heterogeneity (the
    paper's PP baseline in Table 4) — its Eq. 11 dominant step is whatever
    stage happens to land on the slowest device."""
    t0 = time.perf_counter()
    table = profile.table
    N = len(profile.cluster.devices)
    P = n_stages or N
    M = global_batch // micro_batch
    total = table.flops(0, table.L)
    cuts, acc, target = [0], 0.0, total / P
    for li in range(table.L):
        acc += table.layers[li].flops_fwd
        if acc >= target * len(cuts) and len(cuts) < P:
            cuts.append(li + 1)
    while len(cuts) < P + 1:
        cuts.append(table.L)
    cuts[-1] = table.L

    steps = []
    stages = []
    for p in range(P):
        i, j = cuts[p], cuts[p + 1]
        d = p  # device rank p
        ef = profile.t_fwd(d, micro_batch, i, j)
        eb = profile.t_bwd(d, micro_batch, i, j)
        steps.append(Step("exec", ef, eb, 0.0, (d,), (i, j), (micro_batch,)))
        stages.append(StagePlan((i, j), (d,), (micro_batch,), kp_policy(P, p)))
        if p < P - 1:
            steps.append(_comm_step(profile, micro_batch, j, (d,), (d + 1,)))
    lat = round_latency(tuple(steps), M)
    return Plan(arch, tuple(stages), tuple(steps), micro_batch, M, lat,
                "gpipe", time.perf_counter() - t0)


def plan_homogeneous_hpp(profile: Profile, global_batch: int, micro_batch: int,
                         arch: str = "", include_allreduce: bool = False,
                         name: str = "pipedream") -> Plan:
    """PipeDream / Dapple-style planning: treats the cluster as homogeneous
    (mean capacity), ignores per-device memory budgets; Dapple additionally
    models the synchronous AllReduce cost (include_allreduce=True).

    The chosen configuration is then re-priced on the REAL heterogeneous
    profile (Eq. 8 at the actual device times) — the gap between the two
    is what deploying a homogeneity-assuming plan costs (Fig. 13)."""
    import numpy as np

    from .hardware import Cluster, DeviceProfile

    t0 = time.perf_counter()
    devs = profile.cluster.devices
    mean_flops = float(np.mean([d.flops for d in devs]))
    mean_mem = float(np.mean([d.mem_bytes for d in devs]))
    homog = Cluster(tuple(
        DeviceProfile(f"homog{i}", mem_bytes=mean_mem, flops=mean_flops,
                      sat_batch=devs[i].sat_batch, overhead=devs[i].overhead)
        for i in range(len(devs))), profile.cluster.bandwidth,
        profile.cluster.bw_matrix)
    homog_profile = Profile.analytic(profile.table, homog, profile.max_batch)

    plan = plan_hpp(homog_profile, global_batch, micro_batch, arch=arch,
                    check_memory=False)
    # Re-evaluate the chosen configuration on the REAL cluster (this is what
    # deploying a homogeneity-assuming plan on heterogeneous devices costs).
    steps = []
    for st in plan.steps:
        if st.kind == "comm":
            steps.append(st)
            continue
        i, j = st.layers
        ef = max(profile.t_fwd(d, y, i, j) for d, y in zip(st.group, st.alloc))
        eb = max(profile.t_bwd(d, y, i, j) for d, y in zip(st.group, st.alloc))
        ta = st.ta if include_allreduce else st.ta
        steps.append(Step("exec", ef, eb, ta, st.group, st.layers, st.alloc))
    lat = round_latency(tuple(steps), plan.n_micro)
    return Plan(arch, plan.stages, tuple(steps), micro_batch, plan.n_micro,
                lat, name, time.perf_counter() - t0)


def plan_hetpipe_hdp(profile: Profile, global_batch: int, micro_batch: int,
                     arch: str = "", n_groups: int = 2):
    """HetPipe-style HDP: devices split into virtual workers (intra-group PP,
    inter-group DP through a parameter server).  Returns (per-round latency,
    comm volume per Eq. 1) for the Table 2 comm-volume comparison — the
    bidirectional full-model PS sync is the term Eq. 2's HPP avoids."""
    from .costmodel import hdp_volume

    table = profile.table
    N = len(profile.cluster.devices)
    n_groups = min(n_groups, N)
    ranks = list(range(N))
    groups = [tuple(ranks[i::n_groups]) for i in range(n_groups)]
    batches = [global_batch // n_groups] * n_groups
    batches[0] += global_batch - sum(batches)

    # per-group pipeline: equal-FLOPs split over group devices
    lat = 0.0
    vol_groups = []
    for g, beta in zip(groups, batches):
        sub = plan_gpipe_sub(profile, g, beta, micro_batch)
        lat = max(lat, sub)
        bounds = [table.boundary_act(table.L * (k + 1) // len(g))
                  for k in range(len(g) - 1)]
        vol_groups.append({"batch": beta, "act_bytes": bounds})
    # PS bidirectional full-model sync through the slowest link
    p_bytes = table.param_bytes(0, table.L)
    ps_time = 2.0 * p_bytes / profile.cluster.bandwidth if n_groups > 1 else 0.0
    lat += ps_time
    vol = hdp_volume(p_bytes, vol_groups)
    return lat, vol


def plan_gpipe_sub(profile: Profile, group, global_batch: int,
                   micro_batch: int) -> float:
    """Round latency of an equal-FLOPs pipeline over a device subset."""
    table = profile.table
    P = len(group)
    M = max(1, global_batch // micro_batch)
    total = table.flops(0, table.L)
    cuts, acc, target = [0], 0.0, total / P
    for li in range(table.L):
        acc += table.layers[li].flops_fwd
        if acc >= target * len(cuts) and len(cuts) < P:
            cuts.append(li + 1)
    while len(cuts) < P + 1:
        cuts.append(table.L)
    cuts[-1] = table.L
    steps = []
    for p in range(P):
        i, j = cuts[p], cuts[p + 1]
        d = group[p]
        steps.append(Step("exec", profile.t_fwd(d, micro_batch, i, j),
                          profile.t_bwd(d, micro_batch, i, j), 0.0, (d,),
                          (i, j), (micro_batch,)))
        if p < P - 1:
            steps.append(_comm_step(profile, micro_batch, j, (d,), (group[p + 1],)))
    return round_latency(tuple(steps), M)
