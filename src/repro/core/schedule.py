"""Micro-batch schedules: memory-efficient 1F1B with per-stage warm-up K_p.

The paper's §3.2: GPipe runs all M forwards then all backwards, so peak
activation memory scales O(M).  Asteroid performs ``K_p`` forwards on stage
p before strictly alternating one-forward-one-backward, bounding resident
activations to O(K_p) with ``K_p = 2*(P-p)-1`` chosen so parallelism is not
sacrificed (Fig. 15b compares the neighboring policies).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from .costmodel import kp_policy


@dataclasses.dataclass(frozen=True)
class Op:
    kind: str          # 'F' | 'B' (compute stream) | 'S' | 'R' | 'A' (comm)
    micro: int


def stage_order_1f1b(M: int, k_p: int) -> tuple[Op, ...]:
    """Op order for one stage under 1F1B with warm-up depth k_p."""
    k = max(1, min(k_p, M))
    ops: list[Op] = [Op("F", m) for m in range(k)]
    nf, nb = k, 0
    while nb < M:
        ops.append(Op("B", nb))
        nb += 1
        if nf < M:
            ops.append(Op("F", nf))
            nf += 1
    return tuple(ops)


def stage_order_gpipe(M: int) -> tuple[Op, ...]:
    return tuple([Op("F", m) for m in range(M)] + [Op("B", m) for m in range(M)])


def schedule_orders(P: int, M: int, policy: str = "ours") -> list[tuple[Op, ...]]:
    """Per-stage op orders for a P-stage pipeline.

    policy in {'ours', 'a', 'b', 'c'} selects the K_p formula (Fig. 15b);
    'gpipe' is backward-after-forward.
    """
    if policy == "gpipe":
        return [stage_order_gpipe(M) for _ in range(P)]
    return [stage_order_1f1b(M, kp_policy(P, p, policy)) for p in range(P)]


def max_inflight(order: tuple[Op, ...]) -> int:
    """Peak number of micro-batches whose activations are resident."""
    live = 0
    peak = 0
    for op in order:
        live += 1 if op.kind == "F" else -1
        peak = max(peak, live)
    return peak


# ---------------------------------------------------------------------------
# Async (two-stream) schedule enumeration
# ---------------------------------------------------------------------------
#
# The overlapped runtime splits every stage into a compute stream (the F/B
# order above, unchanged — overlap never reorders compute) and a comm
# stream: per forward an activation send 'S' to stage p+1, per backward a
# gradient send 'R' to stage p-1, each launched one compute slot after the
# op that produced it (the double buffer), plus — under staleness >= 1 — a
# trailing 'A' (gradient AllReduce) that drains during the next round's
# warm-up forwards instead of extending this round.


def comm_stream(order: tuple[Op, ...], p: int, P: int,
                staleness: int = 1) -> tuple[Op, ...]:
    """Comm-stream op order for stage p given its compute order.

    'S m' follows F(m) for every non-last stage, 'R m' follows B(m) for
    every non-first stage — in compute completion order, which is the order
    the double buffer hands transfers to the link.  With ``staleness >= 1``
    a terminal 'A' marks the overlapped gradient AllReduce; with
    ``staleness == 0`` the AllReduce is synchronous (it lives in the round
    boundary, not on the overlapped stream) and is omitted here.
    """
    ops: list[Op] = []
    for op in order:
        if op.kind == "F" and p < P - 1:
            ops.append(Op("S", op.micro))
        elif op.kind == "B" and p > 0:
            ops.append(Op("R", op.micro))
    if staleness >= 1:
        ops.append(Op("A", -1))
    return tuple(ops)


def two_stream_orders(P: int, M: int, policy: str = "ours",
                      staleness: int = 1):
    """Per-stage (compute, comm) op orders for the overlapped pipeline.

    Returns ``(compute_orders, comm_orders)``; ``compute_orders`` is
    exactly ``schedule_orders(P, M, policy)`` (overlap moves transfers to
    a second stream, it does not re-schedule compute), and
    ``comm_orders[p]`` is stage p's comm stream (``comm_stream``).
    """
    compute = schedule_orders(P, M, policy)
    comm = [comm_stream(compute[p], p, P, staleness) for p in range(P)]
    return compute, comm


def scan_ticks(P: int, M: int, double_buffer: bool = False) -> int:
    """Forward-scan length of the runtime pipeline: the double-buffered
    variant pays a 2-tick stage hop (compute tick + in-flight tick) for
    the overlap, so warm-up doubles while steady state is unchanged."""
    return M + (2 * (P - 1) if double_buffer else (P - 1))
