"""Micro-batch schedules: memory-efficient 1F1B with per-stage warm-up K_p.

The paper's §3.2: GPipe runs all M forwards then all backwards, so peak
activation memory scales O(M).  Asteroid performs ``K_p`` forwards on stage
p before strictly alternating one-forward-one-backward, bounding resident
activations to O(K_p) with ``K_p = 2*(P-p)-1`` chosen so parallelism is not
sacrificed (Fig. 15b compares the neighboring policies).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

from .costmodel import kp_policy


@dataclasses.dataclass(frozen=True)
class Op:
    kind: str          # 'F' | 'B'
    micro: int


def stage_order_1f1b(M: int, k_p: int) -> tuple[Op, ...]:
    """Op order for one stage under 1F1B with warm-up depth k_p."""
    k = max(1, min(k_p, M))
    ops: list[Op] = [Op("F", m) for m in range(k)]
    nf, nb = k, 0
    while nb < M:
        ops.append(Op("B", nb))
        nb += 1
        if nf < M:
            ops.append(Op("F", nf))
            nf += 1
    return tuple(ops)


def stage_order_gpipe(M: int) -> tuple[Op, ...]:
    return tuple([Op("F", m) for m in range(M)] + [Op("B", m) for m in range(M)])


def schedule_orders(P: int, M: int, policy: str = "ours") -> list[tuple[Op, ...]]:
    """Per-stage op orders for a P-stage pipeline.

    policy in {'ours', 'a', 'b', 'c'} selects the K_p formula (Fig. 15b);
    'gpipe' is backward-after-forward.
    """
    if policy == "gpipe":
        return [stage_order_gpipe(M) for _ in range(P)]
    return [stage_order_1f1b(M, kp_policy(P, p, policy)) for p in range(P)]


def max_inflight(order: tuple[Op, ...]) -> int:
    """Peak number of micro-batches whose activations are resident."""
    live = 0
    peak = 0
    for op in order:
        live += 1 if op.kind == "F" else -1
        peak = max(peak, live)
    return peak
