"""Asteroid cost models: Eq. 1/2 (comm volume), Eq. 3 (memory), Eq. 5
(AllReduce time), and the dominant-step HPP-Round latency (Eqs. 4, 6, 11)."""

from __future__ import annotations

import dataclasses
from typing import Sequence

from .hardware import Cluster
from .profiler import GRAD_BYTES, LayerTable, Profile

OPT_STATE_BYTES_PER_PARAM = 8      # Adam m+v fp32 (per fp32 param)


# ---------------------------------------------------------------------------
# §2.3 communication-volume analysis
# ---------------------------------------------------------------------------


def hdp_volume(model_param_bytes: float, groups: Sequence[dict]) -> float:
    """Eq. (1): HetPipe-style Hybrid Data Parallelism volume per mini-batch.

    groups: [{"batch": beta_i, "act_bytes": [a_{i,1}..a_{i,|g|-1}]}, ...]
    """
    G = len(groups)
    intra = sum(2.0 * g["batch"] * sum(g["act_bytes"]) for g in groups)
    if G == 1:
        return intra
    return 2.0 * G * model_param_bytes + intra


def hpp_volume(stage_param_bytes: Sequence[float], group_sizes: Sequence[int],
               boundary_act_bytes: Sequence[float], global_batch: int) -> float:
    """Eq. (2): Hybrid Pipeline Parallelism volume per mini-batch."""
    G = len(stage_param_bytes)
    allreduce = sum(2.0 * (g - 1) * p for p, g in zip(stage_param_bytes, group_sizes))
    if G == 1:
        return allreduce
    pipe = 2.0 * global_batch * sum(boundary_act_bytes)
    return allreduce + pipe


# ---------------------------------------------------------------------------
# Eq. 3 memory model
# ---------------------------------------------------------------------------


def kp_policy(P: int, p: int, policy: str = "ours") -> int:
    """Warm-up depth K_p for stage p (0-indexed) in a P-stage pipeline.

    'ours'  : 2*(P-p)-1   (the paper's choice)
    'a'     : 2*(P-p)
    'b'     : P-p
    'c'     : 2*(P-p)+1
    'gpipe' : M  (caller substitutes — returns a sentinel large value)
    """
    if policy == "ours":
        return 2 * (P - p) - 1
    if policy == "a":
        return 2 * (P - p)
    if policy == "b":
        return P - p
    if policy == "c":
        return 2 * (P - p) + 1
    if policy == "gpipe":
        return 1 << 30
    raise ValueError(policy)


def stage_memory(table: LayerTable, i: int, j: int, beta: int, k_p: int,
                 n_microbatches: int | None = None) -> float:
    """Eq. (3): Mem_p = MOD + OPT + K_p * ACT(beta) for layers [i, j)."""
    w = table.param_bytes(i, j)
    mod = w + w * (GRAD_BYTES / 4.0)            # params + accumulated grads
    opt = w / 4.0 * OPT_STATE_BYTES_PER_PARAM
    act = table.act_bytes_sum(i, j) * beta
    k = k_p if n_microbatches is None else min(k_p, n_microbatches)
    return mod + opt + k * act


# ---------------------------------------------------------------------------
# Steps & the dominant-step latency model (Eqs. 4, 6, 11)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Step:
    """One pipeline step: an execution step (stage) or a communication step."""

    kind: str                      # 'exec' | 'comm'
    ef: float                      # forward time of this step per micro-batch
    eb: float                      # backward time per micro-batch
    ta: float = 0.0                # AllReduce phase time (exec steps only)
    group: tuple[int, ...] = ()    # device ranks (exec)
    layers: tuple[int, int] = (0, 0)
    alloc: tuple[int, ...] = ()    # micro-batch sample allocation across group

    @property
    def e_total(self) -> float:
        return self.ef + self.eb


def allreduce_time(param_bytes: float, group, cluster: Cluster) -> float:
    """Eq. (5) AllReduce phase: ring over the min intra-group bandwidth."""
    g = len(group)
    if g <= 1:
        return 0.0
    return 2.0 * (g - 1) * param_bytes / (g * cluster.min_bw(group))


def dominant_index(steps: Sequence[Step], M: int) -> int:
    """The step with the fewest Execution-Phase bubbles == the largest
    aligned total M*(Ef+Eb)_s + sum_{i<s}(Ef+Eb)_i (Eq. 11 generalized)."""
    best, best_val = 0, -1.0
    acc = 0.0
    for s, st in enumerate(steps):
        val = M * st.e_total + acc
        if val > best_val:
            best, best_val = s, val
        acc += st.e_total
    return best


def round_latency(steps: Sequence[Step], M: int) -> float:
    """HPP-Round latency, Eq. (4) with T_w (Eq. 5) and T_e (Eq. 6)."""
    if not steps:
        return 0.0
    dm = dominant_index(steps, M)
    e_dm = M * steps[dm].e_total
    # prefix sums
    worst = 0.0
    tw = 0.0
    for s, st in enumerate(steps):
        if s < dm:
            shift = sum(x.e_total for x in steps[s:dm])
            te = e_dm + shift
        else:
            shift = sum(x.e_total for x in steps[dm:s])
            te = e_dm - shift
        worst = max(worst, tw + te + st.ta)
        tw += st.ef
    return worst


# ---------------------------------------------------------------------------
# Two-stream (compute / comm) round models — the async 1F1B variant
# ---------------------------------------------------------------------------


def exec_phase_latency(steps: Sequence[Step], M: int) -> float:
    """Execution-Phase makespan only: Eqs. (4)/(6) with every AllReduce
    phase stripped.  The compute-stream half of the two-stream model."""
    return round_latency(tuple(dataclasses.replace(s, ta=0.0)
                               for s in steps), M)


def max_allreduce(steps: Sequence[Step]) -> float:
    """Largest per-stage AllReduce phase (Eq. 5) across the pipeline."""
    return max((s.ta for s in steps if s.kind == "exec"), default=0.0)


def round_latency_async(steps: Sequence[Step], M: int) -> float:
    """Steady-state HPP-Round latency of the *overlapped* pipeline.

    Two-resource model: stage compute and boundary P2P transfers pipeline
    as before (comm steps are pipeline steps in Eq. 4 already — the
    double-buffered runtime realizes that assumption), while the gradient
    AllReduce of round r runs on the comm stream during round r+1
    (staleness 1: round r's gradients are applied at the r+1 boundary, so
    the AllReduce has a full Execution Phase to hide in).  Only un-hidden
    comm is charged: a round cannot complete faster than its Execution
    Phase, nor faster than the slowest stage's AllReduce drains.
    """
    return max(exec_phase_latency(steps, M), max_allreduce(steps))


def unhidden_allreduce(steps: Sequence[Step], M: int) -> float:
    """AllReduce seconds the Execution Phase cannot hide (0 when the
    gradient sync leaves the critical path entirely)."""
    return max(0.0, max_allreduce(steps) - exec_phase_latency(steps, M))


def hpp_round_latency(steps: Sequence[Step], M: int,
                      staleness: int = 0) -> float:
    """Round latency under the chosen gradient-sync semantics: Eq. (4)
    synchronous rounds at staleness 0, the two-stream overlapped model at
    staleness >= 1."""
    if staleness >= 1:
        return round_latency_async(steps, M)
    return round_latency(steps, M)


def round_latency_serialized(steps: Sequence[Step], M: int) -> float:
    """Round latency when boundary transfers SERIALIZE with stage compute
    (the pre-double-buffer tick scan: the ppermute of micro-batch m sits
    between the compute of m and m+1 on every device).

    Modeled by folding each comm step's per-micro cost into the downstream
    exec step, leaving no independent comm resource to pipeline on — the
    one-stream lower bound that ``round_latency_async`` /
    ``round_latency`` improve on.
    """
    merged: list[Step] = []
    pending_f = pending_b = 0.0
    for s in steps:
        if s.kind == "comm":
            pending_f, pending_b = s.ef, s.eb
            continue
        merged.append(dataclasses.replace(s, ef=s.ef + pending_f,
                                          eb=s.eb + pending_b))
        pending_f = pending_b = 0.0
    return round_latency(tuple(merged), M)


# ---------------------------------------------------------------------------
# Compressed-transfer pricing (DESIGN.md §10)
# ---------------------------------------------------------------------------

#: (de)quantization arithmetic per element (abs, max-reduce, divide, round
#: on the sender; multiply on the receiver) — charged against each
#: endpoint's device flops.  Deliberately coarse: the kernels are
#: bandwidth-bound single-pass maps, so a handful of flops/elem bounds
#: them from above.
QUANT_FLOPS_PER_ELEM = 8.0

#: payload bits per fp32 element for each wire format (per-tile scale
#: amortized separately via ``CompressionConfig.wire_ratio``)
_FMT_BITS = {"int8": 8.0, "fp8": 8.0}


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Planner-visible compressed-transfer configuration.

    Mirrors the runtime knobs (``TrainSpec.compress`` / ``quant_tile`` /
    ``bucket_mb`` / ``error_feedback``) so a ``Plan`` carries the choice
    through replay replans and ``reprice_plan`` re-applies it on fresh
    profiles.
    """

    fmt: str = "int8"              # 'int8' | 'fp8'
    tile: int = 256                # elements per scale tile
    bucket_mb: float | None = None # gradient bucket bound (None = per-group)
    error_feedback: bool = True

    def __post_init__(self):
        if self.fmt not in _FMT_BITS:
            raise ValueError(f"unknown compression format {self.fmt!r}")
        if self.tile <= 0:
            raise ValueError(f"quant tile must be positive, got {self.tile}")

    @property
    def wire_ratio(self) -> float:
        """Compressed bytes / fp32 bytes: payload bits plus one fp32 scale
        per ``tile`` elements ((8 + 32/tile) / 32 ≈ 0.254 for int8@256)."""
        return (_FMT_BITS[self.fmt] + 32.0 / self.tile) / 32.0


def parse_compress(compress) -> CompressionConfig | None:
    """Normalize the planner knob: None/'none' -> None, 'int8'/'fp8' -> a
    default config, a ``CompressionConfig`` passes through."""
    if compress is None or compress == "none":
        return None
    if isinstance(compress, CompressionConfig):
        return compress
    if isinstance(compress, str):
        return CompressionConfig(fmt=compress)
    raise TypeError(f"compress must be None, a format string or a "
                    f"CompressionConfig, got {type(compress)}")


def quant_endpoint_cost(nbytes: float, flops: float) -> float:
    """Seconds to (de)quantize an ``nbytes`` fp32 buffer on a device with
    ``flops`` peak throughput — the compute toll each endpoint pays for
    the cheaper wire."""
    if flops <= 0:
        return 0.0
    return (nbytes / 4.0) * QUANT_FLOPS_PER_ELEM / flops


def compressed_comm_time(nbytes: float, bw: float, compress,
                         flops_a: float, flops_b: float) -> float:
    """One boundary transfer under (optional) compression: compressed
    bytes over the link plus quantize on the sender and dequantize on the
    receiver.  ``compress=None`` prices the raw fp32 transfer."""
    cc = parse_compress(compress)
    if cc is None:
        return nbytes / bw
    return (nbytes * cc.wire_ratio / bw
            + quant_endpoint_cost(nbytes, flops_a)
            + quant_endpoint_cost(nbytes, flops_b))


def compressed_allreduce_time(param_bytes: float, group, cluster: Cluster,
                              compress, min_flops: float) -> float:
    """Eq. (5) over the compressed gradient stream: the ring moves
    ``wire_ratio`` of the bytes, and every rank quantizes its local
    contribution + dequantizes the result once per round."""
    cc = parse_compress(compress)
    if cc is None:
        return allreduce_time(param_bytes, group, cluster)
    t = allreduce_time(param_bytes * cc.wire_ratio, group, cluster)
    if len(group) > 1:
        t += 2.0 * quant_endpoint_cost(param_bytes, min_flops)
    return t


# ---------------------------------------------------------------------------
# Serve-mode pricing (DESIGN.md §11): one-token decode steps, slot memory,
# and the open-loop latency-percentile objective
# ---------------------------------------------------------------------------


def decode_step_time(profile: Profile, dev: int, beta: int, i: int, j: int,
                     seq_len: int) -> float:
    """Predicted seconds for ONE decode step of layers [i, j) at batch beta.

    The profile's ``(tf)`` rows measure a full ``seq_len``-token forward;
    a decode step runs the same layers over a single token, so we charge
    the per-token slice ``t_fwd / seq_len``.  Deliberately coarse — it
    ignores the worse arithmetic intensity of single-token GEMVs — but it
    is *measured* (device-specific, batch-specific, layer-specific), which
    is what makes heterogeneous stage/split choices comparable.
    """
    if seq_len <= 0:
        raise ValueError(f"seq_len must be positive, got {seq_len}")
    return profile.t_fwd(dev, max(beta, 1), i, j) / seq_len


def decode_boundary_bytes(table: LayerTable, j: int, beta: int,
                          seq_len: int) -> float:
    """Wire bytes of one decode-step boundary hop after layer ``j``: the
    profiled full-sequence boundary activation scaled to a single token."""
    return table.boundary_act(j) / max(seq_len, 1) * beta


def decode_boundary_time(table: LayerTable, j: int, beta: int, seq_len: int,
                         bw: float, compress, flops_a: float,
                         flops_b: float) -> float:
    """One-token boundary transfer after layer ``j`` at batch ``beta``,
    priced with the §10 compression-aware link model."""
    nbytes = decode_boundary_bytes(table, j, beta, seq_len)
    return compressed_comm_time(nbytes, bw, compress, flops_a, flops_b)


def slot_cache_bytes(table: LayerTable, i: int, j: int,
                     cache_len: int, seq_len: int) -> float:
    """Per-slot KV/state cache bytes for layers [i, j).

    The layer table's activation bytes are per-sample at ``seq_len``
    tokens; the decode cache holds per-token K/V (or recurrent state) for
    ``cache_len`` positions, so the per-token activation footprint is the
    planner's proxy for per-token cache bytes.
    """
    return table.act_bytes_sum(i, j) / max(seq_len, 1) * cache_len


def serve_stage_slots(table: LayerTable, i: int, j: int, mem_bytes: float,
                      cache_len: int, seq_len: int,
                      mem_fraction: float = 0.9) -> int:
    """Admission-control cap: how many decode slots fit on a device serving
    layers [i, j) — Eq. 3 with the training terms (grads, opt state, warm-up
    activations) replaced by params + slots × per-slot cache."""
    budget = mem_bytes * mem_fraction - table.param_bytes(i, j)
    per_slot = slot_cache_bytes(table, i, j, cache_len, seq_len)
    if budget <= 0 or per_slot <= 0:
        return 0
    return int(budget // per_slot)


def queue_wait_quantile(arrival_rate: float, service_rate: float,
                        p: float) -> float:
    """M/M/1 waiting-time quantile: P(W > t) = rho * exp(-mu (1-rho) t).

    Returns the smallest t with P(W <= t) >= p (0 when the tail is already
    below 1-p at t=0), or +inf when the queue is unstable (rho >= 1).
    """
    import math

    if service_rate <= 0:
        return math.inf
    rho = arrival_rate / service_rate
    if rho >= 1.0:
        return math.inf
    if rho <= 0.0:
        return 0.0
    t = math.log(rho / (1.0 - p)) / (service_rate * (1.0 - rho))
    return max(0.0, t)


def serve_latency_quantile(step_time: float, slots: int,
                           arrival_rate: float, p: float = 0.99) -> float:
    """Predicted per-token latency percentile of an open-loop decode server.

    The engine retires ``slots`` tokens every ``step_time`` seconds — an
    M/M/1 approximation with service rate mu = slots/step_time serving
    Poisson arrivals at ``arrival_rate`` tokens/s.  A token's latency is
    its queueing delay plus the step that computes it.
    """
    import math

    if step_time <= 0 or slots <= 0:
        return math.inf
    mu = slots / step_time
    return step_time + queue_wait_quantile(arrival_rate, mu, p)


def bucketed_allreduce_residual(ta: float, backward_s: float,
                                param_bytes: float, compress) -> float:
    """Un-hidden AllReduce seconds under DDP-style bucketed overlap.

    With the gradient tree split into size-bounded buckets, each bucket's
    psum launches as soon as its layers' backward completes — only the
    part of the total AllReduce that outlasts the remaining backward stays
    on the critical path, and the LAST bucket can never be hidden (its
    layers finish when the backward does).  Mirrors ``plan_dp``'s
    ``max(ta - eb*M, 0.1*ta)`` overlap pricing, with the floor set by the
    actual bucket count instead of a fixed 10%.
    """
    cc = parse_compress(compress)
    if cc is None or ta <= 0.0:
        return ta
    if cc.bucket_mb is None:
        n_buckets = 1
    else:
        n_buckets = max(1, -(-param_bytes * cc.wire_ratio
                             // (cc.bucket_mb * (1 << 20))))
    return max(ta - backward_s, ta / n_buckets)
