"""Algorithm 1: allocation of a micro-batch's samples across a device group.

Phase 1 — MemoryAwareBalancing: recursively split the micro-batch in
proportion to device computing capacity v_d (Eq. 9), capping each device at
the largest batch its memory budget admits (Eq. 3), and re-distributing the
unallocated remainder among devices with memory left.

Phase 2 — StragglerWorkloadOffloading: because time-vs-batch is non-linear
(Fig. 6), proportional allocation is suboptimal; iteratively move one block
of samples from the straggler to the fastest device with spare memory until
the straggler stops improving.
"""

from __future__ import annotations

import dataclasses

from .costmodel import stage_memory
from .profiler import Profile


class AllocationError(RuntimeError):
    """Group cannot host the stage within memory budgets (T = inf)."""


@dataclasses.dataclass(frozen=True)
class Allocation:
    y: tuple[int, ...]            # samples per device (group order)
    ef: float                     # Eq. 8: max_d fwd time
    eb: float                     # Eq. 8: max_d bwd time

    @property
    def t(self) -> float:
        return self.ef + self.eb


def _max_batch_under_budget(profile: Profile, dev_rank: int, i: int, j: int,
                            k_p: int, micro_batch: int) -> int:
    """Largest beta with Mem(beta) <= u_d (binary search; Eq. 3 is monotone)."""
    dev = profile.cluster.devices[dev_rank]
    lo, hi = 0, micro_batch
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if stage_memory(profile.table, i, j, mid, k_p) <= dev.mem_bytes:
            lo = mid
        else:
            hi = mid - 1
    return lo


def allocate_microbatch(profile: Profile, group: tuple[int, ...], micro_batch: int,
                        i: int, j: int, k_p: int, block: int = 1,
                        offload: bool = True) -> Allocation:
    """Run Algorithm 1 for stage layers [i, j) on ``group`` device ranks.

    ``offload=False`` disables Phase 2 (the ablation in Fig. 15a)."""
    cluster = profile.cluster
    caps = {d: _max_batch_under_budget(profile, d, i, j, k_p, micro_batch)
            for d in group}

    # Eq. 9: capacity = inverse of full-micro-batch fwd+bwd latency
    v = {d: 1.0 / max(profile.t_both(d, micro_batch, i, j), 1e-12) for d in group}

    y = {d: 0 for d in group}

    # ---- Phase 1: MemoryAwareBalancing (recursive) ----------------------
    def balance(g: list[int], beta: int):
        if beta == 0:
            return
        if not g:
            raise AllocationError(f"stage [{i},{j}) needs {beta} more samples "
                                  f"but no device has memory left")
        vsum = sum(v[d] for d in g)
        # proportional share, floored; remainder goes to the fastest devices
        shares = {d: int(v[d] / vsum * beta) for d in g}
        rem = beta - sum(shares.values())
        for d in sorted(g, key=lambda d: -v[d]):
            if rem == 0:
                break
            shares[d] += 1
            rem -= 1
        leftover = 0
        for d in g:
            take = min(shares[d], caps[d] - y[d])
            y[d] += take
            leftover += shares[d] - take
        g2 = [d for d in g if y[d] < caps[d]]
        if leftover:
            balance(g2, leftover)

    balance(list(group), micro_batch)

    # ---- Phase 2: StragglerWorkloadOffloading ---------------------------
    def lat(d: int) -> float:
        return profile.t_both(d, y[d], i, j)

    while offload:
        order = sorted(group, key=lat)
        straggler = order[-1]
        old = lat(straggler)
        moved = False
        for fast in order[:-1]:
            if y[fast] + block <= caps[fast] and y[straggler] >= block:
                y[fast] += block
                y[straggler] -= block
                new_straggler = max(group, key=lat)
                if lat(new_straggler) < old:
                    moved = True
                    break
                y[fast] -= block          # revert: offload made things worse
                y[straggler] += block
        if not moved:
            break

    ef = max(profile.t_fwd(d, y[d], i, j) for d in group)
    eb = max(profile.t_bwd(d, y[d], i, j) for d in group)
    return Allocation(tuple(y[d] for d in group), ef, eb)
