"""Plan portfolio: closed-loop strategy selection over live probation
windows (DESIGN.md §12).

``profile_gap`` (PR 4) shows the analytic cost model can misprice a
measured round by 25%+, so no single open-loop strategy — sync HPP, async
staleness-1, DP-overlap, compressed variants — plans best on every mesh.
This module turns the planner into an *algorithm portfolio* (cf. borg's
portfolio solvers): every priced strategy family in ``core.planner``
contributes a candidate, structural duplicates are folded, and the top-K
by predicted round latency become *finalists* that the runtime auctions
over short live probation windows (``PipelineSession.probe_portfolio``).
Measured round latency — not the model — picks the winner; the
``DriftWatchdog`` re-opens the auction when the observed/predicted ratio
(via ``simulator.reprice_plan``) drifts.

Everything here is pure planning/bookkeeping: no jax, no runtime imports.
The session layer owns the probation execution.
"""

from __future__ import annotations

import dataclasses

from .allocation import AllocationError
from .planner import (Plan, plan_dp, plan_gpipe, plan_hetpipe_hdp,
                      plan_homogeneous_hpp, plan_hpp)
from .profiler import Profile
from .simulator import reprice_plan

#: plan_hpp axes enumerated as distinct families.  'auto' variants are not
#: enumerated separately: auto returns one of its constituents, so the
#: structural dedupe would fold it anyway.
HPP_STALENESS = (0, 1)
HPP_COMPRESS = (None, "int8", "fp8")


def plan_key(plan: Plan) -> tuple:
    """Structural identity of a plan: the *decisions* that determine what
    the runtime executes — stage layer ranges, device groups, per-device
    allocations, batch geometry, gradient-sync semantics, wire format.

    Deliberately excludes every priced quantity (step costs, latency,
    plan_time, planner name), so the key is stable under
    ``simulator.reprice_plan`` — re-pricing a plan on another profile
    never changes which candidate it *is*.
    """
    comp = getattr(plan, "compress", None)
    ckey = ((comp.fmt, comp.tile, comp.bucket_mb, comp.error_feedback)
            if comp is not None else None)
    return (plan.arch,
            tuple((st.layers, st.group, st.alloc) for st in plan.stages),
            plan.micro_batch, plan.n_micro,
            getattr(plan, "staleness", 0), ckey)


def renumber_plan(plan: Plan, ranks: tuple[int, ...]) -> Plan:
    """Map a plan's device ranks from subset-profile order back to the
    parent cluster's numbering (``ranks[i]`` is the parent rank of subset
    device ``i``) — the inverse of planning on ``profiler.subset_profile``.
    """
    stages = tuple(dataclasses.replace(st, group=tuple(ranks[d]
                                                       for d in st.group))
                   for st in plan.stages)
    steps = tuple(dataclasses.replace(s, group=tuple(ranks[d]
                                                     for d in s.group))
                  if s.group else s for s in plan.steps)
    return dataclasses.replace(plan, stages=stages, steps=steps)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One enumerated strategy: a priced plan, or a reference-only entry
    (``plan=None``) for families that price a latency but produce no
    runnable ``Plan`` — HetPipe's HDP arrangement prices the parameter
    server round but its virtual-worker layout has no HPP lowering."""

    family: str                 # e.g. "hpp/async/int8", "dp/eddl"
    plan: Plan | None
    predicted_s: float
    note: str = ""

    @property
    def runnable(self) -> bool:
        return self.plan is not None

    @property
    def key(self) -> tuple:
        return (plan_key(self.plan) if self.plan is not None
                else ("reference", self.family))


@dataclasses.dataclass(frozen=True)
class PlanPortfolio:
    """The deduped candidate set of every strategy family, priced on one
    profile."""

    candidates: tuple[Candidate, ...]   # deduped, sorted by predicted_s
    n_enumerated: int                   # before structural dedupe

    @classmethod
    def enumerate(cls, profile: Profile, global_batch: int, micro_batch: int,
                  *, arch: str = "", allowed_stages=None, intra_opt="auto",
                  ranks: tuple[int, ...] | None = None) -> "PlanPortfolio":
        """Collect candidates from every planner family.

        Families that are infeasible on this cluster (memory caps,
        stage-count restrictions) are skipped, not fatal.  ``ranks``: when
        ``profile`` is a ``subset_profile`` of a larger session cluster,
        the parent ranks of its devices — every candidate plan is
        renumbered back into parent coordinates (post-churn auctions plan
        over the survivors but execute on the original mesh numbering).
        """
        cands: list[Candidate] = []

        def add(family: str, fn, note: str = ""):
            try:
                plan = fn()
            except AllocationError:
                return
            if ranks is not None:
                plan = renumber_plan(plan, ranks)
            cands.append(Candidate(family, plan, plan.latency, note))

        for staleness in HPP_STALENESS:
            for comp in HPP_COMPRESS:
                add(f"hpp/{'async' if staleness else 'sync'}/"
                    f"{comp or 'raw'}",
                    lambda s=staleness, c=comp: plan_hpp(
                        profile, global_batch, micro_batch, arch=arch,
                        allowed_stages=allowed_stages, intra_opt=intra_opt,
                        staleness=s, compress=c))
        add("dp/eddl", lambda: plan_dp(profile, global_batch, micro_batch,
                                       arch=arch, heterogeneous=True))
        n_dev = len(profile.cluster.devices)
        pp_stages = (min(n_dev, max(allowed_stages))
                     if allowed_stages else None)
        add("pp/gpipe", lambda: plan_gpipe(profile, global_batch,
                                           micro_batch, arch=arch,
                                           n_stages=pp_stages))
        add("hpp/pipedream", lambda: plan_homogeneous_hpp(
            profile, global_batch, micro_batch, arch=arch))
        add("hpp/dapple", lambda: plan_homogeneous_hpp(
            profile, global_batch, micro_batch, arch=arch,
            include_allreduce=True, name="dapple"))
        n_enumerated = len(cands)
        try:
            lat, vol = plan_hetpipe_hdp(profile, global_batch, micro_batch,
                                        arch=arch)
            cands.append(Candidate("hdp/hetpipe", None, lat,
                                   note=f"ps_volume={vol:.3g}B"))
            n_enumerated += 1
        except (AllocationError, ZeroDivisionError):
            pass

        # structural dedupe: identical decisions keep one entry — the
        # cheapest pricing (families can reach the same configuration with
        # different cost assumptions; probation measures it once either way)
        best: dict[tuple, Candidate] = {}
        for c in cands:
            k = c.key
            if k not in best or c.predicted_s < best[k].predicted_s:
                best[k] = c
        deduped = tuple(sorted(best.values(),
                               key=lambda c: (c.predicted_s, c.family)))
        return cls(deduped, n_enumerated)

    def finalists(self, k: int, runnable=None) -> list[Candidate]:
        """Top-``k`` runnable candidates by predicted round latency.

        ``runnable``: optional extra predicate (the session passes "does it
        relower on my mesh"); reference-only candidates never qualify."""
        out = []
        for c in self.candidates:
            if not c.runnable:
                continue
            if runnable is not None and not runnable(c):
                continue
            out.append(c)
            if len(out) == k:
                break
        return out

    def on_profile(self, profile: Profile) -> "PlanPortfolio":
        """Every runnable candidate re-priced on ``profile`` (decisions
        kept, costs recomputed — ``simulator.reprice_plan``)."""
        out = []
        for c in self.candidates:
            if c.plan is None:
                out.append(c)
                continue
            p = reprice_plan(c.plan, profile)
            out.append(dataclasses.replace(c, plan=p, predicted_s=p.latency))
        return PlanPortfolio(tuple(sorted(
            out, key=lambda c: (c.predicted_s, c.family))), self.n_enumerated)

    def records(self) -> list[dict]:
        """Benchmark-friendly rows, one per candidate."""
        return [{"family": c.family, "predicted_s": c.predicted_s,
                 "runnable": c.runnable,
                 "stages": len(c.plan.stages) if c.plan else 0,
                 "staleness": getattr(c.plan, "staleness", 0) if c.plan else 0,
                 "compress": (c.plan.compress.fmt
                              if c.plan is not None and c.plan.compress
                              else "none")}
                for c in self.candidates]


# ---------------------------------------------------------------------------
# probation statistics + report
# ---------------------------------------------------------------------------


def robust_latency(rounds, warmup: int = 1) -> float:
    """Warmup-trimmed median of per-round wall times.

    The first ``warmup`` rounds carry jit compilation (or a cold step
    cache) and are dropped; the median of the rest resists the one-off
    scheduler hiccups short probation windows cannot average away.  Falls
    back to the full median when trimming would leave nothing."""
    kept = sorted(rounds[warmup:]) if len(rounds) > warmup else sorted(rounds)
    if not kept:
        raise ValueError("robust_latency needs at least one round")
    n = len(kept)
    return (kept[n // 2] if n % 2
            else 0.5 * (kept[n // 2 - 1] + kept[n // 2]))


@dataclasses.dataclass(frozen=True)
class ProbeResult:
    """One finalist's probation outcome."""

    family: str
    predicted_s: float
    measured_s: float
    rounds: tuple[float, ...]        # raw per-round wall times (incl. warmup)
    installed: bool = False


@dataclasses.dataclass(frozen=True)
class ProbeReport:
    """One full portfolio auction: finalists in predicted-latency order
    (index 0 is the analytic first choice), with the measured winner."""

    results: tuple[ProbeResult, ...]
    winner_index: int
    n_candidates: int               # deduped portfolio size
    n_enumerated: int               # before dedupe
    window: int                     # probation rounds per finalist
    churned: bool                   # False = winner was already installed

    @property
    def winner(self) -> ProbeResult:
        return self.results[self.winner_index]

    @property
    def first_choice(self) -> ProbeResult:
        return self.results[0]

    def to_record(self) -> dict:
        w, f = self.winner, self.first_choice
        return {
            "finalists": len(self.results),
            "candidates": self.n_candidates,
            "enumerated": self.n_enumerated,
            "window": self.window,
            "churned": self.churned,
            "first_choice": f.family,
            "first_choice_predicted_s": f.predicted_s,
            "first_choice_measured_s": f.measured_s,
            "winner": w.family,
            "winner_predicted_s": w.predicted_s,
            "winner_measured_s": w.measured_s,
            # >= 1.0 by construction (the winner is the measured argmin)
            "measured_winner_gain": (f.measured_s / w.measured_s
                                     if w.measured_s > 0 else 1.0),
        }


def pick_winner(measured, hysteresis: float = 0.0) -> int:
    """Index of the measured winner among finalists listed in
    predicted-latency order.

    Strictly-less-than comparison *is* the tie hysteresis: a later
    finalist must measure genuinely faster to displace an earlier
    (analytically better) one, so measurements equal to predictions keep
    the analytic first choice and ties never churn the installed plan.
    ``hysteresis`` widens the margin: a challenger must beat the incumbent
    by that fraction."""
    best = 0
    for i in range(1, len(measured)):
        if measured[i] < measured[best] * (1.0 - hysteresis):
            best = i
    return best


# ---------------------------------------------------------------------------
# drift watchdog
# ---------------------------------------------------------------------------


class DriftWatchdog:
    """EWMA drift detector on the observed/predicted round-latency ratio.

    On ``install`` the incumbent plan is re-priced on the session profile
    (``simulator.reprice_plan``) to fix ``predicted_s``.  Observed step
    wall times then feed an EWMA of ``observed / predicted``; the first
    post-warmup observation sets the *baseline* ratio (host seconds and
    simulated-cluster seconds live on different scales, so only relative
    drift is meaningful).  When the EWMA drifts more than ``threshold``
    away from the baseline the watchdog trips — the session re-opens the
    auction — and re-arms on a fresh baseline."""

    def __init__(self, threshold: float = 0.25, alpha: float = 0.3,
                 warmup: int = 1):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.predicted_s: float | None = None
        self.baseline: float | None = None
        self.ewma: float | None = None
        self._skip = 0
        self.observations = 0
        self.trips = 0

    def install(self, plan: Plan, profile: Profile) -> None:
        """Arm for a freshly installed plan: re-price it on ``profile`` and
        restart the warmup/baseline cycle."""
        self.predicted_s = reprice_plan(plan, profile).latency
        self.baseline = None
        self.ewma = None
        self._skip = self.warmup

    @property
    def drift(self) -> float:
        if self.baseline is None or self.ewma is None or self.baseline <= 0:
            return 0.0
        return abs(self.ewma / self.baseline - 1.0)

    def observe(self, observed_s: float) -> bool:
        """Feed one measured round; returns True when the auction should
        re-open."""
        if self.predicted_s is None or self.predicted_s <= 0:
            return False
        if self._skip > 0:
            self._skip -= 1
            return False
        ratio = observed_s / self.predicted_s
        self.observations += 1
        if self.baseline is None:
            self.baseline = ratio
            self.ewma = ratio
            return False
        self.ewma = self.alpha * ratio + (1.0 - self.alpha) * self.ewma
        if self.drift > self.threshold:
            self.trips += 1
            # re-arm on a fresh baseline so one drifted regime fires once,
            # not on every subsequent step
            self.baseline = None
            self.ewma = None
            self._skip = self.warmup
            return True
        return False
