"""Discrete-event simulator for HPP training rounds.

Executes a ``Plan`` under a micro-batch schedule (1F1B with K_p, or GPipe)
with explicit inter-stage communication channels, producing:

* the HPP-Round makespan (validates the planner's dominant-step estimate),
* per-device peak memory (validates Eq. 3 and the K_p policies, Fig. 15b),
* per-stage utilization / bubble fractions,
* a step-level trace for visualization.

The model: each stage executes its op order sequentially (the device group
acts in lockstep; intra-group DP runs concurrently so an op costs the max
over members, which is exactly the planner's Ef/Eb).  Each adjacent-stage
link carries one transfer at a time per direction.
"""

from __future__ import annotations

import dataclasses
import heapq

from .costmodel import Step, hpp_round_latency, stage_memory
from .planner import Plan
from .profiler import Profile
from .schedule import Op, schedule_orders


@dataclasses.dataclass
class SimResult:
    makespan: float
    peak_mem: dict[int, float]          # device rank -> bytes
    stage_busy: list[float]             # busy seconds per stage (lockstep max)
    bubble_frac: list[float]
    trace: list[tuple]                  # (t_start, t_end, stage, op)
    # per-device compute seconds at the *allocated* sample count y_d — the
    # Eq. (8) decomposition of each stage's lockstep op time (a device whose
    # allocation is below the stage max idles for the difference)
    device_busy: dict[int, float] = dataclasses.field(default_factory=dict)
    # two-stream decomposition (DESIGN.md §8): the Execution-Phase span
    # (compute stream), the largest stage AllReduce (comm stream), and the
    # AllReduce seconds the round actually charges after overlap.  Under
    # staleness 0 every AllReduce is charged (sync semantics); under
    # staleness >= 1 only the part exceeding the Execution Phase is.
    exec_span_s: float = 0.0
    allreduce_s: float = 0.0
    charged_allreduce_s: float = 0.0
    staleness: int = 0

    @property
    def max_peak_mem(self) -> float:
        return max(self.peak_mem.values())

    @property
    def hidden_comm_s(self) -> float:
        """AllReduce seconds the overlap removed from the critical path."""
        return self.allreduce_s - self.charged_allreduce_s

    def device_util(self, d: int) -> float:
        """Fraction of the round this device computes (vs idles/bubbles)."""
        return self.device_busy[d] / self.makespan if self.makespan else 0.0


def simulate(plan: Plan, profile: Profile, policy: str = "ours", *,
             staleness: int | None = None,
             serialize_p2p: bool = False) -> SimResult:
    """Discrete-event execution of ``plan``.

    Two resources per boundary: each stage's compute stream and each
    adjacent-stage link (one transfer at a time per direction).

    ``serialize_p2p=True`` additionally charges each boundary transfer to
    the *sending stage's compute stream* — the pre-double-buffer runtime,
    whose tick scan holds the stage while the ppermute drains.  The default
    models the double-buffered runtime, where a send only occupies the
    link.

    ``staleness`` (default: ``plan.staleness``) selects how the gradient
    AllReduce phases are charged: 0 appends each stage's T_a to its
    execution span (sync rounds); >= 1 runs them on the comm stream during
    the next round's warm-up, so the makespan only grows past the
    Execution Phase when the slowest AllReduce outlasts a whole round.
    """
    stages = plan.stages
    P, M = len(stages), plan.n_micro
    if staleness is None:
        staleness = getattr(plan, "staleness", 0)
    exec_steps = [s for s in plan.steps if s.kind == "exec"]
    comm_steps = [s for s in plan.steps if s.kind == "comm"]
    assert len(exec_steps) == P and len(comm_steps) == P - 1

    orders = schedule_orders(P, M, policy)

    # per-device op times at the allocated sample counts (Eq. 8 terms)
    dev_times: list[tuple[tuple[int, float, float], ...]] = []
    for st in stages:
        i, j = st.layers
        dev_times.append(tuple(
            (d, profile.t_fwd(d, y, i, j), profile.t_bwd(d, y, i, j))
            for d, y in zip(st.group, st.alloc)))
    device_busy = {d: 0.0 for st in stages for d in st.group}

    # --- readiness state -------------------------------------------------
    f_done = [[False] * M for _ in range(P)]        # F(p, m) finished
    b_done = [[False] * M for _ in range(P)]
    f_arrived = [[False] * M for _ in range(P)]     # activations available
    b_arrived = [[False] * M for _ in range(P)]     # gradient available
    for m in range(M):
        f_arrived[0][m] = True                      # stage 0 reads input
    op_idx = [0] * P
    stage_free_at = [0.0] * P
    link_free_fwd = [0.0] * (P - 1)
    link_free_bwd = [0.0] * (P - 1)

    trace: list[tuple] = []
    busy = [0.0] * P

    # event heap: (time, seq, kind, payload)
    heap: list[tuple] = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(heap, (t, seq, kind, payload))
        seq += 1

    def ready(p: int, op: Op) -> bool:
        if op.kind == "F":
            return f_arrived[p][op.micro]
        if p == P - 1:
            return f_done[p][op.micro]
        return b_arrived[p][op.micro]

    def try_start(p: int, now: float):
        if op_idx[p] >= len(orders[p]):
            return
        op = orders[p][op_idx[p]]
        if not ready(p, op):
            return
        start = max(now, stage_free_at[p])
        dur = exec_steps[p].ef if op.kind == "F" else exec_steps[p].eb
        end = start + dur
        stage_free_at[p] = end
        op_idx[p] += 1
        busy[p] += dur
        for d, tf, tb in dev_times[p]:
            device_busy[d] += tf if op.kind == "F" else tb
        trace.append((start, end, p, f"{op.kind}{op.micro}"))
        push(end, "exec_done", (p, op))

    now = 0.0
    for p in range(P):
        try_start(p, 0.0)

    while heap:
        now, _, kind, payload = heapq.heappop(heap)
        if kind == "exec_done":
            p, op = payload
            if op.kind == "F":
                f_done[p][op.micro] = True
                if p < P - 1:   # send activation forward
                    t0 = max(now, link_free_fwd[p])
                    t1 = t0 + comm_steps[p].ef
                    link_free_fwd[p] = t1
                    if serialize_p2p:   # the tick scan holds the stage too
                        stage_free_at[p] = max(stage_free_at[p], t1)
                    push(t1, "fwd_arrive", (p + 1, op.micro))
            else:
                b_done[p][op.micro] = True
                if p > 0:       # send gradient backward
                    t0 = max(now, link_free_bwd[p - 1])
                    t1 = t0 + comm_steps[p - 1].eb
                    link_free_bwd[p - 1] = t1
                    if serialize_p2p:
                        stage_free_at[p] = max(stage_free_at[p], t1)
                    push(t1, "bwd_arrive", (p - 1, op.micro))
            try_start(p, now)
        elif kind == "fwd_arrive":
            p, m = payload
            f_arrived[p][m] = True
            try_start(p, now)
        elif kind == "bwd_arrive":
            p, m = payload
            b_arrived[p][m] = True
            try_start(p, now)

    # AllReduce phases: appended to each stage's span (sync), or drained on
    # the comm stream during the next round's warm-up (staleness >= 1) —
    # then only an AllReduce outlasting the whole Execution Phase extends
    # the steady-state round.
    exec_span = max(stage_free_at)
    ar_max = max((s.ta for s in exec_steps), default=0.0)
    if staleness >= 1:
        makespan = max(exec_span, ar_max)
        charged_ar = makespan - exec_span
    else:
        makespan = 0.0
        for p in range(P):
            stage_end = stage_free_at[p] + exec_steps[p].ta
            makespan = max(makespan, stage_end)
        charged_ar = makespan - exec_span

    # peak resident activations per stage, from the executed trace: a
    # micro-batch is resident from its F's *start* (not scheduling time —
    # an op can be queued behind a still-running one) until its B's end.
    act_peak = [0] * P
    events: list[list[tuple]] = [[] for _ in range(P)]
    for (t0, t1, p, op) in trace:
        if op[0] == "F":
            events[p].append((t0, 1))
        else:
            events[p].append((t1, -1))
    for p in range(P):
        live = 0
        for _, delta in sorted(events[p]):      # (-1) sorts before (+1) at ties
            live += delta
            act_peak[p] = max(act_peak[p], live)

    # memory accounting (per device)
    peak_mem: dict[int, float] = {}
    for p, st in enumerate(stages):
        w = profile.table.param_bytes(*st.layers)
        for d, y in zip(st.group, st.alloc):
            share = w  # each replica holds the full stage model
            static = stage_memory(profile.table, *st.layers, 0, 0)  # MOD+OPT
            act = profile.table.act_bytes_sum(*st.layers) * y
            peak_mem[d] = static + act_peak[p] * act

    bubble = [1.0 - busy[p] / exec_span if exec_span > 0 else 0.0
              for p in range(P)]
    return SimResult(makespan, peak_mem, busy, bubble, trace, device_busy,
                     exec_span_s=exec_span, allreduce_s=ar_max,
                     charged_allreduce_s=charged_ar, staleness=staleness)


# ---------------------------------------------------------------------------
# Cross-profile evaluation: predicted vs measured gap
# ---------------------------------------------------------------------------


def reprice_plan(plan: Plan, profile: Profile) -> Plan:
    """Re-price ``plan``'s steps under a (possibly different) ``Profile``.

    Keeps the plan's *decisions* — stage layer ranges, device groups,
    per-device allocations, micro-batch structure — and recomputes the step
    costs from ``profile``: Eq. (8) stage times at the allocated counts,
    Eq. (5) AllReduce over the stage group, boundary-activation transfer
    over the slowest inter-group link.  ``latency`` is re-evaluated with
    Eqs. (4)–(6).  The plan's compression choice (``plan.compress``) is
    re-applied, so a compressed plan stays priced over the quantized wire
    on the new profile.  This is how "what would this plan actually cost
    on the measured device times" is asked of an analytically-planned
    pipeline.
    """
    from .planner import _comm_step, _stage_ta

    compress = getattr(plan, "compress", None)
    exec_in = [s for s in plan.steps if s.kind == "exec"]
    steps: list[Step] = []
    for k, s in enumerate(exec_in):
        i, j = s.layers
        ef = max(profile.t_fwd(d, y, i, j) for d, y in zip(s.group, s.alloc))
        eb = max(profile.t_bwd(d, y, i, j) for d, y in zip(s.group, s.alloc))
        ta = _stage_ta(profile, i, j, s.group, compress, eb * plan.n_micro)
        steps.append(Step("exec", ef, eb, ta, s.group, s.layers, s.alloc))
        if k < len(exec_in) - 1:
            steps.append(_comm_step(profile, plan.micro_batch, j, s.group,
                                    exec_in[k + 1].group, compress))
    lat = hpp_round_latency(tuple(steps), plan.n_micro,
                            getattr(plan, "staleness", 0))
    return dataclasses.replace(plan, steps=tuple(steps), latency=lat)


def prediction_gap(plan: Plan, reference: Profile,
                   policy: str = "ours") -> dict:
    """Quantify how well ``plan``'s own latency estimate predicts its cost
    under ``reference`` (typically the *measured* profile).

    Returns a record with the planner's dominant-step estimate
    (``predicted_s``, Eqs. 4–6 on the profile the plan was made with), the
    same estimate re-priced on ``reference`` (``reference_s``), the
    event-accurate simulation of the re-priced plan
    (``reference_sim_s``), and ``gap_ratio = reference_s / predicted_s`` —
    the factor by which the planning profile misprices reality.  A plan
    made *on* the reference profile has gap_ratio 1 by construction; an
    analytically-planned pipeline evaluated against measured tables shows
    the error the paper's measured profiler exists to remove.
    """
    repriced = reprice_plan(plan, reference)
    sim = simulate(repriced, reference, policy)
    return {
        "reference_source": reference.source,
        "predicted_s": plan.latency,
        "reference_s": repriced.latency,
        "reference_sim_s": sim.makespan,
        "gap_ratio": (repriced.latency / plan.latency
                      if plan.latency > 0 else float("inf")),
    }


def observed_gap(plan: Plan, reference: Profile, observed_s: float) -> dict:
    """``prediction_gap``'s closed-loop sibling: compare a *measured* round
    latency against the plan re-priced on ``reference``.

    Where ``prediction_gap`` compares two analytic pricings (planning
    profile vs reference profile), this compares the reference pricing
    against what the live mesh actually measured — the quantity the
    portfolio drift watchdog (DESIGN.md §12) tracks.  ``gap_ratio`` is
    observed/predicted; host wall-seconds and simulated-cluster seconds
    live on different scales, so consumers should track *drift* of this
    ratio, not its absolute value.
    """
    repriced = reprice_plan(plan, reference)
    return {
        "reference_source": reference.source,
        "predicted_s": repriced.latency,
        "observed_s": observed_s,
        "gap_ratio": (observed_s / repriced.latency
                      if repriced.latency > 0 else float("inf")),
    }


def reprice_serve_plan(plan, profile: Profile):
    """Re-price a ``ServePlan``'s latency figures under ``profile``.

    The serving analogue of ``reprice_plan``: keeps the plan's decisions —
    stage count, tp width, layer cuts, per-shard slot split — and
    recomputes step_time / token_latency / percentiles from ``profile``'s
    measured per-token forward slices and the §10 link model, at the same
    offered load.  This is how a plan made on the analytic profile is asked
    what it would cost on the measured one.
    """
    from .planner import _price_serve_alloc

    st, lat, pct = _price_serve_alloc(
        profile, plan.shard_alloc, stage=plan.stage, tp=plan.tp,
        cuts=plan.cuts, seq_len=plan.seq_len,
        arrival_rate=plan.arrival_rate, compress=plan.compress)
    return dataclasses.replace(plan, step_time=st, token_latency=lat,
                               predicted_p50=pct[0], predicted_p95=pct[1],
                               predicted_p99=pct[2])


def serve_prediction_gap(plan, reference: Profile) -> dict:
    """Predicted-vs-repriced gap for a ``ServePlan`` (the p99 analogue of
    ``prediction_gap``): re-prices the plan's slot split on ``reference``
    and reports the p99 ratio the planning profile mispriced by."""
    repriced = reprice_serve_plan(plan, reference)
    return {
        "reference_source": reference.source,
        "predicted_p99_s": plan.predicted_p99,
        "reference_p99_s": repriced.predicted_p99,
        "predicted_step_s": plan.step_time,
        "reference_step_s": repriced.step_time,
        "gap_ratio": (repriced.predicted_p99 / plan.predicted_p99
                      if plan.predicted_p99 > 0 else float("inf")),
    }
