"""Lower an Asteroid ``Plan`` (Algorithm 2 output) into the pipeline runtime.

The planner reasons about an edge cluster in *layer-table* coordinates:
stages are layer ranges ``[i, j)`` over ``embed + n_layers + head`` pseudo
layers, device groups are ranks into the profiled cluster, and micro-batch
allocations are per-device sample counts.  The shard_map runtime
(``repro.runtime``) executes in *mesh* coordinates: a refined
``(pod, data, stage, tp)`` mesh whose ``stage`` axis slices the stacked
period params, with ``M`` micro-batches streamed through a circular
ppermute pipeline.

``lower_plan`` translates between the two worlds:

* stage count        -> ``MeshPlan.stage`` (must divide the mesh model axis),
* layer ranges       -> per-stage *period* ranges, cuts snapped to period
                        boundaries (periods are the runtime's atomic unit),
* ``Plan.n_micro``   -> the runtime's micro-batch count ``M``,
* per-stage warm-up  -> K_p from ``core.schedule`` (validated against the
                        plan's own ``StagePlan.k_p``).

``plan_to_train_step`` then builds the runnable distributed train step, and
``check_against_simulator`` cross-checks the lowered schedule against the
discrete-event simulator: per-stage op counts, the unit-cost makespan in
ticks, and the O(K_p) resident-activation bound (DESIGN.md §2–3).
"""

from __future__ import annotations

import dataclasses

from .costmodel import kp_policy, stage_memory
from .planner import Plan
from .profiler import Profile
from .schedule import max_inflight, schedule_orders
from .simulator import SimResult, simulate


class LoweringError(RuntimeError):
    """The plan cannot be realized on the requested runtime mesh."""


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    """Runtime-coordinate view of an Asteroid ``Plan``."""

    arch: str
    stage: int                                  # pipeline depth P
    n_micro: int                                # micro-batches per round M
    micro_batch: int                            # samples per micro-batch
    global_batch: int
    n_periods: int                              # real periods in the model
    stage_periods: tuple[tuple[int, int], ...]  # period range [i, j) per stage
    stage_layers: tuple[tuple[int, int], ...]   # original table layer ranges
    device_groups: tuple[tuple[int, ...], ...]  # edge-cluster ranks (Plan)
    micro_alloc: tuple[tuple[int, ...], ...]    # per-device sample allocation
    warmup: tuple[int, ...]                     # K_p per stage

    @property
    def k_per_stage(self) -> int:
        """Uniform periods-per-stage slice width (max range, zero-padded)."""
        return max(j - i for i, j in self.stage_periods)

    @property
    def forward_ticks(self) -> int:
        """Scan length of the runtime's circular forward pipeline."""
        return self.n_micro + self.stage - 1

    @property
    def total_ticks(self) -> int:
        """Forward scan + its grad-reversed backward scan."""
        return 2 * self.forward_ticks

    def orders(self, policy: str = "ours"):
        """Per-stage 1F1B op orders for this plan's (P, M)."""
        return schedule_orders(self.stage, self.n_micro, policy)

    def peak_inflight(self, policy: str = "ours") -> tuple[int, ...]:
        """Peak resident micro-batches per stage under the op orders."""
        return tuple(max_inflight(o) for o in self.orders(policy))

    def memory_bound(self, profile: Profile) -> dict[int, float]:
        """Eq. (3) per-device peak bytes implied by the lowered schedule."""
        out: dict[int, float] = {}
        for st_layers, group, alloc, k in zip(self.stage_layers,
                                              self.device_groups,
                                              self.micro_alloc, self.warmup):
            for d, y in zip(group, alloc):
                out[d] = stage_memory(profile.table, *st_layers, y, k,
                                      self.n_micro)
        return out

    def tick_makespan(self, policy: str = "ours") -> int:
        """Schedule completion time in unit ticks (ef = eb = 1, zero comm).

        An independent list-scheduling implementation of the simulator's
        dependency rules, used to cross-validate the two.
        """
        P, M = self.stage, self.n_micro
        orders = self.orders(policy)
        f_done = [[None] * M for _ in range(P)]
        b_done = [[None] * M for _ in range(P)]
        idx = [0] * P
        free = [0] * P
        remaining = sum(len(o) for o in orders)
        while remaining:
            progressed = False
            for p in range(P):
                while idx[p] < len(orders[p]):
                    op = orders[p][idx[p]]
                    if op.kind == "F":
                        dep = 0 if p == 0 else f_done[p - 1][op.micro]
                    elif p == P - 1:
                        dep = f_done[p][op.micro]
                    else:
                        dep = b_done[p + 1][op.micro]
                    if dep is None:
                        break
                    end = max(free[p], dep) + 1
                    free[p] = end
                    (f_done if op.kind == "F" else b_done)[p][op.micro] = end
                    idx[p] += 1
                    remaining -= 1
                    progressed = True
            if not progressed:
                raise LoweringError("deadlocked schedule (invalid op orders)")
        return max(free)


# ---------------------------------------------------------------------------
# Plan -> runtime coordinates
# ---------------------------------------------------------------------------


def _snap_to_periods(stage_layers, n_layers: int, pattern_len: int,
                     n_periods: int) -> tuple[tuple[int, int], ...]:
    """Snap table-coordinate layer cuts to period boundaries.

    Table layout: index 0 = embed, 1..n_layers = real layers, L-1 = head.
    Interior cuts land on the nearest period boundary, kept strictly
    monotone so every stage owns >= 1 period.
    """
    P = len(stage_layers)
    if P > n_periods:
        raise LoweringError(
            f"plan has {P} stages but the model only has {n_periods} periods")
    cuts = [0]
    for s, (i, j) in enumerate(stage_layers[:-1]):
        r = min(max(j - 1, 0), n_layers)           # cut in real-layer coords
        per = round(r / pattern_len)
        # strictly monotone, leaving >= 1 period for each remaining stage
        per = max(per, cuts[-1] + 1)
        per = min(per, n_periods - (P - 1 - s))
        cuts.append(per)
    cuts.append(n_periods)
    return tuple((cuts[p], cuts[p + 1]) for p in range(P))


def lower_plan(plan: Plan, cfg, model_axis: int | None = None) -> LoweredPlan:
    """Translate ``plan`` into runtime coordinates for ``cfg``.

    ``model_axis``: size of the production mesh's model axis; when given the
    stage count must divide it (tp = model_axis / stage).
    """
    P = len(plan.stages)
    if model_axis is not None and model_axis % P != 0:
        raise LoweringError(
            f"stage count {P} does not divide the mesh model axis "
            f"{model_axis}; re-plan with max_stages set to a divisor")
    if cfg.n_layers % len(cfg.pattern) != 0:
        raise LoweringError(
            f"n_layers {cfg.n_layers} not a multiple of the pattern "
            f"({len(cfg.pattern)})")
    n_periods = cfg.n_layers // len(cfg.pattern)

    stage_layers = tuple(st.layers for st in plan.stages)
    for (a, b), (c, _) in zip(stage_layers[:-1], stage_layers[1:]):
        if b != c:
            raise LoweringError(f"stage layer ranges not contiguous: {b} != {c}")

    stage_periods = _snap_to_periods(stage_layers, cfg.n_layers,
                                     len(cfg.pattern), n_periods)

    warmup = tuple(kp_policy(P, p) for p in range(P))
    for p, st in enumerate(plan.stages):
        if st.k_p != warmup[p]:
            raise LoweringError(
                f"stage {p} warm-up {st.k_p} != schedule K_p {warmup[p]}")
        if sum(st.alloc) != plan.micro_batch:
            raise LoweringError(
                f"stage {p} allocation {st.alloc} does not sum to the "
                f"micro-batch {plan.micro_batch}")
    if plan.n_micro * plan.micro_batch != plan.global_batch:
        raise LoweringError("n_micro * micro_batch != global_batch")

    return LoweredPlan(
        arch=plan.arch, stage=P, n_micro=plan.n_micro,
        micro_batch=plan.micro_batch, global_batch=plan.global_batch,
        n_periods=n_periods, stage_periods=stage_periods,
        stage_layers=stage_layers,
        device_groups=tuple(st.group for st in plan.stages),
        micro_alloc=tuple(st.alloc for st in plan.stages), warmup=warmup)


# ---------------------------------------------------------------------------
# Simulator cross-check
# ---------------------------------------------------------------------------


def _unitize(plan: Plan) -> Plan:
    """Copy of ``plan`` with unit exec cost and free communication."""
    steps = tuple(
        dataclasses.replace(s, ef=1.0, eb=1.0, ta=0.0) if s.kind == "exec"
        else dataclasses.replace(s, ef=0.0, eb=0.0) for s in plan.steps)
    return dataclasses.replace(plan, steps=steps)


def check_against_simulator(lowered: LoweredPlan, plan: Plan,
                            profile: Profile, policy: str = "ours",
                            rel_tol: float = 1e-6) -> SimResult:
    """Assert the lowered schedule agrees with the discrete-event simulator.

    1. every stage executes exactly M forwards + M backwards,
    2. the simulator's makespan on a unit-cost copy of the plan equals the
       lowered schedule's tick count (two independent implementations of
       the same dependency rules),
    3. peak resident activations per stage equal ``min(max(1, K_p), M)`` —
       the O(K_p) 1F1B memory bound — and the simulator's per-device peak
       bytes stay within the Eq. (3) budget the lowering derives.
    Returns the (real-cost) simulation for further inspection.
    """
    M, P = lowered.n_micro, lowered.stage
    sim = simulate(plan, profile, policy)

    ops_per_stage = [0] * P
    for (_, _, p, _) in sim.trace:
        ops_per_stage[p] += 1
    assert ops_per_stage == [2 * M] * P, (ops_per_stage, M)

    unit = simulate(_unitize(plan), profile, policy)
    ticks = lowered.tick_makespan(policy)
    assert abs(unit.makespan - ticks) <= rel_tol * ticks, \
        (unit.makespan, ticks)

    inflight = lowered.peak_inflight(policy)
    expected = tuple(min(max(1, k), M) for k in lowered.warmup)
    assert inflight == expected, (inflight, expected)

    bound = lowered.memory_bound(profile)
    for d, peak in sim.peak_mem.items():
        assert peak <= bound[d] * (1 + rel_tol), (d, peak, bound[d])
    return sim


# ---------------------------------------------------------------------------
# Runtime bridge
# ---------------------------------------------------------------------------


def plan_to_train_step(plan: Plan, profile: Profile | None, cfg,
                       production_mesh=None, *, check: bool = True, **kw):
    """Build a runnable distributed train step from an Asteroid ``Plan``.

    Returns ``(TrainStep, LoweredPlan)``.  ``production_mesh`` defaults to a
    ``(data=1, model=N)`` mesh over the local jax devices.  When ``profile``
    is given and ``check`` is True, the lowered schedule is cross-checked
    against the simulator before anything is compiled.
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.runtime.train import build_train_step

    if production_mesh is None:
        devs = jax.devices()
        production_mesh = Mesh(np.array(devs).reshape(1, len(devs)),
                               ("data", "model"))
    lowered = lower_plan(plan, cfg, production_mesh.shape["model"])
    if check and profile is not None:
        check_against_simulator(lowered, plan, profile)

    dp = (production_mesh.shape.get("pod", 1) *
          production_mesh.shape["data"])
    if lowered.global_batch % dp or (lowered.global_batch // dp) % lowered.n_micro:
        raise LoweringError(
            f"global batch {lowered.global_batch} not divisible into "
            f"{lowered.n_micro} micro-batches per {dp} data shards")

    ts = build_train_step(cfg, production_mesh,
                          global_batch=lowered.global_batch,
                          stage=lowered.stage, n_micro=lowered.n_micro,
                          stage_periods=lowered.stage_periods, **kw)
    return ts, lowered
