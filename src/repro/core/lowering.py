"""Lower an Asteroid ``Plan`` (Algorithm 2 output) into the pipeline runtime.

The planner reasons about an edge cluster in *layer-table* coordinates:
stages are layer ranges ``[i, j)`` over ``embed + n_layers + head`` pseudo
layers, device groups are ranks into the profiled cluster, and micro-batch
allocations are per-device sample counts.  The shard_map runtime
(``repro.runtime``) executes in *mesh* coordinates: a refined
``(pod, data, stage, tp)`` mesh whose ``stage`` axis slices the stacked
period params, with ``M`` micro-batches streamed through a circular
ppermute pipeline.

``lower_plan`` translates between the two worlds:

* stage count        -> ``MeshPlan.stage`` (must divide the mesh model axis),
* layer ranges       -> per-stage *period* ranges, cuts snapped to period
                        boundaries (periods are the runtime's atomic unit),
* ``Plan.n_micro``   -> the runtime's micro-batch count ``M``,
* per-stage warm-up  -> K_p from ``core.schedule`` (validated against the
                        plan's own ``StagePlan.k_p``),
* ``micro_alloc``    -> per-data-shard sample counts (``lower_micro_alloc``):
                        Algorithm 1's heterogeneous intra-stage allocation,
                        realized by padding every shard's micro-batch to
                        ``B_max = max_d y_d`` with a static validity mask —
                        the batch-dimension analogue of how
                        ``arrange_periods`` realizes heterogeneous layer
                        splits.

``plan_to_train_step`` then builds the runnable distributed train step, and
``check_against_simulator`` cross-checks the lowered schedule against the
discrete-event simulator: per-stage op counts, the unit-cost makespan in
ticks, and the O(K_p) resident-activation bound (DESIGN.md §2, §4).

The *replay* half of the module makes a lowered pipeline re-lowerable while
training (DESIGN.md §7): ``relower`` lowers a replacement ``Plan`` against
an existing ``LoweredPlan``'s runtime, ``migrate_params`` /
``migrate_opt_state`` re-arrange the stacked period params (and optimizer
moments, with the same index map) from the old stage split to the new one,
and ``reconcile_migration`` checks the resulting per-boundary bytes against
the analytical ``RecoveryReport`` a ``lightweight_replay`` produced.
"""

from __future__ import annotations

import dataclasses

from .costmodel import kp_policy, stage_memory
from .planner import Plan
from .profiler import Profile
from .schedule import max_inflight, schedule_orders
from .simulator import SimResult, simulate


class LoweringError(RuntimeError):
    """The plan cannot be realized on the requested runtime mesh."""


@dataclasses.dataclass(frozen=True)
class LoweredPlan:
    """Runtime-coordinate view of an Asteroid ``Plan``."""

    arch: str
    stage: int                                  # pipeline depth P
    n_micro: int                                # micro-batches per round M
    micro_batch: int                            # samples per micro-batch
    global_batch: int
    n_periods: int                              # real periods in the model
    stage_periods: tuple[tuple[int, int], ...]  # period range [i, j) per stage
    stage_layers: tuple[tuple[int, int], ...]   # original table layer ranges
    device_groups: tuple[tuple[int, ...], ...]  # edge-cluster ranks (Plan)
    micro_alloc: tuple[tuple[int, ...], ...]    # per-device sample allocation
    warmup: tuple[int, ...]                     # K_p per stage

    @property
    def k_per_stage(self) -> int:
        """Uniform periods-per-stage slice width (max range, zero-padded)."""
        return max(j - i for i, j in self.stage_periods)

    @property
    def forward_ticks(self) -> int:
        """Scan length of the runtime's circular forward pipeline."""
        return self.n_micro + self.stage - 1

    @property
    def total_ticks(self) -> int:
        """Forward scan + its grad-reversed backward scan."""
        return 2 * self.forward_ticks

    def orders(self, policy: str = "ours"):
        """Per-stage 1F1B op orders for this plan's (P, M)."""
        return schedule_orders(self.stage, self.n_micro, policy)

    def peak_inflight(self, policy: str = "ours") -> tuple[int, ...]:
        """Peak resident micro-batches per stage under the op orders."""
        return tuple(max_inflight(o) for o in self.orders(policy))

    def memory_bound(self, profile: Profile) -> dict[int, float]:
        """Eq. (3) per-device peak bytes implied by the lowered schedule."""
        out: dict[int, float] = {}
        for st_layers, group, alloc, k in zip(self.stage_layers,
                                              self.device_groups,
                                              self.micro_alloc, self.warmup):
            for d, y in zip(group, alloc):
                out[d] = stage_memory(profile.table, *st_layers, y, k,
                                      self.n_micro)
        return out

    def tick_makespan(self, policy: str = "ours") -> int:
        """Schedule completion time in unit ticks (ef = eb = 1, zero comm).

        An independent list-scheduling implementation of the simulator's
        dependency rules, used to cross-validate the two.
        """
        P, M = self.stage, self.n_micro
        orders = self.orders(policy)
        f_done = [[None] * M for _ in range(P)]
        b_done = [[None] * M for _ in range(P)]
        idx = [0] * P
        free = [0] * P
        remaining = sum(len(o) for o in orders)
        while remaining:
            progressed = False
            for p in range(P):
                while idx[p] < len(orders[p]):
                    op = orders[p][idx[p]]
                    if op.kind == "F":
                        dep = 0 if p == 0 else f_done[p - 1][op.micro]
                    elif p == P - 1:
                        dep = f_done[p][op.micro]
                    else:
                        dep = b_done[p + 1][op.micro]
                    if dep is None:
                        break
                    end = max(free[p], dep) + 1
                    free[p] = end
                    (f_done if op.kind == "F" else b_done)[p][op.micro] = end
                    idx[p] += 1
                    remaining -= 1
                    progressed = True
            if not progressed:
                raise LoweringError("deadlocked schedule (invalid op orders)")
        return max(free)


# ---------------------------------------------------------------------------
# Plan -> runtime coordinates
# ---------------------------------------------------------------------------


def _snap_to_periods(stage_layers, n_layers: int, pattern_len: int,
                     n_periods: int) -> tuple[tuple[int, int], ...]:
    """Snap table-coordinate layer cuts to period boundaries.

    Table layout: index 0 = embed, 1..n_layers = real layers, L-1 = head.
    Interior cuts land on the nearest period boundary, kept strictly
    monotone so every stage owns >= 1 period.
    """
    P = len(stage_layers)
    if P > n_periods:
        raise LoweringError(
            f"plan has {P} stages but the model only has {n_periods} periods")
    cuts = [0]
    for s, (i, j) in enumerate(stage_layers[:-1]):
        r = min(max(j - 1, 0), n_layers)           # cut in real-layer coords
        per = round(r / pattern_len)
        # strictly monotone, leaving >= 1 period for each remaining stage
        per = max(per, cuts[-1] + 1)
        per = min(per, n_periods - (P - 1 - s))
        cuts.append(per)
    cuts.append(n_periods)
    return tuple((cuts[p], cuts[p + 1]) for p in range(P))


def lower_plan(plan: Plan, cfg, model_axis: int | None = None) -> LoweredPlan:
    """Translate ``plan`` into runtime coordinates for ``cfg``.

    ``model_axis``: size of the production mesh's model axis; when given the
    stage count must divide it (tp = model_axis / stage).

    Validates the plan's internal contract before anything compiles: stage
    ranges contiguous, per-stage warm-ups equal to the schedule's
    ``kp_policy`` K_p (the Eq. 3 memory bound assumes them), allocations
    summing to the micro-batch, ``n_micro * micro_batch == global_batch``.
    """
    P = len(plan.stages)
    if model_axis is not None and model_axis % P != 0:
        raise LoweringError(
            f"stage count {P} does not divide the mesh model axis "
            f"{model_axis}; re-plan with max_stages set to a divisor")
    if cfg.n_layers % len(cfg.pattern) != 0:
        raise LoweringError(
            f"n_layers {cfg.n_layers} not a multiple of the pattern "
            f"({len(cfg.pattern)})")
    n_periods = cfg.n_layers // len(cfg.pattern)

    stage_layers = tuple(st.layers for st in plan.stages)
    for (a, b), (c, _) in zip(stage_layers[:-1], stage_layers[1:]):
        if b != c:
            raise LoweringError(f"stage layer ranges not contiguous: {b} != {c}")

    stage_periods = _snap_to_periods(stage_layers, cfg.n_layers,
                                     len(cfg.pattern), n_periods)

    warmup = tuple(kp_policy(P, p) for p in range(P))
    for p, st in enumerate(plan.stages):
        if st.k_p != warmup[p]:
            raise LoweringError(
                f"stage {p} warm-up {st.k_p} != schedule K_p {warmup[p]}")
        if sum(st.alloc) != plan.micro_batch:
            raise LoweringError(
                f"stage {p} allocation {st.alloc} does not sum to the "
                f"micro-batch {plan.micro_batch}")
    if plan.n_micro * plan.micro_batch != plan.global_batch:
        raise LoweringError("n_micro * micro_batch != global_batch")

    return LoweredPlan(
        arch=plan.arch, stage=P, n_micro=plan.n_micro,
        micro_batch=plan.micro_batch, global_batch=plan.global_batch,
        n_periods=n_periods, stage_periods=stage_periods,
        stage_layers=stage_layers,
        device_groups=tuple(st.group for st in plan.stages),
        micro_alloc=tuple(st.alloc for st in plan.stages), warmup=warmup)


# ---------------------------------------------------------------------------
# Micro-batch allocation -> data-shard coordinates
# ---------------------------------------------------------------------------


def _project_alloc(alloc: tuple[int, ...], dp: int) -> tuple[int, ...]:
    """Project one stage's per-device allocation onto ``dp`` data shards.

    Devices keep the planner's order.  With more devices than shards,
    contiguous device blocks aggregate onto one shard; with fewer, each
    device's share is split evenly across its block of shards (that device's
    work is data-parallel over several mesh columns).
    """
    G = len(alloc)
    if G == dp:
        return tuple(alloc)
    if G > dp:
        bounds = [s * G // dp for s in range(dp + 1)]
        return tuple(sum(alloc[bounds[s]:bounds[s + 1]]) for s in range(dp))
    out = [0] * dp
    for g, y in enumerate(alloc):
        lo, hi = g * dp // G, (g + 1) * dp // G
        q, r = divmod(y, hi - lo)
        for k in range(hi - lo):
            out[lo + k] = q + (1 if k < r else 0)
    return tuple(out)


def lower_micro_alloc(lowered: LoweredPlan, dp_shards: int) -> tuple[int, ...]:
    """Collapse the plan's per-stage device allocations (Algorithm 1 /
    Eq. 9) into the single per-data-shard sample allocation the shard_map
    runtime executes.

    In mesh coordinates every stage's intra-stage group is the *same* set of
    ``dp_shards`` data columns (the mesh is rectangular), and the circular
    pipeline never re-splits samples across the data axis between stages —
    so Algorithm 1's per-stage allocations are projected onto ``dp_shards``
    slots (``_project_alloc``) and, when stages disagree, combined by
    largest-remainder rounding of their mean.  When every stage projects to
    the same vector the result is exact; the returned counts always sum to
    ``lowered.micro_batch``.
    """
    if dp_shards < 1:
        raise LoweringError(f"dp_shards must be >= 1, got {dp_shards}")
    mb = lowered.micro_batch
    projs = [_project_alloc(a, dp_shards) for a in lowered.micro_alloc]
    if all(p == projs[0] for p in projs):
        out = projs[0]
    else:
        mean = [sum(p[d] for p in projs) / len(projs)
                for d in range(dp_shards)]
        base = [int(x) for x in mean]
        rem = mb - sum(base)
        order = sorted(range(dp_shards), key=lambda d: (base[d] - mean[d], d))
        for d in order[:rem]:
            base[d] += 1
        out = tuple(base)
    if sum(out) != mb or any(y < 0 for y in out):
        raise LoweringError(
            f"collapsed allocation {out} does not partition the micro-batch "
            f"{mb} over {dp_shards} data shards")
    return out


# ---------------------------------------------------------------------------
# Simulator cross-check
# ---------------------------------------------------------------------------


def _unitize(plan: Plan) -> Plan:
    """Copy of ``plan`` with unit exec cost and free communication."""
    steps = tuple(
        dataclasses.replace(s, ef=1.0, eb=1.0, ta=0.0) if s.kind == "exec"
        else dataclasses.replace(s, ef=0.0, eb=0.0) for s in plan.steps)
    return dataclasses.replace(plan, steps=steps)


def check_against_simulator(lowered: LoweredPlan, plan: Plan,
                            profile: Profile, policy: str = "ours",
                            rel_tol: float = 1e-6) -> SimResult:
    """Assert the lowered schedule agrees with the discrete-event simulator.

    1. every stage executes exactly M forwards + M backwards,
    2. the simulator's makespan on a unit-cost copy of the plan equals the
       lowered schedule's tick count (two independent implementations of
       the same dependency rules),
    3. peak resident activations per stage equal ``min(max(1, K_p), M)`` —
       the O(K_p) 1F1B memory bound — and the simulator's per-device peak
       bytes stay within the Eq. (3) budget the lowering derives,
    4. the plan's stage latencies are Eq. (8): the max over the group of
       per-device times priced at the *allocated* sample counts (catches
       plans whose steps went stale against their allocations),
    5. the simulator's per-device busy times scale with allocated samples —
       ``M * (t_f(d, y_d) + t_b(d, y_d))`` exactly — and never exceed the
       lockstep stage busy time.
    Returns the (real-cost) simulation for further inspection.
    """
    M, P = lowered.n_micro, lowered.stage
    sim = simulate(plan, profile, policy)

    ops_per_stage = [0] * P
    for (_, _, p, _) in sim.trace:
        ops_per_stage[p] += 1
    assert ops_per_stage == [2 * M] * P, (ops_per_stage, M)

    unit = simulate(_unitize(plan), profile, policy)
    ticks = lowered.tick_makespan(policy)
    assert abs(unit.makespan - ticks) <= rel_tol * ticks, \
        (unit.makespan, ticks)

    inflight = lowered.peak_inflight(policy)
    expected = tuple(min(max(1, k), M) for k in lowered.warmup)
    assert inflight == expected, (inflight, expected)

    bound = lowered.memory_bound(profile)
    for d, peak in sim.peak_mem.items():
        assert peak <= bound[d] * (1 + rel_tol), (d, peak, bound[d])

    exec_steps = [s for s in plan.steps if s.kind == "exec"]
    for p, st in enumerate(exec_steps):
        i, j = st.layers
        ef = max(profile.t_fwd(d, y, i, j) for d, y in zip(st.group, st.alloc))
        eb = max(profile.t_bwd(d, y, i, j) for d, y in zip(st.group, st.alloc))
        assert abs(st.ef - ef) <= rel_tol * max(ef, 1e-12), (p, st.ef, ef)
        assert abs(st.eb - eb) <= rel_tol * max(eb, 1e-12), (p, st.eb, eb)
        for d, y in zip(st.group, st.alloc):
            t_dev = M * (profile.t_fwd(d, y, i, j) + profile.t_bwd(d, y, i, j))
            assert abs(sim.device_busy[d] - t_dev) <= \
                rel_tol * max(t_dev, 1e-12), (d, sim.device_busy[d], t_dev)
            assert sim.device_busy[d] <= sim.stage_busy[p] * (1 + rel_tol), \
                (d, p, sim.device_busy[d], sim.stage_busy[p])
    return sim


# ---------------------------------------------------------------------------
# Live replay: re-lowering and parameter migration
# ---------------------------------------------------------------------------


def relower(old: LoweredPlan, new_plan: Plan, cfg,
            model_axis: int | None = None) -> LoweredPlan:
    """Lower ``new_plan`` as a replacement for ``old`` on the same runtime.

    Beyond ``lower_plan``'s own checks, validates that the two lowered plans
    describe the same model and micro-batch structure, so the stacked period
    params (and optimizer state) can be migrated rather than re-initialized.
    """
    if old.arch and new_plan.arch and old.arch != new_plan.arch:
        raise LoweringError(f"arch changed across replay: {old.arch!r} -> "
                            f"{new_plan.arch!r}")
    new = lower_plan(new_plan, cfg, model_axis)
    if new.n_periods != old.n_periods:
        raise LoweringError(f"period count changed: {old.n_periods} -> "
                            f"{new.n_periods}")
    if new.global_batch != old.global_batch or new.n_micro != old.n_micro:
        raise LoweringError(
            f"batch structure changed: (B={old.global_batch}, M={old.n_micro})"
            f" -> (B={new.global_batch}, M={new.n_micro})")
    return new


def snap_plan(plan: Plan, lowered: LoweredPlan, L: int) -> Plan:
    """``plan`` with stage layer ranges snapped to what was deployed.

    Lowering snaps layer cuts to period boundaries; the plan the runtime
    actually executes therefore owns the *snapped* ranges.  The returned
    plan (stage ranges and exec-step ranges rewritten; costs kept as the
    planner's estimates) is what a session should feed back into
    ``lightweight_replay`` so old-ownership accounting matches reality.
    """
    plen = (L - 2) // lowered.n_periods
    cuts = [0] + [1 + j * plen for _, j in lowered.stage_periods[:-1]] + [L]
    ranges = [(cuts[p], cuts[p + 1]) for p in range(lowered.stage)]
    stages = tuple(dataclasses.replace(st, layers=r)
                   for st, r in zip(plan.stages, ranges))
    ex = iter(ranges)
    steps = tuple(dataclasses.replace(s, layers=next(ex))
                  if s.kind == "exec" else s for s in plan.steps)
    return dataclasses.replace(plan, stages=stages, steps=steps)


def period_owner(lp: LoweredPlan) -> tuple[int, ...]:
    """Owning stage of each canonical period under ``lp``'s split."""
    out = [0] * lp.n_periods
    for p, (i, j) in enumerate(lp.stage_periods):
        for t in range(i, j):
            out[t] = p
    return tuple(out)


def period_positions(lp: LoweredPlan) -> dict[int, int]:
    """canonical period -> row in ``lp``'s arranged period stack.

    The single source of truth for the ``runtime.pipeline.arrange_periods``
    layout (stage p's uniform slice ``[p*k, (p+1)*k)`` holds its assigned
    periods then zero padding) — migration, backup scatter/restore, and the
    bit-identicality checks all index through it.
    """
    pos: dict[int, int] = {}
    k = lp.k_per_stage
    for p, (i, j) in enumerate(lp.stage_periods):
        for t in range(i, j):
            pos[t] = p * k + (t - i)
    return pos


def migration_index(old: LoweredPlan, new: LoweredPlan):
    """Gather index mapping the OLD arranged period stack onto the NEW one.

    Returns ``(take, mask)`` such that
    ``new_leaf = where(mask, old_leaf[take], 0)``.
    """
    pos = period_positions(old)
    k_new = new.k_per_stage
    take: list[int] = []
    mask: list[float] = []
    for i, j in new.stage_periods:
        take += [pos[t] for t in range(i, j)] + [0] * (k_new - (j - i))
        mask += [1.0] * (j - i) + [0.0] * (k_new - (j - i))
    return take, mask


def _period_migrator(old: LoweredPlan, new: LoweredPlan):
    """leaf -> leaf gather realizing ``migration_index`` (pure jnp)."""
    import jax.numpy as jnp

    take, mask = migration_index(old, new)
    idx = jnp.asarray(take)
    m = jnp.asarray(mask, jnp.float32)

    def f(x):
        g = x[idx]
        keep = (m > 0).reshape(-1, *([1] * (g.ndim - 1)))
        return jnp.where(keep, g, jnp.zeros_like(g))

    return f


# ``old_owner`` sentinel for migrate_params: the period's old holder is not
# any stage of the new plan — it streams *directly* from an off-plan source
# (a draining/evicted leaver pushing its layers out, symmetric to a restore
# but from live state) instead of hopping adjacent-stage boundaries.
DIRECT_SOURCE = -1


@dataclasses.dataclass(frozen=True)
class MigrationReport:
    """What ``migrate_params`` moved, per boundary of the NEW plan."""

    moved_periods: tuple[int, ...]            # canonical indices that moved
    restored_periods: tuple[int, ...]         # restored from backup instead
    boundary_periods: tuple[tuple[int, ...], ...]   # per new-plan boundary
    boundary_bytes: tuple[float, ...]         # actual array bytes crossing
    period_bytes: float                       # bytes of one period's params
    total_bytes: float
    direct_periods: tuple[int, ...] = ()      # streamed off an off-plan source
    direct_bytes: float = 0.0


def migrate_params(params, old: LoweredPlan, new: LoweredPlan, *,
                   old_owner=None):
    """Pure migration of the stacked period params across a plan swap.

    The gather itself (``migration_index``) is direction-agnostic: it
    realizes any old->new stage re-arrangement, scale-in (a survivor
    absorbing a failed stage) and scale-out (periods landing on a freshly
    admitted device's stage) alike, bit-identically for every period that
    has an owner in both stacks.

    ``old_owner``: per-canonical-period owner in the NEW plan's stage
    coordinates; ``None`` entries mark periods restored from a backup and
    ``DIRECT_SOURCE`` entries periods streamed off an off-plan source (a
    draining leaver) — both excluded from boundary accounting.  Defaults to
    the old plan's own stage indices, which is exact when the stage count
    is unchanged.

    Returns ``(migrated_params, MigrationReport)``.  Leaves outside
    ``params["periods"]`` are returned untouched (vocab re-padding for a tp
    change is the session layer's job).
    """
    import jax

    f = _period_migrator(old, new)
    out = dict(params)
    out["periods"] = jax.tree.map(f, params["periods"])

    if old_owner is None:
        old_owner = period_owner(old)
    new_own = period_owner(new)
    moved = tuple(t for t in range(new.n_periods)
                  if old_owner[t] is not None
                  and old_owner[t] != DIRECT_SOURCE
                  and old_owner[t] != new_own[t])
    direct = tuple(t for t in range(new.n_periods)
                   if old_owner[t] == DIRECT_SOURCE)
    restored = tuple(t for t in range(new.n_periods) if old_owner[t] is None)
    period_bytes = sum(leaf.nbytes / leaf.shape[0]
                       for leaf in jax.tree.leaves(params["periods"]))
    boundary_periods: list[tuple[int, ...]] = []
    boundary_bytes: list[float] = []
    for p in range(new.stage - 1):
        crossing = tuple(t for t in moved
                         if min(old_owner[t], new_own[t]) <= p
                         < max(old_owner[t], new_own[t]))
        boundary_periods.append(crossing)
        boundary_bytes.append(period_bytes * len(crossing))
    report = MigrationReport(moved, restored, tuple(boundary_periods),
                             tuple(boundary_bytes), period_bytes,
                             period_bytes * len(moved)
                             + period_bytes * len(direct),
                             direct, period_bytes * len(direct))
    return out, report


def migrate_opt_state(opt_state, old: LoweredPlan, new: LoweredPlan):
    """Optimizer moments follow the params through the SAME index map."""
    import jax

    from repro.optim import AdamWState, SGDState

    f = _period_migrator(old, new)

    def mig(tree):
        out = dict(tree)
        out["periods"] = jax.tree.map(f, tree["periods"])
        return out

    if isinstance(opt_state, AdamWState):
        return AdamWState(opt_state.step, mig(opt_state.m), mig(opt_state.v))
    if isinstance(opt_state, SGDState):
        return SGDState(opt_state.step, mig(opt_state.mom))
    raise TypeError(type(opt_state))


def reconcile_migration(mig: MigrationReport, report, new: LoweredPlan,
                        table, pattern_len: int,
                        rel_tol: float = 1e-6) -> dict:
    """Assert ``migrate_params``'s moved bytes match the analytical
    ``RecoveryReport`` migration inputs (a replay run with
    ``layer_quantum=pattern_len`` so its cuts are period-aligned).

    Prices both directions: boundary crossings are checked per boundary of
    the new plan whether the periods flowed toward a survivor (scale-in) or
    onto a freshly admitted stage (scale-out) — the crossing predicate is
    symmetric in old/new owner.  Reports carrying ``direct_moves`` (a
    draining leaver streaming its layers straight to their new owners) are
    additionally reconciled against ``mig.direct_periods``.

    Returns per-boundary ``{analytic_bytes, table_bytes, runtime_bytes}``
    (plus a ``"direct"`` entry when direct streams were priced) where
    ``table_bytes`` re-prices the runtime's moved periods with the
    profiler's layer table — the quantity that must equal the analytical
    bytes exactly.
    """
    def period_table_bytes(periods):
        return sum(
            table.param_bytes(1 + t * pattern_len, 1 + (t + 1) * pattern_len)
            for t in periods)

    analytic = {bm.boundary: bm for bm in report.boundary_moves}
    out: dict = {}
    for p in range(new.stage - 1):
        periods = mig.boundary_periods[p]
        bm = analytic.get(p)
        if bm is None:
            assert not periods, (
                f"runtime moved periods {periods} across boundary {p} but "
                f"the recovery report shows no migration there")
            continue
        hull = set(range((bm.lo - 1) // pattern_len,
                         -(-(bm.hi - 1) // pattern_len)))
        assert set(periods) <= hull, (p, periods, sorted(hull))
        table_bytes = period_table_bytes(periods)
        assert abs(table_bytes - bm.nbytes) <= rel_tol * max(table_bytes, 1.0), (
            f"boundary {p}: runtime periods {periods} price to "
            f"{table_bytes:.0f} B in the layer table, but the recovery "
            f"report migrated {bm.nbytes:.0f} B")
        out[p] = {"analytic_bytes": bm.nbytes, "table_bytes": table_bytes,
                  "runtime_bytes": mig.boundary_bytes[p]}

    direct_moves = getattr(report, "direct_moves", ())
    if mig.direct_periods or direct_moves:
        hull = set()
        for dm in direct_moves:
            hull |= set(range((dm.lo - 1) // pattern_len,
                              -(-(dm.hi - 1) // pattern_len)))
        assert set(mig.direct_periods) <= hull, (
            f"runtime direct-streamed periods {mig.direct_periods} outside "
            f"the report's direct-move hull {sorted(hull)}")
        table_bytes = period_table_bytes(mig.direct_periods)
        # the analytic moves may also carry the leaver's embed/head bytes
        # (table edge pseudo-layers); compare on the real-layer span only
        L = table.L
        analytic_bytes = sum(
            table.param_bytes(max(dm.lo, 1), min(dm.hi, L - 1))
            for dm in direct_moves)
        assert abs(table_bytes - analytic_bytes) <= \
            rel_tol * max(table_bytes, 1.0), (
            f"direct streams: runtime periods {mig.direct_periods} price to "
            f"{table_bytes:.0f} B, but the report streams "
            f"{analytic_bytes:.0f} B of real layers off the leaver")
        out["direct"] = {"analytic_bytes": analytic_bytes,
                         "table_bytes": table_bytes,
                         "runtime_bytes": mig.direct_bytes}
    return out


# ---------------------------------------------------------------------------
# Runtime bridge
# ---------------------------------------------------------------------------


def plan_to_train_step(plan: Plan, profile: Profile | None, cfg,
                       production_mesh=None, *, check: bool = True, **kw):
    """Build a runnable distributed train step from an Asteroid ``Plan``.

    Returns ``(TrainStep, LoweredPlan)``.  ``production_mesh`` defaults to a
    ``(data=1, model=N)`` mesh over the local jax devices.  When ``profile``
    is given and ``check`` is True, the lowered schedule is cross-checked
    against the simulator before anything is compiled.
    """
    import jax
    import numpy as np
    from jax.sharding import Mesh

    from repro.runtime.train import build_train_step_from_lowered

    if production_mesh is None:
        devs = jax.devices()
        production_mesh = Mesh(np.array(devs).reshape(1, len(devs)),
                               ("data", "model"))
    lowered = lower_plan(plan, cfg, production_mesh.shape["model"])
    if check and profile is not None:
        check_against_simulator(lowered, plan, profile)

    try:
        ts = build_train_step_from_lowered(cfg, production_mesh, lowered, **kw)
    except ValueError as e:
        raise LoweringError(str(e)) from e
    return ts, lowered
