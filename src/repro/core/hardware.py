"""Device and link models for the Asteroid planner.

The paper profiles real Jetson boards; we model each device with a peak
compute rate plus a *non-linear batch-efficiency curve* (the paper's Fig. 6
observation: small batches underutilize the GPU, so time-vs-batch is not
linear).  ``eff(beta) = beta / (beta + k)`` saturates with half-saturation
``k`` — larger accelerators have larger ``k``.

All times are seconds, sizes bytes, rates FLOP/s and bytes/s.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    mem_bytes: float           # memory budget u_d
    flops: float               # datasheet peak (fp16/bf16 training mix)
    sat_batch: float = 8.0     # half-saturation batch size k (Fig. 6 shape)
    sat_flops: float = 1e9     # half-saturation work per kernel launch:
                               # small convolutions badly underutilize wide
                               # accelerators (the second non-linearity the
                               # paper's profiler captures)
    overhead: float = 3e-4     # fixed per-layer launch overhead (s)

    def eff(self, beta: float) -> float:
        return beta / (beta + self.sat_batch)

    def eff_size(self, flops_per_sample: float) -> float:
        # per-sample (batch-independent) so layer_time stays monotone in beta
        return flops_per_sample / (flops_per_sample + self.sat_flops)

    def layer_time(self, flops_per_sample: float, beta: float) -> float:
        """Execution time of one layer pass at batch size beta (monotone
        non-decreasing in beta; per-sample time non-increasing — Fig. 6)."""
        if beta <= 0:
            return 0.0
        work = flops_per_sample * beta
        return work / (self.flops * self.eff(beta) *
                       self.eff_size(flops_per_sample)) + self.overhead


# ---------------------------------------------------------------------------
# Presets
# ---------------------------------------------------------------------------

# Constants calibrated against the paper's Table 1 epoch times (grid fit,
# max log-error <= 0.21 across all nine (model, device) pairs): small-conv
# training on Jetsons runs far below datasheet peak, captured by sat_flops.
JETSON_NANO = DeviceProfile("nano", mem_bytes=4e9, flops=1.0e11, sat_batch=8,
                            sat_flops=3.7e7, overhead=3e-4)
JETSON_TX2 = DeviceProfile("tx2", mem_bytes=8e9, flops=4.0e11, sat_batch=12,
                           sat_flops=6.9e7, overhead=5e-4)
JETSON_NX = DeviceProfile("nx", mem_bytes=8e9, flops=1.0e12, sat_batch=16,
                          sat_flops=9e7, overhead=4e-4)
A100 = DeviceProfile("a100", mem_bytes=40e9, flops=2.0e13, sat_batch=64,
                     sat_flops=2e7, overhead=1e-4)

# TPU v5e chip (production target; constants from the assignment)
TPU_V5E = DeviceProfile("v5e", mem_bytes=16e9, flops=1.97e14, sat_batch=64,
                        sat_flops=3e7, overhead=2e-5)
TPU_V5E_HBM_BW = 819e9        # bytes/s
TPU_V5E_ICI_BW = 50e9         # bytes/s per link

MBPS_100 = 100e6 / 8          # paper's two D2D settings
MBPS_1000 = 1000e6 / 8


@dataclasses.dataclass(frozen=True)
class Cluster:
    """A pool of devices with a uniform (or matrix) D2D bandwidth."""

    devices: tuple[DeviceProfile, ...]
    bandwidth: float = MBPS_100            # uniform D2D bytes/s
    bw_matrix: tuple[tuple[float, ...], ...] | None = None

    def bw(self, i: int, j: int) -> float:
        if self.bw_matrix is not None:
            return self.bw_matrix[i][j]
        return self.bandwidth

    def min_bw(self, ranks) -> float:
        ranks = list(ranks)
        if len(ranks) < 2:
            return self.bandwidth
        return min(self.bw(i, j) for i in ranks for j in ranks if i != j)

    def sorted_by_memory(self) -> "Cluster":
        """Planner preprocessing: descending memory (earlier stages get more)."""
        order = sorted(range(len(self.devices)),
                       key=lambda i: (-self.devices[i].mem_bytes, -self.devices[i].flops))
        return Cluster(tuple(self.devices[i] for i in order), self.bandwidth,
                       self.bw_matrix)


# Paper testbeds (Table 6)
def env_a() -> Cluster:
    return Cluster((JETSON_NANO,) * 5)


def env_b(bw: float = MBPS_100) -> Cluster:
    return Cluster((JETSON_NX,) * 3 + (JETSON_TX2,) * 2, bandwidth=bw)


def env_c() -> Cluster:
    return Cluster((JETSON_NX,) + (JETSON_TX2,) * 2 + (JETSON_NANO,) * 3)


def env_d() -> Cluster:
    return Cluster((JETSON_TX2,) + (JETSON_NANO,) * 3)


ENVS = {"A": env_a, "B": env_b, "C": env_c, "D": env_d}
