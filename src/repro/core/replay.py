"""§3.4 Fault-tolerant pipeline replay.

Three modules, faithful to the paper:

1. **Heartbeat-guided failure detection** — every device emits heartbeats to
   the coordinator; a missed deadline triggers a probe; an unanswered probe
   confirms the failure.  ``ReplayCoordinator`` is the state machine
   (heartbeat -> probe -> confirm -> replan -> migrate -> resume); it drives
   a live executor (``repro.runtime.session.PipelineSession``) through the
   same transitions the analytical model charges time for.

2. **Topology-driven model replication** — single-device stages back up
   their stage model to a *backup node* in the next stage (last stage wraps
   to the first); multi-device stages are implicitly replicated by their DP
   peers.  Periodic checkpoint traffic is charged to the D2D links.

3. **Layer-wise lightweight re-planning** — on failure, instead of rerunning
   Algorithm 2, the surviving stages re-split the layer range proportionally
   to their aggregate computing capacity (FLOPs-based), and adjacent stages
   migrate boundary layers *concurrently*; weights owned by the failed
   device are restored from its backup directly to their new owner stages.

The heavy-rescheduling baseline (aggregate → re-plan → redistribute) is also
implemented for the Fig. 16/17 comparison.
"""

from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from .allocation import AllocationError, allocate_microbatch
from .costmodel import Step, allreduce_time, hpp_round_latency, kp_policy
from .planner import Plan, StagePlan, _comm_step, plan_hpp
from .profiler import Profile

HEARTBEAT_PERIOD = 0.5        # s
HEARTBEAT_TIMEOUT = 2.0       # missed-deadline threshold
PROBE_TIMEOUT = 1.0

# Heavy rescheduling re-plans on the strongest *surviving* edge device; our
# planner executes on this host, so its wall time is scaled to Jetson-NX
# speed (calibrated at 8x host/NX planner throughput) for derived ratios.
JETSON_REPLAN_SCALE = 8.0


@dataclasses.dataclass(frozen=True)
class BackupAssignment:
    """stage -> backup device rank holding its replica."""

    backup_of_stage: dict[int, int]
    checkpoint_bytes: dict[int, float]


def assign_backups(plan: Plan, profile: Profile) -> BackupAssignment:
    """Topology-driven replication (Fig. 9 left)."""
    stages = plan.stages
    P = len(stages)
    backup: dict[int, int] = {}
    ckpt: dict[int, float] = {}
    for p, st in enumerate(stages):
        if len(st.group) > 1:
            continue                       # DP peers already replicate
        nxt = stages[(p + 1) % P]
        backup[p] = nxt.group[0]
        ckpt[p] = profile.table.param_bytes(*st.layers)
    return BackupAssignment(backup, ckpt)


def checkpoint_cost(assign: BackupAssignment, profile: Profile) -> float:
    """Seconds to push one round of stage-model checkpoints."""
    if not assign.checkpoint_bytes:
        return 0.0
    return max(b / profile.cluster.bandwidth for b in assign.checkpoint_bytes.values())


# ---------------------------------------------------------------------------
# Failure detection (simulated clock)
# ---------------------------------------------------------------------------


def detection_latency(fail_time: float, heartbeat_period: float = HEARTBEAT_PERIOD,
                      timeout: float = HEARTBEAT_TIMEOUT,
                      probe_timeout: float = PROBE_TIMEOUT) -> float:
    """Time from failure to confirmed detection."""
    # last heartbeat was at the period boundary before the failure
    last_beat = math.floor(fail_time / heartbeat_period) * heartbeat_period
    deadline = last_beat + heartbeat_period + timeout
    return (deadline - fail_time) + probe_timeout


class ReplayCoordinator:
    """Failure-handling state machine over a simulated clock.

    monitoring --missed deadline--> probing --probe timeout--> confirmed
    --> replanning --> migrating --> resuming --> monitoring

    Callers feed ``heartbeat(rank, now)`` and advance detection with
    ``poll(now)``; once a failure is confirmed, ``run_recovery`` drives an
    *executor* — any object with ``replan(failed_rank) -> RecoveryReport``,
    ``migrate(report)`` and ``resume(report, migration)`` — through the
    replay, stamping each transition with the report's own component costs.
    The live executor is ``repro.runtime.session.PipelineSession``; tests
    drive the machine with a scripted clock.
    """

    def __init__(self, ranks, heartbeat_period: float = HEARTBEAT_PERIOD,
                 timeout: float = HEARTBEAT_TIMEOUT,
                 probe_timeout: float = PROBE_TIMEOUT, now: float = 0.0):
        self.heartbeat_period = heartbeat_period
        self.timeout = timeout
        self.probe_timeout = probe_timeout
        self.last_beat = {r: now for r in ranks}
        self.state = "monitoring"
        self.suspect: int | None = None
        self._probe_sent = 0.0
        self.events: list[tuple[str, float, int | None]] = [
            ("monitoring", now, None)]

    def _transition(self, state: str, now: float, rank: int | None = None):
        self.state = state
        self.events.append((state, now, rank))

    def heartbeat(self, rank: int, now: float) -> None:
        if rank in self.last_beat:
            self.last_beat[rank] = max(self.last_beat[rank], now)

    def poll(self, now: float) -> int | None:
        """Advance failure detection; returns a rank once it is confirmed."""
        if self.state == "monitoring":
            for r, t in sorted(self.last_beat.items()):
                if now - t > self.heartbeat_period + self.timeout:
                    self.suspect, self._probe_sent = r, now
                    self._transition("probing", now, r)
                    break
        if self.state == "probing":
            if self.last_beat[self.suspect] > self._probe_sent:
                self._transition("monitoring", now)   # probe answered
                self.suspect = None
            elif now - self._probe_sent >= self.probe_timeout:
                rank = self.suspect
                self._transition("confirmed", now, rank)
                return rank
        return None

    def run_recovery(self, failed_rank: int, executor, now: float = 0.0):
        """Drive replan -> migrate -> resume on ``executor``.

        Returns ``(RecoveryReport, migration)`` where ``migration`` is
        whatever ``executor.migrate`` produced.
        """
        if self.state != "confirmed":
            raise RuntimeError(f"recovery requires a confirmed failure "
                               f"(state={self.state})")
        self.last_beat.pop(failed_rank, None)
        self.suspect = None
        self._transition("replanning", now, failed_rank)
        report = executor.replan(failed_rank)
        t = now + report.replan_s
        self._transition("migrating", t, failed_rank)
        migration = executor.migrate(report)
        t += report.migration_s + report.restore_s
        self._transition("resuming", t, failed_rank)
        executor.resume(report, migration)
        self._transition("monitoring", t, None)
        return report, migration


# ---------------------------------------------------------------------------
# Lightweight layer-wise re-planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BoundaryMove:
    """Weights crossing one boundary of the *new* plan during migration."""

    boundary: int                  # between new stages boundary, boundary+1
    lo: int                        # table-layer hull [lo, hi) of moved layers
    hi: int
    nbytes: float                  # exact bytes crossing this boundary
    link_bw: float                 # D2D bandwidth of the boundary link


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    detection_s: float
    replan_s: float
    migration_s: float
    restore_s: float
    new_plan: Plan
    mode: str
    boundary_moves: tuple[BoundaryMove, ...] = ()

    @property
    def total_s(self) -> float:
        return self.detection_s + self.replan_s + self.migration_s + self.restore_s


def _stage_capacity(profile: Profile, group, i: int, j: int, mb: int) -> float:
    """Aggregate computing capacity sum_d v_d (Eq. 9) of a group."""
    return sum(1.0 / max(profile.t_both(d, mb, i, j), 1e-12) for d in group)


def _snap_cuts(cuts: list[int], quantum: int, L: int) -> list[int]:
    """Snap interior table-layer cuts to period boundaries.

    Mirrors ``lowering._snap_to_periods`` (table layer 1 + r*quantum is the
    boundary after real-layer period r) so a snapped plan lowers to exactly
    these cuts.  Kept strictly monotone with >= 1 period per stage.
    """
    n_layers = L - 2                       # embed + real layers + head
    n_per = n_layers // quantum
    P = len(cuts) - 1
    if P > n_per:
        raise AllocationError(f"{P} stages but only {n_per} periods")
    pers = [0]
    for p in range(P - 1):
        r = min(max(cuts[p + 1] - 1, 0), n_layers)
        per = round(r / quantum)
        per = max(per, pers[-1] + 1)
        per = min(per, n_per - (P - 1 - p))
        pers.append(per)
    return [0] + [1 + per * quantum for per in pers[1:]] + [L]


def lightweight_replay(plan: Plan, profile: Profile, failed_rank: int,
                       fail_time: float = 10.0,
                       layer_quantum: int | None = None) -> RecoveryReport:
    """Layer-wise lightweight re-planning after ``failed_rank`` exits.

    ``layer_quantum``: when re-planning for the period-granular runtime
    (``core.lowering``), snap the new cuts to period boundaries (= the
    model's pattern length in table layers) so the analytical migration
    inputs coincide exactly with what ``migrate_params`` moves.
    """
    t0 = time.perf_counter()
    table = profile.table
    stages = list(plan.stages)
    mb = plan.micro_batch
    L = table.L

    # 1) drop the failed device, remembering each original stage's survivor
    #    index (None = the whole stage failed: restored, not migrated).
    survivors: list[StagePlan] = []
    surv_of_orig: dict[int, int] = {}
    for q, st in enumerate(stages):
        group = tuple(d for d in st.group if d != failed_rank)
        if group:
            surv_of_orig[q] = len(survivors)
            survivors.append(StagePlan(st.layers, group, st.alloc, st.k_p))
    P = len(survivors)
    if P == 0:
        raise RuntimeError("no surviving devices")

    # 2) FLOPs-proportional re-partition over surviving stages' capacities
    caps = [_stage_capacity(profile, st.group, 0, L, mb) for st in survivors]
    total_cap = sum(caps)
    total_flops = table.flops(0, L)
    cuts = [0]
    acc = 0.0
    li = 0
    for p in range(P - 1):
        acc += total_flops * caps[p] / total_cap
        while li < L and table.flops(0, li) < acc:
            li += 1
        cuts.append(min(li, L - (P - 1 - p)))
    cuts.append(L)
    if layer_quantum:
        cuts = _snap_cuts(cuts, layer_quantum, L)

    # 3) per-layer ownership among the *survivors*.  Old ownership follows
    #    the ORIGINAL plan partition (so a fully-failed stage's range is not
    #    silently attributed to a neighbour); its layers have no surviving
    #    owner — they are restored from backup, not migrated.
    old_owner: list[int | None] = [None] * L
    for q, st in enumerate(stages):
        so = surv_of_orig.get(q)
        for l in range(*st.layers):
            old_owner[l] = so
    new_owner = [0] * L
    for p in range(P):
        for l in range(cuts[p], cuts[p + 1]):
            new_owner[l] = p

    # 4) concurrent layer migration between adjacent stages: a layer's
    #    weights cross boundary p iff its old->new owner path does.
    migration = 0.0
    moves: list[BoundaryMove] = []
    for p in range(P - 1):
        crossing = [l for l in range(L) if old_owner[l] is not None
                    and min(old_owner[l], new_owner[l]) <= p
                    < max(old_owner[l], new_owner[l])]
        link_bw = profile.cluster.bw(survivors[p].group[0],
                                     survivors[p + 1].group[0])
        if crossing:
            nbytes = sum(table.layers[l].param_bytes for l in crossing)
            moves.append(BoundaryMove(p, min(crossing), max(crossing) + 1,
                                      nbytes, link_bw))
            migration = max(migration, nbytes / link_bw)   # concurrent

    # 5) restore a fully-failed single-device stage's weights from its
    #    backup node *directly to their new owners*, over the actual backup
    #    links (concurrent pushes; a push to the backup holder's own new
    #    stage is local and free).
    assign = assign_backups(plan, profile)
    restore = 0.0
    for q, st in enumerate(stages):
        if failed_rank in st.group and len(st.group) == 1:
            backup_rank = assign.backup_of_stage.get(q)
            if backup_rank is None:
                continue
            for p in range(P):
                lo = max(st.layers[0], cuts[p])
                hi = min(st.layers[1], cuts[p + 1])
                if lo >= hi or backup_rank in survivors[p].group:
                    continue
                nbytes = table.param_bytes(lo, hi)
                bw = profile.cluster.bw(backup_rank, survivors[p].group[0])
                restore = max(restore, nbytes / bw)

    # 6) build the new plan (re-run Algorithm 1 within each stage)
    new_stages = []
    steps: list[Step] = []
    for p in range(P):
        i, j = cuts[p], cuts[p + 1]
        alloc = allocate_microbatch(profile, survivors[p].group, mb, i, j,
                                    kp_policy(P, p))
        ta = allreduce_time(table.param_bytes(i, j), survivors[p].group,
                            profile.cluster)
        steps.append(Step("exec", alloc.ef, alloc.eb, ta, survivors[p].group,
                          (i, j), alloc.y))
        new_stages.append(StagePlan((i, j), survivors[p].group, alloc.y,
                                    kp_policy(P, p)))
        if p < P - 1:
            steps.append(_comm_step(profile, mb, j, survivors[p].group,
                                    survivors[p + 1].group))
    # the survivors' pipeline inherits the failed plan's gradient-sync
    # semantics (a replayed async session stays async)
    lat = hpp_round_latency(tuple(steps), plan.n_micro,
                            getattr(plan, "staleness", 0))
    new_plan = Plan(plan.arch, tuple(new_stages), tuple(steps), mb,
                    plan.n_micro, lat, "replay",
                    staleness=getattr(plan, "staleness", 0))
    replan_s = time.perf_counter() - t0
    return RecoveryReport(detection_latency(fail_time), replan_s, migration,
                          restore, new_plan, "lightweight", tuple(moves))


def heavy_rescheduling(plan: Plan, profile: Profile, failed_rank: int,
                       fail_time: float = 10.0,
                       replan_compute_scale: float = JETSON_REPLAN_SCALE,
                       allowed_stages=None) -> RecoveryReport:
    """Straw-man baseline: aggregate stage models to the coordinator, re-run
    Algorithm 2 from scratch, redistribute all weights.

    ``allowed_stages`` restricts the re-planned stage count (e.g. divisors
    of a runtime mesh's model axis, so the result stays lowerable)."""
    from .hardware import Cluster

    table = profile.table
    bw = profile.cluster.bandwidth

    # 1) aggregate every stage model to the coordinator (serialized in/out)
    aggregate = sum(table.param_bytes(*st.layers) for st in plan.stages) / bw

    # 2) full re-planning on the strongest surviving device
    devs = [d for i, d in enumerate(profile.cluster.devices) if i != failed_rank]
    sub_cluster = Cluster(tuple(devs), profile.cluster.bandwidth)
    sub_profile = Profile.analytic(table, sub_cluster, profile.max_batch)
    t0 = time.perf_counter()
    new_plan = plan_hpp(sub_profile, plan.global_batch, plan.micro_batch,
                        arch=plan.arch, allowed_stages=allowed_stages,
                        staleness=getattr(plan, "staleness", 0))
    replan = (time.perf_counter() - t0) * replan_compute_scale

    # sub-cluster ranks -> the original cluster's rank space, so the new
    # plan stays addressable by the same device identities as the old one
    remap = {i: r for i, r in enumerate(
        r for r in range(len(profile.cluster.devices)) if r != failed_rank)}
    stages = tuple(dataclasses.replace(st, group=tuple(remap[g] for g in st.group))
                   for st in new_plan.stages)
    steps = tuple(dataclasses.replace(s, group=tuple(remap[g] for g in s.group))
                  if s.group else s for s in new_plan.steps)
    new_plan = dataclasses.replace(new_plan, stages=stages, steps=steps)

    # 3) redistribute all stage weights
    redistribute = sum(table.param_bytes(*st.layers) for st in new_plan.stages) / bw

    return RecoveryReport(detection_latency(fail_time), replan,
                          aggregate + redistribute, 0.0, new_plan, "heavy")
