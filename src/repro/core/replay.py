"""§3.4 pipeline replay, generalized to elastic membership.

The paper's replay only shrinks the mesh: a device fails and survivors
absorb its layers.  Real edge fleets are elastic — phones land on chargers
and join, throttled boards drain gracefully, preempted devices leave with
warning — so the failure-specific coordinator is one *event handler* of a
general ``MembershipController`` driven by typed membership events:

* ``DeviceFailed``   — the paper's §3.4 crash path: heartbeat-guided
  detection (missed deadline -> probe -> confirm), lightweight layer-wise
  re-planning, concurrent boundary migration, backup restore of the fully
  failed stage.
* ``DeviceJoined``   — scale-out admission: the newcomer (profiled on
  arrival, analytic fallback) is priced into incremental candidate
  placements (``admission_replay``) and accepted only when the re-priced
  plan beats the incumbent by a hysteresis margin.  FTPipeHD handles
  dynamic membership by periodic *full* weight redistribution; here the
  pure-gather migration moves only what the new cuts displace.
* ``DeviceDraining`` — graceful departure: the leaver keeps serving while
  its layers stream off (``departure_replay``), so the pipeline stalls only
  for the re-plan — no detection latency, no backup restore.
* ``DeviceEvicted``  — immediate planned removal: same re-plan as a drain
  but the pipeline pauses for the migration.

Mechanisms shared by the handlers, faithful to the paper:

1. **Heartbeat-guided failure detection** — every device emits heartbeats;
   a missed deadline triggers a probe; an unanswered probe confirms.
2. **Topology-driven model replication** — single-device stages back up
   their stage model to a *backup node* in the next stage (last stage wraps
   to the first); multi-device stages are implicitly replicated by their DP
   peers.  Periodic checkpoint traffic is charged to the D2D links.
3. **Layer-wise lightweight re-planning** — instead of rerunning
   Algorithm 2, the (remaining or extended) stages re-split the layer range
   proportionally to their aggregate computing capacity (FLOPs-based), and
   adjacent stages migrate boundary layers *concurrently*.

The controller drives a live executor
(``repro.runtime.session.PipelineSession``) through the same transitions
the analytical model charges time for.  The heavy-rescheduling baseline
(aggregate → re-plan → redistribute) is also implemented for the
Fig. 16/17 comparison.  ``ReplayCoordinator`` remains as a compatibility
alias of ``MembershipController``.
"""

from __future__ import annotations

import dataclasses
import math
import time

import numpy as np

from .allocation import AllocationError, allocate_microbatch
from .costmodel import Step, allreduce_time, hpp_round_latency, kp_policy
from .hardware import DeviceProfile
from .lowering import DIRECT_SOURCE
from .planner import Plan, StagePlan, _comm_step, plan_hpp
from .profiler import Profile

HEARTBEAT_PERIOD = 0.5        # s
HEARTBEAT_TIMEOUT = 2.0       # missed-deadline threshold
PROBE_TIMEOUT = 1.0

# A join is admitted only when the re-priced plan beats the incumbent's
# HPP-Round latency by this margin — churn whose gain is smaller than the
# re-plan + migration it triggers is rejected.
ADMISSION_HYSTERESIS = 0.05

# Heavy rescheduling re-plans on the strongest *surviving* edge device; our
# planner executes on this host, so its wall time is scaled to Jetson-NX
# speed (calibrated at 8x host/NX planner throughput) for derived ratios.
JETSON_REPLAN_SCALE = 8.0


# ---------------------------------------------------------------------------
# Typed membership events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    """Base class for the controller's typed membership events."""


@dataclasses.dataclass(frozen=True)
class DeviceFailed(MembershipEvent):
    """Unplanned crash: detection latency + backup restore apply."""

    rank: int


@dataclasses.dataclass(frozen=True)
class DeviceJoined(MembershipEvent):
    """A newcomer offers itself; admission is hysteresis-gated.

    ``arrival``: the newcomer's measured on-arrival sweep (a
    ``core.profiler.MeasuredProfile``); ``None`` means price it with the
    analytic FLOP model of ``device``."""

    device: DeviceProfile
    arrival: object | None = None
    hysteresis: float = ADMISSION_HYSTERESIS


@dataclasses.dataclass(frozen=True)
class DeviceDraining(MembershipEvent):
    """Graceful departure: the leaver serves while its layers stream off."""

    rank: int


@dataclasses.dataclass(frozen=True)
class DeviceEvicted(MembershipEvent):
    """Immediate planned removal: the pipeline pauses for the migration."""

    rank: int


@dataclasses.dataclass(frozen=True)
class BackupAssignment:
    """stage -> backup device rank holding its replica."""

    backup_of_stage: dict[int, int]
    checkpoint_bytes: dict[int, float]


def assign_backups(plan: Plan, profile: Profile) -> BackupAssignment:
    """Topology-driven replication (Fig. 9 left)."""
    stages = plan.stages
    P = len(stages)
    backup: dict[int, int] = {}
    ckpt: dict[int, float] = {}
    for p, st in enumerate(stages):
        if len(st.group) > 1:
            continue                       # DP peers already replicate
        nxt = stages[(p + 1) % P]
        backup[p] = nxt.group[0]
        ckpt[p] = profile.table.param_bytes(*st.layers)
    return BackupAssignment(backup, ckpt)


def checkpoint_cost(assign: BackupAssignment, profile: Profile) -> float:
    """Seconds to push one round of stage-model checkpoints."""
    if not assign.checkpoint_bytes:
        return 0.0
    return max(b / profile.cluster.bandwidth for b in assign.checkpoint_bytes.values())


# ---------------------------------------------------------------------------
# Failure detection (simulated clock)
# ---------------------------------------------------------------------------


def detection_latency(fail_time: float, heartbeat_period: float = HEARTBEAT_PERIOD,
                      timeout: float = HEARTBEAT_TIMEOUT,
                      probe_timeout: float = PROBE_TIMEOUT) -> float:
    """Time from failure to confirmed detection."""
    # last heartbeat was at the period boundary before the failure
    last_beat = math.floor(fail_time / heartbeat_period) * heartbeat_period
    deadline = last_beat + heartbeat_period + timeout
    return (deadline - fail_time) + probe_timeout


class MembershipController:
    """Membership state machine over a simulated clock.

    Crash path (the paper's §3.4 replay, one event handler among four):

    monitoring --missed deadline--> probing --probe timeout--> confirmed
    --> replanning --> migrating --> resuming --> monitoring

    Planned transitions take the same spine without detection:

    monitoring --DeviceJoined-->   admitting (--> rejected) --> migrating
    monitoring --DeviceDraining--> draining                 --> migrating
    monitoring --DeviceEvicted-->  evicting                 --> migrating
                ... --> resuming --> monitoring

    Callers feed ``heartbeat(rank, now)`` and advance failure detection
    with ``poll(now)``; ``handle(event, executor, now)`` dispatches a typed
    ``MembershipEvent`` to its handler, which drives an *executor* through
    plan -> migrate -> resume, stamping each transition with the report's
    own component costs.  The executor protocol: ``replan(failed_rank)``
    (crash), ``admit_replan(event) -> AdmissionDecision``,
    ``drain_replan(rank)`` / ``evict_replan(rank)`` -> ``RecoveryReport``,
    plus ``migrate(report)`` and ``resume(report, migration)`` shared by
    every path.  The live executor is
    ``repro.runtime.session.PipelineSession``; tests drive the machine with
    a scripted clock.
    """

    def __init__(self, ranks, heartbeat_period: float = HEARTBEAT_PERIOD,
                 timeout: float = HEARTBEAT_TIMEOUT,
                 probe_timeout: float = PROBE_TIMEOUT, now: float = 0.0):
        self.heartbeat_period = heartbeat_period
        self.timeout = timeout
        self.probe_timeout = probe_timeout
        self.last_beat = {r: now for r in ranks}
        self.state = "monitoring"
        self.suspect: int | None = None
        self._probe_sent = 0.0
        self.events: list[tuple[str, float, int | None]] = [
            ("monitoring", now, None)]
        # called as auction_hook(kind, rank) after every COMPLETED plan swap
        # (crash recovery, accepted join, drain, evict) — rejected
        # admissions change nothing, so they don't fire.  The portfolio
        # session registers a callback here to re-arbitrate the post-churn
        # analytic replan against the runner-up with a cheap 2-candidate
        # probation (DESIGN.md §12) instead of trusting the cost model.
        self.auction_hook = None

    def _post_swap(self, kind: str, rank: int | None) -> None:
        if self.auction_hook is not None:
            self.auction_hook(kind, rank)

    def _transition(self, state: str, now: float, rank: int | None = None):
        self.state = state
        self.events.append((state, now, rank))

    def heartbeat(self, rank: int, now: float) -> None:
        if rank in self.last_beat:
            self.last_beat[rank] = max(self.last_beat[rank], now)

    def poll(self, now: float) -> int | None:
        """Advance failure detection; returns a rank once it is confirmed."""
        if self.state == "monitoring":
            for r, t in sorted(self.last_beat.items()):
                if now - t > self.heartbeat_period + self.timeout:
                    self.suspect, self._probe_sent = r, now
                    self._transition("probing", now, r)
                    break
        if self.state == "probing":
            if self.last_beat[self.suspect] > self._probe_sent:
                self._transition("monitoring", now)   # probe answered
                self.suspect = None
            elif now - self._probe_sent >= self.probe_timeout:
                rank = self.suspect
                self._transition("confirmed", now, rank)
                return rank
        return None

    # -- event dispatch ------------------------------------------------------

    def handle(self, event: MembershipEvent, executor, now: float = 0.0):
        """Dispatch a typed membership event to its handler.

        Returns what the handler returns: ``(RecoveryReport, migration)``
        for failures and departures, ``(AdmissionDecision, migration |
        None)`` for joins."""
        if isinstance(event, DeviceFailed):
            return self.run_recovery(event.rank, executor, now=now)
        if isinstance(event, DeviceJoined):
            return self._on_joined(event, executor, now)
        if isinstance(event, DeviceDraining):
            return self._on_departing(event.rank, executor, now,
                                      graceful=True)
        if isinstance(event, DeviceEvicted):
            return self._on_departing(event.rank, executor, now,
                                      graceful=False)
        raise TypeError(f"unknown membership event {type(event).__name__}")

    def run_recovery(self, failed_rank: int, executor, now: float = 0.0):
        """DeviceFailed handler: drive replan -> migrate -> resume.

        Requires a *confirmed* failure (heartbeat -> probe walked first).
        Returns ``(RecoveryReport, migration)`` where ``migration`` is
        whatever ``executor.migrate`` produced.
        """
        if self.state != "confirmed":
            raise RuntimeError(f"recovery requires a confirmed failure "
                               f"(state={self.state})")
        self.last_beat.pop(failed_rank, None)
        self.suspect = None
        self._transition("replanning", now, failed_rank)
        report = executor.replan(failed_rank)
        t = now + report.replan_s
        self._transition("migrating", t, failed_rank)
        migration = executor.migrate(report)
        t += report.migration_s + report.restore_s
        self._transition("resuming", t, failed_rank)
        executor.resume(report, migration)
        self._transition("monitoring", t, None)
        self._post_swap("failed", failed_rank)
        return report, migration

    def _on_joined(self, event: DeviceJoined, executor, now: float):
        """DeviceJoined handler: hysteresis-gated admission.

        A rejection returns to monitoring after the pricing work alone; an
        accepted join migrates (boundary moves + any DP-peer replica push)
        and registers the new plan's ranks for heartbeats."""
        if self.state != "monitoring":
            raise RuntimeError(f"admission requires a quiet controller "
                               f"(state={self.state})")
        self._transition("admitting", now, None)
        decision = executor.admit_replan(event)
        t = now + decision.replan_s
        if not decision.accepted:
            self._transition("rejected", t, None)
            self._transition("monitoring", t, None)
            return decision, None
        report = decision.report
        self._transition("migrating", t, None)
        migration = executor.migrate(report)
        t += report.migration_s + report.replicate_s
        self._transition("resuming", t, None)
        executor.resume(report, migration)
        for st in report.new_plan.stages:
            for d in st.group:
                self.last_beat.setdefault(d, t)
        self._transition("monitoring", t, None)
        self._post_swap("joined", None)
        return decision, migration

    def _on_departing(self, rank: int, executor, now: float, *,
                      graceful: bool):
        """DeviceDraining / DeviceEvicted handler.

        No detection and no restore — the leaver is alive.  A graceful
        drain's migration overlaps continued serving, so the resuming
        timestamp advances by the re-plan only; an evict pauses for the
        migration like the crash path does."""
        if self.state != "monitoring":
            raise RuntimeError(f"departure requires a quiet controller "
                               f"(state={self.state})")
        self._transition("draining" if graceful else "evicting", now, rank)
        report = (executor.drain_replan(rank) if graceful
                  else executor.evict_replan(rank))
        t = now + report.replan_s
        self._transition("migrating", t, rank)
        migration = executor.migrate(report)
        if not report.overlapped:
            t += report.migration_s + report.restore_s
        self._transition("resuming", t, rank)
        executor.resume(report, migration)
        self.last_beat.pop(rank, None)
        self._transition("monitoring", t, None)
        self._post_swap("drained" if graceful else "evicted", rank)
        return report, migration


# The failure-only coordinator this controller generalizes; kept as an
# alias so existing imports and the paper-facing §3.4 name keep working.
ReplayCoordinator = MembershipController


# ---------------------------------------------------------------------------
# Lightweight layer-wise re-planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BoundaryMove:
    """Weights crossing one boundary of the *new* plan during migration."""

    boundary: int                  # between new stages boundary, boundary+1
    lo: int                        # table-layer hull [lo, hi) of moved layers
    hi: int
    nbytes: float                  # exact bytes crossing this boundary
    link_bw: float                 # D2D bandwidth of the boundary link


@dataclasses.dataclass(frozen=True)
class DirectMove:
    """Weights streamed straight from an off-plan source (a draining or
    evicted leaver) to one new owner stage — no boundary hops."""

    src_rank: int                  # the leaver's cluster rank
    dst_rank: int                  # the receiving stage's lead device
    lo: int                        # table-layer range [lo, hi) streamed
    hi: int
    nbytes: float
    link_bw: float                 # bw(src_rank, dst_rank)


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    """Analytical timing of one membership transition.

    ``mode``: "lightweight" | "heavy" (crash paths), "admission" (join),
    "drain" | "evict" (planned departures).  ``overlapped`` marks a
    graceful drain whose migration streams while the pipeline keeps
    serving; ``replicate_s`` charges the stage-model replica a DP-peer
    admission pushes onto the newcomer."""

    detection_s: float
    replan_s: float
    migration_s: float
    restore_s: float
    new_plan: Plan
    mode: str
    boundary_moves: tuple[BoundaryMove, ...] = ()
    direct_moves: tuple[DirectMove, ...] = ()
    replicate_s: float = 0.0
    overlapped: bool = False

    @property
    def total_s(self) -> float:
        return (self.detection_s + self.replan_s + self.migration_s
                + self.restore_s + self.replicate_s)

    @property
    def stall_s(self) -> float:
        """Time the pipeline is not producing.  An overlapped (graceful
        drain) migration streams concurrently with serving, so only the
        re-plan and any restore stall the round."""
        if self.overlapped:
            return self.detection_s + self.replan_s + self.restore_s
        return self.total_s


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    """Outcome of pricing a ``DeviceJoined`` event.

    ``report`` is set only when the join was accepted; a rejection still
    records how close the best candidate came, so churn benchmarks and the
    session's membership log can account for admission work."""

    accepted: bool
    report: RecoveryReport | None
    incumbent_latency: float
    candidate_latency: float
    hysteresis: float
    replan_s: float
    reason: str


def _stage_capacity(profile: Profile, group, i: int, j: int, mb: int) -> float:
    """Aggregate computing capacity sum_d v_d (Eq. 9) of a group."""
    return sum(1.0 / max(profile.t_both(d, mb, i, j), 1e-12) for d in group)


def _snap_cuts(cuts: list[int], quantum: int, L: int) -> list[int]:
    """Snap interior table-layer cuts to period boundaries.

    Mirrors ``lowering._snap_to_periods`` (table layer 1 + r*quantum is the
    boundary after real-layer period r) so a snapped plan lowers to exactly
    these cuts.  Kept strictly monotone with >= 1 period per stage.
    """
    n_layers = L - 2                       # embed + real layers + head
    n_per = n_layers // quantum
    P = len(cuts) - 1
    if P > n_per:
        raise AllocationError(f"{P} stages but only {n_per} periods")
    pers = [0]
    for p in range(P - 1):
        r = min(max(cuts[p + 1] - 1, 0), n_layers)
        per = round(r / quantum)
        per = max(per, pers[-1] + 1)
        per = min(per, n_per - (P - 1 - p))
        pers.append(per)
    return [0] + [1 + per * quantum for per in pers[1:]] + [L]


def _capacity_cuts(profile: Profile, groups, mb: int,
                   layer_quantum: int | None = None) -> list[int]:
    """FLOPs-proportional layer cuts over the groups' aggregate capacities
    (step 2 of lightweight re-planning, Eq. 9 capacities)."""
    table = profile.table
    L = table.L
    P = len(groups)
    caps = [_stage_capacity(profile, g, 0, L, mb) for g in groups]
    total_cap = sum(caps)
    total_flops = table.flops(0, L)
    cuts = [0]
    acc = 0.0
    li = 0
    for p in range(P - 1):
        acc += total_flops * caps[p] / total_cap
        while li < L and table.flops(0, li) < acc:
            li += 1
        cuts.append(min(li, L - (P - 1 - p)))
    cuts.append(L)
    if layer_quantum:
        cuts = _snap_cuts(cuts, layer_quantum, L)
    return cuts


def _boundary_moves(profile: Profile, old_owner, new_owner,
                    groups) -> tuple[float, tuple[BoundaryMove, ...]]:
    """Concurrent adjacent-boundary migration: a layer's weights cross
    boundary p iff its old->new owner path does.

    ``old_owner[l]`` of ``None`` (no surviving owner: restored from backup)
    or a negative sentinel (streamed directly from an off-plan leaver) is
    excluded — those layers never ride the boundary links."""
    table = profile.table
    L = table.L
    P = len(groups)
    migration = 0.0
    moves: list[BoundaryMove] = []
    for p in range(P - 1):
        crossing = [l for l in range(L)
                    if old_owner[l] is not None and old_owner[l] >= 0
                    and min(old_owner[l], new_owner[l]) <= p
                    < max(old_owner[l], new_owner[l])]
        link_bw = profile.cluster.bw(groups[p][0], groups[p + 1][0])
        if crossing:
            nbytes = sum(table.layers[l].param_bytes for l in crossing)
            moves.append(BoundaryMove(p, min(crossing), max(crossing) + 1,
                                      nbytes, link_bw))
            migration = max(migration, nbytes / link_bw)   # concurrent
    return migration, tuple(moves)


def _plan_from_cuts(plan: Plan, profile: Profile, groups, cuts,
                    planner: str = "replay") -> Plan:
    """Re-run Algorithm 1 within each stage and price the new chain.

    The rebuilt pipeline inherits the incumbent plan's gradient-sync
    semantics (a replayed async session stays async)."""
    table = profile.table
    mb = plan.micro_batch
    P = len(groups)
    new_stages = []
    steps: list[Step] = []
    for p in range(P):
        i, j = cuts[p], cuts[p + 1]
        alloc = allocate_microbatch(profile, groups[p], mb, i, j,
                                    kp_policy(P, p))
        ta = allreduce_time(table.param_bytes(i, j), groups[p],
                            profile.cluster)
        steps.append(Step("exec", alloc.ef, alloc.eb, ta, groups[p],
                          (i, j), alloc.y))
        new_stages.append(StagePlan((i, j), groups[p], alloc.y,
                                    kp_policy(P, p)))
        if p < P - 1:
            steps.append(_comm_step(profile, mb, j, groups[p],
                                    groups[p + 1]))
    lat = hpp_round_latency(tuple(steps), plan.n_micro,
                            getattr(plan, "staleness", 0))
    return Plan(plan.arch, tuple(new_stages), tuple(steps), mb,
                plan.n_micro, lat, planner,
                staleness=getattr(plan, "staleness", 0))


def _drop_rank(stages, rank: int):
    """Remove ``rank`` from every stage group; returns the surviving
    stages and a map from original stage index to survivor index (missing
    = the whole stage left with ``rank``)."""
    survivors: list[StagePlan] = []
    surv_of_orig: dict[int, int] = {}
    for q, st in enumerate(stages):
        group = tuple(d for d in st.group if d != rank)
        if group:
            surv_of_orig[q] = len(survivors)
            survivors.append(StagePlan(st.layers, group, st.alloc, st.k_p))
    return survivors, surv_of_orig


def lightweight_replay(plan: Plan, profile: Profile, failed_rank: int,
                       fail_time: float = 10.0,
                       layer_quantum: int | None = None) -> RecoveryReport:
    """Layer-wise lightweight re-planning after ``failed_rank`` crashes.

    ``layer_quantum``: when re-planning for the period-granular runtime
    (``core.lowering``), snap the new cuts to period boundaries (= the
    model's pattern length in table layers) so the analytical migration
    inputs coincide exactly with what ``migrate_params`` moves.
    """
    t0 = time.perf_counter()
    table = profile.table
    stages = list(plan.stages)
    mb = plan.micro_batch
    L = table.L

    # 1) drop the failed device, remembering each original stage's survivor
    #    index (None = the whole stage failed: restored, not migrated).
    survivors, surv_of_orig = _drop_rank(stages, failed_rank)
    P = len(survivors)
    if P == 0:
        raise RuntimeError("no surviving devices")
    groups = [st.group for st in survivors]

    # 2) FLOPs-proportional re-partition over surviving stages' capacities
    cuts = _capacity_cuts(profile, groups, mb, layer_quantum)

    # 3) per-layer ownership among the *survivors*.  Old ownership follows
    #    the ORIGINAL plan partition (so a fully-failed stage's range is not
    #    silently attributed to a neighbour); its layers have no surviving
    #    owner — they are restored from backup, not migrated.
    old_owner: list[int | None] = [None] * L
    for q, st in enumerate(stages):
        so = surv_of_orig.get(q)
        for l in range(*st.layers):
            old_owner[l] = so
    new_owner = [0] * L
    for p in range(P):
        for l in range(cuts[p], cuts[p + 1]):
            new_owner[l] = p

    # 4) concurrent layer migration between adjacent stages
    migration, moves = _boundary_moves(profile, old_owner, new_owner, groups)

    # 5) restore a fully-failed single-device stage's weights from its
    #    backup node *directly to their new owners*, over the actual backup
    #    links (concurrent pushes; a push to the backup holder's own new
    #    stage is local and free).
    assign = assign_backups(plan, profile)
    restore = 0.0
    for q, st in enumerate(stages):
        if failed_rank in st.group and len(st.group) == 1:
            backup_rank = assign.backup_of_stage.get(q)
            if backup_rank is None:
                continue
            for p in range(P):
                lo = max(st.layers[0], cuts[p])
                hi = min(st.layers[1], cuts[p + 1])
                if lo >= hi or backup_rank in survivors[p].group:
                    continue
                nbytes = table.param_bytes(lo, hi)
                bw = profile.cluster.bw(backup_rank, survivors[p].group[0])
                restore = max(restore, nbytes / bw)

    # 6) build the new plan (re-run Algorithm 1 within each stage)
    new_plan = _plan_from_cuts(plan, profile, groups, cuts)
    replan_s = time.perf_counter() - t0
    return RecoveryReport(detection_latency(fail_time), replan_s, migration,
                          restore, new_plan, "lightweight", moves)


def departure_replay(plan: Plan, profile: Profile, rank: int, *,
                     graceful: bool,
                     layer_quantum: int | None = None) -> RecoveryReport:
    """Planned departure of ``rank`` (drain when ``graceful``, else evict).

    Same FLOPs-proportional re-split as the crash path, but the leaver is
    *alive*: no detection latency, and a fully-departed stage's layers
    stream straight off the leaver to their new owners (``DirectMove``)
    instead of being restored from a backup node.  A graceful drain's
    migration overlaps continued serving (``overlapped=True``), so only
    the re-plan stalls the pipeline; an evict pauses like a crash does.
    """
    t0 = time.perf_counter()
    table = profile.table
    stages = list(plan.stages)
    mb = plan.micro_batch
    L = table.L

    survivors, surv_of_orig = _drop_rank(stages, rank)
    P = len(survivors)
    if P == 0:
        raise RuntimeError("no surviving devices")
    groups = [st.group for st in survivors]
    cuts = _capacity_cuts(profile, groups, mb, layer_quantum)

    # Old ownership follows the ORIGINAL partition; a fully-departed
    # stage's layers carry the DIRECT_SOURCE sentinel — they ride
    # leaver->owner links, not the boundary chain.
    old_owner: list[int | None] = [None] * L
    for q, st in enumerate(stages):
        so = surv_of_orig.get(q)
        for l in range(*st.layers):
            old_owner[l] = so if so is not None else DIRECT_SOURCE
    new_owner = [0] * L
    for p in range(P):
        for l in range(cuts[p], cuts[p + 1]):
            new_owner[l] = p

    migration, moves = _boundary_moves(profile, old_owner, new_owner, groups)

    # Direct streams off the leaver (only a stage it held alone needs them;
    # a DP peer's replicas already live on the survivors).  Concurrent with
    # each other and with the boundary moves.
    direct: list[DirectMove] = []
    for q, st in enumerate(stages):
        if rank in st.group and len(st.group) == 1:
            for p in range(P):
                lo = max(st.layers[0], cuts[p])
                hi = min(st.layers[1], cuts[p + 1])
                if lo >= hi:
                    continue
                nbytes = table.param_bytes(lo, hi)
                bw = profile.cluster.bw(rank, survivors[p].group[0])
                direct.append(DirectMove(rank, survivors[p].group[0],
                                         lo, hi, nbytes, bw))
                migration = max(migration, nbytes / bw)

    new_plan = _plan_from_cuts(plan, profile, groups, cuts)
    replan_s = time.perf_counter() - t0
    return RecoveryReport(0.0, replan_s, migration, 0.0, new_plan,
                          "drain" if graceful else "evict", moves,
                          direct_moves=tuple(direct), overlapped=graceful)


def admission_replay(plan: Plan, profile: Profile, new_rank: int, *,
                     hysteresis: float = ADMISSION_HYSTERESIS,
                     layer_quantum: int | None = None,
                     allowed_stages=None) -> AdmissionDecision:
    """Price a newcomer into the pipeline; accept only past hysteresis.

    ``profile`` must already include the newcomer as rank ``new_rank``
    (see ``profiler.extend_profile``).  Two incremental candidate families
    are priced — FTPipeHD would instead redistribute every weight:

    * **DP peer**: the newcomer joins an existing stage's data-parallel
      group; its stage model is *replicated* onto it from an incumbent
      member (``replicate_s``), and the FLOPs-proportional re-cut may
      shift boundaries (priced as boundary moves).
    * **Own stage**: the newcomer becomes a fresh stage at each insert
      position; it owns no layers yet, so everything it picks up rides
      the boundary chain onto it.

    ``allowed_stages`` restricts candidate stage counts (e.g. divisors of
    a runtime mesh's model axis, so the result stays lowerable).
    """
    t0 = time.perf_counter()
    table = profile.table
    stages = list(plan.stages)
    mb = plan.micro_batch
    L = table.L
    P0 = len(stages)

    def price(groups, old_to_new, newcomer_stage):
        """Price one candidate arrangement; returns (latency, report)."""
        cuts = _capacity_cuts(profile, groups, mb, layer_quantum)
        old_owner: list[int | None] = [None] * L
        for q, st in enumerate(stages):
            for l in range(*st.layers):
                old_owner[l] = old_to_new[q]
        new_owner = [0] * L
        for p in range(len(groups)):
            for l in range(cuts[p], cuts[p + 1]):
                new_owner[l] = p
        migration, moves = _boundary_moves(profile, old_owner, new_owner,
                                           groups)
        replicate = 0.0
        if newcomer_stage is not None:
            i, j = cuts[newcomer_stage], cuts[newcomer_stage + 1]
            src = next(d for d in groups[newcomer_stage] if d != new_rank)
            replicate = table.param_bytes(i, j) / profile.cluster.bw(
                src, new_rank)
        cand = _plan_from_cuts(plan, profile, groups, cuts)
        report = RecoveryReport(0.0, 0.0, migration, 0.0, cand,
                                "admission", moves, replicate_s=replicate)
        return cand.latency, report

    candidates: list[tuple[float, RecoveryReport, str]] = []
    # DP peer of each existing stage
    if allowed_stages is None or P0 in allowed_stages:
        for p in range(P0):
            groups = [st.group + ((new_rank,) if q == p else ())
                      for q, st in enumerate(stages)]
            try:
                lat, rep = price(groups, {q: q for q in range(P0)}, p)
                candidates.append((lat, rep, f"dp-peer of stage {p}"))
            except (AllocationError, RuntimeError):
                continue
    # Own stage at each insert position
    if allowed_stages is None or P0 + 1 in allowed_stages:
        for q_ins in range(P0 + 1):
            groups = ([st.group for st in stages[:q_ins]] + [(new_rank,)]
                      + [st.group for st in stages[q_ins:]])
            old_to_new = {q: (q if q < q_ins else q + 1) for q in range(P0)}
            try:
                lat, rep = price(groups, old_to_new, None)
                candidates.append((lat, rep,
                                   f"own stage at position {q_ins}"))
            except (AllocationError, RuntimeError):
                continue

    replan_s = time.perf_counter() - t0
    if not candidates:
        return AdmissionDecision(False, None, plan.latency, math.inf,
                                 hysteresis, replan_s,
                                 "no feasible candidate placement")
    lat, report, desc = min(candidates, key=lambda c: c[0])
    threshold = plan.latency * (1.0 - hysteresis)
    if lat >= threshold:
        return AdmissionDecision(
            False, None, plan.latency, lat, hysteresis, replan_s,
            f"best candidate ({desc}) at {lat:.4f}s does not beat the "
            f"incumbent's {plan.latency:.4f}s by the {hysteresis:.0%} "
            f"hysteresis margin")
    report = dataclasses.replace(report, replan_s=replan_s)
    return AdmissionDecision(True, report, plan.latency, lat, hysteresis,
                             replan_s, f"accepted as {desc}")


def heavy_rescheduling(plan: Plan, profile: Profile, failed_rank: int,
                       fail_time: float = 10.0,
                       replan_compute_scale: float = JETSON_REPLAN_SCALE,
                       allowed_stages=None) -> RecoveryReport:
    """Straw-man baseline: aggregate stage models to the coordinator, re-run
    Algorithm 2 from scratch, redistribute all weights.

    ``allowed_stages`` restricts the re-planned stage count (e.g. divisors
    of a runtime mesh's model axis, so the result stays lowerable)."""
    from .hardware import Cluster

    table = profile.table
    bw = profile.cluster.bandwidth

    # 1) aggregate every stage model to the coordinator (serialized in/out)
    aggregate = sum(table.param_bytes(*st.layers) for st in plan.stages) / bw

    # 2) full re-planning on the strongest surviving device
    devs = [d for i, d in enumerate(profile.cluster.devices) if i != failed_rank]
    sub_cluster = Cluster(tuple(devs), profile.cluster.bandwidth)
    sub_profile = Profile.analytic(table, sub_cluster, profile.max_batch)
    t0 = time.perf_counter()
    new_plan = plan_hpp(sub_profile, plan.global_batch, plan.micro_batch,
                        arch=plan.arch, allowed_stages=allowed_stages,
                        staleness=getattr(plan, "staleness", 0))
    replan = (time.perf_counter() - t0) * replan_compute_scale

    # sub-cluster ranks -> the original cluster's rank space, so the new
    # plan stays addressable by the same device identities as the old one
    remap = {i: r for i, r in enumerate(
        r for r in range(len(profile.cluster.devices)) if r != failed_rank)}
    stages = tuple(dataclasses.replace(st, group=tuple(remap[g] for g in st.group))
                   for st in new_plan.stages)
    steps = tuple(dataclasses.replace(s, group=tuple(remap[g] for g in s.group))
                  if s.group else s for s in new_plan.steps)
    new_plan = dataclasses.replace(new_plan, stages=stages, steps=steps)

    # 3) redistribute all stage weights
    redistribute = sum(table.param_bytes(*st.layers) for st in new_plan.stages) / bw

    return RecoveryReport(detection_latency(fail_time), replan,
                          aggregate + redistribute, 0.0, new_plan, "heavy")
