"""§3.4 Fault-tolerant pipeline replay.

Three modules, faithful to the paper:

1. **Heartbeat-guided failure detection** — every device emits heartbeats to
   the coordinator; a missed deadline triggers a probe; an unanswered probe
   confirms the failure.  (Simulated clock; the same state machine drives the
   live JAX demo in examples/fault_tolerance.py.)

2. **Topology-driven model replication** — single-device stages back up
   their stage model to a *backup node* in the next stage (last stage wraps
   to the first); multi-device stages are implicitly replicated by their DP
   peers.  Periodic checkpoint traffic is charged to the D2D links.

3. **Layer-wise lightweight re-planning** — on failure, instead of rerunning
   Algorithm 2, the surviving stages re-split the layer range proportionally
   to their aggregate computing capacity (FLOPs-based), and adjacent stages
   migrate boundary layers *concurrently*; weights owned by the failed
   device are restored from its backup.

The heavy-rescheduling baseline (aggregate → re-plan → redistribute) is also
implemented for the Fig. 16/17 comparison.
"""

from __future__ import annotations

import dataclasses

from .allocation import allocate_microbatch
from .costmodel import Step, allreduce_time, kp_policy, round_latency
from .planner import Plan, StagePlan, _comm_step, plan_hpp
from .profiler import Profile

HEARTBEAT_PERIOD = 0.5        # s
HEARTBEAT_TIMEOUT = 2.0       # missed-deadline threshold
PROBE_TIMEOUT = 1.0


@dataclasses.dataclass(frozen=True)
class BackupAssignment:
    """stage -> backup device rank holding its replica."""

    backup_of_stage: dict[int, int]
    checkpoint_bytes: dict[int, float]


def assign_backups(plan: Plan, profile: Profile) -> BackupAssignment:
    """Topology-driven replication (Fig. 9 left)."""
    stages = plan.stages
    P = len(stages)
    backup: dict[int, int] = {}
    ckpt: dict[int, float] = {}
    for p, st in enumerate(stages):
        if len(st.group) > 1:
            continue                       # DP peers already replicate
        nxt = stages[(p + 1) % P]
        backup[p] = nxt.group[0]
        ckpt[p] = profile.table.param_bytes(*st.layers)
    return BackupAssignment(backup, ckpt)


def checkpoint_cost(assign: BackupAssignment, profile: Profile) -> float:
    """Seconds to push one round of stage-model checkpoints."""
    if not assign.checkpoint_bytes:
        return 0.0
    return max(b / profile.cluster.bandwidth for b in assign.checkpoint_bytes.values())


# ---------------------------------------------------------------------------
# Failure detection (simulated clock)
# ---------------------------------------------------------------------------


def detection_latency(fail_time: float, heartbeat_period: float = HEARTBEAT_PERIOD,
                      timeout: float = HEARTBEAT_TIMEOUT,
                      probe_timeout: float = PROBE_TIMEOUT) -> float:
    """Time from failure to confirmed detection."""
    # last heartbeat was at the period boundary before the failure
    import math
    last_beat = math.floor(fail_time / heartbeat_period) * heartbeat_period
    deadline = last_beat + heartbeat_period + timeout
    return (deadline - fail_time) + probe_timeout


# ---------------------------------------------------------------------------
# Lightweight layer-wise re-planning
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RecoveryReport:
    detection_s: float
    replan_s: float
    migration_s: float
    restore_s: float
    new_plan: Plan
    mode: str

    @property
    def total_s(self) -> float:
        return self.detection_s + self.replan_s + self.migration_s + self.restore_s


def _stage_capacity(profile: Profile, group, i: int, j: int, mb: int) -> float:
    """Aggregate computing capacity sum_d v_d (Eq. 9) of a group."""
    return sum(1.0 / max(profile.t_both(d, mb, i, j), 1e-12) for d in group)


def lightweight_replay(plan: Plan, profile: Profile, failed_rank: int,
                       fail_time: float = 10.0) -> RecoveryReport:
    """Layer-wise lightweight re-planning after ``failed_rank`` exits."""
    import time as _time

    t0 = _time.perf_counter()
    table = profile.table
    stages = list(plan.stages)
    mb = plan.micro_batch

    # 1) drop the failed device; a stage left empty is merged away below.
    survivors: list[StagePlan] = []
    for st in stages:
        group = tuple(d for d in st.group if d != failed_rank)
        if group:
            survivors.append(StagePlan(st.layers, group, st.alloc, st.k_p))
        # fully-failed stage: its layer range is redistributed among the rest
    P = len(survivors)
    if P == 0:
        raise RuntimeError("no surviving devices")

    # 2) FLOPs-proportional re-partition over surviving stages' capacities
    caps = [_stage_capacity(profile, st.group, 0, table.L, mb) for st in survivors]
    total_cap = sum(caps)
    total_flops = table.flops(0, table.L)
    cuts = [0]
    acc = 0.0
    li = 0
    for p in range(P - 1):
        acc += total_flops * caps[p] / total_cap
        while li < table.L and table.flops(0, li) < acc:
            li += 1
        cuts.append(min(li, table.L - (P - 1 - p)))
    cuts.append(table.L)

    # 3) concurrent layer migration between adjacent stages
    #    bytes moved on each boundary = weights of layers that switch stages
    old_cuts = [0] + [st.layers[1] for st in survivors[:-1]] + [table.L]
    migration = 0.0
    for p in range(P - 1):
        lo, hi = sorted((old_cuts[p + 1], cuts[p + 1]))
        nbytes = table.param_bytes(lo, hi)
        link_bw = profile.cluster.bw(survivors[p].group[0], survivors[p + 1].group[0])
        migration = max(migration, nbytes / link_bw)   # concurrent transfers

    # 4) restore the failed device's weights from its backup node
    assign = assign_backups(plan, profile)
    restore = 0.0
    for p, st in enumerate(plan.stages):
        if failed_rank in st.group and len(st.group) == 1:
            restore = table.param_bytes(*st.layers) / profile.cluster.bandwidth

    # 5) build the new plan (re-run Algorithm 1 within each stage)
    new_stages = []
    steps: list[Step] = []
    for p in range(P):
        i, j = cuts[p], cuts[p + 1]
        alloc = allocate_microbatch(profile, survivors[p].group, mb, i, j,
                                    kp_policy(P, p))
        ta = allreduce_time(table.param_bytes(i, j), survivors[p].group,
                            profile.cluster)
        steps.append(Step("exec", alloc.ef, alloc.eb, ta, survivors[p].group,
                          (i, j), alloc.y))
        new_stages.append(StagePlan((i, j), survivors[p].group, alloc.y,
                                    kp_policy(P, p)))
        if p < P - 1:
            steps.append(_comm_step(profile, mb, j, survivors[p].group,
                                    survivors[p + 1].group))
    lat = round_latency(tuple(steps), plan.n_micro)
    new_plan = Plan(plan.arch, tuple(new_stages), tuple(steps), mb,
                    plan.n_micro, lat, "replay")
    replan_s = _time.perf_counter() - t0
    return RecoveryReport(detection_latency(fail_time), replan_s, migration,
                          restore, new_plan, "lightweight")


def heavy_rescheduling(plan: Plan, profile: Profile, failed_rank: int,
                       fail_time: float = 10.0,
                       replan_compute_scale: float = 1.0) -> RecoveryReport:
    """Straw-man baseline: aggregate stage models to the coordinator, re-run
    Algorithm 2 from scratch, redistribute all weights."""
    import numpy as np

    from .hardware import Cluster

    table = profile.table
    bw = profile.cluster.bandwidth

    # 1) aggregate every stage model to the coordinator (serialized in/out)
    aggregate = sum(table.param_bytes(*st.layers) for st in plan.stages) / bw

    # 2) full re-planning on the strongest surviving device
    devs = [d for i, d in enumerate(profile.cluster.devices) if i != failed_rank]
    sub_cluster = Cluster(tuple(devs), profile.cluster.bandwidth)
    sub_profile = Profile.analytic(table, sub_cluster, profile.max_batch)
    import time as _time
    t0 = _time.perf_counter()
    new_plan = plan_hpp(sub_profile, plan.global_batch, plan.micro_batch,
                        arch=plan.arch)
    replan = (_time.perf_counter() - t0) * replan_compute_scale

    # 3) redistribute all stage weights
    redistribute = sum(table.param_bytes(*st.layers) for st in new_plan.stages) / bw

    return RecoveryReport(detection_latency(fail_time), replan,
                          aggregate + redistribute, 0.0, new_plan, "heavy")
