"""Asteroid Profiler (§3.3): per-layer sizes and per-(device, batch) times.

Two construction paths:

* ``LayerTable.from_model_config`` — analytic per-layer FLOPs/bytes derived
  from a ``repro.models.ModelConfig`` (every assigned architecture), plus
  hand-built tables for the paper's CNNs (``paper_models.py``).
* ``measure_layer_times`` — a *real* profiler that executes jitted layer
  functions on the local device across a batch-size sweep (used on CPU in
  tests/examples; on a Jetson it would profile the real board — same code).

The planner consumes a ``Profile``: cumulative per-layer time tables
``t_f/t_b [device][beta][layer]`` with prefix sums so any layer-range cost
is O(1).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Sequence

import numpy as np

from .hardware import Cluster, DeviceProfile

BWD_FLOP_RATIO = 2.0           # backward ~= 2x forward FLOPs
GRAD_BYTES = 4                 # accumulated grads fp32
PARAM_BYTES = 4
ACT_BYTES = 4


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Static per-layer facts (per *sample* where applicable)."""

    name: str
    flops_fwd: float           # per sample
    param_bytes: float         # w_l
    act_bytes: float           # a_l — output activation per sample (the
                               # tensor crossing a stage boundary after l)


@dataclasses.dataclass(frozen=True)
class LayerTable:
    """The profiled DNN as a topologically-sorted layer sequence."""

    name: str
    layers: tuple[LayerCost, ...]

    @property
    def L(self) -> int:
        return len(self.layers)

    def param_bytes(self, i: int, j: int) -> float:
        return sum(l.param_bytes for l in self.layers[i:j])

    def act_bytes_sum(self, i: int, j: int) -> float:
        return sum(l.act_bytes for l in self.layers[i:j])

    def boundary_act(self, j: int) -> float:
        """Activation size crossing the boundary after layer j-1."""
        return self.layers[j - 1].act_bytes if 0 < j <= self.L else 0.0

    def flops(self, i: int, j: int) -> float:
        return sum(l.flops_fwd for l in self.layers[i:j])

    # ------------------------------------------------------------------
    @staticmethod
    def from_model_config(cfg, seq_len: int) -> "LayerTable":
        """Analytic table for a transformer ModelConfig (per-sample costs).

        One entry per LayerSpec instance plus embed/head pseudo-layers.
        """
        d, S = cfg.d_model, seq_len
        layers = [LayerCost("embed", 2 * d * S, cfg.vocab_size * d * PARAM_BYTES,
                            S * d * ACT_BYTES)]
        for li in range(cfg.n_layers):
            spec = cfg.pattern[li % len(cfg.pattern)]
            p_count = cfg.layer_param_count(spec)
            p_active = cfg.layer_active_param_count(spec)
            flops = 2.0 * p_active * S
            if spec.kind == "attn" and cfg.attn is not None:
                a = cfg.attn
                win = spec.window if not spec.full_attention else None
                eff_ctx = S if win is None else min(S, win)
                flops += 2.0 * 2.0 * S * eff_ctx * a.n_heads * a.head_dim / 2.0
            act = S * d * ACT_BYTES
            layers.append(LayerCost(f"{spec.kind}{li}", flops,
                                    p_count * PARAM_BYTES, act))
        layers.append(LayerCost("head", 2 * d * cfg.vocab_size * S,
                                (0 if cfg.tie_embeddings else cfg.vocab_size * d * PARAM_BYTES),
                                S * cfg.vocab_size * ACT_BYTES))
        return LayerTable(cfg.name, tuple(layers))


@dataclasses.dataclass
class Profile:
    """Planner input: time tables + sizes.  Times indexed [dev][beta][layer]
    as *cumulative* sums over layers (prefix[l] = sum of layers < l)."""

    table: LayerTable
    cluster: Cluster
    max_batch: int
    tf_prefix: np.ndarray      # (D, max_batch+1, L+1)
    tb_prefix: np.ndarray

    # -- range queries ---------------------------------------------------
    def t_fwd(self, dev: int, beta: int, i: int, j: int) -> float:
        if beta <= 0:
            return 0.0
        beta = min(beta, self.max_batch)
        return float(self.tf_prefix[dev, beta, j] - self.tf_prefix[dev, beta, i])

    def t_bwd(self, dev: int, beta: int, i: int, j: int) -> float:
        if beta <= 0:
            return 0.0
        beta = min(beta, self.max_batch)
        return float(self.tb_prefix[dev, beta, j] - self.tb_prefix[dev, beta, i])

    def t_both(self, dev: int, beta: int, i: int, j: int) -> float:
        return self.t_fwd(dev, beta, i, j) + self.t_bwd(dev, beta, i, j)

    # ------------------------------------------------------------------
    @staticmethod
    def analytic(table: LayerTable, cluster: Cluster, max_batch: int) -> "Profile":
        D, L = len(cluster.devices), table.L
        tf = np.zeros((D, max_batch + 1, L + 1))
        tb = np.zeros((D, max_batch + 1, L + 1))
        flops = np.array([l.flops_fwd for l in table.layers])
        for di, dev in enumerate(cluster.devices):
            for beta in range(1, max_batch + 1):
                work = flops * beta
                eff = dev.eff(beta) * flops / (flops + dev.sat_flops)
                per_layer_f = work / (dev.flops * np.maximum(eff, 1e-9)) + dev.overhead
                tf[di, beta, 1:] = np.cumsum(per_layer_f)
                tb[di, beta, 1:] = np.cumsum(per_layer_f * BWD_FLOP_RATIO)
        return Profile(table, cluster, max_batch, tf, tb)

    @staticmethod
    def measured(table: LayerTable, cluster: Cluster, max_batch: int,
                 tf_samples: np.ndarray, tb_samples: np.ndarray) -> "Profile":
        """From measured per-layer times: samples (D, max_batch+1, L)."""
        D, _, L = tf_samples.shape
        tf = np.zeros((D, max_batch + 1, L + 1))
        tb = np.zeros((D, max_batch + 1, L + 1))
        tf[:, :, 1:] = np.cumsum(tf_samples, axis=2)
        tb[:, :, 1:] = np.cumsum(tb_samples, axis=2)
        return Profile(table, cluster, max_batch, tf, tb)


# ---------------------------------------------------------------------------
# Real measurement path (runs on the local JAX device)
# ---------------------------------------------------------------------------


def measure_layer_times(layer_fns: Sequence[Callable], make_input: Callable,
                        batch_sizes: Sequence[int], repeats: int = 3):
    """Measure wall-clock fwd and bwd times of each layer callable.

    layer_fns: list of (params, x)->y pure fns already bound to params.
    make_input: (beta, layer_idx) -> x.
    Returns (tf, tb) arrays of shape (len(batch_sizes), L).
    """
    import jax

    L = len(layer_fns)
    tf = np.zeros((len(batch_sizes), L))
    tb = np.zeros((len(batch_sizes), L))
    for bi, beta in enumerate(batch_sizes):
        for li, fn in enumerate(layer_fns):
            x = make_input(beta, li)
            fwd = jax.jit(fn)
            vjp_fn = jax.jit(lambda x: jax.vjp(fn, x)[1](jnp_ones_like(fn(x))))
            fwd(x).block_until_ready()           # compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                fwd(x).block_until_ready()
            tf[bi, li] = (time.perf_counter() - t0) / repeats
            try:
                vjp_fn(x)[0].block_until_ready() # compile
                t0 = time.perf_counter()
                for _ in range(repeats):
                    vjp_fn(x)[0].block_until_ready()
                tb[bi, li] = (time.perf_counter() - t0) / repeats
            except Exception:
                tb[bi, li] = tf[bi, li] * BWD_FLOP_RATIO
    return tf, tb


def jnp_ones_like(x):
    import jax.numpy as jnp
    return jnp.ones_like(x)
