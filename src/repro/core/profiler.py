"""Asteroid Profiler (§3.3): per-layer sizes and per-(device, batch) times.

Three construction paths:

* ``LayerTable.from_model_config`` — analytic per-layer FLOPs/bytes derived
  from a ``repro.models.ModelConfig`` (every assigned architecture), plus
  hand-built tables for the paper's CNNs (``paper_models.py``).
* ``measure_layer_times`` — a *real* profiler that executes jitted layer
  functions on the local device across a batch-size sweep (used on CPU in
  tests/examples; on a Jetson it would profile the real board — same code).
* ``MeasuredProfile`` — the serializable artifact produced by
  ``repro.launch.profile``: raw measured ``(tf, tb)`` sweeps per device plus
  the cluster/config fingerprints needed to decide whether the measurement
  is still valid.  ``save_profile``/``load_profile`` round-trip it through
  versioned JSON bit-exactly; ``MeasuredProfile.to_profile`` densifies the
  sweeps into ``Profile.measured`` tables for the planner.

The planner consumes a ``Profile``: cumulative per-layer time tables
``t_f/t_b [device][beta][layer]`` with prefix sums so any layer-range cost
is O(1).  ``Profile.source`` records which path built it ("analytic" or
"measured") so downstream reporting (``core.simulator.prediction_gap``,
``BENCH_throughput.json``) can attribute prediction error to the profile.

See DESIGN.md §3 (Measured profiling) for the JSON schema, fingerprinting,
and staleness rules.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from typing import Callable, Sequence

import numpy as np

from .hardware import MBPS_1000, Cluster, DeviceProfile

BWD_FLOP_RATIO = 2.0           # backward ~= 2x forward FLOPs
GRAD_BYTES = 4                 # accumulated grads fp32
PARAM_BYTES = 4
ACT_BYTES = 4

PROFILE_SCHEMA = "asteroid-profile"
PROFILE_VERSION = 1


class ProfileError(ValueError):
    """A profile artifact or sample table is malformed or incompatible."""


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Static per-layer facts (per *sample* where applicable)."""

    name: str
    flops_fwd: float           # per sample
    param_bytes: float         # w_l
    act_bytes: float           # a_l — output activation per sample (the
                               # tensor crossing a stage boundary after l)


@dataclasses.dataclass(frozen=True)
class LayerTable:
    """The profiled DNN as a topologically-sorted layer sequence."""

    name: str
    layers: tuple[LayerCost, ...]

    @property
    def L(self) -> int:
        return len(self.layers)

    def param_bytes(self, i: int, j: int) -> float:
        return sum(l.param_bytes for l in self.layers[i:j])

    def act_bytes_sum(self, i: int, j: int) -> float:
        return sum(l.act_bytes for l in self.layers[i:j])

    def boundary_act(self, j: int) -> float:
        """Activation size crossing the boundary after layer j-1."""
        return self.layers[j - 1].act_bytes if 0 < j <= self.L else 0.0

    def flops(self, i: int, j: int) -> float:
        return sum(l.flops_fwd for l in self.layers[i:j])

    # ------------------------------------------------------------------
    @staticmethod
    def from_model_config(cfg, seq_len: int) -> "LayerTable":
        """Analytic table for a transformer ModelConfig (per-sample costs).

        One entry per LayerSpec instance plus embed/head pseudo-layers.
        """
        d, S = cfg.d_model, seq_len
        layers = [LayerCost("embed", 2 * d * S, cfg.vocab_size * d * PARAM_BYTES,
                            S * d * ACT_BYTES)]
        for li in range(cfg.n_layers):
            spec = cfg.pattern[li % len(cfg.pattern)]
            p_count = cfg.layer_param_count(spec)
            p_active = cfg.layer_active_param_count(spec)
            flops = 2.0 * p_active * S
            if spec.kind == "attn" and cfg.attn is not None:
                a = cfg.attn
                win = spec.window if not spec.full_attention else None
                eff_ctx = S if win is None else min(S, win)
                flops += 2.0 * 2.0 * S * eff_ctx * a.n_heads * a.head_dim / 2.0
            act = S * d * ACT_BYTES
            layers.append(LayerCost(f"{spec.kind}{li}", flops,
                                    p_count * PARAM_BYTES, act))
        layers.append(LayerCost("head", 2 * d * cfg.vocab_size * S,
                                (0 if cfg.tie_embeddings else cfg.vocab_size * d * PARAM_BYTES),
                                S * cfg.vocab_size * ACT_BYTES))
        return LayerTable(cfg.name, tuple(layers))


@dataclasses.dataclass
class Profile:
    """Planner input: time tables + sizes.  Times indexed [dev][beta][layer]
    as *cumulative* sums over layers (prefix[l] = sum of layers < l)."""

    table: LayerTable
    cluster: Cluster
    max_batch: int
    tf_prefix: np.ndarray      # (D, max_batch+1, L+1)
    tb_prefix: np.ndarray
    source: str = "analytic"   # "analytic" | "measured" (provenance only)

    # -- range queries ---------------------------------------------------
    def t_fwd(self, dev: int, beta: int, i: int, j: int) -> float:
        if beta <= 0:
            return 0.0
        beta = min(beta, self.max_batch)
        return float(self.tf_prefix[dev, beta, j] - self.tf_prefix[dev, beta, i])

    def t_bwd(self, dev: int, beta: int, i: int, j: int) -> float:
        if beta <= 0:
            return 0.0
        beta = min(beta, self.max_batch)
        return float(self.tb_prefix[dev, beta, j] - self.tb_prefix[dev, beta, i])

    def t_both(self, dev: int, beta: int, i: int, j: int) -> float:
        return self.t_fwd(dev, beta, i, j) + self.t_bwd(dev, beta, i, j)

    # ------------------------------------------------------------------
    @staticmethod
    def analytic(table: LayerTable, cluster: Cluster, max_batch: int) -> "Profile":
        D, L = len(cluster.devices), table.L
        tf = np.zeros((D, max_batch + 1, L + 1))
        tb = np.zeros((D, max_batch + 1, L + 1))
        for di, dev in enumerate(cluster.devices):
            f, b = analytic_layer_times(dev, table, max_batch)
            tf[di, :, 1:] = np.cumsum(f, axis=1)
            tb[di, :, 1:] = np.cumsum(b, axis=1)
        return Profile(table, cluster, max_batch, tf, tb)

    @staticmethod
    def measured(table: LayerTable, cluster: Cluster, max_batch: int,
                 tf_samples: np.ndarray, tb_samples: np.ndarray) -> "Profile":
        """From measured per-layer times: samples (D, max_batch+1, L).

        Every device's table must cover every batch size up to ``max_batch``
        (row ``beta`` holds the per-layer times at batch ``beta``; row 0 is
        zero).  A shape mismatch raises ``ProfileError`` up front instead of
        the planner later hitting a silent out-of-range index/broadcast
        fault mid-DP.
        """
        D, L = len(cluster.devices), table.L
        want = (D, max_batch + 1, L)
        arrs = []
        for name, s in (("tf_samples", tf_samples), ("tb_samples", tb_samples)):
            s = np.asarray(s, dtype=np.float64)
            if s.shape != want:
                raise ProfileError(
                    f"{name} shape {s.shape} does not cover the profile: "
                    f"need (devices={D}, batch rows=max_batch+1={max_batch + 1}, "
                    f"layers={L}) — every device's sample table must cover "
                    f"batch sizes 0..{max_batch} for all {L} layers of "
                    f"{table.name!r}")
            if not np.isfinite(s).all() or (s < 0).any():
                raise ProfileError(
                    f"{name} contains negative or non-finite layer times")
            zero = np.argwhere(s[:, 1:, :].sum(axis=2) == 0.0)
            if zero.size:
                d, b = (int(x) for x in zero[0])
                raise ProfileError(
                    f"{name} has a zero measured-time row: device {d} at "
                    f"batch {b + 1} totals 0s across all {L} layers — an "
                    f"all-zero sweep row means the measurement failed for "
                    f"that (device, batch); re-profile or drop the device")
            arrs.append(s)
        tf_samples, tb_samples = arrs
        tf = np.zeros((D, max_batch + 1, L + 1))
        tb = np.zeros((D, max_batch + 1, L + 1))
        tf[:, :, 1:] = np.cumsum(tf_samples, axis=2)
        tb[:, :, 1:] = np.cumsum(tb_samples, axis=2)
        return Profile(table, cluster, max_batch, tf, tb, source="measured")


def analytic_layer_times(device: DeviceProfile, table: LayerTable,
                         max_batch: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-layer analytic ``(tf, tb)`` sample tables for one device.

    Shape ``(max_batch+1, L)`` with row 0 zero — the single-device slice of
    what ``Profile.analytic`` builds, exposed so ``extend_profile`` can
    price an unprofiled newcomer with the identical FLOP model."""
    L = table.L
    tf = np.zeros((max_batch + 1, L))
    flops = np.array([l.flops_fwd for l in table.layers])
    for beta in range(1, max_batch + 1):
        work = flops * beta
        eff = device.eff(beta) * flops / (flops + device.sat_flops)
        tf[beta] = work / (device.flops * np.maximum(eff, 1e-9)) + device.overhead
    return tf, tf * BWD_FLOP_RATIO


def extend_profile(profile: Profile, device: DeviceProfile,
                   tf_samples: np.ndarray | None = None,
                   tb_samples: np.ndarray | None = None, *,
                   bw: float | None = None) -> Profile:
    """Append one device to ``profile`` as the LAST cluster rank.

    The scale-out half of elastic membership
    (``core.replay.admission_replay``): incumbent devices keep their ranks —
    the running plan and the migration accounting stay addressable by the
    same device identities — and the newcomer becomes rank ``D``.

    ``tf_samples``/``tb_samples``: the newcomer's per-layer time tables of
    shape ``(max_batch+1, L)`` with row 0 zero, e.g. its measured on-arrival
    sweep densified by ``MeasuredProfile.device_rows``.  Omitted, the
    analytic FLOP model of ``device`` fills the row (the fallback when a
    newcomer arrives unprofiled).

    ``bw``: D2D bandwidth between the newcomer and every incumbent when the
    cluster prices links through a ``bw_matrix`` (defaults to the
    cluster-wide bandwidth)."""
    table, mb = profile.table, profile.max_batch
    D, L = len(profile.cluster.devices), table.L
    measured_row = tf_samples is not None and tb_samples is not None
    if (tf_samples is None) != (tb_samples is None):
        raise ProfileError(
            "pass both tf_samples and tb_samples, or neither")
    if not measured_row:
        tf_samples, tb_samples = analytic_layer_times(device, table, mb)
    arrs = []
    for name, s in (("tf_samples", tf_samples), ("tb_samples", tb_samples)):
        s = np.asarray(s, dtype=np.float64)
        if s.shape != (mb + 1, L):
            raise ProfileError(
                f"{name} shape {s.shape} != {(mb + 1, L)}: the newcomer's "
                f"table must cover batch sizes 0..{mb} for all {L} layers "
                f"of {table.name!r}")
        if not np.isfinite(s).all() or (s < 0).any():
            raise ProfileError(
                f"{name} contains negative or non-finite layer times")
        arrs.append(s)
    tf_samples, tb_samples = arrs
    tfp = np.zeros((D + 1, mb + 1, L + 1))
    tbp = np.zeros((D + 1, mb + 1, L + 1))
    tfp[:D], tbp[:D] = profile.tf_prefix, profile.tb_prefix
    tfp[D, :, 1:] = np.cumsum(tf_samples, axis=1)
    tbp[D, :, 1:] = np.cumsum(tb_samples, axis=1)
    bwm = profile.cluster.bw_matrix
    if bwm is not None:
        link = bw if bw is not None else profile.cluster.bandwidth
        bwm = tuple(tuple(row) + (link,) for row in bwm) \
            + (tuple([link] * D + [0.0]),)
    cluster = Cluster(profile.cluster.devices + (device,),
                      profile.cluster.bandwidth, bwm)
    source = profile.source
    if measured_row and source == "analytic":
        source = "mixed"
    elif not measured_row and source == "measured":
        source = "mixed"
    return Profile(table, cluster, mb, tfp, tbp, source)


def subset_profile(profile: Profile, ranks: Sequence[int]) -> Profile:
    """``profile`` restricted to cluster ranks ``ranks`` (order preserved).

    The post-churn planning view: after failures/evictions the session's
    profile still carries every original device, but a portfolio auction
    must only enumerate plans over the survivors.  Device ``i`` of the
    returned profile is original rank ``ranks[i]``; use
    ``portfolio.renumber_plan(plan, ranks)`` to map a plan made on the
    subset back into the parent cluster's numbering."""
    ranks = tuple(int(r) for r in ranks)
    D = len(profile.cluster.devices)
    if not ranks or len(set(ranks)) != len(ranks) or \
            any(not 0 <= r < D for r in ranks):
        raise ProfileError(
            f"ranks {ranks} must be distinct indices into 0..{D - 1}")
    bwm = profile.cluster.bw_matrix
    if bwm is not None:
        bwm = tuple(tuple(bwm[a][b] for b in ranks) for a in ranks)
    cluster = Cluster(tuple(profile.cluster.devices[r] for r in ranks),
                      profile.cluster.bandwidth, bwm)
    idx = np.asarray(ranks)
    return Profile(profile.table, cluster, profile.max_batch,
                   profile.tf_prefix[idx], profile.tb_prefix[idx],
                   profile.source)


def resolve_profile(measured, cfg, seq_len: int, table: LayerTable,
                    max_batch: int, *, label: str = "measured profile",
                    fallback_note: str = "") -> Profile | None:
    """Turn a loaded ``MeasuredProfile`` into a planner ``Profile``, or
    ``None`` (with a warning) when it no longer describes this run.

    The stale-artifact policy in one place: fingerprint mismatches and
    densification errors degrade to the analytic fallback with a warning —
    never a crash — because a stale measurement is an expected state (model
    edited, different host), not a bug."""
    import warnings

    if measured is None:
        return None
    issues = measured.compatibility_issues(cfg, seq_len)
    prof = None
    if not issues:
        try:
            prof = measured.to_profile(table, max_batch)
        except ProfileError as e:
            issues = [str(e)]
    if prof is None:
        warnings.warn(
            f"{label} is stale or incompatible — falling back to the "
            f"analytic profile{fallback_note}: " + "; ".join(issues))
    return prof


# ---------------------------------------------------------------------------
# Real measurement path (runs on the local JAX device)
# ---------------------------------------------------------------------------


def measure_layer_times(layer_fns: Sequence[Callable], make_input: Callable,
                        batch_sizes: Sequence[int], repeats: int = 3):
    """Measure wall-clock fwd and bwd times of each layer callable.

    layer_fns: list of (params, x)->y pure fns already bound to params.
    make_input: (beta, layer_idx) -> x.
    Returns (tf, tb) arrays of shape (len(batch_sizes), L).
    """
    import jax

    L = len(layer_fns)
    tf = np.zeros((len(batch_sizes), L))
    tb = np.zeros((len(batch_sizes), L))
    for bi, beta in enumerate(batch_sizes):
        for li, fn in enumerate(layer_fns):
            x = make_input(beta, li)
            fwd = jax.jit(fn)
            vjp_fn = jax.jit(lambda x: jax.vjp(fn, x)[1](jnp_ones_like(fn(x))))
            fwd(x).block_until_ready()           # compile
            t0 = time.perf_counter()
            for _ in range(repeats):
                fwd(x).block_until_ready()
            tf[bi, li] = (time.perf_counter() - t0) / repeats
            try:
                vjp_fn(x)[0].block_until_ready() # compile
                t0 = time.perf_counter()
                for _ in range(repeats):
                    vjp_fn(x)[0].block_until_ready()
                tb[bi, li] = (time.perf_counter() - t0) / repeats
            except Exception:
                tb[bi, li] = tf[bi, li] * BWD_FLOP_RATIO
    return tf, tb


def jnp_ones_like(x):
    import jax.numpy as jnp
    return jnp.ones_like(x)


# ---------------------------------------------------------------------------
# Measured-profile artifact: fingerprints, serialization, densification
# ---------------------------------------------------------------------------


def config_fingerprint(cfg, seq_len: int) -> str:
    """Stable hash of everything that shapes the layer table.

    Covers the full ``ModelConfig`` (nested dataclasses stringified) plus
    the sequence length — a measured profile is only valid for the exact
    (model, seq_len) it profiled, because per-layer times scale with both.
    """
    blob = json.dumps({"cfg": dataclasses.asdict(cfg), "seq_len": seq_len},
                      sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


def device_fingerprint() -> str:
    """Hash of the local JAX device the measurement would run on.

    Platform + device kind + process count: enough to detect "this artifact
    was measured on different hardware", without being so strict that a
    rebuild of the same container — or forcing extra *virtual* host devices
    with ``--xla_force_host_platform_device_count`` (the sweep always runs
    on one local device per process) — invalidates it.
    """
    import jax

    dev = jax.local_devices()[0]
    blob = json.dumps({
        "platform": dev.platform,
        "device_kind": getattr(dev, "device_kind", ""),
        "processes": jax.process_count(),
    }, sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class MeasuredProfile:
    """A measured on-device profile, as serialized by ``save_profile``.

    Holds the *raw* per-device sweeps — ``tf/tb[d, bi, l]`` is the measured
    forward/backward wall-clock of layer ``l`` on device ``d`` at batch size
    ``batch_sizes[bi]`` — plus the metadata needed to (a) rebuild a planner
    ``Profile`` (``to_profile``) and (b) decide whether the measurement
    still describes the current model and hardware
    (``compatibility_issues``).
    """

    arch: str                          # cfg.name at measurement time
    seq_len: int
    batch_sizes: tuple[int, ...]       # ascending swept batch sizes
    layer_names: tuple[str, ...]       # one per LayerTable entry
    tf: np.ndarray                     # (D, len(batch_sizes), L) seconds
    tb: np.ndarray
    device_names: tuple[str, ...]      # one per profiled (virtual) device
    config_hash: str                   # config_fingerprint(cfg, seq_len)
    device_hash: str                   # device_fingerprint() at measurement
    mem_bytes: tuple[float, ...]       # per-device memory budget u_d
    est_flops: tuple[float, ...]       # effective FLOP/s at the largest batch
    bandwidth: float = MBPS_1000       # assumed D2D bandwidth (bytes/s)
    repeats: int = 1
    meta: dict = dataclasses.field(default_factory=dict)
    version: int = PROFILE_VERSION

    @property
    def D(self) -> int:
        return len(self.device_names)

    @property
    def L(self) -> int:
        return len(self.layer_names)

    def __post_init__(self):
        want = (self.D, len(self.batch_sizes), self.L)
        for name, a in (("tf", self.tf), ("tb", self.tb)):
            if a.shape != want:
                raise ProfileError(f"{name} shape {a.shape} != {want} "
                                   f"(devices, batch_sizes, layers)")
        if list(self.batch_sizes) != sorted(set(self.batch_sizes)) or \
                (self.batch_sizes and self.batch_sizes[0] < 1):
            raise ProfileError(
                f"batch_sizes must be ascending positive ints, got "
                f"{self.batch_sizes}")
        if len(self.mem_bytes) != self.D or len(self.est_flops) != self.D:
            raise ProfileError("per-device metadata length != device count")

    # -- planner-facing views ------------------------------------------------

    def cluster(self) -> Cluster:
        """The measured devices as a planner ``Cluster``.

        ``flops`` is the *effective* rate observed at the largest measured
        batch (not a datasheet peak), and the Fig. 6 saturation constants
        are zeroed — so ``Profile.analytic`` on this cluster is the classic
        linear FLOP model calibrated to the same hardware (total forward
        time at the calibration batch matches the measurement exactly).
        The residual error ``core.simulator.prediction_gap`` reports is
        then precisely the per-layer / per-batch structure only a measured
        profile captures.
        """
        devs = tuple(
            DeviceProfile(name, mem_bytes=self.mem_bytes[d],
                          flops=self.est_flops[d], sat_batch=0.0,
                          sat_flops=0.0, overhead=0.0)
            for d, name in enumerate(self.device_names))
        return Cluster(devs, bandwidth=self.bandwidth)

    def densify(self, max_batch: int) -> tuple[np.ndarray, np.ndarray]:
        """Fill the swept batch sizes out to ``(D, max_batch+1, L)`` tables.

        Linear interpolation between measured batch sizes, constant
        extension below the smallest (launch overhead dominates there), and
        linear extrapolation above the largest using the last segment's
        slope.  The result is clamped non-negative and made monotone
        non-decreasing in beta, preserving the Fig. 6 shape the allocation
        search (Algorithm 1) relies on.
        """
        if max_batch < 1:
            raise ProfileError(f"max_batch must be >= 1, got {max_batch}")
        bs = np.asarray(self.batch_sizes, dtype=np.float64)
        betas = np.arange(1, max_batch + 1, dtype=np.float64)
        out = []
        for raw in (self.tf, self.tb):
            dense = np.zeros((self.D, max_batch + 1, self.L))
            for d in range(self.D):
                for l in range(self.L):
                    y = raw[d, :, l]
                    vals = np.interp(betas, bs, y)
                    if len(bs) >= 2 and max_batch > bs[-1]:
                        slope = (y[-1] - y[-2]) / (bs[-1] - bs[-2])
                        hi = betas > bs[-1]
                        vals[hi] = y[-1] + slope * (betas[hi] - bs[-1])
                    vals = np.maximum.accumulate(np.maximum(vals, 0.0))
                    dense[d, 1:, l] = vals
            out.append(dense)
        return out[0], out[1]

    def to_profile(self, table: LayerTable, max_batch: int,
                   sort_by_memory: bool = True) -> Profile:
        """Densify into a planner ``Profile`` over ``table``.

        ``sort_by_memory`` applies the planner's descending-memory device
        preorder (§3.3) to the *measured rows and the cluster together*, so
        device rank d in the returned profile is the same physical device
        in both.
        """
        if table.L != self.L or tuple(l.name for l in table.layers) != \
                self.layer_names:
            raise ProfileError(
                f"layer table {table.name!r} ({table.L} layers) does not "
                f"match the measured layers {list(self.layer_names)}")
        tf_s, tb_s = self.densify(max_batch)
        cluster = self.cluster()
        if sort_by_memory:
            order = sorted(range(self.D),
                           key=lambda i: (-cluster.devices[i].mem_bytes,
                                          -cluster.devices[i].flops))
            cluster = Cluster(tuple(cluster.devices[i] for i in order),
                              cluster.bandwidth, cluster.bw_matrix)
            tf_s, tb_s = tf_s[order], tb_s[order]
        return Profile.measured(table, cluster, max_batch, tf_s, tb_s)

    def device_rows(self, table: LayerTable, max_batch: int,
                    dev: int = 0) -> tuple[np.ndarray, np.ndarray]:
        """One device's densified ``(tf, tb)`` tables, ``(max_batch+1, L)``.

        The newcomer-admission view: a single-device on-arrival sweep
        (``launch.profile.measure_model`` on the joining board) becomes the
        row ``extend_profile`` appends.  Validates the measured layers
        against ``table`` like ``to_profile`` does — an incompatible sweep
        raises ``ProfileError`` so callers can fall back to the analytic
        device model."""
        if not 0 <= dev < self.D:
            raise ProfileError(f"device index {dev} out of range "
                               f"(artifact has {self.D} rows)")
        if table.L != self.L or tuple(l.name for l in table.layers) != \
                self.layer_names:
            raise ProfileError(
                f"layer table {table.name!r} ({table.L} layers) does not "
                f"match the measured layers {list(self.layer_names)}")
        tf_s, tb_s = self.densify(max_batch)
        return tf_s[dev], tb_s[dev]

    # -- staleness / compatibility ------------------------------------------

    def compatibility_issues(self, cfg, seq_len: int,
                             check_device: bool = True) -> list[str]:
        """Human-readable reasons this artifact should NOT be used.

        Empty list == compatible.  Checks the model-config + seq_len
        fingerprint and (optionally) the local device fingerprint; callers
        are expected to fall back to ``Profile.analytic`` with a warning
        when issues are reported.
        """
        issues = []
        if self.version > PROFILE_VERSION:
            issues.append(f"artifact version {self.version} is newer than "
                          f"supported {PROFILE_VERSION}")
        want = config_fingerprint(cfg, seq_len)
        if want != self.config_hash:
            issues.append(
                f"model/seq fingerprint mismatch: artifact profiled "
                f"{self.arch!r} at seq_len={self.seq_len} "
                f"(hash {self.config_hash}), current is {cfg.name!r} at "
                f"seq_len={seq_len} (hash {want})")
        if check_device:
            cur = device_fingerprint()
            if cur != self.device_hash:
                issues.append(
                    f"device fingerprint mismatch: artifact measured on "
                    f"{self.device_hash}, current host is {cur} — re-run "
                    f"repro.launch.profile on this host")
        return issues


def save_profile(path: str, mp: MeasuredProfile) -> None:
    """Serialize a ``MeasuredProfile`` to versioned JSON.

    Floats go through Python ``repr`` (the json encoder), which round-trips
    IEEE-754 doubles exactly — ``load_profile(save_profile(mp))`` is
    bit-identical, pinned by tests.
    """
    doc = {
        "schema": PROFILE_SCHEMA,
        "version": mp.version,
        "arch": mp.arch,
        "seq_len": mp.seq_len,
        "batch_sizes": list(mp.batch_sizes),
        "layer_names": list(mp.layer_names),
        "device_names": list(mp.device_names),
        "config_hash": mp.config_hash,
        "device_hash": mp.device_hash,
        "mem_bytes": list(mp.mem_bytes),
        "est_flops": list(mp.est_flops),
        "bandwidth": mp.bandwidth,
        "repeats": mp.repeats,
        "meta": mp.meta,
        "tf": np.asarray(mp.tf, np.float64).tolist(),
        "tb": np.asarray(mp.tb, np.float64).tolist(),
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def load_profile(path: str) -> MeasuredProfile:
    """Parse a ``save_profile`` artifact, validating schema and shapes."""
    with open(path) as f:
        try:
            doc = json.load(f)
        except json.JSONDecodeError as e:
            raise ProfileError(f"{path}: not valid JSON ({e})") from e
    if doc.get("schema") != PROFILE_SCHEMA:
        raise ProfileError(
            f"{path}: schema {doc.get('schema')!r} != {PROFILE_SCHEMA!r}")
    missing = [k for k in ("version", "arch", "seq_len", "batch_sizes",
                           "layer_names", "device_names", "config_hash",
                           "device_hash", "mem_bytes", "est_flops", "tf",
                           "tb") if k not in doc]
    if missing:
        raise ProfileError(f"{path}: missing keys {missing}")
    return MeasuredProfile(
        arch=doc["arch"], seq_len=int(doc["seq_len"]),
        batch_sizes=tuple(int(b) for b in doc["batch_sizes"]),
        layer_names=tuple(doc["layer_names"]),
        tf=np.asarray(doc["tf"], np.float64),
        tb=np.asarray(doc["tb"], np.float64),
        device_names=tuple(doc["device_names"]),
        config_hash=doc["config_hash"], device_hash=doc["device_hash"],
        mem_bytes=tuple(float(m) for m in doc["mem_bytes"]),
        est_flops=tuple(float(x) for x in doc["est_flops"]),
        bandwidth=float(doc.get("bandwidth", MBPS_1000)),
        repeats=int(doc.get("repeats", 1)), meta=doc.get("meta", {}),
        version=int(doc["version"]))
