"""CausalLM assembly: embedding -> scanned periods -> norm -> head (+MTP).

Covers every assigned architecture:

* text LMs (dense / MoE / SSM / RWKV / hybrid),
* MusicGen-style multi-codebook audio decoding (sum of codebook embeddings,
  one head per codebook; the EnCodec frontend is a stub — see
  ``frontend.py``),
* VLM (InternVL2): stub vision embeddings are projected and prepended as a
  prefix; loss is masked to text positions,
* DeepSeek-V3 MTP: one extra transformer block predicting token t+2 from
  [emb(t+1); h_t], sharing embedding and head.

Sharding note: this module is written for single-device semantics; the
distributed runtime reuses ``apply_periods`` inside shard_map and adds
sharding constraints around the embed/head (auto mode).  The loss is
computed in sequence chunks so (B, S, V) logits are never materialized.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .blocks import (apply_period, apply_periods, decode_periods, init_period,
                     init_period_states, init_periods)
from .config import ModelConfig
from .module import NO_PARALLEL, ParallelCtx, dense_init, embed_init, split_keys, vscan
from .norms import init_rmsnorm, rmsnorm

MTP_WEIGHT = 0.3


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig):
    ks = split_keys(key, 6)
    d, v, dtype = cfg.d_model, cfg.vocab_size, cfg.pdtype
    params = {
        "embed": embed_init(ks[0], (cfg.n_codebooks, v, d) if cfg.n_codebooks > 1 else (v, d), dtype),
        "periods": init_periods(ks[1], cfg),
        "final_norm": init_rmsnorm(ks[2], d, dtype, cfg.zero_centered_norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(
            ks[3], (cfg.n_codebooks, d, v) if cfg.n_codebooks > 1 else (d, v),
            in_dim=d, dtype=dtype)
    if cfg.prefix_len > 0:
        # frontend stub projector (frontend_dim -> d_model); frontend_dim
        # rides in as half of d_model by convention of frontend.py
        from .frontend import frontend_dim
        params["prefix_proj"] = dense_init(ks[4], (frontend_dim(cfg), d),
                                           in_dim=frontend_dim(cfg), dtype=dtype)
    if cfg.mtp_depth > 0:
        params["mtp"] = {
            "combine": dense_init(ks[5], (2 * d, d), in_dim=2 * d, dtype=dtype),
            "norm_h": init_rmsnorm(jax.random.fold_in(key, 11), d, dtype, cfg.zero_centered_norm),
            "norm_e": init_rmsnorm(jax.random.fold_in(key, 12), d, dtype, cfg.zero_centered_norm),
            "block": init_period(jax.random.fold_in(key, 13), cfg),
            "final_norm": init_rmsnorm(jax.random.fold_in(key, 14), d, dtype, cfg.zero_centered_norm),
        }
    return params


# ---------------------------------------------------------------------------
# Embedding / head helpers
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg: ModelConfig):
    """tokens: (B, S) or (B, n_codebooks, S) -> (B, S, D)."""
    if cfg.n_codebooks > 1:
        x = sum(params["embed"][cb][tokens[:, cb]] for cb in range(cfg.n_codebooks))
    else:
        x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x.astype(cfg.cdtype)


def _head_weight(params, cfg: ModelConfig, codebook: int | None = None):
    if cfg.tie_embeddings:
        w = params["embed"]
        w = (w[codebook] if cfg.n_codebooks > 1 else w).T
    else:
        w = params["head"]
        w = w[codebook] if cfg.n_codebooks > 1 else w
    return w


def chunked_ce_loss(h, head_w, targets, mask, softcap=None, chunk: int = 2048):
    """Cross entropy without materializing full logits.

    h: (B, S, D); head_w: (D, V); targets/mask: (B, S).  Returns (sum_loss,
    sum_count, sum_correct).
    """
    B, S, D = h.shape
    chunk = min(chunk, S)
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    hc = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, chunk).transpose(1, 0, 2)
    mc = mask.reshape(B, n, chunk).transpose(1, 0, 2)

    def step(carry, args):
        s_loss, s_cnt, s_acc = carry
        hi, ti, mi = args
        logits = (hi @ head_w).astype(jnp.float32)
        if softcap is not None:
            logits = softcap * jnp.tanh(logits / softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ti[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * mi
        acc = (logits.argmax(-1) == ti) * mi
        return (s_loss + loss.sum(), s_cnt + mi.sum(), s_acc + acc.sum()), None

    zero = (jnp.zeros((), jnp.float32),) * 3
    (s_loss, s_cnt, s_acc), _ = vscan(step, zero, (hc, tc, mc))
    return s_loss, s_cnt, s_acc


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def model_forward(params, tokens, cfg: ModelConfig, ctx: ParallelCtx = NO_PARALLEL,
                  prefix: jnp.ndarray | None = None, remat: bool = True):
    """Backbone forward.  tokens (B,S) or (B,CB,S); prefix (B,P,F) stub embeds.

    Returns (h (B, S_total, D), aux_loss, positions).
    """
    x = embed_tokens(params, tokens, cfg)
    B = x.shape[0]
    if cfg.prefix_len > 0:
        assert prefix is not None, "frontend prefix embeddings required"
        px = (prefix.astype(cfg.cdtype) @ params["prefix_proj"]).astype(cfg.cdtype)
        x = jnp.concatenate([px, x], axis=1)
    S_total = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S_total, dtype=jnp.int32), (B, S_total))
    h, aux = apply_periods(params["periods"], x, positions, cfg, ctx, remat=remat)
    return h, aux, positions


def loss_fn(params, batch, cfg: ModelConfig, ctx: ParallelCtx = NO_PARALLEL,
            remat: bool = True, ce_chunk: int = 2048):
    """Next-token LM loss.  batch: {"tokens", optional "prefix", optional "mask"}.

    Returns (loss, metrics dict).
    """
    tokens = batch["tokens"]
    h, aux, _ = model_forward(params, tokens, cfg, ctx, batch.get("prefix"), remat)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps, cfg.zero_centered_norm)
    if cfg.prefix_len > 0:
        h = h[:, cfg.prefix_len:]         # loss on text positions only

    if cfg.n_codebooks > 1:
        total, count, correct = 0.0, 0.0, 0.0
        for cb in range(cfg.n_codebooks):
            t_in = tokens[:, cb]
            tgt = t_in[:, 1:]
            mask = batch.get("mask", jnp.ones_like(t_in))[:, 1:].astype(jnp.float32)
            l, c, a = chunked_ce_loss(h[:, :-1], _head_weight(params, cfg, cb),
                                      tgt, mask, cfg.logit_softcap, ce_chunk)
            total, count, correct = total + l, count + c, correct + a
    else:
        tgt = tokens[:, 1:]
        mask = batch.get("mask", jnp.ones_like(tokens))[:, 1:].astype(jnp.float32)
        total, count, correct = chunked_ce_loss(
            h[:, :-1], _head_weight(params, cfg), tgt, mask,
            cfg.logit_softcap, ce_chunk)

    loss = total / jnp.maximum(count, 1.0)
    metrics = {"ce": loss, "aux": aux, "acc": correct / jnp.maximum(count, 1.0),
               "tokens": count}
    loss = loss + aux

    if cfg.mtp_depth > 0 and cfg.n_codebooks == 1:
        mtp_loss = _mtp_loss(params, h if cfg.prefix_len == 0 else h, tokens, cfg, ctx,
                             batch.get("mask"), ce_chunk)
        metrics["mtp"] = mtp_loss
        loss = loss + MTP_WEIGHT * mtp_loss
    return loss, metrics


def _mtp_loss(params, h, tokens, cfg: ModelConfig, ctx, mask, ce_chunk):
    """DeepSeek-V3 multi-token prediction (depth 1): predict t+2 from
    [norm(emb(t+1)); norm(h_t)] -> combine -> 1 block -> shared head."""
    m = params["mtp"]
    B, S = tokens.shape
    emb_next = embed_tokens(params, tokens, cfg)       # (B,S,D) — emb(token_t)
    # position t uses h_t and emb(t+1): shift emb left by 1
    e = jnp.concatenate([emb_next[:, 1:], jnp.zeros_like(emb_next[:, :1])], axis=1)
    zc = cfg.zero_centered_norm
    hh = jnp.concatenate([rmsnorm(m["norm_e"], e, cfg.norm_eps, zc),
                          rmsnorm(m["norm_h"], h, cfg.norm_eps, zc)], axis=-1)
    hh = (hh @ m["combine"]).astype(cfg.cdtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    hh, _ = apply_period(m["block"], hh, positions, cfg, ctx)
    hh = rmsnorm(m["final_norm"], hh, cfg.norm_eps, zc)
    # target at position t is token t+2
    tgt = jnp.concatenate([tokens[:, 2:], jnp.zeros_like(tokens[:, :2])], axis=1)
    msk = jnp.ones_like(tokens, jnp.float32) if mask is None else mask.astype(jnp.float32)
    valid = jnp.arange(S) < S - 2
    msk = msk * valid[None, :]
    l, c, _ = chunked_ce_loss(hh, _head_weight(params, cfg), tgt, msk,
                              cfg.logit_softcap, ce_chunk)
    return l / jnp.maximum(c, 1.0)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def init_decode_states(batch: int, max_len: int, cfg: ModelConfig,
                       seq_shards: int = 1):
    return init_period_states(batch, max_len, cfg, cfg.cdtype, seq_shards)


def decode_step(params, token, position, states, cfg: ModelConfig,
                ctx: ParallelCtx = NO_PARALLEL):
    """One decode step.

    token: (B,) int32 (or (B, CB) for multi-codebook); position: () int32,
    or (B,) int32 to decode each row at its own position (continuous
    batching — attention masks per-row; recurrent archs are position-free).
    Returns (logits (B, V) or (B, CB, V), new_states).
    """
    if cfg.n_codebooks > 1:
        x = sum(params["embed"][cb][token[:, cb]] for cb in range(cfg.n_codebooks))
    else:
        x = params["embed"][token]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    x = x.astype(cfg.cdtype)

    h, new_states = decode_periods(params["periods"], x, position, states, cfg, ctx)
    h = rmsnorm(params["final_norm"], h, cfg.norm_eps, cfg.zero_centered_norm)

    if cfg.n_codebooks > 1:
        logits = jnp.stack([
            (h @ _head_weight(params, cfg, cb)).astype(jnp.float32)
            for cb in range(cfg.n_codebooks)], axis=1)
    else:
        logits = (h @ _head_weight(params, cfg)).astype(jnp.float32)
    if cfg.logit_softcap is not None:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, new_states
