"""Attention: GQA/MQA, sliding-window, logit softcap, MLA — TP-aware.

Two execution paths:

* ``blocked_causal_attention`` — training / prefill.  Exact causal (and
  optionally sliding-window) attention computed in (q-chunk × kv-chunk)
  blocks with an online-softmax accumulator, so the full S×S score matrix is
  never materialized.  The q-chunk loop is a *static* Python loop whose
  kv-range is trimmed per chunk — no wasted FLOPs on fully-masked blocks
  (this is the XLA-native analogue of the Pallas flash kernel in
  ``repro.kernels.flash_attention``).

* ``decode_attention`` — serve_step: one query token against a KV cache.
  Supports a sequence-sharded cache (flash-decoding style): each shard
  computes a partial softmax over its KV slice and the results are combined
  with ``pmax``/``psum`` over ``ctx.seq_axis``.

TP layout (Megatron): q/k/v projections column-parallel over heads, output
projection row-parallel (+psum).  Layer code sees local head counts.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from .module import ParallelCtx, NO_PARALLEL, dense_init, split_keys, vscan
from .norms import init_rmsnorm, rmsnorm
from .rotary import rope_cos_sin, apply_rope, apply_rope_partial

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 Multi-head Latent Attention dims (per head unless noted)."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rope_theta: float = 10000.0
    window: int | None = None          # sliding-window size (None = full)
    softcap: float | None = None       # attn logit softcapping (Gemma2)
    mla: MLAConfig | None = None
    q_chunk: int = 1024
    kv_chunk: int = 1024
    # When tp > n_kv_heads the KV projections are *replicated* across tp
    # shards and each shard dynamically slices its single KV head:
    #   kv_head = tp_index // kv_slice_div .
    kv_slice_div: int | None = None

    def local(self, tp: int) -> "AttentionConfig":
        """Per-shard head counts under tp-way tensor parallelism.

        Query heads are always sharded; KV heads are sharded when divisible
        by ``tp``, otherwise the KV projection weights stay replicated and
        each shard slices out the one KV head its query heads attend to.
        """
        if tp == 1:
            return self
        assert self.n_heads % tp == 0, (self.n_heads, tp)
        if self.n_kv_heads % tp == 0:
            return dataclasses.replace(
                self, n_heads=self.n_heads // tp, n_kv_heads=self.n_kv_heads // tp)
        assert tp % self.n_kv_heads == 0, (self.n_kv_heads, tp)
        return dataclasses.replace(
            self, n_heads=self.n_heads // tp, kv_slice_div=tp // self.n_kv_heads)

    @property
    def cache_kv_heads(self) -> int:
        """KV heads held in the decode cache.

        When KV is replicated across tp (kv_slice_div set) the cache keeps
        *all* KV heads — identical on every tp shard, so the global cache
        array is expressible with a replicated head dim — and the shard's
        head is sliced at attention-compute time."""
        return self.n_kv_heads


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, cfg: AttentionConfig, dtype=jnp.float32):
    """Standard GQA attention params with *local* (per-tp-shard) head counts."""
    if cfg.mla is not None:
        return init_mla_attention(key, d_model, cfg, dtype)
    ks = split_keys(key, 4)
    h, kvh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "wq": dense_init(ks[0], (d_model, h * d), in_dim=d_model, dtype=dtype),
        "wk": dense_init(ks[1], (d_model, kvh * d), in_dim=d_model, dtype=dtype),
        "wv": dense_init(ks[2], (d_model, kvh * d), in_dim=d_model, dtype=dtype),
        "wo": dense_init(ks[3], (h * d, d_model), in_dim=h * d, dtype=dtype),
    }


def init_mla_attention(key, d_model: int, cfg: AttentionConfig, dtype=jnp.float32):
    m = cfg.mla
    ks = split_keys(key, 8)
    h = cfg.n_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        # query path: down-proj -> norm -> up-proj to per-head (nope+rope)
        "wq_a": dense_init(ks[0], (d_model, m.q_lora_rank), in_dim=d_model, dtype=dtype),
        "q_norm": init_rmsnorm(ks[1], m.q_lora_rank, dtype),
        "wq_b": dense_init(ks[2], (m.q_lora_rank, h * qk_dim), in_dim=m.q_lora_rank, dtype=dtype),
        # kv path: joint down-proj to latent (+ shared rope key)
        "wkv_a": dense_init(ks[3], (d_model, m.kv_lora_rank + m.qk_rope_dim), in_dim=d_model, dtype=dtype),
        "kv_norm": init_rmsnorm(ks[4], m.kv_lora_rank, dtype),
        "wk_b": dense_init(ks[5], (m.kv_lora_rank, h * m.qk_nope_dim), in_dim=m.kv_lora_rank, dtype=dtype),
        "wv_b": dense_init(ks[6], (m.kv_lora_rank, h * m.v_head_dim), in_dim=m.kv_lora_rank, dtype=dtype),
        "wo": dense_init(ks[7], (h * m.v_head_dim, d_model), in_dim=h * m.v_head_dim, dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Blocked exact attention (training / prefill)
# ---------------------------------------------------------------------------


def _chunk_scores(q, k, scale, softcap):
    # q: (B, Cq, Hkv, G, D)  k: (B, Ck, Hkv, D) -> (B, Hkv, G, Cq, Ck)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


def blocked_causal_attention(
    q: jnp.ndarray,           # (B, S, Hq, D)
    k: jnp.ndarray,           # (B, S, Hkv, D)
    v: jnp.ndarray,           # (B, S, Hkv, Dv)
    *,
    scale: float,
    window: int | None = None,
    softcap: float | None = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Exact causal attention, O(q_chunk × kv_chunk) live score memory."""
    B, S, Hq, D = q.shape
    Hkv = k.shape[2]
    Dv = v.shape[-1]
    G = Hq // Hkv
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, S)
    n_q = -(-S // q_chunk)

    # Pad K/V so every dynamic_slice is in bounds (padded tail positions have
    # kv_pos >= S and are always causally masked).
    S_pad = -(-S // kv_chunk) * kv_chunk
    if S_pad != S:
        k = jnp.pad(k, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))

    qg = q.reshape(B, S, Hkv, G, D)
    out = jnp.zeros((B, S, Hkv, G, Dv), dtype=q.dtype)

    for i in range(n_q):
        q_lo = i * q_chunk
        q_hi = min(S, q_lo + q_chunk)
        Cq = q_hi - q_lo
        qi = qg[:, q_lo:q_hi]
        # kv range needed by this q chunk (static bounds)
        kv_hi = q_hi
        kv_lo = 0 if window is None else max(0, q_lo - window)
        kv_lo = (kv_lo // kv_chunk) * kv_chunk
        n_kv = -(-(kv_hi - kv_lo) // kv_chunk)

        q_pos = (q_lo + jnp.arange(Cq))[:, None]  # (Cq, 1)

        def kv_step(carry, j):
            m, l, acc = carry
            start = kv_lo + j * kv_chunk
            kj = lax.dynamic_slice_in_dim(k, start, kv_chunk, axis=1)
            vj = lax.dynamic_slice_in_dim(v, start, kv_chunk, axis=1)
            s = _chunk_scores(qi, kj, scale, softcap)  # (B,Hkv,G,Cq,Ck)
            kv_pos = start + jnp.arange(kv_chunk)[None, :]
            mask = kv_pos <= q_pos
            if window is not None:
                mask &= kv_pos > q_pos - window
            # positions beyond S (when kv_chunk doesn't divide) are masked by causality
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p, vj.astype(jnp.float32))
            acc_new = acc * corr[..., None] + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, Hkv, G, Cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, Cq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, Cq, Dv), jnp.float32)
        (m, l, acc), _ = vscan(kv_step, (m0, l0, a0), jnp.arange(n_kv))
        oi = (acc / jnp.maximum(l, 1e-37)[..., None]).transpose(0, 3, 1, 2, 4)
        out = lax.dynamic_update_slice_in_dim(out, oi.astype(q.dtype), q_lo, axis=1)

    return out.reshape(B, S, Hq, Dv)


# ---------------------------------------------------------------------------
# Decode attention (single new token vs KV cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jnp.ndarray,            # (B, Hq, D) single position
    k_cache: jnp.ndarray,      # (B, S_local, Hkv, D)   (seq-sharded if ctx.seq_axis)
    v_cache: jnp.ndarray,      # (B, S_local, Hkv, Dv)
    cache_len: jnp.ndarray,    # () or (B,) int32 — valid *global* positions
    *,
    scale: float,
    window: int | None = None,
    softcap: float | None = None,
    ctx: ParallelCtx = NO_PARALLEL,
) -> jnp.ndarray:
    """One-token attention with partial-softmax combine over a sharded cache.

    ``cache_len`` may be per-row ``(B,)`` — continuous batching decodes each
    slot at its own sequence position — or a scalar shared by the batch.
    """
    B, S_local, Hkv, D = k_cache.shape
    Hq = q.shape[1]
    G = Hq // Hkv
    Dv = v_cache.shape[-1]
    qg = q.reshape(B, Hkv, G, D)

    # Global positions owned by this shard.
    shard = ctx.seq_index()
    pos = shard * S_local + jnp.arange(S_local)  # (S_local,)
    if jnp.ndim(cache_len) == 1:
        valid = pos[None, :] < cache_len[:, None]          # (B, S_local)
        if window is not None:
            valid &= pos[None, :] >= cache_len[:, None] - window
        mask = valid[:, None, None, :]
    else:
        valid = pos < cache_len
        if window is not None:
            valid &= pos >= cache_len - window
        mask = valid[None, None, None]

    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32), k_cache.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    s = jnp.where(mask, s, NEG_INF)

    m_local = s.max(axis=-1)                      # (B,Hkv,G)
    m = ctx.pmax_seq(m_local)
    p = jnp.exp(s - m[..., None])
    l = ctx.psum_seq(p.sum(axis=-1))
    pv = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    pv = ctx.psum_seq(pv)
    out = pv / jnp.maximum(l, 1e-37)[..., None]
    return out.reshape(B, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention layers (projections + rope + attention + out-proj)
# ---------------------------------------------------------------------------


def _slice_kv(t: jnp.ndarray, cfg: AttentionConfig, ctx: ParallelCtx) -> jnp.ndarray:
    """Select this shard's KV head when KV projections are replicated."""
    if cfg.kv_slice_div is None:
        return t
    head = ctx.tp_index() // cfg.kv_slice_div
    return lax.dynamic_slice_in_dim(t, head, 1, axis=-2)


def attention_forward(
    params,
    x: jnp.ndarray,            # (B, S, d_model)
    positions: jnp.ndarray,    # (B, S) int32
    cfg: AttentionConfig,
    ctx: ParallelCtx = NO_PARALLEL,
) -> jnp.ndarray:
    """Training / prefill attention over a full sequence (causal)."""
    if cfg.mla is not None:
        return mla_forward(params, x, positions, cfg, ctx)
    B, S, _ = x.shape
    h, kvh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, h, d)
    k = _slice_kv((x @ params["wk"]).reshape(B, S, kvh, d), cfg, ctx)
    v = _slice_kv((x @ params["wv"]).reshape(B, S, kvh, d), cfg, ctx)
    cos, sin = rope_cos_sin(positions, d, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = blocked_causal_attention(
        q, k, v, scale=d ** -0.5, window=cfg.window, softcap=cfg.softcap,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
    )
    out = o.reshape(B, S, h * d) @ params["wo"]
    return ctx.psum_tp(out)


def attention_decode(
    params,
    x: jnp.ndarray,            # (B, d_model) — single position
    position: jnp.ndarray,     # () or (B,) int32 — current position (== cache_len)
    cache: dict,               # {"k": (B,S_loc,Hkv,D), "v": ...}
    cfg: AttentionConfig,
    ctx: ParallelCtx = NO_PARALLEL,
):
    """One decode step.  Returns (out (B,d_model), updated cache).

    A ``(B,)`` position decodes each batch row at its own sequence position
    (continuous-batching slots); a scalar decodes the whole batch in lockstep.
    """
    if cfg.mla is not None:
        return mla_decode(params, x, position, cache, cfg, ctx)
    B, _ = x.shape
    h, kvh, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, h, d)
    k = (x @ params["wk"]).reshape(B, kvh, d)
    v = (x @ params["wv"]).reshape(B, kvh, d)
    if jnp.ndim(position) == 1:
        cos, sin = rope_cos_sin(position, d, cfg.rope_theta)    # (B, d/2)
        q = apply_rope(q[:, None], cos[:, None], sin[:, None])[:, 0]
        k = apply_rope(k[:, None], cos[:, None], sin[:, None])[:, 0]
    else:
        cos, sin = rope_cos_sin(position[None], d, cfg.rope_theta)  # (1, d/2)
        q = apply_rope(q[:, None], cos[None], sin[None])[:, 0]
        k = apply_rope(k[:, None], cos[None], sin[None])[:, 0]

    # cache keeps all local KV heads; when KV is replicated across tp the
    # shard's head is sliced at attention time (cache stays tp-identical)
    eff_len = cache["k"].shape[1] * max(ctx.seq_size, 1)
    if cfg.window is not None and eff_len <= cfg.window:
        # Ring-buffer cache holding exactly the window: eviction enforces the
        # window, so no position mask beyond "slot already written" is needed.
        slot = position % eff_len
        cache = _cache_insert(cache, {"k": k, "v": v}, slot, ctx)
        cache_len = jnp.minimum(position + 1, eff_len)
        win = None
    else:
        cache = _cache_insert(cache, {"k": k, "v": v}, position, ctx)
        cache_len = position + 1
        win = cfg.window
    k_att = _slice_kv(cache["k"], cfg, ctx)
    v_att = _slice_kv(cache["v"], cfg, ctx)
    o = decode_attention(q, k_att, v_att, cache_len, scale=d ** -0.5,
                         window=win, softcap=cfg.softcap, ctx=ctx)
    out = o.reshape(B, h * d) @ params["wo"]
    return ctx.psum_tp(out), cache


def _cache_insert(cache: dict, new: dict, position, ctx: ParallelCtx):
    """Insert this step's K/V (or latent) into a (possibly seq-sharded) cache.

    Scalar ``position`` uses a single dynamic_update_slice (whole batch writes
    one seq slot); per-row ``(B,)`` positions scatter each row into its own
    slot via a one-hot select.  Rows whose position falls outside this shard's
    seq range (seq-sharded cache) leave the buffer untouched.
    """
    out = dict(cache)
    per_row = jnp.ndim(position) == 1
    for name, val in new.items():
        buf = cache[name]                      # (B, S_local, ...)
        S_local = buf.shape[1]
        local_pos = position - ctx.seq_index() * S_local
        if per_row:
            hit = jnp.arange(S_local)[None, :] == local_pos[:, None]  # (B, S_local)
            hit = hit.reshape(hit.shape + (1,) * (buf.ndim - 2))
            out[name] = jnp.where(hit, val[:, None].astype(buf.dtype), buf)
        else:
            owner = (local_pos >= 0) & (local_pos < S_local)
            idx = jnp.clip(local_pos, 0, S_local - 1)
            updated = lax.dynamic_update_slice_in_dim(buf, val[:, None].astype(buf.dtype), idx, axis=1)
            out[name] = jnp.where(owner, updated, buf) if ctx.seq_axis is not None else updated
    return out


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# ---------------------------------------------------------------------------


def mla_forward(params, x, positions, cfg: AttentionConfig, ctx: ParallelCtx):
    """MLA training/prefill: expand latent to per-head K/V (naive path)."""
    m = cfg.mla
    B, S, _ = x.shape
    h = cfg.n_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim

    cq = rmsnorm(params["q_norm"], x @ params["wq_a"])
    q = (cq @ params["wq_b"]).reshape(B, S, h, qk_dim)

    kv_a = x @ params["wkv_a"]                       # (B,S,rank+rope)
    c_kv = rmsnorm(params["kv_norm"], kv_a[..., : m.kv_lora_rank])
    k_rope = kv_a[..., m.kv_lora_rank:]              # (B,S,rope) shared across heads

    cos, sin = rope_cos_sin(positions, m.qk_rope_dim, cfg.rope_theta)
    q = apply_rope_partial(q, cos, sin, m.qk_rope_dim)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (B,S,1,rope)

    k_nope = (c_kv @ params["wk_b"]).reshape(B, S, h, m.qk_nope_dim)
    v = (c_kv @ params["wv_b"]).reshape(B, S, h, m.v_head_dim)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (B, S, h, m.qk_rope_dim))], axis=-1)

    o = blocked_causal_attention(
        q, k, v, scale=qk_dim ** -0.5, softcap=cfg.softcap,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
    )
    out = o.reshape(B, S, h * m.v_head_dim) @ params["wo"]
    return ctx.psum_tp(out)


def mla_decode(params, x, position, cache, cfg: AttentionConfig, ctx: ParallelCtx):
    """MLA decode with *latent* cache and absorbed projections.

    Cache stores (c_kv, k_rope) only — the paper's memory saving.  Score and
    value computation are done in latent space by absorbing wk_b into the
    query and wv_b into the output (the production DeepSeek decode path).
    """
    m = cfg.mla
    B, _ = x.shape
    h = cfg.n_heads
    rank = m.kv_lora_rank

    cq = rmsnorm(params["q_norm"], x @ params["wq_a"])
    q = (cq @ params["wq_b"]).reshape(B, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]

    kv_a = x @ params["wkv_a"]
    c_kv = rmsnorm(params["kv_norm"], kv_a[..., :rank])          # (B, rank)
    if jnp.ndim(position) == 1:
        cos, sin = rope_cos_sin(position, m.qk_rope_dim, cfg.rope_theta)  # (B, rope/2)
        q_rope = apply_rope(q_rope[:, None], cos[:, None], sin[:, None])[:, 0]
        k_rope = apply_rope(kv_a[..., rank:][:, None, None, :], cos[:, None], sin[:, None])[:, 0, 0]
    else:
        cos, sin = rope_cos_sin(position[None], m.qk_rope_dim, cfg.rope_theta)
        q_rope = apply_rope(q_rope[:, None], cos[None], sin[None])[:, 0]
        k_rope = apply_rope(kv_a[..., rank:][:, None, None, :], cos[None], sin[None])[:, 0, 0]

    cache = _cache_insert(cache, {"c_kv": c_kv, "k_rope": k_rope}, position, ctx)

    # Absorb wk_b into q:  q_lat[b,h,r] = sum_d q_nope[b,h,d] * wk_b[r, h*d]
    wk_b = params["wk_b"].reshape(rank, h, m.qk_nope_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32), wk_b.astype(jnp.float32))

    ckv_buf = cache["c_kv"]                                     # (B, S_loc, rank)
    krope_buf = cache["k_rope"]                                 # (B, S_loc, rope)
    S_local = ckv_buf.shape[1]
    shard = ctx.seq_index()
    pos = shard * S_local + jnp.arange(S_local)
    if jnp.ndim(position) == 1:
        valid = pos[None, :] < (position[:, None] + 1)           # (B, S_local)
        vmask = valid[:, None]
    else:
        valid = pos < (position + 1)
        vmask = valid[None, None]

    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    s = jnp.einsum("bhr,bkr->bhk", q_lat, ckv_buf.astype(jnp.float32))
    s += jnp.einsum("bhd,bkd->bhk", q_rope.astype(jnp.float32), krope_buf.astype(jnp.float32))
    s = s * scale
    s = jnp.where(vmask, s, NEG_INF)

    m_local = s.max(axis=-1)
    mx = ctx.pmax_seq(m_local)
    p = jnp.exp(s - mx[..., None])
    l = ctx.psum_seq(p.sum(axis=-1))
    o_lat = ctx.psum_seq(jnp.einsum("bhk,bkr->bhr", p, ckv_buf.astype(jnp.float32)))
    o_lat = o_lat / jnp.maximum(l, 1e-37)[..., None]            # (B,h,rank)

    # Absorb wv_b:  o[b,h,dv] = sum_r o_lat[b,h,r] * wv_b[r, h*dv]
    wv_b = params["wv_b"].reshape(rank, h, m.v_head_dim)
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wv_b.astype(jnp.float32))
    out = o.reshape(B, h * m.v_head_dim).astype(x.dtype) @ params["wo"]
    return ctx.psum_tp(out), cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def init_attention_cache(batch: int, max_len: int, cfg: AttentionConfig, dtype,
                         seq_shards: int = 1) -> dict:
    """Empty decode cache.  ``max_len`` is the *global* cache length."""
    S_local = max_len // seq_shards
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, S_local, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, S_local, m.qk_rope_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, S_local, cfg.cache_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, S_local, cfg.cache_kv_heads, cfg.head_dim), dtype),
    }
