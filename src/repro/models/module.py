"""Minimal pure-JAX module utilities (no flax).

Parameters are nested dicts of jnp arrays ("param trees").  Every layer is a
pair of pure functions::

    init_<layer>(key, cfg, ...) -> params
    <layer>(params, x, *, ctx, ...) -> y

``ParallelCtx`` carries the SPMD context (mesh axis names) so the same layer
code runs single-device (all axes ``None``) and inside ``shard_map`` with
Megatron-style tensor parallelism / expert parallelism.  All collectives are
routed through the ctx so they are no-ops outside shard_map.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax import lax

PyTree = Any


# ---------------------------------------------------------------------------
# Parallel context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelCtx:
    """SPMD context threaded through every layer.

    tp_axis:  tensor-parallel mesh axis (Megatron-style).  Weight matrices are
              sharded on heads / ffn / vocab dims; each device sees *local*
              shapes.  ``psum_tp`` reduces row-parallel matmul partials.
    ep_axis:  expert-parallel axis for MoE all_to_all dispatch.
    dp_axes:  data-parallel axes (gradient reduction happens outside layers).
    seq_axis: axis over which a decode KV cache is sequence-sharded
              (flash-decoding style partial-softmax combine).
    """

    tp_axis: str | None = None
    tp_size: int = 1
    ep_axis: str | None = None
    ep_size: int = 1
    dp_axes: tuple[str, ...] = ()
    seq_axis: str | None = None
    seq_size: int = 1

    # -- collective helpers -------------------------------------------------
    def psum_tp(self, x):
        return lax.psum(x, self.tp_axis) if self.tp_axis is not None else x

    def pmax_tp(self, x):
        return lax.pmax(x, self.tp_axis) if self.tp_axis is not None else x

    def psum_seq(self, x):
        return lax.psum(x, self.seq_axis) if self.seq_axis is not None else x

    def pmax_seq(self, x):
        return lax.pmax(x, self.seq_axis) if self.seq_axis is not None else x

    def tp_index(self):
        if self.tp_axis is None:
            return jnp.int32(0)
        return lax.axis_index(self.tp_axis)

    def ep_index(self):
        if self.ep_axis is None:
            return jnp.int32(0)
        return lax.axis_index(self.ep_axis)

    def seq_index(self):
        if self.seq_axis is None:
            return jnp.int32(0)
        return lax.axis_index(self.seq_axis)

    def all_to_all_ep(self, x, split_axis: int, concat_axis: int):
        if self.ep_axis is None:
            return x
        return lax.all_to_all(
            x, self.ep_axis, split_axis=split_axis, concat_axis=concat_axis, tiled=True
        )


NO_PARALLEL = ParallelCtx()


# ---------------------------------------------------------------------------
# vma-robust scan (works the same inside and outside shard_map)
# ---------------------------------------------------------------------------


def _manual_axes() -> tuple:
    from repro.distributed.compat import manual_axes
    return manual_axes()


def vary_all(tree: PyTree) -> PyTree:
    """Mark every leaf varying over all manual mesh axes (no-op outside
    shard_map and on jax without vma typing).  pcast is a pure type
    operation — no communication."""
    from repro.distributed.compat import pcast_varying
    axes = _manual_axes()
    if not axes:
        return tree
    return jax.tree.map(lambda x: pcast_varying(x, axes), tree)


def vscan(body: Callable, init, xs, **kw):
    """``lax.scan`` whose carry typing is robust under shard_map: the initial
    carry and each step's output carry are cast varying over all manual axes,
    so layer code does not need to reason about vma propagation."""
    axes = _manual_axes()
    if not axes:
        return lax.scan(body, init, xs, **kw)

    def wrapped(carry, x):
        carry, y = body(carry, x)
        return vary_all(carry), y

    return lax.scan(wrapped, vary_all(init), xs, **kw)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_dim: int | None = None, dtype=jnp.float32, scale: float = 1.0):
    """Truncated-normal fan-in init (matches common LM init)."""
    if in_dim is None:
        in_dim = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / math.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Param tree utilities
# ---------------------------------------------------------------------------


def tree_size(tree: PyTree) -> int:
    """Total number of parameters."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree.map(jnp.zeros_like, tree)


def assert_finite(tree: PyTree, name: str = "tree") -> None:
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            if not bool(jnp.isfinite(leaf).all()):
                raise AssertionError(f"non-finite values in {name}{jax.tree_util.keystr(path)}")


def stack_trees(trees: list[PyTree]) -> PyTree:
    """Stack a list of identically-structured param trees along a new axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *trees)


def unstack_tree(tree: PyTree, n: int) -> list[PyTree]:
    return [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(n)]


def vmap_init(init_fn: Callable, key, n: int, *args, **kwargs) -> PyTree:
    """Initialize ``n`` stacked copies of a layer (for scan-over-layers)."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: init_fn(k, *args, **kwargs))(keys)
